"""Geo-distributed training, end to end:

1. Algorithm 1 picks the DC split for a 2-DC fleet (what-if, no hardware).
2. The discrete-event simulator compares Atlas vs Varuna/GPipe on it.
3. The REAL cross-pod pipeline (shard_map + ppermute over the `pod` axis,
   striped Atlas boundary) trains a reduced model on 8 emulated devices.

  PYTHONPATH=src python examples/geo_train.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_smoke_config
from repro.core import topology, wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.simulator import GeoTopology, simulate, testbed_spec
from repro.data.pipeline import DataConfig, make_batches
from repro.models.transformer import build_model
from repro.optim.optimizer import OptimizerConfig, init_opt_state, make_train_step
from repro.parallel.pipeline import make_pipeline_loss


def main(steps: int = 30):
    # ---- 1) plan ----
    job = JobModel(
        t_fwd_ms=10.0,
        act_bytes=wan.activation_bytes(1, 4096, 4096),
        partition_param_bytes=412e6 * 2,
        microbatches=16,
    )
    plans = algorithm1(job, {"us-east": 240, "us-west": 240}, P=8)
    plan = best_plan(plans)
    print(f"[plan] best D={plan.D} partitions={plan.partitions} "
          f"throughput={plan.throughput:.4f} gpus={plan.gpus_used}")

    # ---- 2) simulate ----
    stage_dc = []
    for i, dc in enumerate(sorted(plan.partitions)):
        stage_dc += [i] * plan.partitions[dc]
    spec = testbed_spec(
        hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
        layer_params=412e6, num_stages=len(stage_dc), microbatches=16,
        stage_dc=stage_dc,
    )
    for policy, mt, D in (("gpipe", False, 1), ("varuna", False, 1), ("atlas", True, 2)):
        r = simulate(spec, GeoTopology(wan_latency_ms=40, multi_tcp=mt),
                     policy=policy, n_pipelines=D, validate=True)
        print(f"[sim] {policy:7s} multi_tcp={mt}  iter={r.iteration_ms:8.0f}ms "
              f"util={r.utilization:.0%}")

    # ---- 2b) same job on a heterogeneous (skewed) WAN ----
    for name, topo in (("uniform", GeoTopology(wan_latency_ms=40)),
                       ("skewed", topology.skewed_3dc()),
                       ("azure", topology.azure_testbed())):
        r = simulate(spec, topo, policy="atlas", n_pipelines=2, validate=True)
        print(f"[sim] atlas on {name:8s} iter={r.iteration_ms:8.0f}ms "
              f"util={r.utilization:.0%}")

    # ---- 3) real cross-pod pipeline on emulated devices ----
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("gpt_a")
    model = build_model(cfg)
    print(f"[pipeline] mesh={dict(mesh.shape)} arch={cfg.name} boundary=striped")
    with compat.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        loss_fn = make_pipeline_loss(cfg, mesh, n_micro=4, boundary="striped")
        step_fn = jax.jit(
            make_train_step(loss_fn, OptimizerConfig(peak_lr=3e-3, warmup_steps=5,
                                                     total_steps=steps),
                            loss_has_metrics=False),
            donate_argnums=(0, 1),
        )
        opt_state = init_opt_state(params)
        for i, b in enumerate(
            make_batches(cfg, DataConfig(batch_size=8, seq_len=64), num_steps=steps)
        ):
            params, opt_state, m = step_fn(
                params, opt_state, {k: jnp.asarray(v) for k, v in b.items()}
            )
            if i % 10 == 0 or i == steps - 1:
                print(f"[pipeline] step {i:3d} loss {float(m['loss']):.4f}")
    print("[pipeline] done — PP across pods, DP+TP inside (paper §4.2 layout)")


if __name__ == "__main__":
    main()
