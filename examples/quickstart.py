"""Quickstart: train a small GPT on synthetic data, checkpoint it, and
serve it with the batched engine — the whole substrate in one file.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import AsyncCheckpointer, load_pytree
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, make_batches
from repro.models.transformer import build_model
from repro.optim.optimizer import OptimizerConfig, init_opt_state, make_train_step
from repro.serving.engine import Request, ServingEngine


def main(steps: int = 150):
    cfg = get_smoke_config("gpt_a")
    model = build_model(cfg)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(peak_lr=3e-3, warmup_steps=10, total_steps=steps)
    step_fn = jax.jit(make_train_step(model.loss, opt_cfg), donate_argnums=(0, 1))
    opt_state = init_opt_state(params)

    for i, batch in enumerate(
        make_batches(cfg, DataConfig(batch_size=8, seq_len=128), num_steps=steps)
    ):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % 25 == 0 or i == steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  lr {float(m['lr']):.2e}")

    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(steps, {"params": params})
        ck.close()
        restored = load_pytree(ck.latest_path(), {"params": params})["params"]
        print("checkpoint round-trip: ok")

    engine = ServingEngine(cfg, restored, max_batch=4, max_len=256)
    reqs = [
        Request(i, np.arange(5 + 3 * i, dtype=np.int32) % cfg.vocab_size, max_new_tokens=8)
        for i in range(4)
    ]
    done = engine.generate(reqs)
    for r in done:
        print(f"req {r.req_id}: ttft={r.ttft_ms:.0f}ms  tokens={r.generated}")


if __name__ == "__main__":
    main()
