"""End-to-end driver: train a ~100M-parameter GPT for a few hundred steps
on synthetic data with checkpointing — the training-kind deliverable (b).

On the CPU container this takes tens of minutes; pass --steps to shorten.

  PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import AsyncCheckpointer
from repro.data.pipeline import DataConfig, make_batches
from repro.models.modules import ModelConfig
from repro.models.transformer import build_model
from repro.optim.optimizer import OptimizerConfig, init_opt_state, make_train_step

# ~100M params: 12 x (4*512^2 attn + 3*512*2048 GLU) + 2 * 32768*512 emb/head
CFG_100M = ModelConfig(
    name="gpt-100m",
    family="dense",
    num_layers=12,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    ffn_activation="swiglu",
    remat="none",
    source="quickstart-scale GPT (deliverable b)",
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args(argv)

    model = build_model(CFG_100M)
    print(f"params: {CFG_100M.param_count()/1e6:.1f}M  steps: {args.steps}")
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(peak_lr=6e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model.loss, opt_cfg), donate_argnums=(0, 1))
    opt_state = init_opt_state(params)
    ck = AsyncCheckpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    first = last = None
    for i, b in enumerate(
        make_batches(CFG_100M, DataConfig(batch_size=args.batch, seq_len=args.seq),
                     num_steps=args.steps)
    ):
        params, opt_state, m = step_fn(
            params, opt_state, {k: jnp.asarray(v) for k, v in b.items()}
        )
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = (i + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i:4d}  loss {loss:.4f}  tok/s {tok_s:,.0f}", flush=True)
        if i and i % 100 == 0:
            ck.save(i, {"params": params}, {"loss": loss})
    ck.save(args.steps, {"params": params}, {"loss": last})
    ck.close()
    print(f"done: loss {first:.3f} -> {last:.3f}; checkpoint at {ck.latest_path()}")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
