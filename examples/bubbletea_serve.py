"""BubbleTea prefill-as-a-service demo:

1. Simulate an Atlas training iteration (12 GPUs / 3 DCs) and collect its
   consolidated bubbles.  ``res.bubbles`` stops at the pipeline end: the
   trailing DP all-reduce span is busy communication, so no prefill can
   be placed there (it used to be mis-recorded as one giant bubble per
   GPU) — the utilization figures below are computed from the corrected
   bubbles.
2. Replay a seeded production trace (``ArrivalProcess``: diurnal +
   bursty Poisson, prompt-length mixture, SLO tiers) through the
   BubbleTea controller: per-tier admission (§5 TTFT-SLO check — late
   placements are rejected back to the dedicated fleet), placement,
   TTFT percentiles per tier, utilization 45% -> ~94% (paper Fig 13).
3. Run a REAL Splitwise-style prefill/decode split on a reduced model to
   show the KV-cache handoff.

  PYTHONPATH=src python examples/bubbletea_serve.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.bubbletea import (
    ArrivalProcess,
    BubbleTeaController,
    InferenceModelSpec,
    PrefillLatencyModel,
    PromptMix,
    utilization_with_prefills,
)
from repro.core.simulator import GeoTopology, simulate, testbed_spec
from repro.models.transformer import build_model
from repro.serving.engine import Request, SplitwiseCluster


def main():
    # ---- 1) training bubbles ----
    spec = testbed_spec(
        hidden=8192, seq_len=6144, micro_batch=1, layers_per_stage=1,
        layer_params=1.2e9, num_stages=4, microbatches=16, stage_dc=[0, 0, 1, 2],
    )
    res = simulate(spec, GeoTopology(wan_latency_ms=40, multi_tcp=True),
                   policy="atlas", n_pipelines=3, dp_replicas_for_allreduce=3)
    pp_end = res.iteration_ms - res.allreduce_ms
    print(f"[atlas] iter={res.iteration_ms:.0f}ms util={res.utilization:.0%} "
          f"(bubbles to fill; all-reduce span "
          f"[{pp_end:.0f}, {res.iteration_ms:.0f}]ms stays busy)")

    # ---- 2) prefill-as-a-service ----
    lm = PrefillLatencyModel(InferenceModelSpec("llama3-8b", 8e9))
    ctrl = BubbleTeaController(
        [list(res.bubbles[g]) for g in sorted(res.bubbles)], lm, pp_degree=1,
        tiers={"gold": 1_500.0, "best_effort": 5_000.0},
        clock=time.perf_counter,
    )
    reqs = ArrivalProcess(
        rate_per_s=1_000.0 / 1.2, horizon_ms=res.iteration_ms, seed=0,
    ).generate(
        PromptMix(lengths=(128, 256, 512, 1024, 2048),
                  weights=(0.3, 0.25, 0.2, 0.15, 0.1)),
        tiers={"gold": 0.3, "best_effort": 0.7},
    )
    for r in reqs:
        ctrl.submit(r)
    busy = sum(iv.end - iv.start for ivs in res.busy.values() for iv in ivs)
    total = res.iteration_ms * len(res.busy)
    after = utilization_with_prefills(busy, total, ctrl)
    print(f"[bubbletea] requests={len(reqs)} placed={len(ctrl.placements)} "
          f"accept={ctrl.acceptance_rate():.0%} "
          f"slo-rejects={len(ctrl.rejected_slo)}")
    print(f"[bubbletea] utilization {res.utilization:.0%} -> {after:.0%} "
          f"(paper: 45% -> 94%)")
    for tier, rep in ctrl.tier_report().items():
        print(f"[bubbletea]   {tier}: accept={rep['acceptance']:.0%} "
          f"TTFT ms p50={rep['ttft_p50_ms']:.0f} p99={rep['ttft_p99_ms']:.0f}")
    print(f"[bubbletea] placement search "
          f"p50={np.percentile(ctrl.search_time_us, 50):.0f}us")

    # ---- 3) real Splitwise handoff on a reduced model ----
    cfg = get_smoke_config("gpt_a")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cluster = SplitwiseCluster(cfg, params, max_batch=4, max_len=128)
    reqs = [
        Request(i, (np.arange(6 + i) * 5 % cfg.vocab_size).astype(np.int32),
                max_new_tokens=6)
        for i in range(4)
    ]
    done = cluster.serve(reqs)
    print(f"[splitwise] served {len(done)} requests; "
          f"KV moved {cluster.kv_bytes_moved/1e6:.1f} MB; "
          f"sample tokens {done[0].generated}")


if __name__ == "__main__":
    main()
