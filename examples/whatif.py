"""What-if DC planning (paper §4.5): sweep candidate DC sets and GPU
counts through Algorithm 1 and print the cost/performance frontier — no
deployment required.

  PYTHONPATH=src python examples/whatif.py
"""
import dataclasses

from repro.core import topology, wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan, what_if


def main():
    # a Llama-70B-ish pretraining job: 80 layers, 875M params/layer
    job = JobModel(
        t_fwd_ms=2 * 875e6 * 4096 / 312e12 * 1e3,  # one microbatch, one layer-partition
        act_bytes=wan.activation_bytes(1, 4096, 8192),
        partition_param_bytes=875e6 * 2,
        microbatches=64,
    )
    print(f"comm/compute ratio C = {job.comm_compute_ratio:.1f}")

    scenarios = {
        "single-dc-1200": {"virginia": 1200},
        "two-equal-600": {"virginia": 600, "oregon": 600},
        "paper-dc-set-2": {"a": 600, "b": 500, "c": 400, "d": 300, "e": 200},
        "lopsided-1000+10": {"virginia": 1000, "saopaulo": 10},
    }
    out = what_if(job, scenarios, P=80, gpu_cost_per_hour=2.0)
    print(f"{'scenario':18s} {'D':>3s} {'gpus':>5s} {'iter_ms':>9s} "
          f"{'thr':>8s} {'$ /iter':>8s}  partitions")
    for name, v in out.items():
        print(f"{name:18s} {v['best_D']:3d} {v['gpus_used']:5d} "
              f"{v['total_ms']:9.0f} {v['throughput']:8.4f} "
              f"{v['cost_per_iteration']:8.4f}  {v['partitions']}")

    # heterogeneous WAN: the same fleet on a skewed topology — the
    # topology-aware placement search keeps the slow pair off the cut
    print("\nSkewed-WAN placement (dc0<->dc2 is 150 ms single-TCP):")
    fleet = {"dc0": 16, "dc1": 16, "dc2": 20}  # must span all three DCs
    job_skew = dataclasses.replace(job, topology=topology.skewed_3dc())
    for tag, search in (("topology-aware", None), ("availability-order", False)):
        b = best_plan(algorithm1(job_skew, fleet, P=40, C=1, search_orders=search))
        order = ">".join(d for d in b.dc_order if b.partitions.get(d, 0))
        print(f"  {tag:18s} iter={b.total_ms:9.0f}ms  order={order}")

    # Fig 12-style sweep
    print("\nFig 12 sweep (dc1=600 fixed, dc2 grows):")
    base = best_plan(algorithm1(job, {"dc1": 600}, P=80)).throughput
    for F in range(0, 11, 2):
        b = best_plan(algorithm1(job, {"dc1": 600, "dc2": 60 * F}, P=80))
        used2 = b.partitions.get("dc2", 0)
        print(f"  F={F*10:3d}%  gain={b.throughput/base:5.2f}x  "
              f"D={b.D}  dc2_partitions={used2}")


if __name__ == "__main__":
    main()
