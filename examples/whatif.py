"""What-if DC planning (paper §4.5): sweep candidate DC sets and GPU
counts through Algorithm 1 and print the cost/performance frontier — no
deployment required.  Includes the branch-and-bound placement search on
a world-spanning 8-DC WAN (exhaustive search would need 40320 orders
per D), and a time-varying scenario: diurnal congestion plus a
directed-link outage, priced by per-direction *worst-segment* bandwidth
(``wan.BandwidthSchedule``) so the search routes the pipeline around
the degraded pair — bandwidth-asymmetric, not just latency-aware.

  PYTHONPATH=src python examples/whatif.py

Viewing a run in Perfetto
-------------------------

Pass ``--trace out.json`` to additionally record the multi-job fleet
cascade scenario (the unplanned a->b outage that pushes job A's re-plan
onto job B's channel) with a :class:`repro.obs.RecordingTracer` and
export it as Chrome trace-event JSON:

  PYTHONPATH=src python examples/whatif.py --trace out.json

Then open https://ui.perfetto.dev and drag ``out.json`` in (or load it
in ``chrome://tracing``).  What you will see:

* one process group per job (``A/gpu``, ``B/gpu``) with a thread lane
  per (pipeline, stage) showing fwd/bwd/bubble/allreduce spans, plus a
  ``migration-stall`` span across every lane while A re-plans;
* ``A/wan`` / ``B/wan`` process groups with one lane per directed DC
  pair showing each activation/gradient transfer, sized by priced
  bandwidth — watch the a->b lane stretch 10x when the outage starts;
* a ``fleet/wan`` group showing the allocator's channel-reservation
  ledger (who held which pair, at what granted rate), and
  ``fleet/alloc`` grant/throttle instants per scheduling window;
* per-job ``*/control`` groups with drift-fire / re-plan / migration /
  outage instants — B's drift fire lands *after* A's migration arrives
  on its channel, which is the cascade the scenario demonstrates.

Before the file is written the recorded spans are re-audited against
the engines' own accounting (``repro.obs.verify_trace``): per-window
busy/bubble/allreduce totals, utilization and per-channel bits must
match ``SimResult.stats`` exactly, so the picture you load is a second
witness to the numbers the run printed, not a best-effort log.  The
same file round-trips through ``python -m repro.obs report out.json``
(metrics summary) and ``python -m repro.obs validate out.json``
(structural + dead-DC checks).
"""
import argparse
import dataclasses
import time

from repro.core import topology, wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan, what_if


def main(trace_path=None):
    # a Llama-70B-ish pretraining job: 80 layers, 875M params/layer
    job = JobModel(
        t_fwd_ms=2 * 875e6 * 4096 / 312e12 * 1e3,  # one microbatch, one layer-partition
        act_bytes=wan.activation_bytes(1, 4096, 8192),
        partition_param_bytes=875e6 * 2,
        microbatches=64,
    )
    print(f"comm/compute ratio C = {job.comm_compute_ratio:.1f}")

    scenarios = {
        "single-dc-1200": {"virginia": 1200},
        "two-equal-600": {"virginia": 600, "oregon": 600},
        "paper-dc-set-2": {"a": 600, "b": 500, "c": 400, "d": 300, "e": 200},
        "lopsided-1000+10": {"virginia": 1000, "saopaulo": 10},
    }
    out = what_if(job, scenarios, P=80, gpu_cost_per_hour=2.0)
    print(f"{'scenario':18s} {'D':>3s} {'gpus':>5s} {'iter_ms':>9s} "
          f"{'thr':>8s} {'$ /iter':>8s}  partitions")
    for name, v in out.items():
        print(f"{name:18s} {v['best_D']:3d} {v['gpus_used']:5d} "
              f"{v['total_ms']:9.0f} {v['throughput']:8.4f} "
              f"{v['cost_per_iteration']:8.4f}  {v['partitions']}")

    # heterogeneous WAN: the same fleet on a skewed topology — the
    # topology-aware placement search keeps the slow pair off the cut
    print("\nSkewed-WAN placement (dc0<->dc2 is 150 ms single-TCP):")
    fleet = {"dc0": 16, "dc1": 16, "dc2": 20}  # must span all three DCs
    job_skew = dataclasses.replace(job, topology=topology.skewed_3dc())
    for tag, search in (("topology-aware", None), ("availability-order", False)):
        b = best_plan(algorithm1(job_skew, fleet, P=40, C=1, search_orders=search))
        order = ">".join(d for d in b.dc_order if b.partitions.get(d, 0))
        print(f"  {tag:18s} iter={b.total_ms:9.0f}ms  order={order}")

    # 8-DC world WAN: the pruned (branch-and-bound) placement search —
    # beyond the old 6-DC exhaustive cap — routes the pipeline along the
    # geographic chain instead of criss-crossing oceans
    print("\n8-DC placement search (branch-and-bound, latencies ~ geography):")
    cities = ("virginia", "oregon", "frankfurt", "dublin", "tokyo",
              "singapore", "sydney", "saopaulo")
    lat = [
        #  vir   ore   fra   dub   tok   sin   syd   sao
        [0.0,  60.0,  90.0, 70.0, 150.0, 210.0, 200.0, 120.0],
        [60.0,  0.0, 140.0, 120.0, 100.0, 160.0, 140.0, 180.0],
        [90.0, 140.0,  0.0, 25.0, 230.0, 160.0, 280.0, 190.0],
        [70.0, 120.0, 25.0,  0.0, 210.0, 180.0, 260.0, 170.0],
        [150.0, 100.0, 230.0, 210.0, 0.0, 70.0, 110.0, 260.0],
        [210.0, 160.0, 160.0, 180.0, 70.0, 0.0, 90.0, 320.0],
        [200.0, 140.0, 280.0, 260.0, 110.0, 90.0, 0.0, 310.0],
        [120.0, 180.0, 190.0, 170.0, 260.0, 320.0, 310.0, 0.0],
    ]
    world = topology.TopologyMatrix.from_latency(lat, multi_tcp=True,
                                                 dc_names=cities, name="world8")
    job_world = dataclasses.replace(job, topology=world, microbatches=64)
    fleet8 = {c: 60 for c in cities}  # every DC must hold partitions
    t0 = time.perf_counter()
    b = best_plan(algorithm1(job_world, fleet8, P=24, C=2, search_orders=True))
    dt_ms = (time.perf_counter() - t0) * 1e3
    order = ">".join(d for d in b.dc_order if b.partitions.get(d, 0))
    print(f"  searched 8 DCs in {dt_ms:.0f} ms (exhaustive would scan 8! orders)")
    print(f"  best iter={b.total_ms:9.0f}ms  D={b.D}  order={order}")

    # time-varying WAN (paper Fig 7): diurnal congestion everywhere plus
    # a 6-hour outage-reroute on one *direction* of the pair the static
    # plan crossed first.  Algorithm 1 prices every boundary at its
    # worst-segment bandwidth per direction, so the placement search
    # routes the pipeline around the degraded pair instead of riding a
    # link that will collapse mid-iteration.
    print("\nTime-varying WAN (diurnal dip + directed outage, worst-segment pricing):")
    a0, a1 = b.dc_order[0], b.dc_order[1]  # first boundary of the static plan
    i0, i1 = world.index_of(a0), world.index_of(a1)
    scheds = {
        (a, c): wan.BandwidthSchedule.diurnal(
            peak_gbps=world.link(a, c).bw_gbps,
            trough_gbps=0.8 * world.link(a, c).bw_gbps,
        )
        for a, c in world.wan_pairs()
    }
    scheds[(i0, i1)] = wan.BandwidthSchedule.outage(
        world.link(i0, i1).bw_gbps,
        start_ms=2 * 3.6e6, end_ms=8 * 3.6e6,
        degraded_gbps=0.1 * world.link(i0, i1).bw_gbps,
    )
    job_tv = dataclasses.replace(
        job_world, topology=world.with_bandwidth_schedules(scheds)
    )
    b_tv = best_plan(algorithm1(job_tv, fleet8, P=24, C=2, search_orders=True))
    order_tv = ">".join(d for d in b_tv.dc_order if b_tv.partitions.get(d, 0))
    print(f"  outage {a0}->{a1} (10x degradation, hours 2-8), ~20% diurnal dip")
    print(f"  best iter={b_tv.total_ms:9.0f}ms  D={b_tv.D}  order={order_tv}")
    adj = [tuple(sorted((b_tv.dc_order[i], b_tv.dc_order[i + 1])))
           for i in range(len(order_tv.split('>')) - 1)]
    routed = tuple(sorted((a0, a1))) not in adj
    print(f"  degraded pair off the stage boundaries: {routed}")

    # reactive control plane (ISSUE 4): the planner did NOT know about
    # the outage this time.  A static plan rides the degraded direction
    # for the whole window; the control plane detects the sustained
    # delivery miss, re-runs Algorithm 1 on the observed WAN, pays the
    # stage migration, and routes around — then migrates nothing when
    # the link recovers and the incumbent is already cost-equal.
    print("\nReplan vs static under an unplanned outage (control plane):")
    from repro.core import control

    lat3 = [[0.0, 20.0, 20.0], [20.0, 0.0, 20.0], [20.0, 20.0, 0.0]]
    tri = topology.TopologyMatrix.from_latency(
        lat3, multi_tcp=True, dc_names=("east", "central", "west"))
    bw3 = tri.link(0, 1).bw_gbps
    live = tri.with_bandwidth_schedules({
        (0, 1): wan.BandwidthSchedule.outage(
            bw3, 10_000.0, 200_000.0, bw3 / 10.0),
        (1, 0): wan.BandwidthSchedule.flat(bw3),
    })
    job3 = dataclasses.replace(
        job, act_bytes=1e7, partition_param_bytes=2e8, microbatches=24,
        topology=None)
    fleet3 = {"east": 4, "central": 4, "west": 4}
    kw3 = dict(P=10, live_topo=live, planned_topo=tri, n_iterations=80, C=1)
    st = control.simulate_horizon(job3, fleet3, **kw3)
    rx = control.simulate_horizon(
        job3, fleet3, control=control.ControlConfig(), **kw3)
    print(f"  outage: east->central drops 10x over [10s, 200s] "
          f"(planner assumed nominal)")
    print(f"  static plan : {st.total_ms/1e3:8.1f}s for "
          f"{st.samples:.0f} samples, {st.replans} re-plans")
    print(f"  reactive    : {rx.total_ms/1e3:8.1f}s "
          f"({rx.replans} re-plan(s), {rx.migration_ms/1e3:.1f}s migrating, "
          f"{rx.stats['replans_noop']} no-op re-anchor(s) on recovery)")
    for m in rx.migrations:
        names = tri.dc_names
        moved = ", ".join(f"stage {i}: {names[a]}->{names[b]}"
                          for i, a, b in m.moves)
        print(f"    t={m.at_ms/1e3:7.1f}s migrated [{moved}] in "
              f"{m.duration_ms/1e3:.1f}s (projected gain "
              f"{m.projected_gain_ms/1e3:.0f}s over "
              f"{m.remaining_samples:.0f} remaining samples)")
    for e in rx.epochs:
        used = ">".join(d for d in e.plan.dc_order if e.plan.partitions.get(d, 0))
        print(f"    epoch {e.index}: {e.iterations} iterations on {used}")
    print(f"  reactive saves {(st.total_ms - rx.total_ms)/1e3:.1f}s "
          f"end-to-end, migration stall included")

    # multi-job fleet sharing one WAN (ISSUE 5): the links above were a
    # single job's private network; real fleets contend.  Two jobs whose
    # channel demands FIT one shared pair together lose nothing under
    # contention-aware temporal sharing (transfers serialize into each
    # other's idle windows — Atlas §4.2 across jobs), while the naive
    # always-fair-share strawman halves both jobs' rates anyway.  Then
    # the cascade: an unplanned outage pushes job A's re-plan onto the
    # pair job B crosses; B's drift detector fires on the *contention*
    # (not the outage — B never crossed the degraded pair) and B
    # re-plans away, bounded by the fleet's convergence guard.
    print("\nMulti-job fleet on one WAN (contention-priced channels):")
    from repro.core import fleet as fl

    duo = topology.TopologyMatrix.from_latency(
        [[0.0, 20.0], [20.0, 0.0]], multi_tcp=True, dc_names=("east", "west"))
    job_fit = dataclasses.replace(
        job3, act_bytes=2e7, partition_param_bytes=2e8, microbatches=24)
    mk = lambda n: fl.FleetJob(  # noqa: E731
        n, job_fit, {"east": 2, "west": 2}, P=4, n_iterations=32, C=1)
    tmp = fl.simulate_fleet([mk("jobA"), mk("jobB")], duo, validate=True)
    fair = fl.simulate_fleet([mk("jobA"), mk("jobB")], duo,
                             config=fl.FleetConfig(sharing="fair"),
                             validate=True)
    print(f"  two jobs, one east<->west pair, demands fit together:")
    print(f"    temporal sharing : {tmp.total_ms/1e3:7.1f}s "
          f"(throttled iterations: "
          f"{sum(v['throttled_iterations'] for v in tmp.stats['per_job'].values())})")
    print(f"    naive fair-share : {fair.total_ms/1e3:7.1f}s "
          f"(every overlapping window pinned to half rate)")
    print(f"    contention-aware sharing saves "
          f"{(fair.total_ms - tmp.total_ms)/1e3:.1f}s end-to-end")

    quad = topology.TopologyMatrix.from_latency(
        [[0.0 if i == j else 20.0 for j in range(4)] for i in range(4)],
        multi_tcp=True, dc_names=("a", "b", "c", "d"))
    bwq = quad.link(0, 1).bw_gbps
    live_q = quad.with_bandwidth_schedules({
        (0, 1): wan.BandwidthSchedule.outage(bwq, 20_000.0, 1e9, bwq / 10.0)})
    job_cs = dataclasses.replace(job_fit, act_bytes=1.2e8)
    tracer = None
    if trace_path is not None:
        from repro import obs
        tracer = obs.RecordingTracer()
    frc = fl.simulate_fleet(
        [fl.FleetJob("A", job_cs, {"a": 2, "b": 2, "c": 2}, P=6,
                     n_iterations=60, C=1, planned_topo=quad,
                     control=control.ControlConfig()),
         fl.FleetJob("B", job_cs, {"a": 2, "c": 2, "d": 2}, P=6,
                     n_iterations=60, C=1, planned_topo=quad,
                     control=control.ControlConfig())],
        live_q, validate=True, tracer=tracer)
    if tracer is not None:
        from repro.core.validate import check_trace
        n_windows = check_trace(tracer)  # second witness before export
        obs.write_chrome_trace(tracer, trace_path, label="whatif-cascade")
        print(f"  [trace] {tracer.n_events} events ({n_windows} iteration "
              f"windows crosschecked) -> {trace_path}  "
              f"(open in https://ui.perfetto.dev)")
    print(f"  cascade under an unplanned a->b outage "
          f"(per-channel invariant checked):")
    for nm in ("A", "B"):
        hr = frc.jobs[nm]
        routes = [">".join(quad.dc_names[d] for d in dict.fromkeys(e.spec.stage_dc))
                  for e in hr.epochs]
        pj = frc.stats["per_job"][nm]
        print(f"    job {nm}: {' -> '.join(routes)}  "
              f"({hr.replans} re-plan(s), "
              f"{pj['throttled_iterations']} contended iteration(s))")
    print(f"    B never crossed the degraded pair — its re-plan was "
          f"triggered by A's migration landing on B's channel")

    # prefill-as-a-service on the fleet (ISSUE 6): sell the training
    # bubbles to production inference traffic (paper §5, Fig 13) — at
    # fleet scale.  Host job A spans a,b,c; contender B squeezes the
    # a<->b channel; decode GPUs live in c, so a prefill placed on an
    # a/b pipeline must ship its KV cache over the *same contended WAN*
    # the training jobs transfer activations on (priced into TTFT before
    # the per-tier SLO gate; reservations land in the fleet ledger under
    # the "~prefill" pseudo-job and are invariant-checked).  The closed
    # loop: B's contention stretches A's iterations -> more bubble
    # supply -> monetized utilization under contention *exceeds* the
    # uncontended ceiling at the same offered load.
    print("\nBubbleTea at fleet scale (prefills ride contended bubbles):")
    from repro.core.bubbletea import (ArrivalProcess, InferenceModelSpec,
                                      PromptMix)

    tri_bt = topology.TopologyMatrix.from_latency(
        [[0.0 if i == j else 20.0 for j in range(3)] for i in range(3)],
        multi_tcp=True, dc_names=("a", "b", "c"))
    job_bt = dataclasses.replace(
        job_fit, t_fwd_ms=10.0, act_bytes=6e7)  # a,b channel demand > fits
    arr = ArrivalProcess(rate_per_s=25.0, horizon_ms=60_000.0, seed=7,
                         diurnal_amplitude=0.3, diurnal_period_ms=30_000.0,
                         burst_rate_mult=4.0, mean_on_ms=1_000.0,
                         mean_off_ms=4_000.0)
    reqs = arr.generate(PromptMix(lengths=(512, 1024, 2048),
                                  weights=(0.25, 0.65, 0.10)),
                        tiers={"gold": 0.3, "best_effort": 0.7})
    svc = fl.PrefillService(
        host_job="A", arrivals=reqs,
        model=InferenceModelSpec("llama3-8b", num_params=8e9,
                                 kv_bytes_per_token=16384.0),
        decode_dc="c", tiers={"gold": 1_200.0, "best_effort": 8_000.0})
    hostA = lambda: fl.FleetJob("A", job_bt, {"a": 2, "b": 2, "c": 2},  # noqa: E731
                                P=6, n_iterations=8, C=1)
    contB = fl.FleetJob("B", job_bt, {"a": 2, "b": 2}, P=4,
                        n_iterations=8, C=1)
    print(f"  {len(reqs)} seeded arrivals (diurnal + bursty), "
          f"gold TTFT<=1.2s / best-effort<=8s, decode in c:")
    for tag, jobs in (("A solo (uncontended)", [hostA()]),
                      ("A + B  (contended)  ", [hostA(), contB])):
        p = fl.simulate_fleet(jobs, tri_bt, prefill=svc,
                              validate=True).stats["prefill"]
        tiers = "  ".join(
            f"{t}: {v['acceptance']:.0%} (p99 {v['ttft_p99_ms']/1e3:.1f}s)"
            for t, v in p["per_tier"].items())
        print(f"    {tag}: train-only {p['utilization_train']:.0%} -> "
              f"with prefills {p['utilization_with_prefills']:.0%}  "
              f"[kv over WAN: {p['kv_wan_transfers']}]")
        print(f"        per-tier acceptance: {tiers}")
    print("    contention grew bubble supply: monetized utilization is "
          "higher in the contended run")

    # failure & elasticity (ISSUE 7): a DC dies mid-horizon with no
    # warning.  Three recovery stances at *fixed* sample count: do
    # nothing (every transfer through the dead DC limps at residual
    # bandwidth), re-plan around it and ship the live weights off the
    # corpse over the degraded WAN, or restore the surviving placement
    # from the nearest async checkpoint and re-earn the samples written
    # since ("replay") — the control plane prices both and takes the
    # cheaper, and validate proves no GPU busy time nor channel
    # reservation ever touches the dead DC inside its outage window.
    print("\nFailure & elasticity (mid-horizon DC loss, checkpoint-aware):")
    from repro.core.failures import (CheckpointPolicy, FailureEvent,
                                     FailureTrace)
    from repro.core.validate import check_horizon

    quad_f = topology.TopologyMatrix.from_latency(
        [[0.0, 30.0, 60.0, 150.0], [30.0, 0.0, 40.0, 170.0],
         [60.0, 40.0, 0.0, 120.0], [150.0, 170.0, 120.0, 0.0]],
        multi_tcp=True, dc_names=("use", "ussc", "usw", "asia"))
    trace = FailureTrace(events=(
        FailureEvent(at_ms=60_000.0, kind="dc_outage", dc="ussc",
                     residual_frac=0.02),))
    ckp = CheckpointPolicy(interval_ms=20_000.0, placement=("use", "usw"),
                           write_bw_gbps=2.0)
    job_f = JobModel(t_fwd_ms=10.0, act_bytes=1e7,
                     partition_param_bytes=4e8, microbatches=64)
    fleet_f = {n: 8 for n in quad_f.dc_names}
    kw_f = dict(P=12, live_topo=quad_f, planned_topo=quad_f,
                n_iterations=64, C=2)
    static_f = control.simulate_horizon(
        job_f, fleet_f, P=12, live_topo=trace.apply_to_topology(quad_f),
        planned_topo=quad_f, n_iterations=64, C=2)
    ship_f = control.simulate_horizon(
        job_f, fleet_f, control=control.ControlConfig(), failures=trace,
        **kw_f)
    ckpt_f = control.simulate_horizon(
        job_f, fleet_f, control=control.ControlConfig(), failures=trace,
        migration=control.MigrationModel(checkpoint=ckp), **kw_f)
    check_horizon(ship_f, live_topo=trace.apply_to_topology(quad_f))
    check_horizon(ckpt_f, live_topo=trace.apply_to_topology(quad_f))
    print(f"  ussc dies at t=60s (residual 2%), {static_f.samples:.0f} "
          f"samples either way:")
    print(f"    static (no reaction)   : {static_f.total_ms/1e3:7.1f}s")
    m_ship = ship_f.migrations[0]
    print(f"    ship live weights      : {ship_f.total_ms/1e3:7.1f}s  "
          f"(stall {m_ship.duration_ms/1e3:.1f}s hauling state off the "
          f"dead DC)")
    m_ck = next(m for m in ckpt_f.migrations if m.mode == "restore")
    print(f"    checkpoint restore     : {ckpt_f.total_ms/1e3:7.1f}s  "
          f"(stall {m_ck.duration_ms/1e3:.1f}s, replay "
          f"{m_ck.replay_samples:.0f} samples since the last landed "
          f"async write)")
    print(f"    both reacting arms re-ran Algorithm 1 with the dead DC "
          f"excluded ({m_ck.reason}); invariants checked")

    # elastic join: a preempted spot slice comes *back* — opportunistic
    # re-plan (never forced), taken only if the projected gain clears
    # the migration + hysteresis bar
    join = FailureTrace(events=(
        FailureEvent(at_ms=60_000.0, kind="dc_outage", dc="ussc",
                     recover_ms=120_000.0, residual_frac=0.02),))
    heal_f = control.simulate_horizon(
        job_f, fleet_f, control=control.ControlConfig(), failures=join,
        migration=control.MigrationModel(checkpoint=ckp), **kw_f)
    kinds = [m.reason for m in heal_f.migrations]
    print(f"  same outage healing at t=180s: {heal_f.total_ms/1e3:.1f}s, "
          f"re-plan trail: {kinds if kinds else 'none'}")

    # Fig 12-style sweep
    print("\nFig 12 sweep (dc1=600 fixed, dc2 grows):")
    base = best_plan(algorithm1(job, {"dc1": 600}, P=80)).throughput
    for F in range(0, 11, 2):
        b = best_plan(algorithm1(job, {"dc1": 600, "dc2": 60 * F}, P=80))
        used2 = b.partitions.get("dc2", 0)
        print(f"  F={F*10:3d}%  gain={b.throughput/base:5.2f}x  "
              f"D={b.D}  dc2_partitions={used2}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record the fleet cascade scenario and export "
                         "Chrome trace-event JSON (Perfetto-loadable)")
    main(trace_path=ap.parse_args().trace)
