"""ckpt/checkpoint.py: save/restore roundtrips and the async writer's
lifecycle (latest pointer, gc, metadata, list-index keys)."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import AsyncCheckpointer, load_pytree, save_pytree


def _train_state():
    """A realistic (params, opt, step) pytree with nested dicts, lists and
    mixed dtypes — the exact shape the train loop checkpoints."""
    params = {
        "embed": np.arange(12, dtype=np.float32).reshape(3, 4),
        "layers": {"wq": np.full((2, 4, 4), 0.5, np.float32),
                   "scale": np.ones((4,), np.float32)},
    }
    opt = {
        "mu": jax.tree.map(np.zeros_like, params),
        "nu": jax.tree.map(np.ones_like, params),
        "step": np.int32(7),
    }
    return {"params": params, "opt": opt, "history": [np.float32(1.5), np.float32(0.9)]}


def test_save_load_roundtrip_exact():
    tree = _train_state()
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.npz")
        save_pytree(p, tree, {"step": 7})
        out = load_pytree(p, tree)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(a, b)
            assert a.dtype == np.asarray(b).dtype
        with open(p + ".json") as f:
            assert json.load(f) == {"step": 7}


def test_roundtrip_from_jax_arrays():
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.zeros((3,), jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.npz")
        save_pytree(p, tree)
        out = load_pytree(p, jax.eval_shape(lambda: tree))
        np.testing.assert_array_equal(out["w"], np.asarray(tree["w"]))
        np.testing.assert_array_equal(out["b"], np.asarray(tree["b"]))


def test_async_checkpointer_lifecycle():
    tree = _train_state()
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=3)
        assert ck.latest_path() is None
        for step in (10, 20, 30, 40, 50):
            stamped = dict(tree, history=[np.float32(step), np.float32(step)])
            ck.save(step, stamped, {"step": step})
        ck.close()
        # gc kept exactly `keep` newest checkpoints
        npzs = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
        assert npzs == ["step_00000030.npz", "step_00000040.npz", "step_00000050.npz"]
        # latest points at the newest, and restores the matching content
        assert ck.latest_path().endswith("step_00000050.npz")
        out = load_pytree(ck.latest_path(), tree)
        assert float(out["history"][0]) == 50.0
        # metadata rode along
        with open(ck.latest_path() + ".json") as f:
            assert json.load(f)["step"] == 50


def test_async_save_snapshots_before_mutation():
    """save() must copy to host immediately — later in-place mutation of
    the live tree must not leak into the checkpoint (donated buffers)."""
    arr = np.ones((4,), np.float32)
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(1, {"w": arr})
        arr *= 0.0  # mutate the "live" training state
        ck.close()
        out = load_pytree(ck.latest_path(), {"w": np.empty((4,), np.float32)})
        # NOTE: np.asarray on an ndarray aliases, so this documents the
        # jax-array path: device arrays are copied by np.asarray
        assert out["w"].shape == (4,)


def test_load_rejects_shape_mismatch():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        save_pytree(p, {"w": np.ones((2, 2))})
        with pytest.raises(AssertionError):
            load_pytree(p, {"w": np.ones((4,))})


def test_load_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        save_pytree(p, {"w": np.ones((2, 2))})
        with pytest.raises(KeyError):
            load_pytree(p, {"w": np.ones((2, 2)), "extra": np.ones((1,))})


def test_wait_blocks_until_write_durable(monkeypatch):
    """Regression: wait() used to poll Queue.empty(), which flips true the
    moment the worker *dequeues* an item — racing the serializer.  With a
    deliberately slow writer, wait() must not return before the bytes and
    the latest pointer are on disk."""
    import time

    import repro.ckpt.checkpoint as ckpt_mod

    real_save = ckpt_mod.save_pytree

    def slow_save(path, tree, meta=None):
        time.sleep(0.3)
        real_save(path, tree, meta)

    monkeypatch.setattr(ckpt_mod, "save_pytree", slow_save)
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(1, {"w": np.ones((2,), np.float32)})
        ck.wait()
        p = ck.latest_path()
        assert p is not None and os.path.exists(p)
        ck.close()


def test_save_pytree_crash_leaves_no_partial_npz(monkeypatch):
    """A crash mid-serialization must not leave a truncated archive at the
    final path — restore sees the previous complete checkpoint or nothing."""

    def exploding_savez(f, **kw):
        f.write(b"partial garbage")
        raise RuntimeError("disk full")

    monkeypatch.setattr(np, "savez", exploding_savez)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.npz")
        with pytest.raises(RuntimeError):
            save_pytree(p, {"w": np.ones((2,))})
        assert not os.path.exists(p)


def test_save_pytree_leaves_no_tmp_droppings():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.npz")
        save_pytree(p, {"w": np.ones((2,))}, {"step": 1})
        assert sorted(os.listdir(d)) == ["ck.npz", "ck.npz.json"]


def test_close_with_pending_error_still_stops_worker(monkeypatch):
    """close() must enqueue the sentinel and join the worker even when a
    pending write failed — the old code raised out of wait() first and
    leaked the thread alive forever."""
    import repro.ckpt.checkpoint as ckpt_mod

    def failing_save(path, tree, meta=None):
        raise IOError("no space left on device")

    monkeypatch.setattr(ckpt_mod, "save_pytree", failing_save)
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(1, {"w": np.ones((2,), np.float32)})
        with pytest.raises(IOError):
            ck.close()
        ck._thread.join(timeout=5)
        assert not ck._thread.is_alive()
