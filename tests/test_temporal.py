"""§4.4 Atlas scheduler invariants (repro.core.temporal)."""
import pytest

from repro.core.simulator import GeoTopology
from repro.core.simulator import testbed_spec as make_spec
from repro.core.temporal import atlas_schedule

SPEC = make_spec(
    hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
    layer_params=412e6, num_stages=4, microbatches=6, stage_dc=[0, 0, 1, 2],
)
TOPO = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)


@pytest.fixture(scope="module")
def sched():
    return atlas_schedule(SPEC, TOPO, n_pipelines=3)


def test_no_gpu_overlap(sched):
    by_gpu = {}
    for t in sched.tasks:
        by_gpu.setdefault((t.pipeline, t.stage), []).append((t.start, t.end))
    for ivs in by_gpu.values():
        ivs.sort()
        for (s0, e0), (s1, e1) in zip(ivs, ivs[1:]):
            assert s1 >= e0 - 1e-9


def test_no_wan_channel_overlap(sched):
    """Rule 1/3: within the DP-cell, one WAN transfer at a time per
    (boundary, direction)."""
    wan_boundaries = {1}  # boundary 1 crosses DC0->DC1; 2 crosses DC1->DC2
    by_chan = {}
    for tr in sched.transfers:
        if SPEC.stage_dc[tr.boundary] != SPEC.stage_dc[tr.boundary + 1]:
            by_chan.setdefault((tr.boundary, tr.direction), []).append(
                (tr.start, tr.end)
            )
    assert by_chan, "no WAN transfers found"
    for ivs in by_chan.values():
        ivs.sort()
        for (s0, e0), (s1, e1) in zip(ivs, ivs[1:]):
            assert s1 >= e0 - 1e-9


def test_memory_cap(sched):
    """Rule 2: forwards-in-flight never exceed the cap at any stage."""
    cap = SPEC.num_stages
    events = []
    for t in sched.tasks:
        events.append((t.end, 1 if t.kind == "fwd" else -1, t.pipeline, t.stage))
    for (p, s) in {(t.pipeline, t.stage) for t in sched.tasks}:
        evs = sorted(e for e in events if e[2] == p and e[3] == s)
        inflight = 0
        for _, d, _, _ in evs:
            inflight += d
            assert inflight <= cap


def test_transfer_starts_at_compute_end(sched):
    """Rule 3: a WAN activation transfer starts exactly when its producing
    forward ends (no buffered stalling on the sender)."""
    fwd_end = {
        (t.pipeline, t.stage, t.micro): t.end for t in sched.tasks if t.kind == "fwd"
    }
    checked = 0
    for tr in sched.transfers:
        if tr.direction != "act":
            continue
        if SPEC.stage_dc[tr.boundary] == SPEC.stage_dc[tr.boundary + 1]:
            continue
        end = fwd_end[(tr.pipeline, tr.boundary, tr.micro)]
        assert tr.start == pytest.approx(end, abs=1e-6)
        checked += 1
    assert checked > 0


def test_backward_priority(sched):
    """Rule 4: when a backward was ready, it was not passed over for a
    forward scheduled later on the same GPU (weak form: per GPU, among
    tasks with equal ready times the bwd runs first — verified by
    checking no fwd starts strictly between a bwd's ready (arrival) and
    its start when the gpu was free)."""
    # structural sanity: every backward for micro m at stage s starts
    # before the forward of micro m+cap (cap respected => priority held)
    by_gpu = {}
    for t in sched.tasks:
        by_gpu.setdefault((t.pipeline, t.stage), []).append(t)
    for tasks in by_gpu.values():
        fwd = sorted(t.start for t in tasks if t.kind == "fwd")
        bwd = sorted(t.start for t in tasks if t.kind == "bwd")
        assert len(fwd) == len(bwd)


def test_makespan_sane(sched):
    work = SPEC.t_fwd_ms * (1 + 1 + 2)  # f + r + b per micro per stage
    lower_bound = SPEC.microbatches * work
    assert sched.makespan >= lower_bound
    assert sched.makespan < 100 * lower_bound
