"""Integration: the Pallas kernels swapped into full models via
``repro.models.attention.set_attention_impl`` must match the XLA path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # compile-heavy: excluded from tier-1

from repro.configs import get_smoke_config
from repro.models import attention
from repro.models.transformer import build_model


@pytest.fixture(autouse=True)
def _restore_impl():
    yield
    attention.set_attention_impl("xla")


def _zeros_cache(model, B, S):
    return jax.tree.map(
        lambda s: jnp.full(s.shape, -1, s.dtype)
        if s.dtype == jnp.int32
        else jnp.zeros(s.shape, s.dtype),
        model.cache_shape(B, S),
    )


@pytest.mark.parametrize("arch", ["minitron_4b", "gpt_a"])
def test_model_loss_with_pallas_flash_attention(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, cfg.vocab_size)}
    l_xla, _ = jax.jit(m.loss)(params, batch)
    attention.set_attention_impl("pallas")
    l_pl, _ = jax.jit(m.loss)(params, batch)
    assert abs(float(l_xla) - float(l_pl)) < 1e-3, (float(l_xla), float(l_pl))


def test_model_decode_with_pallas_decode_attention():
    cfg = get_smoke_config("minitron_4b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, T = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    cache = _zeros_cache(m, B, 128)
    logits, cache = jax.jit(m.prefill)(params, {"tokens": toks}, cache)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), T, jnp.int32)
    d_xla, _ = jax.jit(m.decode_step)(params, cache, nxt, pos)
    attention.set_attention_impl("pallas")
    d_pl, _ = jax.jit(m.decode_step)(params, cache, nxt, pos)
    np.testing.assert_allclose(np.asarray(d_xla), np.asarray(d_pl), atol=1e-3, rtol=1e-3)
