"""Fleet-scale BubbleTea: prefill-as-a-service riding training bubbles,
with WAN-priced KV handoff contending against training transfers
(paper §5 under the PR-5 multi-job allocator).

Geometry: 3 DCs (a, b, c) at 20 ms RTT, host job A spans all three
(stage_dc a,a,b,b,c,c), contender B squeezes the a<->b channel.  Decode
lives in c, so prefills placed on a/b pipelines ship KV over the same
WAN the training jobs are using."""
import math

import pytest

from repro.core import fleet
from repro.core import topology as tp
from repro.core import validate as V
from repro.core.bubbletea import ArrivalProcess, InferenceModelSpec, PromptMix
from repro.core.dc_selection import JobModel


def _world(n=3, names=("a", "b", "c")):
    lat = [[0.0 if i == j else 20.0 for j in range(n)] for i in range(n)]
    return tp.TopologyMatrix.from_latency(lat, multi_tcp=True, dc_names=names)


JOB = JobModel(t_fwd_ms=10.0, act_bytes=6e7, partition_param_bytes=2e8,
               microbatches=24)
MODEL = InferenceModelSpec("llama3-8b", num_params=8e9,
                           kv_bytes_per_token=16384.0)
MIX = PromptMix(lengths=(512, 1024, 2048), weights=(0.25, 0.65, 0.10))
TIER_SLO = {"gold": 1_200.0, "best_effort": 8_000.0}
TIER_SHARE = {"gold": 0.3, "best_effort": 0.7}
RATE = 25.0  # req/s — saturating for this bubble supply


def _service(rate=RATE, seed=7):
    arr = ArrivalProcess(rate_per_s=rate, horizon_ms=60_000.0, seed=seed,
                         diurnal_amplitude=0.3, diurnal_period_ms=30_000.0,
                         burst_rate_mult=4.0, mean_on_ms=1_000.0,
                         mean_off_ms=4_000.0)
    return fleet.PrefillService(
        host_job="A", arrivals=arr.generate(MIX, tiers=TIER_SHARE),
        model=MODEL, decode_dc="c", tiers=TIER_SLO)


def _host():
    return fleet.FleetJob("A", JOB, {"a": 2, "b": 2, "c": 2}, P=6,
                          n_iterations=8, C=1)


def _contender():
    return fleet.FleetJob("B", JOB, {"a": 2, "b": 2}, P=4,
                          n_iterations=8, C=1)


@pytest.fixture(scope="module")
def runs():
    world = _world()
    svc = _service()
    solo = fleet.simulate_fleet([_host()], world, prefill=svc, validate=True)
    duo = fleet.simulate_fleet([_host(), _contender()], world, prefill=svc,
                               validate=True)
    return world, solo, duo


def test_prefill_stats_shape_and_kv_traffic(runs):
    world, solo, duo = runs
    for fr in (solo, duo):
        p = fr.stats["prefill"]
        assert p["requests_offered"] > 500
        # offered = arrivals inside the training horizon; the 60 s trace
        # outlives the 8-iteration fleet run
        assert p["placed"] + p["rejected"] == p["requests_offered"]
        assert p["requests_offered"] <= p["requests_total"]
        assert 0.0 < p["acceptance"] <= 1.0
        assert set(p["per_tier"]) == {"gold", "best_effort"}
        # decode in c, pipelines in a/b/c: both local and WAN handoffs
        assert p["kv_local_transfers"] > 0
        assert p["kv_wan_transfers"] > 0 and p["kv_wan_bits"] > 0
        assert p["kv_reservations"] > 0
    kv = [r for r in duo.reservations if r.job == fleet.KV_JOB]
    assert len(kv) == duo.stats["prefill"]["kv_reservations"]
    ic = world.index_of("c")
    assert {r.pair for r in kv} <= {(0, ic), (1, ic)}
    for r in kv:
        assert r.t1_ms > r.t0_ms and r.rate_gbps > 0 and math.isfinite(r.rate_gbps)


def test_closed_loop_contention_raises_bubble_monetization(runs):
    """The acceptance criterion: WAN contention from job B stretches A's
    iterations, creating *more* bubble supply — at the same offered
    load, A's utilization-with-prefills under contention must exceed its
    uncontended value (Fig 13's economics, closed over the fleet)."""
    _, solo, duo = runs
    ps, pd = solo.stats["prefill"], duo.stats["prefill"]
    # contention really throttled training...
    assert pd["utilization_train"] < ps["utilization_train"]
    # ...and prefills monetized the extra bubbles past the solo ceiling
    assert pd["utilization_with_prefills"] > ps["utilization_with_prefills"]
    assert pd["utilization_with_prefills"] > pd["utilization_train"]


def test_gold_tier_meets_tighter_ttft(runs):
    _, _, duo = runs
    per = duo.stats["prefill"]["per_tier"]
    for tier, slo in TIER_SLO.items():
        assert per[tier]["offered"] > 0
        if per[tier]["placed"]:
            assert per[tier]["ttft_p99_ms"] <= slo


def test_fleet_prefill_deterministic(runs):
    """Same seeded arrivals + same fleet → identical service outcome."""
    world, _, duo = runs
    again = fleet.simulate_fleet([_host(), _contender()], world,
                                 prefill=_service(), validate=True)
    assert again.stats["prefill"] == duo.stats["prefill"]


def test_check_fleet_rejects_corrupted_kv_reservation(runs):
    world, _, _ = runs
    fr = fleet.simulate_fleet([_host(), _contender()], world,
                              prefill=_service(), validate=True)
    V.check_fleet(fr, world)  # honest ledger passes
    victim = next(r for r in fr.reservations if r.job == fleet.KV_JOB)
    victim.rate_gbps *= 50.0
    with pytest.raises(V.InvariantViolation):
        V.check_fleet(fr, world)


def test_check_fleet_rejects_overlapping_kv_transfers(runs):
    """KV transfers serialize per channel behind a cursor; sliding one
    onto its successor is double-booking even when the rate sum still
    fits under capacity."""
    world, _, _ = runs
    fr = fleet.simulate_fleet([_host(), _contender()], world,
                              prefill=_service(), validate=True)
    by_pair = {}
    for r in fr.reservations:
        if r.job == fleet.KV_JOB:
            by_pair.setdefault(r.pair, []).append(r)
    pair, rs = next((p, rs) for p, rs in by_pair.items() if len(rs) >= 2)
    rs.sort(key=lambda r: r.t0_ms)
    a, b = rs[0], rs[1]
    b.t0_ms = a.t1_ms - 0.5 * (a.t1_ms - a.t0_ms)  # overlap, same rates
    with pytest.raises(V.InvariantViolation):
        V.check_fleet(fr, world)
