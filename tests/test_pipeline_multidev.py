"""Cross-pod pipeline tests — run in a subprocess with 8 fake devices
(jax locks the device count at first init, so the main pytest process
cannot host these)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # compile-heavy: excluded from tier-1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PREAMBLE = """
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models.transformer import build_model
from repro.parallel.pipeline import make_pipeline_loss
from repro import compat
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
"""


@pytest.mark.parametrize("arch", ["minitron_4b", "zamba2_2p7b", "deepseek_v2_lite_16b"])
def test_pipeline_matches_reference_loss(arch):
    out = _run(PREAMBLE + f"""
cfg = get_smoke_config("{arch}")
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}}
ref, _ = jax.jit(m.loss)(params, batch)
with compat.set_mesh(mesh):
    s = jax.jit(make_pipeline_loss(cfg, mesh, n_micro=4, boundary="striped"))(params, batch)
    d = jax.jit(make_pipeline_loss(cfg, mesh, n_micro=4, boundary="direct"))(params, batch)
assert abs(float(s) - float(ref)) < 3e-2, (float(s), float(ref))
assert abs(float(s) - float(d)) < 3e-2
print("OK", float(s), float(ref))
""")
    assert "OK" in out


def test_pipeline_gradients_match_reference():
    out = _run(PREAMBLE + """
cfg = get_smoke_config("minitron_4b")
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
with compat.set_mesh(mesh):
    g = jax.jit(jax.grad(make_pipeline_loss(cfg, mesh, n_micro=4)))(params, batch)
g0 = jax.jit(jax.grad(lambda p, b: m.loss(p, b)[0]))(params, batch)
num = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)-b.astype(jnp.float32)))) for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g0)))
den = sum(float(jnp.sum(jnp.abs(b.astype(jnp.float32)))) for b in jax.tree.leaves(g0))
assert num / den < 0.05, num / den
print("OK", num / den)
""")
    assert "OK" in out


def test_striped_boundary_dcn_bytes():
    """Atlas striping never sends MORE inter-pod bytes than the direct
    boundary — and (EXPERIMENTS.md §Perf B) XLA's partitioner performs
    the striping automatically, so the two often lower identically:
    the paper's transport insight is native to GSPMD."""
    out = _run(PREAMBLE + """
from repro.launch.dryrun import collective_bytes
cfg = get_smoke_config("minitron_4b")
m = build_model(cfg)
params_sds = jax.eval_shape(m.init, jax.random.PRNGKey(0))
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
res = {}
with compat.set_mesh(mesh):
    for mode in ("striped", "direct"):
        lf = make_pipeline_loss(cfg, mesh, n_micro=4, boundary=mode)
        compiled = jax.jit(lf).lower(params_sds, batch).compile()
        res[mode] = collective_bytes(compiled.as_text(), pod_stride=4)
print("striped", res["striped"]["dcn"], "direct", res["direct"]["dcn"])
assert res["striped"]["dcn"] > 0, res  # pod boundary is exercised
assert res["striped"]["dcn"] <= res["direct"]["dcn"], res
""")
    assert "striped" in out


def test_identity_padding_is_exact():
    """27-layer (deepseek) and 9-group (zamba2) stacks pad to uniform
    stages without changing the function (checked vs reference loss)."""
    out = _run(PREAMBLE + """
from repro.parallel.pipeline import pad_layer_stack, padded_num_layers
assert padded_num_layers(27, 2) == 28
assert padded_num_layers(9, 2) == 10
for arch in ("deepseek_v2_lite_16b", "zamba2_2p7b"):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
    ref, _ = jax.jit(m.loss)(params, batch)
    with compat.set_mesh(mesh):
        s = jax.jit(make_pipeline_loss(cfg, mesh, n_micro=4))(params, batch)
    assert abs(float(s) - float(ref)) < 3e-2, (arch, float(s), float(ref))
print("OK")
""")
    assert "OK" in out


def test_dryrun_smoke_combo_on_host_mesh():
    """A miniature dry-run (host-mesh sized) proves the lowering path."""
    out = _run("""
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp
from repro.launch.dryrun import collective_bytes, _DTYPE_BYTES, _shape_bytes
# parser unit checks
assert _shape_bytes("bf16[4,8]") == 64
assert _shape_bytes("f32[2,2]") == 16
hlo = '''
  %ar = f32[16,16]{1,0} all-reduce(f32[16,16]{1,0} %x), replica_groups={{0,1},{2,3}}
  %cp = bf16[8]{0} collective-permute(bf16[8]{0} %y), source_target_pairs={{0,4},{1,5}}
'''
c = collective_bytes(hlo, pod_stride=4)
assert c["by_op"]["all-reduce"] == 16*16*4*2
assert c["dcn"] == 16, c  # the permute crosses the pod stride
print("OK")
""")
    assert "OK" in out
