"""Optimizer / data / checkpoint / serving substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import AsyncCheckpointer, load_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, input_batch_for, make_batches
from repro.models.transformer import build_model
from repro.optim.optimizer import (
    OptimizerConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_at,
    make_train_step,
)


# ---------------------------------------------------------------- optimizer


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)
    mid = float(lr_at(cfg, jnp.int32(55)))
    assert 0.1 < mid < 1.0


def test_grad_clipping():
    cfg = OptimizerConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    st = init_opt_state(params)
    _, st2, m = adamw_update(cfg, grads, params, st)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    # clipped: first moment magnitude bounded by (1-b1)*clip-scaled grad
    assert float(jnp.max(jnp.abs(st2.mu["w"]))) < 1.0


def test_weight_decay_only_on_matrices():
    cfg = OptimizerConfig(weight_decay=1.0, peak_lr=0.1, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(cfg, grads, params, init_opt_state(params))
    assert float(jnp.max(jnp.abs(p2["scale"] - 1.0))) < 1e-6  # untouched
    assert float(jnp.max(p2["w"])) < 1.0  # decayed


@pytest.mark.slow  # compiles a full train step
def test_training_learns():
    cfg = get_smoke_config("gpt_a")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m.loss, OptimizerConfig(peak_lr=3e-3, warmup_steps=5, total_steps=40)))
    st = init_opt_state(params)
    losses = []
    for i, b in enumerate(make_batches(cfg, DataConfig(batch_size=8, seq_len=64), num_steps=40)):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, st, met = step(params, st, b)
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::8]


@pytest.mark.slow  # compiles two train-step variants
def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("gpt_a")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in input_batch_for(cfg, 8, 32).items()}
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
    p1, _, m1 = jax.jit(make_train_step(m.loss, ocfg))(params, init_opt_state(params), batch)
    p2, _, m2 = jax.jit(make_train_step(m.loss, ocfg, accum_steps=4))(
        params, init_opt_state(params), batch
    )
    # same data, averaged grads ≈ full-batch grads (bf16 tolerance)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3, rtol=5e-2
        )


# ---------------------------------------------------------------- data


def test_data_deterministic_and_family_keys():
    for arch, keys in [
        ("gpt_a", {"tokens"}),
        ("hubert_xlarge", {"embeds", "labels", "mask"}),
        ("qwen2_vl_7b", {"embeds", "positions", "labels", "mask"}),
    ]:
        cfg = get_smoke_config(arch)
        b1 = input_batch_for(cfg, 4, 32, seed=7)
        b2 = input_batch_for(cfg, 4, 32, seed=7)
        assert set(b1) == keys
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
        b3 = input_batch_for(cfg, 4, 32, seed=8)
        assert any(not np.array_equal(b1[k], b3[k]) for k in b1)


def test_tokens_in_vocab_range():
    cfg = get_smoke_config("gpt_a")
    b = input_batch_for(cfg, 4, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size


def test_vlm_mask_excludes_patches():
    cfg = get_smoke_config("qwen2_vl_7b")
    b = input_batch_for(cfg, 2, 32)
    n_img = 32 // 4
    assert (b["mask"][:, :n_img] == 0).all()
    assert (b["mask"][:, n_img:] == 1).all()
    assert b["positions"].shape == (3, 2, 32)


# ---------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "c": [np.ones(4), np.zeros(2)]}
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for step in (1, 2, 3, 4):
            ck.save(step, tree, {"step": step})
        ck.close()
        files = [f for f in os.listdir(d) if f.endswith(".npz")]
        assert len(files) == 2  # gc kept last 2
        out = load_pytree(ck.latest_path(), tree)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(a, b)


def test_save_load_pytree_shapes_checked():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        save_pytree(p, {"w": np.ones((2, 2))})
        with pytest.raises(AssertionError):
            load_pytree(p, {"w": np.ones((3, 3))})


# serving lifecycle tests live in tests/test_serving_engine.py (one
# shared engine per module keeps the prefill/decode jits compiled once)
