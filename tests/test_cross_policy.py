"""Cross-policy property tests on random specs and topologies.

Two layers of guarantees:

  * On the paper's §6.1 testbed grid (GPT-A/B, M ∈ {4,8,16}, WAN latency
    10–40 ms) the full Fig-9 ordering holds:
        atlas ≤ varuna ≤ gpipe   (baselines on single-TCP).
  * On *random* comm-heavy geo-pipelines (including heterogeneous
    skewed/star/chain/azure matrices) Atlas dominates every baseline and
    every policy passes the physical-invariant checker.  The
    varuna-vs-gpipe leg is intentionally NOT asserted there: in
    latency-dominated corners (t_fwd of a few ms vs 100+ ms RTT) GPipe's
    all-forward phase pipelines transfers better and legitimately wins.
"""
import dataclasses
import random

import pytest

from repro.core import topology as tp
from repro.core import validate as V
from repro.core import wan
from repro.core.simulator import GeoTopology, PipelineSpec, simulate
from repro.core.simulator import testbed_spec as make_testbed_spec

EPS = 1e-6
POLICIES = ("gpipe", "megatron", "varuna", "atlas")

GPT_A = dict(hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
             layer_params=412e6)
GPT_B = dict(hidden=8192, seq_len=6144, micro_batch=1, layers_per_stage=1,
             layer_params=1.2e9)


def _single_tcp(topo):
    """The same topology with every WAN pair limited to one TCP flow."""
    if isinstance(topo, GeoTopology):
        return dataclasses.replace(topo, multi_tcp=False)
    links = {k: wan.wan_link(l.latency_ms, False) for k, l in topo.links.items()}
    return dataclasses.replace(topo, links=links, multi_tcp=False)


def _random_case(rng: random.Random):
    """One comm-heavy geo-pipeline: ≥1 WAN boundary, contiguous stages per
    DC, multi-TCP serialization within 1–4x of t_fwd (the paper's C)."""
    P = rng.choice([2, 3, 4, 6])
    n_dcs = rng.choice([2, 3])
    M = rng.choice([8, 12, 16])
    cuts = sorted(rng.sample(range(1, P), min(n_dcs - 1, P - 1)))
    stage_dc, dc, prev = [], 0, 0
    for c in cuts + [P]:
        stage_dc += [dc] * (c - prev)
        prev, dc = c, dc + 1
    t_f = rng.uniform(5, 30)
    act = rng.uniform(1.0, 4.0) * t_f * 1e-3 * (wan.NODE_PAIR_CAP_GBPS * 1e9) / 8.0
    spec = PipelineSpec(
        num_stages=P, microbatches=M, t_fwd_ms=t_f, act_bytes=act,
        stage_dc=tuple(stage_dc), recompute=True,
    )
    topo = rng.choice([
        GeoTopology(wan_latency_ms=rng.choice([10, 20, 30, 40]), multi_tcp=True),
        tp.skewed_3dc(),
        tp.star(3),
        tp.chain(3),
        tp.azure_testbed(),
        tp.TopologyMatrix.uniform(3, rng.choice([10, 40])),
    ])
    D = rng.choice([2, 3])
    return spec, topo, D


def test_paper_testbed_full_ordering():
    for model in (GPT_A, GPT_B):
        for M in (4, 8, 16):
            for lat in (10, 20, 30, 40):
                spec = make_testbed_spec(**model, num_stages=4, microbatches=M,
                                         stage_dc=[0, 0, 1, 2])
                tb = GeoTopology(wan_latency_ms=lat, multi_tcp=False)
                ta = GeoTopology(wan_latency_ms=lat, multi_tcp=True)
                at = simulate(spec, ta, policy="atlas", n_pipelines=3,
                              validate=True).iteration_ms
                va = simulate(spec, tb, policy="varuna", validate=True).iteration_ms
                gp = simulate(spec, tb, policy="gpipe", validate=True).iteration_ms
                assert at <= va + EPS, (M, lat, at, va)
                assert va <= gp + EPS, (M, lat, va, gp)


@pytest.mark.parametrize("seed", [7, 11, 42])
def test_random_cases_atlas_dominates_and_invariants_hold(seed):
    rng = random.Random(seed)
    for _ in range(25):
        spec, topo, D = _random_case(rng)
        tb = _single_tcp(topo)
        times = {}
        for pol in POLICIES:
            use_topo = topo if pol == "atlas" else tb
            n_pipes = D if pol == "atlas" else 1
            res = simulate(spec, use_topo, policy=pol, n_pipelines=n_pipes,
                           validate=True)
            assert 0.0 <= res.utilization <= 1.0
            assert res.iteration_ms > 0
            times[pol] = res.iteration_ms
        for base in ("gpipe", "megatron", "varuna"):
            assert times["atlas"] <= times[base] + EPS, (spec, topo, base, times)


@pytest.mark.parametrize("seed", [3, 19])
def test_random_cases_atlas_schedule_consistency(seed):
    """The precomputed Atlas schedule must agree with the event-driven
    simulator (and pass the transfer-level checker) on random cases."""
    rng = random.Random(seed)
    for _ in range(8):
        spec, topo, D = _random_case(rng)
        V.check_atlas_consistency(spec, topo, n_pipelines=D, dp_replicas=D)
