"""TopologyMatrix: heterogeneous per-DC-pair WAN model + threading.

Covers the PR's acceptance criterion: a skewed 3-DC matrix (one slow
pair) must change both the DC placement Algorithm 1 picks and the
simulated iteration time, relative to the uniform topology.
"""
import dataclasses

import pytest

from repro.core import topology as tp
from repro.core import wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.simulator import GeoTopology, PipelineSpec, simulate
from repro.core.simulator import testbed_spec as make_testbed_spec


def _spec(stage_dc, M=8):
    return make_testbed_spec(
        hidden=8192, seq_len=6144, micro_batch=1, layers_per_stage=1,
        layer_params=1.2e9, num_stages=len(stage_dc), microbatches=M,
        stage_dc=stage_dc,
    )


# ------------------------------------------------------------- construction


def test_uniform_matrix_matches_geotopology():
    geo = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    mat = geo.matrix(3)
    for a in range(3):
        for b in range(3):
            assert geo.link(a, b) == mat.link(a, b)
    spec = _spec([0, 0, 1, 2])
    for policy in ("gpipe", "megatron", "varuna", "atlas"):
        r_geo = simulate(spec, geo, policy=policy, n_pipelines=2, validate=True)
        r_mat = simulate(spec, mat, policy=policy, n_pipelines=2, validate=True)
        assert r_geo.iteration_ms == pytest.approx(r_mat.iteration_ms, rel=1e-12)


def test_from_latency_uses_tcp_law():
    lat = [[0, 10, 95], [10, 0, 40], [95, 40, 0]]
    m = tp.TopologyMatrix.from_latency(lat, multi_tcp=False)
    assert m.link(0, 1).bw_gbps == pytest.approx(wan.tcp_single_bw_gbps(10))
    assert m.link(0, 2).bw_gbps == pytest.approx(wan.tcp_single_bw_gbps(95))
    assert m.link(0, 2).bw_gbps < m.link(0, 1).bw_gbps
    m2 = tp.TopologyMatrix.from_latency(lat, multi_tcp=True)
    assert m2.link(0, 2).bw_gbps == pytest.approx(wan.NODE_PAIR_CAP_GBPS)


def test_asymmetric_links_allowed():
    links = {
        (0, 1): wan.Link(latency_ms=10.0, bw_gbps=5.0),
        (1, 0): wan.Link(latency_ms=60.0, bw_gbps=1.0),
    }
    m = tp.TopologyMatrix.from_links(2, links)
    assert m.link(0, 1).latency_ms == 10.0
    assert m.link(1, 0).latency_ms == 60.0
    # one-directional entries fall back to the reverse pair
    m2 = tp.TopologyMatrix.from_links(2, {(0, 1): wan.Link(20.0, 3.0)})
    assert m2.link(1, 0) == m2.link(0, 1)


def test_intra_dc_link():
    m = tp.TopologyMatrix.uniform(3)
    assert m.link(1, 1).bw_gbps == wan.INTRA_DC_GBPS
    assert m.link(1, 1).latency_ms == wan.INTRA_DC_LATENCY_MS
    assert not m.is_wan(1, 1) and m.is_wan(0, 1)


def test_presets_shape_and_skew():
    az = tp.azure_testbed()
    assert az.n_dcs == 4 and az.dc_names[0] == "us-east"
    assert az.link(0, 3).latency_ms > az.link(0, 1).latency_ms  # asia >> us

    sk = tp.skewed_3dc()
    slow = sk.link(0, 2)
    assert slow.latency_ms > sk.link(0, 1).latency_ms
    assert slow.bw_gbps < sk.link(0, 1).bw_gbps  # single-TCP collapse
    assert sk.bottleneck() == slow

    st = tp.star(4, hub_ms=15.0)
    assert st.link(1, 2).latency_ms == pytest.approx(30.0)  # via hub
    assert st.link(0, 2).latency_ms == pytest.approx(15.0)

    ch = tp.chain(4, hop_ms=20.0)
    assert ch.link(0, 3).latency_ms == pytest.approx(60.0)
    assert ch.link(0, 3).bw_gbps < ch.link(0, 1).bw_gbps  # distant = single-TCP

    assert tp.preset("skewed").name == "skewed-3dc"
    assert tp.preset("uniform3").n_dcs == 3


# ---------------------------------------------------------- acceptance test


def test_skewed_topology_changes_iteration_time():
    """One slow pair must slow the pipeline iff the pipeline crosses it."""
    uniform = tp.TopologyMatrix.uniform(3, wan_latency_ms=10.0)
    skewed = tp.skewed_3dc(fast_ms=10.0, slow_ms=150.0)
    crossing = _spec([0, 2, 1])  # boundary (0,2) is the slow pair
    avoiding = _spec([0, 1, 2])  # boundaries (0,1), (1,2) are fast
    for policy in ("varuna", "atlas"):
        t_cross_uni = simulate(crossing, uniform, policy=policy, n_pipelines=2,
                               validate=True).iteration_ms
        t_cross_skew = simulate(crossing, skewed, policy=policy, n_pipelines=2,
                                validate=True).iteration_ms
        t_avoid_skew = simulate(avoiding, skewed, policy=policy, n_pipelines=2,
                                validate=True).iteration_ms
        assert t_cross_skew > 1.5 * t_cross_uni  # skew hurts when crossed
        assert t_avoid_skew < t_cross_skew  # and re-placement recovers it
        assert t_avoid_skew == pytest.approx(
            simulate(avoiding, uniform, policy=policy, n_pipelines=2, validate=True).iteration_ms,
            rel=0.01,
        )


def test_skewed_topology_changes_dc_placement():
    """Algorithm 1 must pick a different DC order on the skewed WAN (the
    slow dc0<->dc2 pair stays off the stage boundaries)."""
    fleet = {"dc0": 8, "dc1": 8, "dc2": 10}  # forces a 3-DC span
    base = JobModel(
        t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        partition_param_bytes=800e6 * 2,
        microbatches=24,
    )
    job_u = dataclasses.replace(
        base,
        topology=tp.TopologyMatrix.uniform(3, 10.0, dc_names=("dc0", "dc1", "dc2")),
    )
    job_s = dataclasses.replace(base, topology=tp.skewed_3dc())

    plan_u = best_plan(algorithm1(job_u, fleet, P=12, C=2))
    plan_s = best_plan(algorithm1(job_s, fleet, P=12, C=2))
    plan_s_fixed = best_plan(algorithm1(job_s, fleet, P=12, C=2, search_orders=False))

    # the skewed plan keeps dc1 between dc0 and dc2
    used = [d for d in plan_s.dc_order if plan_s.partitions.get(d, 0)]
    assert used.index("dc1") == 1, plan_s.dc_order
    # placement differs from the availability order the uniform job uses
    assert plan_s.dc_order != plan_s_fixed.dc_order
    # and topology-aware placement is dramatically faster than ignoring it
    assert plan_s.total_ms < 0.5 * plan_s_fixed.total_ms
    # on the uniform WAN the re-placement buys (essentially) nothing
    assert plan_u.total_ms == pytest.approx(plan_s.total_ms, rel=0.05)


def test_hetero_topology_in_closed_form_matches_simulator_direction():
    """get_latency_pp must rank placements the same way the event-driven
    simulator does on a skewed WAN."""
    sk = tp.skewed_3dc()
    job = JobModel(
        t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        partition_param_bytes=0.0,
        microbatches=8,
        topology=sk,
    )
    part = {"dc0": 1, "dc1": 1, "dc2": 1}
    from repro.core.dc_selection import get_latency_pp

    t_good = get_latency_pp(job, part, ("dc0", "dc1", "dc2"), 1)
    t_bad = get_latency_pp(job, part, ("dc0", "dc2", "dc1"), 1)
    assert t_good < t_bad

    sim_good = simulate(_spec([0, 1, 2]), sk, policy="varuna", validate=True).iteration_ms
    sim_bad = simulate(_spec([0, 2, 1]), sk, policy="varuna", validate=True).iteration_ms
    assert sim_good < sim_bad


def test_asymmetric_links_price_gradients_on_reverse_link():
    """Activations ride a->b, gradients b->a: scheduler, simulator and
    validator must all agree on an asymmetric matrix."""
    from repro.core import temporal
    from repro.core import validate as V

    links = {
        (0, 1): wan.Link(latency_ms=10.0, bw_gbps=5.0),   # act direction
        (1, 0): wan.Link(latency_ms=10.0, bw_gbps=0.5),   # grad direction, 10x slower
    }
    topo = tp.TopologyMatrix.from_links(2, links, name="asym2")
    spec = PipelineSpec(num_stages=2, microbatches=4, t_fwd_ms=10.0,
                        act_bytes=1e8, stage_dc=(0, 1))
    D = 2
    sched = temporal.atlas_schedule(spec, topo, D)
    acts = [tr for tr in sched.transfers if tr.direction == "act"]
    grads = [tr for tr in sched.transfers if tr.direction == "grad"]
    ser_act = 1e8 * 8 / (5.0e9) * 1e3 / D
    ser_grad = 1e8 * 8 / (0.5e9) * 1e3 / D
    assert acts[0].end - acts[0].start == pytest.approx(ser_act, rel=1e-9)
    assert grads[0].end - grads[0].start == pytest.approx(ser_grad, rel=1e-9)
    V.check_schedule(sched, spec, topo)
    V.check_atlas_consistency(spec, topo, n_pipelines=D)
    # the event-driven baseline prices the slow reverse link too: the
    # asymmetric matrix must land strictly between all-fast and all-slow
    fast = tp.TopologyMatrix.from_links(
        2, {(0, 1): links[(0, 1)], (1, 0): links[(0, 1)]}, name="fast2")
    slow = tp.TopologyMatrix.from_links(
        2, {(0, 1): links[(1, 0)], (1, 0): links[(1, 0)]}, name="slow2")
    t_fast = simulate(spec, fast, policy="varuna", validate=True).iteration_ms
    t_asym = simulate(spec, topo, policy="varuna", validate=True).iteration_ms
    t_slow = simulate(spec, slow, policy="varuna", validate=True).iteration_ms
    assert t_fast < t_asym < t_slow


def test_explicit_dc_order_disables_auto_search():
    """A caller-supplied §4.5 ordering (e.g. by cost) must be respected,
    not silently permuted away."""
    fleet = {"dc0": 8, "dc1": 8, "dc2": 10}
    job = JobModel(
        t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        partition_param_bytes=800e6 * 2,
        microbatches=24,
        topology=tp.skewed_3dc(),
    )
    order = ("dc0", "dc2", "dc1")  # deliberately crosses the slow pair
    plans = algorithm1(job, fleet, P=12, C=2, dc_order=order)
    assert all(p.dc_order == order for p in plans)
    # opting in still searches, and finds something strictly better
    searched = best_plan(algorithm1(job, fleet, P=12, C=2, dc_order=order,
                                    search_orders=True))
    assert searched.total_ms < best_plan(plans).total_ms
    # positional (unnamed) topologies refuse the search explicitly
    import dataclasses as dc

    job_unnamed = dc.replace(job, topology=tp.star(3))
    with pytest.raises(ValueError):
        algorithm1(job_unnamed, fleet, P=12, C=2, search_orders=True)


def test_default_C_stays_feasible_on_skewed_topology():
    """Auto-derived C must come from the best WAN pair — sizing it from
    the 150 ms single-TCP bottleneck would make every plan infeasible on
    exactly the skewed WANs the placement search handles."""
    job = JobModel(
        t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        partition_param_bytes=800e6 * 2,
        microbatches=60,
        topology=tp.skewed_3dc(),
    )
    best = best_plan(algorithm1(job, {"dc0": 8, "dc1": 8, "dc2": 10}, P=12))
    assert best.throughput > 0
    assert best.total_ms != float("inf")
    # and the chosen order still routes around the slow dc0<->dc2 pair
    used = [d for d in best.dc_order if best.partitions.get(d, 0)]
    assert used.index("dc1") == 1


def test_wan_projection_helper():
    from repro.launch.dryrun import wan_projection

    out = wan_projection(1e9, "skewed")
    assert out["topology"] == "skewed-3dc"
    assert out["worst_pair_s"] > out["best_pair_s"] > 0
    assert "drift" not in out
    # the reactive control-plane projection: a static plan riding a
    # 10x-degraded boundary pair vs re-planned onto the best alternative
    out = wan_projection(1e9, "azure", drift="outage")
    d = out["drift"]
    assert d["static_s"] > out["best_pair_s"]
    assert d["reactive_s"] < d["static_s"]
    assert d["reactive_speedup"] > 1.0


def test_bandwidth_trace_for_link():
    slow = wan.wan_link(150.0, False)
    fast = wan.wan_link(10.0, True)
    tr_slow = wan.bandwidth_trace_for_link(slow, seed=3)
    tr_fast = wan.bandwidth_trace_for_link(fast, seed=3)
    assert abs(sum(tr_slow) / len(tr_slow) - slow.bw_gbps) < 0.1 * slow.bw_gbps
    # longer path fluctuates less (paper Fig 7)
    assert wan.trace_cov(tr_slow) < wan.trace_cov(tr_fast)
