"""Fig 7 bandwidth-stability model + §6.7 compression negative result +
launcher CLI smoke tests."""
import os
import subprocess
import sys

import pytest

from repro.core import wan
from repro.core.simulator import GeoTopology, PipelineSpec, simulate
from repro.core.simulator import testbed_spec as make_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_fig7_cov_matches_paper_ordering():
    """Longer WAN path fluctuates LESS (paper: 0.8% Asia vs 2.3% US-West)."""
    west = wan.trace_cov(wan.bandwidth_trace_gbps(34))
    asia = wan.trace_cov(wan.bandwidth_trace_gbps(95))
    assert asia < west
    assert 0.002 < asia < 0.02
    assert 0.01 < west < 0.04


def test_fig7_trace_deterministic():
    a = wan.bandwidth_trace_gbps(34, seed=1)
    b = wan.bandwidth_trace_gbps(34, seed=1)
    assert a == b
    c = wan.bandwidth_trace_gbps(34, seed=2)
    assert a != c


def test_trace_seed_folds_in_bandwidth():
    """Regression (ISSUE 3): two links with the same *integer* latency
    but different bandwidth (multi- vs single-TCP at one RTT) must not
    emit perfectly correlated fluctuation patterns."""
    multi = wan.wan_link(34.0, True)   # 5 Gbps
    single = wan.wan_link(34.0, False)  # cwnd-limited
    assert multi.bw_gbps != single.bw_gbps
    a = wan.bandwidth_trace_for_link(multi, seed=1)
    b = wan.bandwidth_trace_for_link(single, seed=1)
    # normalize out the mean: compare the fluctuation *patterns*
    na = [x / multi.bw_gbps for x in a]
    nb = [x / single.bw_gbps for x in b]
    assert na != nb


def test_trace_seed_uses_full_precision_latency():
    """Latencies 34.2 vs 34.9 ms truncate to the same int — their traces
    must still decorrelate."""
    a = wan.bandwidth_trace_for_link(wan.Link(34.2, 5.0), seed=1)
    b = wan.bandwidth_trace_for_link(wan.Link(34.9, 5.0), seed=1)
    assert a != b
    # and a fixed link stays deterministic
    again = wan.bandwidth_trace_for_link(wan.Link(34.2, 5.0), seed=1)
    assert a == again


def test_sec67_compression_is_net_loss():
    """§6.7: 4× activation compression at 2× same-loss compute is slower
    than Atlas's semantics-preserving transport."""
    spec = make_spec(
        hidden=8192, seq_len=6144, micro_batch=1, layers_per_stage=1,
        layer_params=1.2e9, num_stages=4, microbatches=16, stage_dc=[0, 0, 1, 2],
    )
    t = GeoTopology(wan_latency_ms=40, multi_tcp=True)
    atlas = simulate(spec, t, policy="atlas", n_pipelines=3, validate=True).iteration_ms
    comp_spec = PipelineSpec(**{
        **spec.__dict__,
        "act_bytes": spec.act_bytes * wan.COMPRESSION_RATIO,
        "t_fwd_ms": spec.t_fwd_ms * wan.COMPRESSION_COMPUTE_MULT,
    })
    comp = simulate(comp_spec, t, policy="varuna", validate=True).iteration_ms
    assert comp > 1.3 * atlas  # paper: ~2× slowdown; direction must hold


@pytest.mark.slow  # subprocess + full jit compile
@pytest.mark.parametrize(
    "argv",
    [
        ["repro.launch.train", "--arch", "gpt-a", "--smoke", "--steps", "3",
         "--batch", "4", "--seq", "32", "--log-every", "1"],
        ["repro.launch.serve", "--arch", "gpt-a", "--requests", "2",
         "--max-new", "3", "--batch", "2"],
    ],
)
def test_launcher_cli_smoke(argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", *argv], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
