"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED same-family
variant (≤2 layers equivalent, d_model ≤ 512, ≤4 experts), run one
forward/train step on CPU, assert output shapes and no NaNs; plus a
prefill→decode consistency check for the decoder families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # compile-heavy: excluded from tier-1

from repro.configs import ARCHS, get_smoke_config
from repro.data.pipeline import input_batch_for
from repro.models.transformer import build_model
from repro.optim.optimizer import OptimizerConfig, init_opt_state, make_train_step

ASSIGNED = ARCHS[:10]
B, T = 2, 64


def _batch(cfg):
    b = input_batch_for(cfg, B, T, seed=0)
    return {k: jnp.asarray(v) for k, v in b.items()}


def _zeros_cache(model, batch, max_len):
    return jax.tree.map(
        lambda s: jnp.full(s.shape, -1, s.dtype)
        if s.dtype == jnp.int32
        else jnp.zeros(s.shape, s.dtype),
        model.cache_shape(batch, max_len),
    )


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 4
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"

    step = jax.jit(make_train_step(model.loss, OptimizerConfig(total_steps=10)))
    opt = init_opt_state(params)
    params2, opt2, m2 = step(params, opt, batch)
    assert bool(jnp.isfinite(m2["loss"]))
    assert bool(jnp.isfinite(m2["grad_norm"])) and float(m2["grad_norm"]) > 0
    # params must actually move
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params2), jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize(
    "arch",
    [a for a in ASSIGNED if get_smoke_config(a).family not in ("audio",)],
)
def test_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    cache = _zeros_cache(model, B, 2 * T)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(
        params, cache, tok, jnp.full((B,), T, jnp.int32)
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits2)).any()


@pytest.mark.parametrize("arch", ["minitron_4b", "rwkv6_7b", "zamba2_2p7b"])
def test_decode_matches_prefill(arch):
    """Greedy decode at position T must equal prefill over T+1 tokens."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    cache = _zeros_cache(model, B, 2 * T)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks}, cache)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    dec, _ = jax.jit(model.decode_step)(params, cache, nxt, jnp.full((B,), T, jnp.int32))

    cache2 = _zeros_cache(model, B, 2 * T)
    full, _ = jax.jit(model.prefill)(
        params, {"tokens": jnp.concatenate([toks, nxt[:, None]], 1)}, cache2
    )
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=6e-2, rtol=6e-2)


def test_hubert_is_encoder_only():
    cfg = get_smoke_config("hubert_xlarge")
    assert cfg.causal is False
    # masked positions see future context: flipping a late frame changes
    # an early frame's logits (bidirectionality)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    emb = jax.random.normal(jax.random.PRNGKey(1), (1, T, cfg.d_model)) * 0.05
    lab = jnp.zeros((1, T), jnp.int32)

    def frame_logits(e):
        # reuse loss path machinery via prefill-style forward: loss over
        # one-hot targets is enough to propagate; instead check loss diff
        loss, _ = model.loss(params, {"embeds": e, "labels": lab, "mask": jnp.ones((1, T))})
        return loss

    base = frame_logits(emb)
    emb2 = emb.at[0, -1].add(1.0)
    assert abs(float(frame_logits(emb2)) - float(base)) > 0
