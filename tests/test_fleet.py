"""Multi-job fleet sharing one WAN (ISSUE 5) — contention-priced
channels, cross-job re-plan cascades, and the fleet capacity invariant.

Nets:
  * allocator unit behaviour: temporal sharing first (fitting demands
    keep full rate), weighted max-min under oversubscription, the naive
    always-fair-share strawman;
  * two jobs forced onto one pair see fair-share rates end-to-end
    (contended iterations ~2x the solo iteration);
  * a single-job fleet is differentially identical to
    ``control.simulate_horizon`` — same totals, same iteration times,
    same engine stats — with and without the control plane;
  * the cascade: job A's outage-triggered migration lands on a pair job
    B crosses, B's drift detector fires on the contention and B
    re-plans away; a thrash-inducing config terminates under the
    fleet's convergence guard (bounded re-plans per cascade epoch,
    suppressions recorded);
  * ``validate.check_fleet`` holds on every run above and rejects a
    corrupted reservation (negative test);
  * the analytic per-iteration channel demand used by the allocator
    matches the bits the engines actually put on each directed pair
    (``simulator`` ``stats["wan_bits"]`` / ``Schedule.wan_bits``).
"""
import dataclasses

import pytest

from repro.core import control, fleet, temporal
from repro.core import topology as tp
from repro.core import validate as V
from repro.core import wan
from repro.core.dc_selection import JobModel
from repro.core.simulator import simulate


def _world(n=3, names=("a", "b", "c")):
    lat = [[0.0 if i == j else 20.0 for j in range(n)] for i in range(n)]
    return tp.TopologyMatrix.from_latency(lat, multi_tcp=True, dc_names=names)


def _job(**kw):
    kw.setdefault("t_fwd_ms", 10.0)
    kw.setdefault("act_bytes", 1e7)
    kw.setdefault("partition_param_bytes", 2e8)
    kw.setdefault("microbatches", 24)
    return JobModel(**kw)


# ------------------------------------------------------------- allocator


def test_weighted_max_min_water_fill():
    # equal weights, symmetric overload: the full unit splits evenly
    assert fleet._weighted_max_min([("a", 0.9, 1.0), ("b", 0.9, 1.0)]) == {
        "a": 0.5, "b": 0.5}
    # a small demand is satisfied exactly, slack goes to the big one
    alloc = fleet._weighted_max_min([("a", 0.9, 1.0), ("b", 0.2, 1.0)])
    assert alloc["b"] == 0.2
    assert alloc["a"] == pytest.approx(0.8)
    # weights split the contested capacity proportionally
    alloc = fleet._weighted_max_min([("a", 1.0, 2.0), ("b", 1.0, 1.0)])
    assert alloc["a"] == pytest.approx(2.0 / 3.0)
    assert alloc["b"] == pytest.approx(1.0 / 3.0)


def test_channel_targets_temporal_first_then_fair_share():
    topo = _world(2, ("a", "b"))
    cap = topo.effective_bw_gbps(0, 1)
    # fitting demands: both keep full rate (targets = needs)
    dem = {"A": {(0, 1): 0.4 * cap}, "B": {(0, 1): 0.5 * cap}}
    tg = fleet.channel_targets(dem, {}, topo)
    assert tg["A"][(0, 1)] == (0.4 * cap, 0.4 * cap, None)
    assert tg["B"][(0, 1)] == (0.5 * cap, 0.5 * cap, None)
    # oversubscribed: weighted max-min (the whole channel is granted)
    dem = {"A": {(0, 1): 0.9 * cap}, "B": {(0, 1): 0.9 * cap}}
    tg = fleet.channel_targets(dem, {}, topo)
    assert tg["A"][(0, 1)][1] == pytest.approx(0.5 * cap)
    assert tg["B"][(0, 1)][1] == pytest.approx(0.5 * cap)
    # the naive strawman pins the rate multiplier even when demand fits
    dem = {"A": {(0, 1): 0.4 * cap}, "B": {(0, 1): 0.2 * cap}}
    tg = fleet.channel_targets(dem, {}, topo, sharing="fair")
    assert tg["A"][(0, 1)][2] == pytest.approx(0.5)
    assert tg["B"][(0, 1)][2] == pytest.approx(0.5)
    # a lone demander is never throttled, in either mode
    tg = fleet.channel_targets({"A": {(0, 1): 2.0 * cap}}, {}, topo, sharing="fair")
    assert tg["A"][(0, 1)] == (cap, cap, None)


def test_fleet_job_validates_weight_and_budget():
    duo, gpus = _world(2, ("a", "b")), {"a": 2, "b": 2}
    with pytest.raises(AssertionError):
        fleet.FleetJob("A", _job(), gpus, P=4, n_iterations=8, weight=0.0)
    with pytest.raises(AssertionError):
        fleet.FleetJob("A", _job(), gpus, P=4, n_iterations=8, weight=-1.0)
    with pytest.raises(AssertionError):
        fleet.FleetJob("A", _job(), gpus, P=4, n_iterations=0)


def test_demand_matches_engine_wan_bits():
    """The allocator's analytic per-iteration demand must count exactly
    the bits the engines put on each directed pair."""
    topo = _world()
    spec = control.plan_spec(
        _job(),
        control.best_plan(control.algorithm1(
            dataclasses.replace(_job(), topology=topo),
            {"a": 2, "b": 2, "c": 2}, 6, C=1)),
        topo,
    )
    res = simulate(spec, topo, policy="atlas", n_pipelines=2, validate=True)
    sched = temporal.atlas_schedule(spec, topo, 2)
    rates = fleet.pair_demand_rates(spec, 2, 1000.0)
    bits = {p: r * 1000.0 * 1e6 for p, r in rates.items()}
    assert bits == res.stats["wan_bits"]
    assert bits == sched.wan_bits(spec)


# ------------------------------------------- contention, end to end


def _duo():
    return _world(2, ("a", "b")), {"a": 2, "b": 2}


def test_two_jobs_on_one_pair_see_fair_share_rates():
    """Both jobs' pipelines cross the single (a, b) pair with demands
    that cannot serialize: each must run at ~half rate, and the ledger
    must respect the pair's capacity throughout."""
    duo, gpus = _duo()
    job = _job(act_bytes=2e8)
    solo = control.simulate_horizon(job, gpus, P=4, live_topo=duo,
                                    n_iterations=8, C=1, validate=True)
    fj = lambda n: fleet.FleetJob(n, job, gpus, P=4, n_iterations=8, C=1)  # noqa: E731
    fr = fleet.simulate_fleet([fj("A"), fj("B")], duo, validate=True)
    for name in ("A", "B"):
        hr = fr.jobs[name]
        # the shared channel is the bottleneck: contended iterations run
        # well above solo (→ 2x as transfers dominate)
        assert hr.iteration_times[0] > 1.5 * solo.iteration_times[0]
        assert fr.stats["per_job"][name]["throttled_iterations"] == 8
    # reservations exist on both directions and stay within capacity
    pairs = {r.pair for r in fr.reservations}
    assert (0, 1) in pairs and (1, 0) in pairs
    assert all(r.mult < 1.0 for r in fr.reservations)
    V.check_fleet(fr, duo)


def test_temporal_sharing_beats_naive_fair_share():
    """Demands that fit the channel together: temporal sharing keeps
    both jobs at solo speed; the always-fair-share strawman halves both
    jobs' rates anyway and loses end-to-end."""
    duo, gpus = _duo()
    job = _job(act_bytes=2e7)
    solo = control.simulate_horizon(job, gpus, P=4, live_topo=duo,
                                    n_iterations=8, C=1, validate=True)
    fj = lambda n: fleet.FleetJob(n, job, gpus, P=4, n_iterations=8, C=1)  # noqa: E731
    tmp = fleet.simulate_fleet([fj("A"), fj("B")], duo, validate=True)
    fair = fleet.simulate_fleet([fj("A"), fj("B")], duo,
                                config=fleet.FleetConfig(sharing="fair"),
                                validate=True)
    assert tmp.total_ms == solo.total_ms  # nobody throttled
    assert all(v["throttled_iterations"] == 0
               for v in tmp.stats["per_job"].values())
    assert fair.total_ms > tmp.total_ms
    assert fair.jobs["A"].total_ms > tmp.jobs["A"].total_ms
    assert fair.jobs["B"].total_ms > tmp.jobs["B"].total_ms


def test_single_job_fleet_identical_to_simulate_horizon():
    """The degenerate fleet must be differentially identical to the
    single-job horizon simulator — static and reactive arms alike."""
    world = _world()
    bw = world.link(0, 1).bw_gbps
    live = world.with_bandwidth_schedules({
        (0, 1): wan.BandwidthSchedule.outage(bw, 10_000.0, 200_000.0, bw / 10.0),
        (1, 0): wan.BandwidthSchedule.flat(bw),
    })
    job = _job()
    gpus = {"a": 4, "b": 4, "c": 4}
    for ctrl in (None, control.ControlConfig()):
        hr = control.simulate_horizon(
            job, gpus, P=10, live_topo=live, planned_topo=world,
            n_iterations=40, C=1, control=ctrl, validate=True)
        fr = fleet.simulate_fleet(
            [fleet.FleetJob("solo", job, gpus, P=10, n_iterations=40, C=1,
                            planned_topo=world, control=ctrl)],
            live, validate=True)
        got = fr.jobs["solo"]
        assert got.total_ms == hr.total_ms
        assert got.iteration_times == hr.iteration_times
        assert got.replans == hr.replans
        assert len(got.migrations) == len(hr.migrations)
        for a, b in zip(got.migrations, hr.migrations):
            assert a.at_ms == b.at_ms and a.duration_ms == b.duration_ms
        assert got.stats["iter_sims"] == hr.stats["iter_sims"]
        assert got.stats["iter_reused"] == hr.stats["iter_reused"]
        # a lone job never contends: every view is the live topology
        assert all(res.mult == 1.0 for res in fr.reservations)
    V.check_horizon(fr.jobs["solo"], live)


# ----------------------------------------------------------- the cascade


def _cascade_fleet(**cfg_kw):
    """Job A spans a,b,c; job B spans a,c,d.  An unplanned outage on
    a->b pushes A onto the (a,c) pair B crosses — the contention then
    pushes B over its drift threshold."""
    world = _world(4, ("a", "b", "c", "d"))
    bw = world.link(0, 1).bw_gbps
    live = world.with_bandwidth_schedules({
        (0, 1): wan.BandwidthSchedule.outage(bw, 20_000.0, 1e9, bw / 10.0),
    })
    job = _job(act_bytes=1.2e8)
    fjA = fleet.FleetJob("A", job, {"a": 2, "b": 2, "c": 2}, P=6,
                         n_iterations=60, C=1, planned_topo=world,
                         control=control.ControlConfig())
    fjB = fleet.FleetJob("B", job, {"a": 2, "c": 2, "d": 2}, P=6,
                         n_iterations=60, C=1, planned_topo=world,
                         control=control.ControlConfig())
    cfg = fleet.FleetConfig(**cfg_kw) if cfg_kw else None
    return world, live, fleet.simulate_fleet([fjA, fjB], live, config=cfg,
                                             validate=True)


def test_cascade_a_migrates_b_drifts_b_replans():
    world, live, fr = _cascade_fleet()
    A, B = fr.jobs["A"], fr.jobs["B"]
    # A re-planned around the outage (off the a->b pair)...
    assert A.replans == 1
    a1 = set(zip(A.epochs[1].spec.stage_dc, A.epochs[1].spec.stage_dc[1:]))
    assert (0, 1) not in a1
    # ... onto (a, c), which B was crossing: B drifted on the contention
    # and re-planned away from the now-shared pair
    assert (0, 2) in a1
    assert B.replans == 1
    assert B.migrations[0].at_ms > A.migrations[0].at_ms
    b1 = set(zip(B.epochs[1].spec.stage_dc, B.epochs[1].spec.stage_dc[1:]))
    assert (0, 2) not in b1
    assert fr.stats["per_job"]["B"]["throttled_iterations"] > 0
    # contention cleared after the cascade: both finish, invariant holds
    assert A.samples == B.samples
    V.check_fleet(fr, live)


def test_cascade_guard_bounds_replan_thrash():
    """A hair-trigger control config (zero-ish threshold, no cooldown,
    hysteresis 1, negative migration margin) makes two jobs chase each
    other between the pairs of a 3-DC WAN; the fleet guard caps
    migrations per cascade epoch, records suppressions, and the horizon
    still terminates with both sample budgets met."""
    world = _world()
    gpus = {"a": 2, "b": 2, "c": 2}
    job = _job(act_bytes=2e8)
    trigger = control.ControlConfig(
        drift_threshold=1e-6, hysteresis=1, cooldown_iterations=0,
        min_gain_ms=-1e15)  # negative margin: any candidate "pays off"
    fj = lambda n: fleet.FleetJob(n, job, gpus, P=4, n_iterations=10, C=1,  # noqa: E731
                                  control=trigger)
    guarded = fleet.simulate_fleet(
        [fj("A"), fj("B")], world,
        config=fleet.FleetConfig(max_cascade_replans=1), validate=True)
    assert guarded.stats["cascade_suppressed"] > 0
    spi = {n: guarded.jobs[n].epochs[0].samples_per_iteration for n in ("A", "B")}
    for name in ("A", "B"):
        hr = guarded.jobs[name]
        assert hr.samples == 10 * spi[name]  # the budget completed
        assert hr.stats["replans_suppressed"] > 0 or hr.replans <= 1
    # with a large budget the same config thrashes far more — the cap
    # is what bounded the guarded run
    thrash = fleet.simulate_fleet(
        [fj("A"), fj("B")], world,
        config=fleet.FleetConfig(max_cascade_replans=100), validate=True)
    assert thrash.stats["cascade_suppressed"] == 0
    assert thrash.replans > guarded.replans


# -------------------------------------------------- invariant (negative)


def test_check_fleet_rejects_oversubscribed_reservation():
    duo, gpus = _duo()
    job = _job(act_bytes=2e8)
    fj = lambda n: fleet.FleetJob(n, job, gpus, P=4, n_iterations=6, C=1)  # noqa: E731
    fr = fleet.simulate_fleet([fj("A"), fj("B")], duo, validate=True)
    V.check_fleet(fr, duo)  # honest ledger passes
    # claim one window ran at 10x its grant: the aggregate on that
    # channel now exceeds the capacity in force
    victim = next(r for r in fr.reservations if r.mult < 1.0)
    victim.rate_gbps *= 10.0
    with pytest.raises(V.InvariantViolation):
        V.check_fleet(fr, duo)


def test_check_fleet_rejects_inverted_window():
    duo, gpus = _duo()
    fr = fleet.simulate_fleet(
        [fleet.FleetJob("A", _job(), gpus, P=4, n_iterations=2, C=1)], duo, validate=True)
    fr.reservations[0].t1_ms = fr.reservations[0].t0_ms - 1.0
    with pytest.raises(V.InvariantViolation):
        V.check_fleet(fr, duo)


# ------------------------------------------- contended topology views


def test_with_rate_multipliers_scales_one_direction_only():
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    sched = wan.BandwidthSchedule.step(bw, bw / 2.0, 100.0)
    topo = base.with_bandwidth_schedules({(0, 1): sched})
    c = topo.with_rate_multipliers({(0, 1): 0.25})
    assert c.link(0, 1).bw_gbps == pytest.approx(0.25 * bw)
    assert c.link(1, 0).bw_gbps == pytest.approx(bw)
    # the reverse direction kept the *unscaled* schedule, even though in
    # the source topology it was served by reverse-pair fallback
    assert topo.bandwidth_schedule(1, 0) is sched
    assert c.bandwidth_schedule(0, 1).bw_gbps == tuple(
        0.25 * b for b in sched.bw_gbps)
    assert c.bandwidth_schedule(1, 0).bw_gbps == sched.bw_gbps
    # identity short-circuits
    assert topo.with_rate_multipliers({}) is topo
    assert topo.with_rate_multipliers({(0, 1): 1.0}) is topo
    assert sched.scaled(1.0) is sched


def test_contended_schedule_prices_transfers_slower():
    """With the channel dominating the steady-state slot, halving the
    granted rate must lengthen the iteration in every engine."""
    spec_topo = _world(2, ("a", "b"))
    job = _job(act_bytes=2e8)  # ser ≈ 320 ms ≫ the 30 ms compute slot
    plan = control.best_plan(control.algorithm1(
        dataclasses.replace(job, topology=spec_topo), {"a": 2, "b": 2}, 4, C=1))
    spec = control.plan_spec(job, plan, spec_topo)
    contended = spec_topo.with_rate_multipliers({(0, 1): 0.5, (1, 0): 0.5})
    for policy in ("varuna", "atlas"):
        full = simulate(spec, spec_topo, policy=policy, n_pipelines=1, validate=True)
        half = simulate(spec, contended, policy=policy, n_pipelines=1, validate=True)
        assert half.iteration_ms > full.iteration_ms * 1.5
