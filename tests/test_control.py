"""Reactive control plane (ISSUE 4) — drift detection, re-planning,
migration pricing, transfer preemption, and the horizon co-simulator.

Nets:
  * ``BandwidthSchedule`` preemption primitives: a transfer split at any
    point (bits kept, remainder re-integrated) reproduces the unsplit
    integration exactly — differential against single-segment pricing;
    ``period_ms`` wraparound replays a trace cyclically instead of
    freezing its last sample.
  * ``simulate(..., start_ms=...)``: flat/static topologies are
    offset-invariant (interval-identical), time-varying transfers are
    priced by the segments in force at the absolute offset, and the
    schedule checker rejects an honest schedule validated at the wrong
    offset.
  * The control plane: on a sustained one-direction 10× outage the
    reactive horizon beats the static plan end-to-end *including* the
    migration stall; a pure-diurnal trace the planner knew about never
    re-plans (hysteresis); every per-epoch plan passes
    ``validate.check_schedule`` via ``check_horizon``; the horizon-level
    iteration reuse is differentially identical to simulating every
    iteration.
"""
import dataclasses
import math

import pytest

from repro.core import control, temporal
from repro.core import topology as tp
from repro.core import validate as V
from repro.core import wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.fastforward import GATE_REPLAN_EPOCH, fast_forward_gate
from repro.core.simulator import PipelineSpec, simulate


def _world():
    lat = [[0.0, 20.0, 20.0], [20.0, 0.0, 20.0], [20.0, 20.0, 0.0]]
    return tp.TopologyMatrix.from_latency(
        lat, multi_tcp=True, dc_names=("a", "b", "c"))


def _job(**kw):
    kw.setdefault("t_fwd_ms", 10.0)
    kw.setdefault("act_bytes", 1e7)
    kw.setdefault("partition_param_bytes", 2e8)
    kw.setdefault("microbatches", 24)
    return JobModel(**kw)


def _outage_live(world, start_ms=10_000.0, end_ms=200_000.0, factor=10.0):
    """One direction a->b drops ``factor``x for a sustained window; the
    reverse direction is pinned flat (single-direction outage)."""
    bw = world.link(0, 1).bw_gbps
    return world.with_bandwidth_schedules({
        (0, 1): wan.BandwidthSchedule.outage(bw, start_ms, end_ms, bw / factor),
        (1, 0): wan.BandwidthSchedule.flat(bw),
    })


# ---------------------------------------------------- preemption primitives


def test_preempt_differential_against_single_segment_pricing():
    """Splitting a transfer at any cut — bits already sent kept, the
    remainder re-integrated from the cut — must reproduce the unsplit
    integration exactly, including cuts exactly on a segment boundary."""
    s = wan.BandwidthSchedule((0.0, 10.0, 30.0), (1.0, 0.25, 2.0))
    nbytes = 40e6 / 8.0
    whole = s.transfer_ms(nbytes, 0.0)
    for cut in (1.0, 10.0, 15.0, 30.0, 42.0):
        sent, rem = s.preempt(nbytes, 0.0, cut)
        assert sent + rem == pytest.approx(nbytes, rel=1e-12)
        if rem <= 0:
            continue
        resumed = cut + s.transfer_ms(rem, cut)
        assert resumed == pytest.approx(whole, rel=1e-12), cut
    # each leg individually matches single-segment pricing: 10 ms at
    # 1 Gbps sends 10e6 bits; the remaining 30e6 bits take the whole
    # 0.25 Gbps segment (5e6 bits over 20 ms) + 25e6 bits at 2 Gbps
    sent, rem = s.preempt(nbytes, 0.0, 10.0)
    assert sent == pytest.approx(10e6 / 8.0)
    assert s.transfer_ms(rem, 10.0) == pytest.approx(20.0 + 25e6 / 2e6)


def test_preempt_with_rate_mult_and_bits_cap():
    s = wan.BandwidthSchedule.step(1.0, 0.5, 10.0)
    nbytes = 15e6 / 8.0
    # at 2x rate the whole transfer fits the first segment
    assert s.bits_sent(nbytes, 0.0, 10.0, rate_mult=2.0) == nbytes * 8.0
    sent, rem = s.preempt(nbytes, 0.0, 1e9)
    assert sent == nbytes and rem == 0.0
    assert s.bits_sent(nbytes, 5.0, 5.0) == 0.0  # empty window


def test_transfer_ms_start_exactly_at_segment_boundary():
    """start_ms == times_ms[i]: the transfer prices entirely in the new
    segment (segments are [t_i, t_i+1))."""
    s = wan.BandwidthSchedule.step(1.0, 0.5, 10.0)
    nbytes = 5e6 / 8.0  # 5e6 bits
    assert s.transfer_ms(nbytes, 10.0) == pytest.approx(10.0)  # 0.5 Gbps
    # one epsilon earlier still rides the fast segment for that epsilon
    eps = 1e-3
    assert s.transfer_ms(nbytes, 10.0 - eps) == pytest.approx(
        eps + (5e6 - eps * 1e6) / 0.5e6, rel=1e-9)
    assert s.bw_at(10.0) == 0.5 and s.bw_at(10.0 - 1e-6) == 1.0


def test_period_wraparound():
    d = wan.BandwidthSchedule.diurnal(5.0, 2.5, period_ms=24.0, steps=8)
    assert d.period_ms == 24.0
    assert d.bw_at(24.0 + 3.0) == d.bw_at(3.0)
    assert d.bw_at(24.0 * 7 + 3.0) == d.bw_at(3.0)
    # a transfer spanning many cycles moves at the cycle-mean rate
    mean = (5.0 + 2.5) / 2.0
    ten_cycles_bytes = mean * 1e6 * 24.0 * 10 / 8.0
    assert d.transfer_ms(ten_cycles_bytes, 0.0) == pytest.approx(240.0, rel=1e-6)
    assert d.mean_bw_gbps(0.0, 24.0) == pytest.approx(mean)
    assert d.mean_bw_gbps(24.0, 48.0) == pytest.approx(mean)
    assert d.constant_over(24.5, 26.9) and not d.constant_over(24.5, 27.5)


def test_period_set_by_trace_and_diurnal_not_by_oneshot_profiles():
    link = wan.wan_link(34.0, True)
    tr = wan.BandwidthSchedule.from_trace(link, hours=2.0, samples_per_hour=4)
    assert tr.period_ms == 2 * 3.6e6
    assert tr.bw_at(2 * 3.6e6 + 50.0) == tr.bw_at(50.0)  # day 2 == day 1
    assert wan.BandwidthSchedule.flat(5.0).period_ms is None
    assert wan.BandwidthSchedule.step(5.0, 1.0, 10.0).period_ms is None
    o = wan.BandwidthSchedule.outage(5.0, 10.0, 20.0, 0.5)
    assert o.period_ms is None
    assert o.bw_at(1e12) == 5.0  # one-shot: holds the last segment forever
    with pytest.raises(AssertionError):
        wan.BandwidthSchedule((0.0, 10.0), (1.0, 2.0), period_ms=10.0)


# ----------------------------------------------------- start_ms threading


def test_simulate_start_ms_offset_invariant_on_flat_and_static():
    spec = PipelineSpec(num_stages=4, microbatches=12, t_fwd_ms=10.0,
                        act_bytes=1.5e8, stage_dc=(0, 0, 1, 2),
                        stage_param_bytes=8e8)
    base = tp.azure_testbed()
    flat = base.with_bandwidth_schedules({
        (a, b): wan.BandwidthSchedule.flat(base.link(a, b).bw_gbps)
        for a, b in base.wan_pairs()})
    for topo in (base, flat):
        for policy in ("varuna", "atlas"):
            r0 = simulate(spec, topo, policy=policy, n_pipelines=2,
                          start_ms=0.0, validate=True)
            r1 = simulate(spec, topo, policy=policy, n_pipelines=2,
                          start_ms=9.9e8, validate=True)
            V.check_equivalent(r0, r1)


def test_simulate_start_ms_prices_segment_in_force():
    spec = PipelineSpec(num_stages=4, microbatches=12, t_fwd_ms=10.0,
                        act_bytes=1.5e8, stage_dc=(0, 0, 1, 2),
                        stage_param_bytes=8e8)
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    step = base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.step(bw, bw / 4.0, 5_000.0)})
    for policy in ("varuna", "atlas"):
        fast = simulate(spec, step, policy=policy, n_pipelines=2,
                        start_ms=0.0, validate=True)
        slow = simulate(spec, step, policy=policy, n_pipelines=2,
                        start_ms=1e6, validate=True)
        assert slow.iteration_ms > fast.iteration_ms


def test_check_schedule_rejects_wrong_offset():
    """An honest schedule computed in the degraded segment claims
    occupancies 4x longer than the nominal rate needs; the same
    schedule validated as if it ran pre-step (or vice versa) must
    fail — offsets are part of the physics."""
    spec = PipelineSpec(num_stages=4, microbatches=10, t_fwd_ms=10.0,
                        act_bytes=1.5e8, stage_dc=(0, 0, 1, 2),
                        stage_param_bytes=8e8)
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    step = base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.step(bw, bw / 4.0, 5_000.0)})
    sched0 = temporal.atlas_schedule(spec, step, 2, start_ms=0.0)
    V.check_schedule(sched0, spec, step, start_ms=0.0)
    with pytest.raises(V.InvariantViolation):
        V.check_schedule(sched0, spec, step, start_ms=1e6)
    V.check_atlas_consistency(spec, step, n_pipelines=2, dp_replicas=2,
                              start_ms=123_456.0)


def test_replan_epoch_gate():
    spec = PipelineSpec(num_stages=2, microbatches=4, t_fwd_ms=1.0,
                        act_bytes=1e6, stage_dc=(0, 1))
    topo = _world()
    assert fast_forward_gate(spec, topo) is None
    assert fast_forward_gate(spec, topo, epoch_boundary=True) == GATE_REPLAN_EPOCH


# -------------------------------------------------------- drift detection


def test_drift_detector_hysteresis_and_reset():
    det = control.DriftDetector(control.ControlConfig(
        drift_threshold=0.2, hysteresis=3))
    assert not det.observe(0.5)
    assert not det.observe(0.5)
    assert det.observe(0.5)  # third consecutive fires
    assert not det.observe(0.5)  # streak reset after a fire
    assert not det.observe(0.5)
    assert not det.observe(0.1)  # one calm iteration clears the streak
    assert not det.observe(0.5)
    assert not det.observe(0.5)
    assert not det.observe(0.1)
    assert det.fires == 1


def test_link_deviation_zero_when_plan_knew_the_trace():
    world = _world()
    di = world.with_bandwidth_schedules({
        (a, b): wan.BandwidthSchedule.diurnal(
            world.link(a, b).bw_gbps, 0.6 * world.link(a, b).bw_gbps,
            period_ms=20_000.0)
        for a, b in world.wan_pairs()})
    assert control.link_deviation(di, di, 3_000.0, 8_000.0) == 0.0
    # ... but large vs the static nominal assumption at the trough
    # (diurnal capacity bottoms at the cycle edges)
    dev = control.link_deviation(di, world, 0.0, 2_500.0)
    assert dev > 0.2


# ------------------------------------------------------- migration pricing


def test_plan_migration_serializes_per_pair_and_prices_live_schedule():
    world = _world()
    bw = world.link(1, 2).bw_gbps
    live = world.with_bandwidth_schedules(
        {(1, 2): wan.BandwidthSchedule.flat(bw / 2.0),  # b->c delivers bw/2
         (2, 1): wan.BandwidthSchedule.flat(bw)})  # reverse stays nominal
    pb = 2e8
    model = control.MigrationModel(opt_state_mult=2.0)
    sb = model.stage_bytes(pb)
    assert sb == pytest.approx(3 * pb)
    ev = control.plan_migration(
        (0, 1, 1, 2), (0, 2, 2, 1),
        param_bytes=pb, dp_replicas_old=2, dp_replicas_new=2,
        topo=live, at_ms=1_000.0, model=model)
    # stages 1, 2 move b->c (serialize at bw/2), stage 3 moves c->b (parallel)
    assert ev.moves == [(1, 1, 2), (2, 1, 2), (3, 2, 1)]
    ser_bc = sb * 8.0 / (bw / 2.0 * 1e9) * 1e3
    ser_cb = sb * 8.0 / (bw * 1e9) * 1e3
    bc = sorted(t for t in ev.transfers if (t[0], t[1]) == (1, 2))
    assert len(bc) == 2
    assert bc[0][2] == pytest.approx(1_000.0)
    assert bc[1][2] == pytest.approx(bc[0][3])  # serialized back-to-back
    assert bc[0][3] - bc[0][2] == pytest.approx(ser_bc)
    lat = live.link(1, 2).latency_ms
    intra_one = sb * 8.0 / (live.intra_bw_gbps * 1e9) * 1e3
    # slowest pair (2 serialized b->c moves) + latency + fan-out of the
    # two stages landing in DC c to the second replica
    want = 2 * ser_bc + lat + 2 * intra_one
    assert ev.duration_ms == pytest.approx(want)
    assert ev.wan_bytes == pytest.approx(3 * sb)
    assert ser_cb < ser_bc  # the parallel pair is not the critical path


def test_plan_migration_pure_D_change_pays_fanout_only():
    world = _world()
    ev = control.plan_migration(
        (0, 1, 2), (0, 1, 2),
        param_bytes=2e8, dp_replicas_old=2, dp_replicas_new=4,
        topo=world, at_ms=0.0, model=control.MigrationModel())
    assert ev.moves == [] and ev.transfers == []
    intra_one = ev.bytes_per_stage * 8.0 / (world.intra_bw_gbps * 1e9) * 1e3
    assert ev.duration_ms == pytest.approx(2 * intra_one)  # 2 extra replicas


# ------------------------------------------------------ warm-started bnb


def test_warm_started_bnb_matches_cold_and_keeps_incumbent_on_ties():
    world = _world()  # fully symmetric: every order is cost-equal
    job = _job(topology=world)
    fleet = {"a": 4, "b": 4, "c": 4}
    cold = best_plan(algorithm1(job, fleet, P=10, C=1))
    warm_same = best_plan(algorithm1(job, fleet, P=10, C=1,
                                     incumbent_order=cold.dc_order))
    assert warm_same.dc_order == cold.dc_order
    assert warm_same.total_ms == pytest.approx(cold.total_ms)
    # a cost-equal non-lex-first incumbent is kept (no gratuitous move)
    warm = best_plan(algorithm1(job, fleet, P=10, C=1,
                                incumbent_order=("b", "a", "c")))
    assert warm.dc_order[:3] == ("b", "a", "c")
    assert warm.total_ms == pytest.approx(cold.total_ms)
    # on a skewed WAN the warm start must not mask a strictly better order
    skew = tp.skewed_3dc()
    job_s = _job(topology=skew)
    fleet_s = {"dc0": 16, "dc1": 16, "dc2": 20}
    cold_s = best_plan(algorithm1(job_s, fleet_s, P=40, C=1))
    warm_s = best_plan(algorithm1(job_s, fleet_s, P=40, C=1,
                                  incumbent_order=("dc0", "dc2", "dc1")))
    assert warm_s.total_ms == pytest.approx(cold_s.total_ms)
    assert warm_s.dc_order == cold_s.dc_order


# --------------------------------------------------- the horizon simulator


def _horizon_pair(n_iterations=80, **ctrl_kw):
    world = _world()
    live = _outage_live(world)
    job = _job()
    fleet = {"a": 4, "b": 4, "c": 4}
    static = control.simulate_horizon(
        job, fleet, P=10, live_topo=live, planned_topo=world,
        n_iterations=n_iterations, C=1, validate=True)
    reactive = control.simulate_horizon(
        job, fleet, P=10, live_topo=live, planned_topo=world,
        n_iterations=n_iterations, C=1,
        control=control.ControlConfig(**ctrl_kw), validate=True)
    return world, live, job, static, reactive


def test_reactive_beats_static_on_sustained_outage():
    """The acceptance scenario: one direction drops 10x mid-horizon for
    a sustained window.  The control plane detects the drift, re-plans
    around the degraded pair, pays the migration, and still finishes
    the same sample budget sooner than the static plan."""
    world, live, job, static, reactive = _horizon_pair()
    assert static.replans == 0
    assert reactive.replans >= 1
    assert reactive.migration_ms > 0
    assert reactive.total_ms < static.total_ms
    assert reactive.samples == static.samples  # same work, end-to-end
    # the re-planned epoch routes around the degraded a->b pair
    ep = reactive.epochs[1]
    boundaries = set(zip(ep.spec.stage_dc, ep.spec.stage_dc[1:]))
    assert (0, 1) not in boundaries
    # drift was sustained, detection respected the hysteresis
    assert reactive.stats["drift_fires"] >= 1


def test_horizon_passes_check_horizon_and_negative():
    world, live, job, static, reactive = _horizon_pair()
    V.check_horizon(static, live)
    V.check_horizon(reactive, live)
    # corrupt one migration transfer to run faster than the live link
    m = reactive.migrations[0]
    src, dst, s, e = m.transfers[0]
    m.transfers[0] = (src, dst, s, s + (e - s) * 0.2)
    with pytest.raises(V.InvariantViolation):
        V.check_horizon(reactive, live)


def test_horizon_never_replans_on_planned_diurnal():
    """Hysteresis acceptance: the planner knew the diurnal trace, so
    delivery never deviates from the plan's assumption and the control
    plane must not thrash."""
    world = _world()
    di = world.with_bandwidth_schedules({
        (a, b): wan.BandwidthSchedule.diurnal(
            world.link(a, b).bw_gbps, 0.6 * world.link(a, b).bw_gbps,
            period_ms=20_000.0)
        for a, b in world.wan_pairs()})
    r = control.simulate_horizon(
        _job(), {"a": 4, "b": 4, "c": 4}, P=10, live_topo=di,
        n_iterations=30, C=1,
        control=control.ControlConfig(drift_threshold=0.15, hysteresis=2), validate=True)
    assert r.replans == 0
    assert r.stats["drift_fires"] == 0
    assert r.stats["drift_iterations"] == 0


def test_horizon_reuse_differential_against_per_iteration_simulation():
    """The horizon-level iteration reuse must be invisible: the total is
    identical to simulating every iteration at its own offset."""
    world = _world()
    live = _outage_live(world, start_ms=8_000.0, end_ms=60_000.0)
    job = _job()
    fleet = {"a": 4, "b": 4, "c": 4}
    n = 24
    static = control.simulate_horizon(
        job, fleet, P=10, live_topo=live, planned_topo=world,
        n_iterations=n, C=1, validate=True)
    assert static.stats["iter_reused"] > 0  # the cache did engage
    assert static.stats["iter_sims"] + static.stats["iter_reused"] == n
    ep = static.epochs[0]
    t = 0.0
    for _ in range(n):
        res = simulate(ep.spec, live, policy="atlas",
                       n_pipelines=ep.n_pipelines,
                       dp_replicas_for_allreduce=ep.dp_replicas, start_ms=t, validate=True)
        t += res.iteration_ms
    assert static.total_ms == pytest.approx(t, rel=1e-12)
    assert len(static.iteration_times) == n


def test_horizon_epoch_gates_recorded():
    _world_, live, job, static, reactive = _horizon_pair()
    gates = reactive.stats["fast_forward_gates"]
    assert GATE_REPLAN_EPOCH in gates  # first post-migration iteration
    assert static.stats["fast_forward_gates"].get(GATE_REPLAN_EPOCH) is None


def test_migration_cost_can_veto_a_switch():
    """With an enormous migration margin the re-planner must decline:
    no migration happens and the horizon equals the static arm."""
    world = _world()
    live = _outage_live(world)
    job = _job()
    fleet = {"a": 4, "b": 4, "c": 4}
    r = control.simulate_horizon(
        job, fleet, P=10, live_topo=live, planned_topo=world,
        n_iterations=40, C=1,
        control=control.ControlConfig(min_gain_ms=1e12), validate=True)
    assert r.replans == 0
    assert r.stats["replans_declined"] >= 1
    s = control.simulate_horizon(
        job, fleet, P=10, live_topo=live, planned_topo=world,
        n_iterations=40, C=1, validate=True)
    assert r.total_ms == pytest.approx(s.total_ms, rel=1e-12)


def test_zero_iteration_horizon_simulates_nothing():
    """n_iterations=0: the budget is exhausted before the first
    iteration — no phantom simulation, no recorded iteration."""
    world = _world()
    r = control.simulate_horizon(
        _job(), {"a": 4, "b": 4, "c": 4}, P=10, live_topo=world,
        n_iterations=0, C=1, validate=True)
    assert r.total_ms == 0.0
    assert r.iteration_times == []
    assert r.epochs[0].iterations == 0
    assert r.stats["iter_sims"] == 0 and r.stats["iter_reused"] == 0


def test_snapshot_observes_live_rates():
    world = _world()
    live = _outage_live(world, start_ms=1_000.0, end_ms=5_000.0)
    bw = world.link(0, 1).bw_gbps
    during = live.snapshot(2_000.0)
    after = live.snapshot(6_000.0)
    assert during.link(0, 1).bw_gbps == pytest.approx(bw / 10.0)
    assert during.link(1, 0).bw_gbps == pytest.approx(bw)  # pinned flat
    assert after.link(0, 1).bw_gbps == pytest.approx(bw)
    assert not during.bw_schedules  # static snapshot
    # trailing-window mean smooths across the outage edge
    win = live.snapshot(6_000.0, window_ms=2_000.0)
    mid = (bw / 10.0 + bw) / 2.0
    assert win.link(0, 1).bw_gbps == pytest.approx(mid)
    assert during.link(0, 2).latency_ms == world.link(0, 2).latency_ms
