"""Discrete-event simulator — reproduces the paper's §3/§6 claims."""
import pytest

from repro.core.simulator import (
    GeoTopology,
    PipelineSpec,
    dp_iteration_ms,
    simulate,
)
from repro.core.simulator import testbed_spec as make_spec
from repro.core import wan

GPT_B = dict(hidden=8192, seq_len=6144, micro_batch=1, layers_per_stage=1,
             layer_params=1.2e9)
GPT_A = dict(hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
             layer_params=412e6)


def _spec(model, M=4, P=4, dcs=(0, 0, 1, 2)):
    return make_spec(**model, num_stages=P, microbatches=M, stage_dc=list(dcs))


def test_single_tcp_utilization_under_5pct():
    """§3.2: with one TCP connection at 40 ms WAN, GPU util < 5%."""
    spec = _spec(GPT_B, M=4, P=6, dcs=(0, 0, 1, 1, 2, 2))
    topo = GeoTopology(wan_latency_ms=40.0, multi_tcp=False)
    r = simulate(spec, topo, policy="varuna", validate=True)
    assert r.utilization < 0.05


def test_slowdown_grows_with_wan_latency():
    """Fig 3: PP training slows as WAN latency rises (single TCP)."""
    spec = _spec(GPT_B)
    times = [
        simulate(spec, GeoTopology(wan_latency_ms=lat, multi_tcp=False),
                 policy="varuna", validate=True).iteration_ms
        for lat in (10, 20, 30, 40)
    ]
    assert times == sorted(times)
    assert times[-1] > 2.5 * times[0]


def test_dp_slowdown_fig2():
    """Fig 2: DP all-reduce over WAN slows >10x vs intra-DC at 40 ms."""
    base = dp_iteration_ms(100.0, 2.4e9 * 2, 6, 40, intra_dc=True)
    wan40 = dp_iteration_ms(100.0, 2.4e9 * 2, 6, 40, multi_tcp=False)
    assert wan40 / base > 10


def test_atlas_vs_baselines_fig9():
    """Fig 9: Atlas (multi-TCP + temporal) beats single-TCP baselines by
    ~an order of magnitude at 40 ms; GPipe is the worst baseline."""
    spec = _spec(GPT_B, M=16)
    tb = GeoTopology(wan_latency_ms=40.0, multi_tcp=False)
    ta = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    gpipe = simulate(spec, tb, policy="gpipe", validate=True).iteration_ms
    megatron = simulate(spec, tb, policy="megatron", validate=True).iteration_ms
    varuna = simulate(spec, tb, policy="varuna", validate=True).iteration_ms
    atlas = simulate(spec, ta, policy="atlas", n_pipelines=3, validate=True).iteration_ms
    assert gpipe / atlas > 10
    assert megatron / atlas > 5
    assert varuna / atlas > 5
    assert gpipe > max(megatron, varuna)


def test_temporal_sharing_helps_fill_drain():
    """Fig 10 regime: all policies get multi-TCP; Atlas still wins on the
    short-pipeline testbed (fill/drain dominated)."""
    spec = _spec(GPT_B, M=16)
    t = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    varuna = simulate(spec, t, policy="varuna", validate=True).iteration_ms
    atlas = simulate(spec, t, policy="atlas", n_pipelines=3, validate=True).iteration_ms
    assert atlas < varuna


def test_bubble_consolidation():
    """§4.3: with D = C pipelines per cell, Atlas removes inter-microbatch
    bubbles — fewer, larger bubbles than Varuna at equal work."""
    spec = _spec(GPT_A, M=8)
    t = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    va = simulate(spec, t, policy="varuna", validate=True)
    C = max(1, round(spec.act_bytes * 8 / (wan.NODE_PAIR_CAP_GBPS * 1e9) * 1e3
                     / spec.t_fwd_ms))
    at = simulate(spec, t, policy="atlas", n_pipelines=min(C, 4), validate=True)
    # compare bubble fragmentation on a mid-pipeline stage
    va_gaps = va.stage_bubbles(0, 2)
    at_gaps = at.stage_bubbles(0, 2)
    va_n = len([g for g in va_gaps if g[1] - g[0] > 1e-6])
    at_n = len([g for g in at_gaps if g[1] - g[0] > 1e-6])
    assert at_n <= va_n


def test_gpipe_barrier_semantics():
    """GPipe backwards start only after all forwards of the pipeline."""
    spec = _spec(GPT_A, M=4)
    t = GeoTopology(wan_latency_ms=10.0, multi_tcp=True)
    r = simulate(spec, t, policy="gpipe", validate=True)
    last_stage = spec.num_stages - 1
    ivs = r.busy[(0, last_stage)]
    last_fwd_end = max(iv.end for iv in ivs if iv.kind == "fwd")
    first_bwd = min(iv.start for iv in ivs if iv.kind == "bwd")
    assert first_bwd >= last_fwd_end - 1e-9


def test_all_microbatches_complete():
    spec = _spec(GPT_A, M=5)
    t = GeoTopology(wan_latency_ms=10.0, multi_tcp=True)
    for pol, D in (("gpipe", 1), ("megatron", 1), ("varuna", 1), ("atlas", 2)):
        r = simulate(spec, t, policy=pol, n_pipelines=D, validate=True)
        for p in range(D):
            for s in range(spec.num_stages):
                ivs = r.busy[(p, s)]
                assert sum(1 for iv in ivs if iv.kind == "fwd") == 5
                assert sum(1 for iv in ivs if iv.kind == "bwd") == 5


def test_intra_dc_fast_baseline():
    """All stages in one DC -> near-ideal utilization for 1F1B."""
    spec = _spec(GPT_B, M=16, dcs=(0, 0, 0, 0))
    t = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    r = simulate(spec, t, policy="varuna", validate=True)
    assert r.utilization > 0.4


@pytest.mark.parametrize("policy", ("gpipe", "megatron", "varuna", "atlas"))
def test_bubbles_exclude_allreduce_span(policy):
    """Regression (ISSUE 3): the DP all-reduce span [pp_end, iteration]
    is busy communication on every GPU — it must never be reported as a
    bubble (BubbleTea would place prefills on all-reducing GPUs)."""
    spec = _spec(GPT_B, M=8)
    assert spec.stage_param_bytes > 0
    t = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    D = 3 if policy == "atlas" else 2
    r = simulate(spec, t, policy=policy, n_pipelines=D,
                 dp_replicas_for_allreduce=4, validate=True)
    assert r.allreduce_ms > 0
    pp_end = r.iteration_ms - r.allreduce_ms
    for g, gaps in r.bubbles.items():
        for a, b in gaps:
            assert b <= pp_end + 1e-9, (g, (a, b), pp_end)
    # the span still counts in the utilization denominator
    busy = sum(iv.end - iv.start for ivs in r.busy.values() for iv in ivs)
    assert r.utilization == pytest.approx(
        busy / (r.iteration_ms * len(r.busy)))
