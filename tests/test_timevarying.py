"""Time-varying WAN bandwidth engine — differential and invariant tests.

Three nets (ISSUE 3):
  * a *flat* ``wan.BandwidthSchedule`` attached to every WAN pair must be
    interval-identical to the static engine (and to the frozen
    pre-refactor reference) across the PR-2 differential grid;
  * a non-flat schedule (2:1 step, outage, measured-style trace) must
    shift iteration time, pass the invariant checker, gate the
    steady-state fast-forward (recorded in ``stats``), and price
    Algorithm-1 placements by per-direction worst-segment bandwidth;
  * the checker must reject a transfer whose occupancy beats the
    bandwidth schedule in force at its start (even when it would pass
    against the static link rate).
"""
import pytest

from repro import units
from repro.core import reference as ref
from repro.core import temporal
from repro.core import topology as tp
from repro.core import validate as V
from repro.core import wan
from repro.core.fastforward import GATE_TIME_VARYING
from repro.core.simulator import (
    GeoTopology,
    PipelineSpec,
    has_time_varying_wan,
    simulate,
)

POLICIES = ("gpipe", "megatron", "varuna", "atlas")


def _spec(M=12, stage_dc=(0, 0, 1, 2), **kw):
    return PipelineSpec(
        num_stages=len(stage_dc), microbatches=M, t_fwd_ms=10.0,
        act_bytes=1.5e8, stage_dc=tuple(stage_dc), stage_param_bytes=8e8,
        **kw,
    )


def _flat_schedules(topo):
    return {
        (a, b): wan.BandwidthSchedule.flat(topo.link(a, b).bw_gbps)
        for a, b in topo.wan_pairs()
    }


def _step_topo(factor=2.0, at_ms=500.0):
    """Azure testbed with a 1/factor bandwidth step on the 0<->1 pair."""
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    return base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.step(bw, bw / factor, at_ms)}
    )


# ------------------------------------------------------- BandwidthSchedule


def test_schedule_bw_at_and_bounds():
    s = wan.BandwidthSchedule((0.0, 10.0, 30.0), (1.0, 0.5, 2.0))
    assert s.bw_at(0.0) == 1.0
    assert s.bw_at(9.999) == 1.0
    assert s.bw_at(10.0) == 0.5
    assert s.bw_at(29.0) == 0.5
    assert s.bw_at(1e9) == 2.0  # last segment extends forever
    assert s.min_bw_gbps() == 0.5 and s.max_bw_gbps() == 2.0
    assert not s.is_flat()
    assert wan.BandwidthSchedule.flat(3.0).is_flat()


def test_schedule_transfer_integrates_across_segments():
    # 1 Gbps for 10 ms, then 0.5 Gbps: 15e6 bits = 10 ms @ 1e6 bits/ms
    # + 5e6 bits @ 0.5e6 bits/ms = 20 ms total
    s = wan.BandwidthSchedule.step(1.0, 0.5, 10.0)
    nbytes = 15e6 / 8.0
    assert s.transfer_ms(nbytes, 0.0) == pytest.approx(20.0)
    # starting mid-segment: 5 ms @ 1 Gbps + 10e6 bits @ 0.5 Gbps = 25 ms
    assert s.transfer_ms(nbytes, 5.0) == pytest.approx(25.0)
    # fully inside the slow segment
    assert s.transfer_ms(nbytes, 10.0) == pytest.approx(30.0)
    # rate multiplier (Atlas temporal sharing): 2x rate inside segment 0
    assert s.transfer_ms(nbytes, 0.0, rate_mult=2.0) == pytest.approx(7.5)


def test_schedule_flat_matches_static_formula_exactly():
    link = wan.wan_link(40.0, True)
    s = wan.BandwidthSchedule.flat(link.bw_gbps)
    nbytes = 1.5e8
    static_ser = nbytes * 8.0 / (link.bw_gbps * 1e9) * 1e3
    assert s.transfer_ms(nbytes, 0.0) == static_ser  # bit-identical
    assert s.transfer_ms(nbytes, 1234.5) == static_ser


def test_schedule_from_samples_coalesces():
    s = wan.BandwidthSchedule.from_samples([5.0, 5.0, 4.0, 4.0, 5.0], 100.0)
    assert s.times_ms == (0.0, 200.0, 400.0)
    assert s.bw_gbps == (5.0, 4.0, 5.0)


def test_schedule_constructor_validation():
    with pytest.raises(AssertionError):
        wan.BandwidthSchedule((1.0,), (5.0,))  # must start at 0
    with pytest.raises(AssertionError):
        wan.BandwidthSchedule((0.0, 5.0, 5.0), (1.0, 2.0, 3.0))  # not increasing
    with pytest.raises(AssertionError):
        wan.BandwidthSchedule((0.0,), (0.0,))  # bandwidth must be positive


def test_outage_and_diurnal_profiles():
    o = wan.BandwidthSchedule.outage(5.0, 1000.0, 2000.0, 0.5)
    assert o.bw_at(500.0) == 5.0
    assert o.bw_at(1500.0) == 0.5
    assert o.bw_at(2500.0) == 5.0
    d = wan.BandwidthSchedule.diurnal(5.0, 2.5, period_ms=24.0, steps=8)
    assert 2.5 <= min(d.bw_gbps) and max(d.bw_gbps) <= 5.0
    assert not d.is_flat()


def test_trace_schedule_deterministic_and_near_mean():
    link = wan.wan_link(34.0, True)
    a = wan.BandwidthSchedule.from_trace(link, seed=7)
    b = wan.BandwidthSchedule.from_trace(link, seed=7)
    assert a == b
    assert abs(a.bw_gbps[0] - link.bw_gbps) < 0.2 * link.bw_gbps


# ---------------------------------- period_ms cycle-boundary exactness
# (ISSUE 5 audit: the drift detector reads mean_bw_gbps/constant_over at
# arbitrary wall offsets, so windows straddling a period_ms cycle
# boundary must integrate exactly — no wraparound miscount.  The audit
# found the segment walker correct; these tests pin the boundary cases.)


def _periodic():
    # [0, 10): 1 Gbps, [10, 24): 3 Gbps, wrapping every 24 ms
    return wan.BandwidthSchedule((0.0, 10.0), (1.0, 3.0), period_ms=24.0)


def test_mean_bw_window_straddling_cycle_boundary():
    s = _periodic()
    # [20, 28): 4 ms of the 3-Gbps tail + 4 ms of the next cycle's head
    assert s.mean_bw_gbps(20.0, 28.0) == pytest.approx((4 * 3.0 + 4 * 1.0) / 8)
    # windows pinned exactly to the cycle edges
    assert s.mean_bw_gbps(10.0, 24.0) == pytest.approx(3.0)  # ends at edge
    assert s.mean_bw_gbps(24.0, 34.0) == pytest.approx(1.0)  # starts at edge
    # a whole cycle from any offset integrates to the cycle mean
    cycle_mean = (10 * 1.0 + 14 * 3.0) / 24.0
    for t0 in (0.0, 7.0, 10.0, 23.0, 24.0, 55.5):
        assert s.mean_bw_gbps(t0, t0 + 24.0) == pytest.approx(cycle_mean)
    # many cycles out, the same window reads the same mean
    assert s.mean_bw_gbps(7 * 24.0 + 20.0, 7 * 24.0 + 28.0) == pytest.approx(
        s.mean_bw_gbps(20.0, 28.0))


def test_constant_over_across_cycle_boundary():
    s = _periodic()
    # constant inside one segment of a later cycle, boundary-exact ends
    assert s.constant_over(24.0, 34.0)  # exactly the wrapped [0, 10) seg
    assert s.constant_over(34.0, 48.0)  # exactly the wrapped [10, 24) seg
    assert not s.constant_over(20.0, 25.0)  # straddles the cycle edge
    assert not s.constant_over(33.0, 35.0)  # straddles a segment edge
    # a flat periodic profile is constant over any window
    flat = wan.BandwidthSchedule((0.0,), (2.0,), period_ms=None)
    assert flat.constant_over(0.0, 1e9)


def test_bits_sent_and_transfer_across_cycle_boundary():
    s = _periodic()
    # [20, 28): 4 ms @ 3 Gbps + 4 ms @ 1 Gbps = 16e6 bits on the wire
    assert s.bits_sent(1e12, 20.0, 28.0) == pytest.approx(16.0e6)
    # a transfer sized to finish exactly at the cycle edge does so
    nbytes = (4 * 3.0e6) / 8.0  # the 3-Gbps tail of the first cycle
    assert s.transfer_ms(nbytes, 20.0) == pytest.approx(4.0)
    # one more bit rides the next cycle's 1-Gbps head
    assert s.transfer_ms(nbytes + 1.0 / 8.0, 20.0) == pytest.approx(
        4.0 + 1.0 / 1e6)
    # split at the cycle edge == unsplit (preemption differential):
    # both legs finish at the same absolute wall time
    big = 40e6 / 8.0
    whole = s.transfer_ms(big, 20.0)
    sent, rem = s.preempt(big, 20.0, 24.0)
    assert sent == pytest.approx(12e6 / 8.0)  # the 3-Gbps tail's bits
    assert 24.0 + s.transfer_ms(rem, 24.0) == pytest.approx(20.0 + whole)


def test_min_bw_over_windows():
    s = _periodic()
    assert s.min_bw_over(10.0, 24.0) == 3.0  # inside the fast segment
    assert s.min_bw_over(20.0, 28.0) == 1.0  # straddles into the slow head
    assert s.min_bw_over(24.0, 34.0) == 1.0
    step = wan.BandwidthSchedule.step(5.0, 2.0, 100.0)
    assert step.min_bw_over(0.0, 50.0) == 5.0
    assert step.min_bw_over(0.0, 200.0) == 2.0
    assert step.min_bw_over(150.0, 1e9) == 2.0


# -------------------------------------------------- topology attachment


def test_topology_schedule_lookup_and_fallback():
    topo = _step_topo()
    assert topo.bandwidth_schedule(0, 0) is None  # intra-DC always static
    assert topo.bandwidth_schedule(0, 1) is not None
    # reverse-pair fallback mirrors the links table
    assert topo.bandwidth_schedule(1, 0) == topo.bandwidth_schedule(0, 1)
    assert topo.bandwidth_schedule(2, 3) is None  # unscheduled pair: static
    assert topo.time_varying()
    flat = tp.azure_testbed().with_bandwidth_schedules(
        _flat_schedules(tp.azure_testbed()))
    assert not flat.time_varying()
    assert GeoTopology().bandwidth_schedule(0, 1) is None


def test_effective_bw_is_worst_segment():
    topo = _step_topo(factor=4.0)
    static = tp.azure_testbed()
    assert topo.effective_bw_gbps(0, 1) == pytest.approx(
        static.link(0, 1).bw_gbps / 4.0)
    assert topo.effective_bw_gbps(2, 3) == static.link(2, 3).bw_gbps


def test_has_time_varying_wan_respects_stage_placement():
    topo = _step_topo()
    assert has_time_varying_wan(_spec(stage_dc=(0, 0, 1, 2)), topo)
    # a pipeline that never crosses the scheduled 0<->1 pair is static
    assert not has_time_varying_wan(_spec(stage_dc=(2, 2, 3, 3)), topo)


# ------------------------------------------- flat identity (differential)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("base_name", ["uniform", "azure", "skewed"])
def test_flat_schedule_interval_identical_to_static(policy, base_name):
    """A flat schedule exercises the segment-integration path but must
    reproduce the static engine (and the frozen reference) exactly."""
    base = {
        "uniform": tp.TopologyMatrix.uniform(3, wan_latency_ms=40.0),
        "azure": tp.azure_testbed(),
        "skewed": tp.skewed_3dc(),
    }[base_name]
    flat = base.with_bandwidth_schedules(_flat_schedules(base))
    for M in (4, 9, 16):
        spec = _spec(M=M)
        D = 3 if policy == "atlas" else 2
        r_static = simulate(spec, base, policy=policy, n_pipelines=D,
                            dp_replicas_for_allreduce=2, fast_forward=False, validate=True)
        r_flat = simulate(spec, flat, policy=policy, n_pipelines=D,
                          dp_replicas_for_allreduce=2, fast_forward=False, validate=True)
        V.check_equivalent(r_static, r_flat)
        r_ref = ref.simulate(spec, base, policy=policy, n_pipelines=D,
                             dp_replicas_for_allreduce=2)
        V.check_equivalent(r_ref, r_flat)
        V.check_sim_result(r_flat, spec, policy=policy)


# --------------------------------------------------- non-flat behaviour


@pytest.mark.parametrize("policy", POLICIES)
def test_step_trace_shifts_iteration_and_validates(policy):
    """A 2:1 step on one boundary slows the iteration; all physical
    invariants must still hold (validate=True)."""
    spec = _spec(M=48)
    base = tp.azure_testbed()
    step = _step_topo(factor=2.0, at_ms=500.0)
    D = 2
    r0 = simulate(spec, base, policy=policy, n_pipelines=D, validate=True)
    r1 = simulate(spec, step, policy=policy, n_pipelines=D, validate=True)
    assert r1.iteration_ms > r0.iteration_ms
    assert r1.stats["fast_forward"] is False


def test_transfer_spans_step_boundary_exactly():
    """An event-engine transfer that straddles the step must occupy the
    channel for the integrated (two-segment) time, not either constant."""
    act_bytes = 1.5e8
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    ser_fast = units.serialization_ms(act_bytes, bw)  # 240 ms at 5 Gbps
    # place the step mid-way through the very first 0->1 transfer: the
    # first forward on stage 1 (DC 0 -> DC 1 boundary is at stages 1|2)
    spec = _spec(M=2, stage_dc=(0, 1, 1, 1))
    r0 = simulate(spec, base, policy="varuna", fast_forward=False, validate=True)
    first_arrival = min(
        iv.start for iv in r0.busy[(0, 1)] if iv.kind == "fwd")
    send_start = spec.t_fwd_ms  # stage 0 forward ends, transfer starts
    step_at = send_start + ser_fast / 2.0
    stepped = base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.step(bw, bw / 2.0, step_at)})
    r1 = simulate(spec, stepped, policy="varuna", fast_forward=False,
                  validate=True)
    got = min(iv.start for iv in r1.busy[(0, 1)] if iv.kind == "fwd")
    # half the bytes at full rate, half at half rate -> 1.5x occupancy
    want_shift = ser_fast / 2.0  # extra time vs the static run
    assert got - first_arrival == pytest.approx(want_shift, rel=1e-9)


def test_atlas_consistency_under_time_varying_bandwidth():
    """Precomputed schedule, event wrapper and invariant checker must all
    agree when transfers are priced by a non-flat schedule."""
    spec = _spec(M=10)
    V.check_atlas_consistency(_spec(M=10), _step_topo(), n_pipelines=2,
                              dp_replicas=2)
    sched = temporal.atlas_schedule(spec, _step_topo(), 2)
    V.check_schedule(sched, spec, _step_topo())


# ----------------------------------------------------- fast-forward gate


def test_fast_forward_gated_off_by_time_varying_bandwidth():
    """Even fast_forward=True must fall back (and record why): probes
    cannot see bandwidth changes beyond their horizon."""
    spec = _spec(M=200)
    topo = _step_topo()
    res = simulate(spec, topo, policy="varuna", fast_forward=True, validate=True)
    assert res.stats["fast_forward"] is False
    assert res.stats["fast_forward_gate"] == GATE_TIME_VARYING
    full = simulate(spec, topo, policy="varuna", fast_forward=False, validate=True)
    V.check_equivalent(res, full)


def test_fast_forward_engages_on_flat_schedules():
    """Flat schedules keep the static periodicity: no gate, fast-forward
    engages and stays interval-identical to full replay."""
    base = tp.azure_testbed()
    flat = base.with_bandwidth_schedules(_flat_schedules(base))
    spec = _spec(M=200)
    res = simulate(spec, flat, policy="varuna", fast_forward=True, validate=True)
    assert res.stats["fast_forward"] is True
    assert "fast_forward_gate" not in res.stats
    full = simulate(spec, flat, policy="varuna", fast_forward=False, validate=True)
    V.check_equivalent(res, full)


def test_late_step_beyond_probe_horizon_not_extrapolated():
    """The dangerous case the gate exists for: a step far past the probe
    horizon.  Without the gate the probes would detect a period and
    extrapolate straight through the step."""
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    spec = _spec(M=256)
    r_static = simulate(spec, base, policy="varuna", fast_forward=False, validate=True)
    late = base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.step(
            bw, bw / 2.0, r_static.iteration_ms / 2.0)})
    fast = simulate(spec, late, policy="varuna", fast_forward=True, validate=True)
    full = simulate(spec, late, policy="varuna", fast_forward=False, validate=True)
    V.check_equivalent(fast, full)
    assert full.iteration_ms > r_static.iteration_ms


# ------------------------------------------------- negative validate test


def test_validate_rejects_over_bandwidth_segment_transfer():
    """A transfer priced at the *nominal* link rate while the schedule is
    degraded would pass the static check — the schedule-aware check must
    reject it."""
    spec = _spec(M=8)
    base = tp.azure_testbed()
    bw = base.link(0, 1).bw_gbps
    # degraded 4:1 from t=0 onwards for a long window: every 0<->1
    # transfer is in the slow segment
    topo = base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.outage(bw, 1e-3, 1e9, bw / 4.0)})
    D = 2
    sched = temporal.atlas_schedule(spec, topo, D)
    V.check_schedule(sched, spec, topo)  # honest schedule passes
    ser_nominal = units.serialization_ms(spec.act_bytes, bw) / D
    wan_b = 1  # stages 1|2 cross DC 0 -> DC 1
    tr = next(t for t in sched.transfers
              if t.boundary == wan_b and t.start > 1e-3)
    # claim the transfer ran at nominal rate: legal statically, but 4x
    # faster than the degraded segment allows
    tr.end = tr.start + ser_nominal
    with pytest.raises(V.InvariantViolation):
        V.check_schedule(sched, spec, topo)


# --------------------------------------- Algorithm 1: bandwidth asymmetry


def test_algorithm1_routes_around_degraded_pair():
    """Equal latencies everywhere: only the bandwidth schedule
    distinguishes the pairs, so the placement search must keep the
    degraded pair off the stage boundaries — bandwidth-asymmetric, not
    latency-aware."""
    from repro.core.dc_selection import JobModel, algorithm1, best_plan

    lat = [[0.0, 20.0, 20.0], [20.0, 0.0, 20.0], [20.0, 20.0, 0.0]]
    base = tp.TopologyMatrix.from_latency(
        lat, multi_tcp=True, dc_names=("dc0", "dc1", "dc2"))
    bw = base.link(0, 2).bw_gbps
    degraded = base.with_bandwidth_schedules(
        {(0, 2): wan.BandwidthSchedule.outage(bw, 3.6e6, 4 * 3.6e6, bw / 10.0)})
    job = JobModel(
        t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        partition_param_bytes=8e8,
        microbatches=60,
        topology=degraded,
    )
    fleet = {"dc0": 8, "dc1": 8, "dc2": 8}
    best = best_plan(algorithm1(job, fleet, P=12, C=2))
    used = [d for d in best.dc_order if best.partitions.get(d, 0)]
    assert len(used) == 3
    assert used.index("dc1") == 1, used  # dc0<->dc2 never adjacent


def test_algorithm1_memo_not_aliased_across_schedules():
    """Two topologies differing only in bw_schedules must not share
    memoized pipeline latencies."""
    from repro.core.dc_selection import JobModel, get_latency_pp

    lat = [[0.0, 20.0], [20.0, 0.0]]
    base = tp.TopologyMatrix.from_latency(
        lat, multi_tcp=True, dc_names=("a", "b"))
    bw = base.link(0, 1).bw_gbps
    slow = base.with_bandwidth_schedules(
        {(0, 1): wan.BandwidthSchedule.step(bw, bw / 8.0, 1.0)})
    kw = dict(t_fwd_ms=10.0, act_bytes=1.5e8, partition_param_bytes=8e8,
              microbatches=32)
    t_base = get_latency_pp(JobModel(topology=base, **kw),
                            {"a": 2, "b": 2}, ("a", "b"), 1)
    t_slow = get_latency_pp(JobModel(topology=slow, **kw),
                            {"a": 2, "b": 2}, ("a", "b"), 1)
    assert t_slow > t_base * 2
