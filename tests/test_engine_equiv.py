"""Differential tests for the fast-path schedule engine.

Two nets, both via ``repro.core.validate.check_equivalent``:

  * the heap-based event core + lazy-heap Atlas list-scheduler must be
    *interval-identical* to the pre-refactor reference engine
    (``repro.core.reference``) across a (policy × topology × M) grid;
  * the steady-state fast-forward must be interval-identical to full
    event replay wherever it engages, and must fall back (not corrupt
    results) where the schedule has no detectable period.
"""
import dataclasses

import pytest

from repro.core import reference as ref
from repro.core import topology as tp
from repro.core import validate as V
from repro.core import wan
from repro.core.simulator import GeoTopology, PipelineSpec, simulate
from repro.core.simulator import testbed_spec as make_spec

GPT_A = dict(hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
             layer_params=412e6)
GPT_B = dict(hidden=8192, seq_len=6144, micro_batch=1, layers_per_stage=1,
             layer_params=1.2e9)

POLICIES = ("gpipe", "megatron", "varuna", "atlas")
TOPOS = {
    "uniform": GeoTopology(wan_latency_ms=40.0, multi_tcp=True),
    "uniform-single": GeoTopology(wan_latency_ms=40.0, multi_tcp=False),
    "azure": tp.azure_testbed(),
    "skewed": tp.skewed_3dc(),
}


def _spec(model, M, P=4, dcs=(0, 0, 1, 2)):
    return make_spec(**model, num_stages=P, microbatches=M, stage_dc=list(dcs))


# ---------------------------------------------------------------- reference


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("topo_name", list(TOPOS))
def test_engine_matches_reference(policy, topo_name):
    topo = TOPOS[topo_name]
    for model in (GPT_A, GPT_B):
        for M in (4, 9, 16):
            spec = _spec(model, M)
            D = 3 if policy == "atlas" else 2
            r_ref = ref.simulate(spec, topo, policy=policy, n_pipelines=D,
                                 dp_replicas_for_allreduce=2)
            r_new = simulate(spec, topo, policy=policy, n_pipelines=D,
                             dp_replicas_for_allreduce=2, fast_forward=False, validate=True)
            V.check_equivalent(r_ref, r_new)
            V.check_sim_result(r_new, spec, policy=policy)


def test_engine_matches_reference_tight_caps():
    """Explicit in-flight caps exercise the parked-forward machinery of
    both the event core and the lazy-heap list scheduler."""
    topo = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    for policy in POLICIES:
        for cap in (1, 2, 3):
            spec = dataclasses.replace(_spec(GPT_B, 12), inflight_cap=cap)
            D = 2
            r_ref = ref.simulate(spec, topo, policy=policy, n_pipelines=D)
            # gpipe under cap < M parks forwards forever (all-forwards-first
            # cannot drain); the schedule is intentionally partial and the
            # invariant checker would (correctly) reject it.  The assertion
            # here is differential: both engines must park identically.
            r_new = simulate(spec, topo, policy=policy, n_pipelines=D,  # lint: ok[api/validate-missing]
                             fast_forward=False)
            V.check_equivalent(r_ref, r_new)


def test_replicated_pipelines_identical():
    """Baseline policies simulate one pipeline and replicate: every
    pipeline's schedule must be identical (they share no resources)."""
    spec = _spec(GPT_B, 8)
    res = simulate(spec, TOPOS["azure"], policy="varuna", n_pipelines=3, validate=True)
    for s in range(spec.num_stages):
        base = [(iv.start, iv.end, iv.kind, iv.micro) for iv in res.busy[(0, s)]]
        for p in (1, 2):
            got = [(iv.start, iv.end, iv.kind, iv.micro) for iv in res.busy[(p, s)]]
            assert got == base


# ------------------------------------------------------------ fast-forward


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("topo_name", list(TOPOS))
def test_fast_forward_interval_identical(policy, topo_name):
    """Where the fast-forward engages it must reproduce full replay
    exactly; on the paper-testbed shape it engages for every policy and
    both M values (a period of 1, 3, 4 or 12 microbatches)."""
    topo = TOPOS[topo_name]
    for M in (200, 333):
        spec = _spec(GPT_B, M)
        D = 3 if policy == "atlas" else 2
        fast, engaged = V.check_fast_forward(spec, topo, policy, n_pipelines=D)
        assert engaged, (policy, topo_name, M)
        assert fast.stats["period"] >= 1
        assert fast.stats["extrapolated_microbatches"] > 0


def test_fast_forward_cross_policy_ordering_preserved():
    """Fig-9 ordering must survive fast-forward at large M."""
    spec = _spec(GPT_B, 256)
    tb = GeoTopology(wan_latency_ms=40.0, multi_tcp=False)
    ta = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    at = simulate(spec, ta, policy="atlas", n_pipelines=3, validate=True).iteration_ms
    va = simulate(spec, tb, policy="varuna", validate=True).iteration_ms
    gp = simulate(spec, tb, policy="gpipe", validate=True).iteration_ms
    assert at <= va <= gp


def test_fast_forward_falls_back_on_aperiodic_schedule():
    """P=16 at 40 ms WAN with C=2 has no period ≤ 32 (latency-delayed cap
    feedback) — the engine must detect that and fall back to full replay,
    bit-compatibly."""
    spec = PipelineSpec(
        num_stages=16, microbatches=224, t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        stage_dc=tuple(sum([[d] * 4 for d in range(4)], [])),
    )
    topo = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    fast, engaged = V.check_fast_forward(spec, topo, "varuna", n_pipelines=1)
    assert not engaged
    assert fast.stats["fast_forward"] is False


def test_fast_forward_disabled_below_probe_size():
    """M smaller than the probes: no fast-forward even when forced."""
    spec = _spec(GPT_B, 16)
    res = simulate(spec, TOPOS["uniform"], policy="varuna", fast_forward=True, validate=True)
    assert res.stats["fast_forward"] is False


def test_fast_forward_respects_explicit_inflight_cap():
    topo = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    spec = dataclasses.replace(_spec(GPT_B, 250), inflight_cap=2)
    fast, engaged = V.check_fast_forward(spec, topo, "varuna", n_pipelines=1)
    V.check_sim_result(fast, spec, policy="varuna", inflight_cap=2)


def test_fast_forward_auto_mode_used_by_default():
    """The default simulate() call must engage the fast-forward on a
    large-M spec (and stay interval-identical — spot check)."""
    spec = _spec(GPT_B, 512)
    topo = TOPOS["uniform"]
    res = simulate(spec, topo, policy="varuna", validate=True)
    assert res.stats["fast_forward"] is True
    full = simulate(spec, topo, policy="varuna", fast_forward=False, validate=True)
    V.check_equivalent(res, full)


def test_engine_stats_recorded():
    spec = _spec(GPT_A, 8)
    res = simulate(spec, TOPOS["uniform"], policy="varuna", n_pipelines=2, validate=True)
    assert res.stats["events"] > 0
    assert res.stats["replicated_pipelines"] == 2
    at = simulate(spec, TOPOS["uniform"], policy="atlas", n_pipelines=2, validate=True)
    assert at.stats["engine"] == "atlas-precomputed"


# ------------------------------------------------------- equivalence checker


def test_check_equivalent_detects_differences():
    spec = _spec(GPT_A, 6)
    res_a = simulate(spec, TOPOS["uniform"], policy="varuna", validate=True)
    res_b = simulate(spec, TOPOS["uniform"], policy="varuna", validate=True)
    V.check_equivalent(res_a, res_b)  # sanity: identical runs agree
    res_b.busy[(0, 1)][3].start += 0.5
    with pytest.raises(V.InvariantViolation):
        V.check_equivalent(res_a, res_b)
