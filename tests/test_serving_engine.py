"""serving/engine.py: request lifecycle, metrics, sampling, padding
isolation and the Splitwise KV handoff.  One engine and one cluster are
shared across the module so the prefill/decode jits compile once."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import build_model
from repro.serving.engine import (
    Request,
    ServingEngine,
    SplitwiseCluster,
    zeros_cache,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("gpt_a")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=3, max_len=64)
    cluster = SplitwiseCluster(cfg, params, max_batch=3, max_len=64)
    return cfg, model, params, engine, cluster


def test_zeros_cache_marks_empty_slots(setup):
    cfg, model, _, _, _ = setup
    cache = zeros_cache(model, batch=2, max_len=16)
    pos_leaves = [x for x in jax.tree.leaves(cache) if x.dtype == np.int32]
    assert pos_leaves and all((np.asarray(x) == -1).all() for x in pos_leaves)


def test_request_lifecycle_metrics(setup):
    cfg, _, _, engine, _ = setup
    reqs = [
        Request(0, np.arange(5, dtype=np.int32), max_new_tokens=6),
        Request(1, np.arange(8, dtype=np.int32), max_new_tokens=3),
    ]
    out = engine.generate(reqs)
    # every request got exactly its token budget
    assert len(out[0].generated) == 6
    assert len(out[1].generated) == 3
    # TTFT recorded once, TBT once per decode step that produced a token
    for r in out:
        assert r.ttft_ms > 0
        assert len(r.tbt_ms) == len(r.generated) - 1
        assert all(t >= 0 for t in r.tbt_ms)
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_greedy_deterministic(setup):
    cfg, _, _, engine, _ = setup
    r1 = engine.generate([Request(0, np.arange(8, dtype=np.int32), max_new_tokens=6)])
    r2 = engine.generate([Request(0, np.arange(8, dtype=np.int32), max_new_tokens=6)])
    assert r1[0].generated == r2[0].generated
    assert len(r1[0].generated) == 6
    assert r1[0].ttft_ms > 0 and len(r1[0].tbt_ms) == 5


def test_batch_isolation_equal_batch(setup):
    """A request's output must not depend on its batch neighbours."""
    cfg, _, _, engine, _ = setup
    p0 = (np.arange(8) % cfg.vocab_size).astype(np.int32)
    alone = engine.generate([Request(0, p0.copy(), max_new_tokens=4)])[0].generated
    other = (np.arange(6) * 7 % cfg.vocab_size).astype(np.int32)
    together = engine.generate(
        [Request(1, p0.copy(), max_new_tokens=4), Request(2, other, max_new_tokens=4)]
    )[0].generated
    assert alone == together


def test_prefill_right_alignment_batch_padding(setup):
    """Unequal-length prompts batched together must each behave as if
    right-aligned alone: pad slots carry position -1 and are masked, so
    the SHORT prompt's tokens are also neighbour-independent."""
    cfg, _, _, engine, _ = setup
    short = (np.arange(4) % cfg.vocab_size).astype(np.int32)
    long = (np.arange(12) * 5 % cfg.vocab_size).astype(np.int32)
    alone = engine.generate([Request(0, short.copy(), max_new_tokens=4)])[0].generated
    mixed = engine.generate([
        Request(1, short.copy(), max_new_tokens=4),
        Request(2, long, max_new_tokens=4),
    ])[0].generated
    assert alone == mixed


def test_ragged_prefill_masked_under_pallas_impl(setup):
    """The pallas flash kernel ignores positions; the engine must pin the
    masking sdpa for ragged batches so pad slots stay invisible even when
    the pallas impl is active."""
    from repro.models import attention

    cfg, _, params, _, _ = setup
    short = (np.arange(4) % cfg.vocab_size).astype(np.int32)
    peer = ((np.arange(4) * 7 + 1) % cfg.vocab_size).astype(np.int32)
    long = (np.arange(12) * 5 % cfg.vocab_size).astype(np.int32)
    engine = ServingEngine(cfg, params, max_batch=2, max_len=64)
    attention.set_attention_impl("pallas")
    try:
        # equal-length batch: no padding, dense fast path
        dense = engine.generate([
            Request(1, short.copy(), max_new_tokens=3),
            Request(2, peer, max_new_tokens=3),
        ])[0].generated
        # ragged batch: 8 pad slots in front of `short`
        ragged = engine.generate([
            Request(3, short.copy(), max_new_tokens=3),
            Request(4, long, max_new_tokens=3),
        ])[0].generated
    finally:
        attention.set_attention_impl("xla")
    assert dense == ragged


def test_temperature_sampling_stays_in_vocab(setup):
    cfg, _, _, engine, _ = setup
    req = Request(5, np.arange(8, dtype=np.int32), max_new_tokens=6,
                  temperature=1.0)
    out = engine.generate([req])[0]
    assert len(out.generated) == 6
    assert all(0 <= t < cfg.vocab_size for t in out.generated)


@pytest.mark.slow  # compiles a second (hybrid ssm+attention) model
def test_recurrent_family_ragged_batches_served_per_request():
    """Mamba/RWKV-style models scan pads into their recurrent state, so
    the engine must split ragged batches instead of left-padding them."""
    cfg = get_smoke_config("zamba2_2p7b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=2, max_len=64)
    short = (np.arange(6) % cfg.vocab_size).astype(np.int32)
    long = (np.arange(12) * 5 % cfg.vocab_size).astype(np.int32)
    alone = engine.generate([Request(0, short.copy(), max_new_tokens=3)])[0].generated
    mixed = engine.generate([
        Request(1, short.copy(), max_new_tokens=3),
        Request(2, long, max_new_tokens=3),
    ])[0].generated
    assert alone == mixed


def test_splitwise_matches_monolithic_and_counts_kv_bytes(setup):
    """Prefill/decode disaggregation must not change the tokens (§5),
    and the KV handoff must actually move bytes."""
    cfg, _, _, engine, cluster = setup
    prompt = (np.arange(8) * 3 % cfg.vocab_size).astype(np.int32)
    before = cluster.kv_bytes_moved
    split = cluster.serve([Request(0, prompt.copy(), max_new_tokens=5)])[0]
    mono = engine.generate([Request(1, prompt.copy(), max_new_tokens=5)])[0]
    assert cluster.kv_bytes_moved > before
    assert split.generated == mono.generated


def test_sampling_decorrelated_across_decode_steps(setup):
    """Regression: the PRNG key used to derive from sum(req_id) only, so
    every decode step of a batch reused the identical key — with flat
    logits each step re-drew the same token forever.  The step index is
    now folded into the key: consecutive steps differ, the same step is
    reproducible, and batches whose ids merely share a sum diverge."""
    cfg, _, _, engine, _ = setup
    flat = jax.numpy.zeros((3, cfg.vocab_size))
    reqs = [Request(i, np.zeros(1, np.int32), temperature=1.0) for i in range(3)]
    s1 = engine._sample(flat, reqs, step=1)
    s2 = engine._sample(flat, reqs, step=2)
    assert list(s1) != list(s2)
    assert list(s1) == list(engine._sample(flat, reqs, step=1))
    # sum-collision: ids (0, 3) and (1, 2) hashed identically before
    a = [Request(0, np.zeros(1, np.int32), temperature=1.0),
         Request(3, np.zeros(1, np.int32), temperature=1.0)]
    b = [Request(1, np.zeros(1, np.int32), temperature=1.0),
         Request(2, np.zeros(1, np.int32), temperature=1.0)]
    flat2 = jax.numpy.zeros((2, cfg.vocab_size))
    draws_a = [int(t) for s in range(4) for t in engine._sample(flat2, a, step=s)]
    draws_b = [int(t) for s in range(4) for t in engine._sample(flat2, b, step=s)]
    assert draws_a != draws_b


def test_kv_bytes_moved_counts_only_valid_positions(setup):
    """Regression: the handoff counter summed whole cache leaves, i.e.
    B × max_len ring slots of which all but prompt_len are pads.  It must
    agree with the latency model's kv_bytes_per_token × prompt_tokens
    accounting instead."""
    cfg, model, _, _, cluster = setup
    # gpt_a smoke: k+v leaves (L=2, B, S, H=4, hd=64) bf16
    #   per token = 2 leaves × 2 × 4 × 64 × 2 B = 2048 B
    from repro.serving.engine import (
        kv_cache_bytes_per_token,
        kv_cache_state_bytes_per_seq,
    )
    ring = cluster.prefill_engine.max_len
    cache = zeros_cache(model, 2, ring)
    per_token = kv_cache_bytes_per_token(cache, ring)
    per_seq = kv_cache_state_bytes_per_seq(cache, ring)
    assert per_token == 2 * cfg.num_layers * 4 * 64 * 2
    assert per_seq == 0.0
    lens = (5, 8)
    before = cluster.kv_bytes_moved
    cluster.serve([
        Request(10 + i, (np.arange(L) % cfg.vocab_size).astype(np.int32),
                max_new_tokens=2)
        for i, L in enumerate(lens)
    ])
    moved = cluster.kv_bytes_moved - before
    assert moved == per_token * sum(lens)
    # strictly below the old full-ring accounting
    full_ring = sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(cache)
        if jax.numpy.issubdtype(x.dtype, jax.numpy.floating)
    )
    assert moved < full_ring
