"""repro.analysis lint suite — positive/negative fixtures per rule.

Every rule gets (at least) one snippet it must fire on and one fixed
form it must stay silent on, plus suppression-comment, baseline, and
whole-tree-clean coverage (ISSUE 8 satellite: the shipped baseline is
empty and stays empty).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import all_rules, analyze_paths, load_baseline, parse_module, run_passes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CORE = "src/repro/core/fixture.py"
SRC = "src/repro/serve/fixture.py"
TESTS = "tests/test_fixture.py"
UNITS = "src/repro/units.py"


def lint(source, path=CORE, rule=None):
    """Run every pass over one in-memory module; optionally filter."""
    mod = parse_module(path, textwrap.dedent(source))
    found = run_passes([mod])
    if rule is not None:
        found = [f for f in found if f.rule == rule]
    return found


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------- units pass


def test_units_mixed_add_fires_and_fixed_form_is_silent():
    bad = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes
    """
    assert rules_of(lint(bad)) == ["units/mixed-units"]
    good = """
        def slack(deadline_ms, arrival_ms):
            return deadline_ms + arrival_ms
    """
    assert lint(good) == []


def test_units_mixed_compare_fires():
    bad = """
        def late(t_ms, size_bytes):
            return t_ms > size_bytes
    """
    assert rules_of(lint(bad)) == ["units/mixed-units"]


def test_units_scale_mismatch_seconds_vs_ms():
    bad = """
        def total(wait_s, step_ms):
            return wait_s + step_ms
    """
    assert rules_of(lint(bad)) == ["units/scale-mismatch"]
    good = """
        def total(wait_s, step_ms):
            return wait_s * 1e3 + step_ms
    """
    assert lint(good) == []


def test_units_bytes_to_bits_without_x8_is_scale_mismatch():
    bad = """
        def mix(a_bits, b_bytes):
            return a_bits + b_bytes
    """
    assert rules_of(lint(bad)) == ["units/scale-mismatch"]
    good = """
        def mix(a_bits, b_bytes):
            return a_bits + b_bytes * 8.0  # lint: ok[units/inline-conversion]
    """
    assert lint(good) == []


def test_units_gbps_window_without_1e6_is_scale_mismatch():
    # Gbps x ms = 1e6 bits; forgetting the 1e6 leaves the wrong scale
    bad = """
        def window(seg_ms, bw_gbps, budget_bits):
            sent_bits = seg_ms * bw_gbps
            return budget_bits - sent_bits
    """
    assert "units/scale-mismatch" in rules_of(lint(bad))
    good = """
        def window(seg_ms, bw_gbps, budget_bits):
            sent_bits = seg_ms * bw_gbps * 1e6  # lint: ok[units/inline-conversion]
            return budget_bits - sent_bits
    """
    assert lint(good) == []


def test_units_propagate_through_assignment_and_call_binding():
    bad = """
        def ser(nbytes, bw_gbps):
            return nbytes / bw_gbps

        def caller(delay_ms, size_bytes):
            t = delay_ms
            return ser(t, size_bytes)
    """
    # delay_ms bound to parameter 'nbytes', size_bytes to 'bw_gbps'
    assert rules_of(lint(bad)) == ["units/mixed-units"]


def test_units_inline_conversion_fires_in_core_only():
    snippet = """
        def ser_ms(nbytes, bw_gbps):
            return (nbytes * 8.0) / (bw_gbps * 1e9) * 1e3
    """
    assert rules_of(lint(snippet, path=CORE)) == ["units/inline-conversion"]
    # the sanctioned module and non-core code are exempt
    assert lint(snippet, path=UNITS) == []
    assert lint(snippet, path=SRC) == []


def test_units_zero_and_epsilon_literals_are_neutral():
    good = """
        def pad(t_ms):
            t_ms += 5.0
            if t_ms > 0:
                return t_ms + 1e-9
            return 0.0
    """
    assert lint(good) == []


# ------------------------------------------------------- determinism pass


def test_det_wall_clock_fires_in_core_only():
    bad = """
        import time

        def stamp():
            return time.perf_counter()
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/wall-clock"]
    assert lint(bad, path=SRC) == []  # serving layer may profile


def test_det_wall_clock_from_import():
    bad = """
        from time import monotonic

        def stamp():
            return monotonic()
    """
    found = lint(bad, path=CORE, rule="det/wall-clock")
    assert len(found) == 2  # the import and the call


def test_det_unseeded_rng():
    bad = """
        import random

        def jitter():
            rng = random.Random()
            return rng.random() + random.uniform(0.0, 1.0)
    """
    found = lint(bad, path=CORE, rule="det/unseeded-rng")
    assert len(found) == 2  # Random() without seed + global uniform()
    good = """
        import random

        def jitter(seed):
            rng = random.Random(seed)
            return rng.random()
    """
    assert lint(good, path=CORE) == []


def test_det_numpy_global_rng():
    bad = """
        import numpy as np

        def draw():
            return np.random.rand(3)
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/unseeded-rng"]
    good = """
        import numpy as np

        def draw(seed):
            return np.random.default_rng(seed).random(3)
    """
    assert lint(good, path=CORE) == []


def test_det_set_iteration_fires_and_sorted_is_sanctioned():
    bad = """
        def order(names):
            pending = set(names)
            out = []
            for n in pending:
                out.append(n)
            return out
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/set-iteration"]
    good = bad.replace("for n in pending:", "for n in sorted(pending):")
    assert lint(good, path=CORE) == []


def test_det_list_wrapper_does_not_sanction_hash_order():
    bad = """
        def order(names):
            pending = set(names)
            return [n for n in list(pending)]
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/set-iteration"]


def test_det_membership_and_len_are_exempt():
    good = """
        def stats(names, probe):
            pending = set(names)
            return probe in pending, len(pending), min(pending)
    """
    assert lint(good, path=CORE) == []


# ------------------------------------------------------- concurrency pass


def test_conc_queue_empty_poll():
    bad = """
        import queue

        class Writer:
            def __init__(self):
                self._q = queue.Queue()

            def wait(self):
                while not self._q.empty():
                    pass
    """
    assert rules_of(lint(bad, path=SRC)) == ["conc/queue-empty-poll"]
    good = """
        import queue

        class Writer:
            def __init__(self):
                self._q = queue.Queue()

            def wait(self):
                self._q.join()
    """
    assert lint(good, path=SRC) == []


def test_conc_unlocked_shared_write():
    bad = """
        import threading

        class Worker:
            def __init__(self):
                self.n = 0
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def _run(self):
                self.n += 1

            def reset(self):
                self.n = 0

            def stop(self):
                self._t.join()
    """
    assert rules_of(lint(bad, path=SRC)) == ["conc/unlocked-shared-write"]
    good = """
        import threading

        class Worker:
            def __init__(self):
                self.n = 0
                self._lock = threading.Lock()
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def _run(self):
                with self._lock:
                    self.n += 1

            def reset(self):
                with self._lock:
                    self.n = 0

            def stop(self):
                self._t.join()
    """
    assert lint(good, path=SRC) == []


def test_conc_thread_no_join():
    bad = """
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            return t
    """
    assert rules_of(lint(bad, path=SRC)) == ["conc/thread-no-join"]
    good = """
        import threading

        def run(fn):
            t = threading.Thread(target=fn)
            t.start()
            t.join()
    """
    assert lint(good, path=SRC) == []


def test_conc_pass_skips_tests_and_threadless_modules():
    snippet = """
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn)
            t.start()
    """
    assert lint(snippet, path=TESTS) == []  # tests may leak threads
    assert lint("x = 1\n", path=SRC) == []


# -------------------------------------------------------------- api pass


def test_api_validate_missing_in_tests_only():
    bad = """
        def test_runs(spec, topo):
            r = simulate(spec, topo)
            assert r is not None
    """
    assert rules_of(lint(bad, path=TESTS)) == ["api/validate-missing"]
    good = bad.replace("simulate(spec, topo)", "simulate(spec, topo, validate=True)")
    assert lint(good, path=TESTS) == []
    # library code composes engines behind its own validate plumbing
    assert lint(bad, path=SRC) == []


def test_api_validate_reference_engine_exempt():
    good = """
        def test_differential(spec, topo):
            a = ref.simulate(spec, topo)
            b = reference.simulate(spec, topo)
            assert a == b
    """
    assert lint(good, path=TESTS) == []


def test_api_float_eq_ms():
    bad = """
        def test_sum(a_ms, b_ms, c_ms):
            assert a_ms + b_ms == c_ms
    """
    assert rules_of(lint(bad, path=TESTS)) == ["api/float-eq-ms"]
    # stored-value identity and approx comparisons are allowed
    good = """
        def test_sum(a_ms, b_ms, c_ms):
            assert a_ms == b_ms
            assert a_ms + b_ms == pytest.approx(c_ms)
            assert c_ms == 0.0
    """
    assert lint(good, path=TESTS) == []


def test_api_mutable_default():
    bad = """
        def collect(item, acc=[]):
            acc.append(item)
            return acc
    """
    assert rules_of(lint(bad, path=SRC)) == ["api/mutable-default"]
    good = """
        def collect(item, acc=None):
            acc = [] if acc is None else acc
            acc.append(item)
            return acc
    """
    assert lint(good, path=SRC) == []


# ------------------------------------------------- suppressions + baseline


def test_suppression_comment_silences_one_line():
    src = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes  # lint: ok[units/mixed-units]
    """
    assert lint(src) == []


def test_suppression_pass_prefix_matches_all_pass_rules():
    src = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes  # lint: ok[units]
    """
    assert lint(src) == []


def test_suppression_for_wrong_rule_does_not_silence():
    src = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes  # lint: ok[det/wall-clock]
    """
    assert rules_of(lint(src)) == ["units/mixed-units"]


def test_every_rule_has_a_description():
    rules = all_rules()
    assert len(rules) == 12
    for rule, desc in rules.items():
        assert "/" in rule and desc


def test_shipped_baseline_is_empty():
    path = os.path.join(REPO, "analysis_baseline.json")
    with open(path, encoding="utf-8") as f:
        assert json.load(f) == []
    assert load_baseline(path) == set()


def test_baseline_filters_fingerprints(tmp_path):
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps(
        [{"rule": "units/mixed-units", "path": "src/x.py", "line": 3}]
    ))
    known = load_baseline(str(base))
    assert ("units/mixed-units", "src/x.py", 3) in known


@pytest.mark.slow
def test_whole_tree_is_clean():
    """The lint gate itself: src/ + tests/ carry zero findings."""
    findings = analyze_paths([os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    assert findings == [], "\n".join(f.render() for f in findings)


@pytest.mark.slow
def test_cli_exit_codes(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--baseline",
         "analysis_baseline.json", "src", "tests"],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = tmp_path / "dirty.py"
    dirty.write_text("def f(t_ms, n_bytes):\n    return t_ms + n_bytes\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(dirty)],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert r.returncode == 1
    assert "units/mixed-units" in r.stdout
