"""repro.analysis lint suite — positive/negative fixtures per rule.

Every rule gets (at least) one snippet it must fire on and one fixed
form it must stay silent on, plus suppression-comment, baseline, and
whole-tree-clean coverage (ISSUE 8 satellite: the shipped baseline is
empty and stays empty).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import all_rules, analyze_paths, load_baseline, parse_module, run_passes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CORE = "src/repro/core/fixture.py"
SRC = "src/repro/serve/fixture.py"
TESTS = "tests/test_fixture.py"
UNITS = "src/repro/units.py"


def lint(source, path=CORE, rule=None):
    """Run every pass over one in-memory module; optionally filter."""
    mod = parse_module(path, textwrap.dedent(source))
    found = run_passes([mod])
    if rule is not None:
        found = [f for f in found if f.rule == rule]
    return found


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------- units pass


def test_units_mixed_add_fires_and_fixed_form_is_silent():
    bad = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes
    """
    assert rules_of(lint(bad)) == ["units/mixed-units"]
    good = """
        def slack(deadline_ms, arrival_ms):
            return deadline_ms + arrival_ms
    """
    assert lint(good) == []


def test_units_mixed_compare_fires():
    bad = """
        def late(t_ms, size_bytes):
            return t_ms > size_bytes
    """
    assert rules_of(lint(bad)) == ["units/mixed-units"]


def test_units_scale_mismatch_seconds_vs_ms():
    bad = """
        def total(wait_s, step_ms):
            return wait_s + step_ms
    """
    assert rules_of(lint(bad)) == ["units/scale-mismatch"]
    good = """
        def total(wait_s, step_ms):
            return wait_s * 1e3 + step_ms
    """
    assert lint(good) == []


def test_units_bytes_to_bits_without_x8_is_scale_mismatch():
    bad = """
        def mix(a_bits, b_bytes):
            return a_bits + b_bytes
    """
    assert rules_of(lint(bad)) == ["units/scale-mismatch"]
    good = """
        def mix(a_bits, b_bytes):
            return a_bits + b_bytes * 8.0  # lint: ok[units/inline-conversion]
    """
    assert lint(good) == []


def test_units_gbps_window_without_1e6_is_scale_mismatch():
    # Gbps x ms = 1e6 bits; forgetting the 1e6 leaves the wrong scale
    bad = """
        def window(seg_ms, bw_gbps, budget_bits):
            sent_bits = seg_ms * bw_gbps
            return budget_bits - sent_bits
    """
    assert "units/scale-mismatch" in rules_of(lint(bad))
    good = """
        def window(seg_ms, bw_gbps, budget_bits):
            sent_bits = seg_ms * bw_gbps * 1e6  # lint: ok[units/inline-conversion]
            return budget_bits - sent_bits
    """
    assert lint(good) == []


def test_units_propagate_through_assignment_and_call_binding():
    bad = """
        def ser(nbytes, bw_gbps):
            return nbytes / bw_gbps

        def caller(delay_ms, size_bytes):
            t = delay_ms
            return ser(t, size_bytes)
    """
    # delay_ms bound to parameter 'nbytes', size_bytes to 'bw_gbps'
    assert rules_of(lint(bad)) == ["units/mixed-units"]


def test_units_inline_conversion_fires_in_core_only():
    snippet = """
        def ser_ms(nbytes, bw_gbps):
            return (nbytes * 8.0) / (bw_gbps * 1e9) * 1e3
    """
    assert rules_of(lint(snippet, path=CORE)) == ["units/inline-conversion"]
    # the sanctioned module and non-core code are exempt
    assert lint(snippet, path=UNITS) == []
    assert lint(snippet, path=SRC) == []


def test_units_zero_and_epsilon_literals_are_neutral():
    good = """
        def pad(t_ms):
            t_ms += 5.0
            if t_ms > 0:
                return t_ms + 1e-9
            return 0.0
    """
    assert lint(good) == []


# ------------------------------------- units pass: flow-sensitive (CFG) cases
# Each of these is invisible to a per-statement walk: the defect only
# exists in the *join* of two paths, across a loop back edge, through a
# tuple unpacking, or after an augmented reassignment.


def test_units_if_else_join_flags_mixed_paths():
    bad = """
        def pick(flag, a_ms, b_bytes, c_ms):
            if flag:
                x = b_bytes
            else:
                x = a_ms
            return x + c_ms
    """
    found = lint(bad, path=SRC, rule="units/mixed-units")
    assert [f.line for f in found] == [7]
    assert "path-dependent" in found[0].message


def test_units_if_else_join_same_unit_is_silent():
    good = """
        def pick(flag, a_ms, b_ms, c_ms):
            if flag:
                x = a_ms
            else:
                x = b_ms
            return x + c_ms
    """
    assert lint(good, path=SRC) == []


def test_units_correlated_alts_do_not_false_positive():
    # both x and y are ms-or-bytes, branch-correlated; flagging x + y
    # would be wrong on both real paths
    good = """
        def pick(flag, a_ms, b_bytes):
            if flag:
                x = a_ms
                y = a_ms
            else:
                x = b_bytes
                y = b_bytes
            return x + y
    """
    assert lint(good, path=SRC) == []


def test_units_loop_carried_reassignment():
    # t is 0.0 (neutral) on iteration one but seconds on every later
    # iteration: the defect flows around the back edge
    bad = """
        def drain(steps, dt_s):
            t = 0.0
            for _ in steps:
                v_ms = t
                t = dt_s
            return t
    """
    found = lint(bad, path=SRC, rule="units/scale-mismatch")
    assert [f.line for f in found] == [5]


def test_units_tuple_unpack_binds_declared_units():
    bad = """
        def stage(n_bytes):
            a_ms, b = probe()
            return a_ms + n_bytes
    """
    found = lint(bad, path=SRC, rule="units/mixed-units")
    assert [f.line for f in found] == [4]


def test_units_augmented_assign_tracks_conversion():
    # x *= 8.0 converts bytes -> bits, so x + y_bytes is a scale clash
    bad = """
        def grow(x_bytes, y_bytes):
            x = x_bytes
            x *= 8.0
            return x + y_bytes
    """
    found = lint(bad, path=SRC, rule="units/scale-mismatch")
    assert [f.line for f in found] == [5]
    good = """
        def grow(x_bytes, y_bits):
            x = x_bytes
            x *= 8.0
            return x + y_bits
    """
    assert lint(good, path=SRC) == []


# ------------------------------------------------------- determinism pass


def test_det_wall_clock_fires_in_core_only():
    bad = """
        import time

        def stamp():
            return time.perf_counter()
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/wall-clock"]
    assert lint(bad, path=SRC) == []  # serving layer may profile


def test_det_wall_clock_from_import():
    bad = """
        from time import monotonic

        def stamp():
            return monotonic()
    """
    found = lint(bad, path=CORE, rule="det/wall-clock")
    assert len(found) == 2  # the import and the call


def test_det_unseeded_rng():
    bad = """
        import random

        def jitter():
            rng = random.Random()
            return rng.random() + random.uniform(0.0, 1.0)
    """
    found = lint(bad, path=CORE, rule="det/unseeded-rng")
    assert len(found) == 2  # Random() without seed + global uniform()
    good = """
        import random

        def jitter(seed):
            rng = random.Random(seed)
            return rng.random()
    """
    assert lint(good, path=CORE) == []


def test_det_numpy_global_rng():
    bad = """
        import numpy as np

        def draw():
            return np.random.rand(3)
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/unseeded-rng"]
    good = """
        import numpy as np

        def draw(seed):
            return np.random.default_rng(seed).random(3)
    """
    assert lint(good, path=CORE) == []


def test_det_set_iteration_fires_and_sorted_is_sanctioned():
    bad = """
        def order(names):
            pending = set(names)
            out = []
            for n in pending:
                out.append(n)
            return out
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/set-iteration"]
    good = bad.replace("for n in pending:", "for n in sorted(pending):")
    assert lint(good, path=CORE) == []


def test_det_list_wrapper_does_not_sanction_hash_order():
    bad = """
        def order(names):
            pending = set(names)
            return [n for n in list(pending)]
    """
    assert rules_of(lint(bad, path=CORE)) == ["det/set-iteration"]


def test_det_membership_and_len_are_exempt():
    good = """
        def stats(names, probe):
            pending = set(names)
            return probe in pending, len(pending), min(pending)
    """
    assert lint(good, path=CORE) == []


# ------------------------------------------------------- concurrency pass


def test_conc_queue_empty_poll():
    bad = """
        import queue

        class Writer:
            def __init__(self):
                self._q = queue.Queue()

            def wait(self):
                while not self._q.empty():
                    pass
    """
    assert rules_of(lint(bad, path=SRC)) == ["conc/queue-empty-poll"]
    good = """
        import queue

        class Writer:
            def __init__(self):
                self._q = queue.Queue()

            def wait(self):
                self._q.join()
    """
    assert lint(good, path=SRC) == []


def test_conc_unlocked_shared_write():
    bad = """
        import threading

        class Worker:
            def __init__(self):
                self.n = 0
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def _run(self):
                self.n += 1

            def reset(self):
                self.n = 0

            def stop(self):
                self._t.join()
    """
    assert rules_of(lint(bad, path=SRC)) == ["conc/unlocked-shared-write"]
    good = """
        import threading

        class Worker:
            def __init__(self):
                self.n = 0
                self._lock = threading.Lock()
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def _run(self):
                with self._lock:
                    self.n += 1

            def reset(self):
                with self._lock:
                    self.n = 0

            def stop(self):
                self._t.join()
    """
    assert lint(good, path=SRC) == []


def test_conc_thread_no_join():
    bad = """
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            return t
    """
    assert rules_of(lint(bad, path=SRC)) == ["conc/thread-no-join"]
    good = """
        import threading

        def run(fn):
            t = threading.Thread(target=fn)
            t.start()
            t.join()
    """
    assert lint(good, path=SRC) == []


def test_conc_pass_skips_tests_and_threadless_modules():
    snippet = """
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn)
            t.start()
    """
    assert lint(snippet, path=TESTS) == []  # tests may leak threads
    assert lint("x = 1\n", path=SRC) == []


# -------------------------------------------------------------- api pass


def test_api_validate_missing_in_tests_only():
    bad = """
        def test_runs(spec, topo):
            r = simulate(spec, topo)
            assert r is not None
    """
    assert rules_of(lint(bad, path=TESTS)) == ["api/validate-missing"]
    good = bad.replace("simulate(spec, topo)", "simulate(spec, topo, validate=True)")
    assert lint(good, path=TESTS) == []
    # library code composes engines behind its own validate plumbing
    assert lint(bad, path=SRC) == []


def test_api_validate_reference_engine_exempt():
    good = """
        def test_differential(spec, topo):
            a = ref.simulate(spec, topo)
            b = reference.simulate(spec, topo)
            assert a == b
    """
    assert lint(good, path=TESTS) == []


def test_api_float_eq_ms():
    bad = """
        def test_sum(a_ms, b_ms, c_ms):
            assert a_ms + b_ms == c_ms
    """
    assert rules_of(lint(bad, path=TESTS)) == ["api/float-eq-ms"]
    # stored-value identity and approx comparisons are allowed
    good = """
        def test_sum(a_ms, b_ms, c_ms):
            assert a_ms == b_ms
            assert a_ms + b_ms == pytest.approx(c_ms)
            assert c_ms == 0.0
    """
    assert lint(good, path=TESTS) == []


def test_api_mutable_default():
    bad = """
        def collect(item, acc=[]):
            acc.append(item)
            return acc
    """
    assert rules_of(lint(bad, path=SRC)) == ["api/mutable-default"]
    good = """
        def collect(item, acc=None):
            acc = [] if acc is None else acc
            acc.append(item)
            return acc
    """
    assert lint(good, path=SRC) == []


# ------------------------------------------------------------- taint pass


def test_taint_wall_clock_into_stats_direct():
    bad = """
        import time

        def finish(stats):
            stats["elapsed_ms"] = time.perf_counter()
    """
    assert rules_of(lint(bad, path=SRC)) == ["taint/wall-time"]
    good = """
        def finish(stats, now_ms):
            stats["elapsed_ms"] = now_ms
    """
    assert lint(good, path=SRC) == []


def test_taint_flows_through_callee_return():
    # interprocedural: the wall read is inside a helper; only its
    # *return value* reaches the sink
    bad = """
        import time

        def now_ms():
            return time.time() * 1e3

        def finish(stats):
            stats["elapsed_ms"] = now_ms()
    """
    found = lint(bad, path=SRC, rule="taint/wall-time")
    assert len(found) == 1
    assert found[0].line == 8


def test_taint_flows_through_sink_parameter():
    # interprocedural the other way: the sink is inside the callee and
    # the wall value arrives through an argument
    bad = """
        import time

        def record(stats, v):
            stats["t_ms"] = v

        def finish(stats):
            record(stats, time.perf_counter())
    """
    found = lint(bad, path=SRC, rule="taint/wall-time")
    assert len(found) == 1


def test_taint_event_constructor_and_tracer_method():
    bad = """
        from datetime import datetime

        def mark(tracer):
            tracer.instant("boot", t_ms=datetime.now().timestamp())
    """
    assert rules_of(lint(bad, path=SRC)) == ["taint/wall-time"]


def test_taint_seeded_rng_and_sim_clock_are_clean():
    good = """
        import random

        def jitter(stats, seed, clock_ms):
            rng = random.Random(seed)
            stats["jitter_ms"] = clock_ms + rng.random()
    """
    assert lint(good, path=SRC) == []


def test_taint_branch_join_keeps_taint_alive():
    # the wall value only taints x on one path — still a finding,
    # because that path can execute
    bad = """
        import time

        def finish(stats, flag, sim_ms):
            if flag:
                x = time.monotonic()
            else:
                x = sim_ms
            stats["t_ms"] = x
    """
    found = lint(bad, path=SRC, rule="taint/wall-time")
    assert [f.line for f in found] == [9]


# -------------------------------------------------------------- res pass


def test_res_file_no_close_fires_and_with_is_silent():
    bad = """
        def dump(path, payload):
            fh = open(path, "w")
            fh.write(payload)
            fh.close()
    """
    assert rules_of(lint(bad, path=SRC)) == ["res/file-no-close"]
    good = """
        def dump(path, payload):
            with open(path, "w") as fh:
                fh.write(payload)
    """
    assert lint(good, path=SRC) == []


def test_res_file_close_in_finally_is_silent():
    good = """
        def dump(path, payload):
            fh = open(path, "w")
            try:
                fh.write(payload)
            finally:
                fh.close()
    """
    assert lint(good, path=SRC) == []


def test_res_file_that_escapes_is_exempt():
    good = """
        def grab(path):
            fh = open(path, "rb")
            return fh
    """
    assert lint(good, path=SRC) == []


def test_res_lock_no_release():
    bad = """
        import threading

        lock = threading.Lock()

        def bump(state):
            lock.acquire()
            state.n += 1
            lock.release()
    """
    assert rules_of(lint(bad, path=SRC)) == ["res/lock-no-release"]
    good = """
        import threading

        lock = threading.Lock()

        def bump(state):
            lock.acquire()
            try:
                state.n += 1
            finally:
                lock.release()
    """
    assert lint(good, path=SRC) == []


def test_res_thread_raise_between_start_and_join():
    bad = """
        import threading

        def run(fn, ready):
            t = threading.Thread(target=fn)
            t.start()
            if not ready:
                raise RuntimeError("not ready")
            t.join()
    """
    assert rules_of(lint(bad, path=SRC)) == ["res/thread-leak-on-raise"]
    good = """
        import threading

        def run(fn, ready):
            t = threading.Thread(target=fn)
            t.start()
            try:
                if not ready:
                    raise RuntimeError("not ready")
            finally:
                t.join()
    """
    assert lint(good, path=SRC) == []


def test_res_daemon_thread_is_exempt():
    good = """
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            t.join()
    """
    assert lint(good, path=SRC) == []


# ------------------------------------------------------------ schema pass


def test_schema_unregistered_stats_key_fires_in_core():
    bad = """
        def finalize(stats):
            stats["zzz_bogus_key"] = 1.0
    """
    assert rules_of(lint(bad, path=CORE)) == ["schema/unregistered-stats-key"]
    # registered segment names are accepted at any nesting level
    good = """
        def finalize(stats):
            stats["events"] = 0
    """
    assert lint(good, path=CORE) == []
    # outside core/obs the pass is silent (scratch dicts, serving layer)
    assert lint(bad, path=SRC) == []


def test_schema_checks_update_kwargs_and_dict_literals():
    bad = """
        def finalize(stats):
            stats.update(zzz_bogus_key=1.0)
    """
    assert rules_of(lint(bad, path=CORE)) == ["schema/unregistered-stats-key"]
    bad_literal = """
        def build(result):
            result.stats = {"zzz_bogus_key": 1.0}
    """
    assert rules_of(lint(bad_literal, path=CORE)) == ["schema/unregistered-stats-key"]


def test_schema_variable_keys_are_map_data_not_schema():
    good = """
        def tally(stats, name):
            stats[name] = 1.0
    """
    assert lint(good, path=CORE) == []


# ------------------------------------------------- suppressions + baseline


def test_suppression_comment_silences_one_line():
    src = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes  # lint: ok[units/mixed-units]
    """
    assert lint(src) == []


def test_suppression_pass_prefix_matches_all_pass_rules():
    src = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes  # lint: ok[units]
    """
    assert lint(src) == []


def test_suppression_for_wrong_rule_does_not_silence():
    # the units finding survives, and the suppression audit flags the
    # det/ comment as silencing nothing on its line
    src = """
        def slack(deadline_ms, payload_bytes):
            return deadline_ms + payload_bytes  # lint: ok[det/wall-clock]
    """
    assert rules_of(lint(src)) == ["lint/unused-suppression", "units/mixed-units"]


def test_unknown_rule_in_suppression_is_a_finding():
    src = """
        def f(x):
            return x  # lint: ok[bogus/no-such-rule]
    """
    found = lint(src, rule="lint/unknown-rule")
    assert len(found) == 1
    assert "bogus/no-such-rule" in found[0].message


def test_unused_suppression_is_a_finding():
    src = """
        def f(a_ms, b_ms):
            return a_ms + b_ms  # lint: ok[units/mixed-units]
    """
    assert rules_of(lint(src)) == ["lint/unused-suppression"]


def test_used_suppressions_in_frozen_reference_are_not_flagged():
    """Positive control: reference.py's shipped suppressions still match
    live findings, so the audit stays silent on the real tree file."""
    path = os.path.join(REPO, "src", "repro", "core", "reference.py")
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    assert "lint: ok[" in source  # the control is meaningful
    mod = parse_module("src/repro/core/reference.py", source)
    assert run_passes([mod]) == []


def test_meta_rules_cannot_be_suppressed():
    src = """
        def f(a_ms, b_ms):
            return a_ms + b_ms  # lint: ok[units/mixed-units]  # lint: ok[lint/unused-suppression]
    """
    assert "lint/unused-suppression" in rules_of(lint(src))


def test_every_rule_has_a_description():
    rules = all_rules()
    assert len(rules) == 19
    for rule, desc in rules.items():
        assert "/" in rule and desc


def test_shipped_baseline_is_empty():
    path = os.path.join(REPO, "analysis_baseline.json")
    with open(path, encoding="utf-8") as f:
        assert json.load(f) == []
    assert load_baseline(path) == set()


def test_baseline_filters_fingerprints(tmp_path):
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps(
        [{"rule": "units/mixed-units", "path": "src/x.py", "line": 3}]
    ))
    known = load_baseline(str(base))
    assert ("units/mixed-units", "src/x.py", 3) in known


# ------------------------------------------------------- autofix + SARIF


FIXABLE_SRC = """\
def order(names, acc=[]):
    pending = set(names)
    for n in pending:
        acc.append(n)
    return acc
"""


def test_autofix_rewrites_and_is_idempotent():
    from repro.analysis.fix import FIXABLE_RULES, apply_fixes

    mod = parse_module(CORE, FIXABLE_SRC)
    first = run_passes([mod])
    assert sorted({f.rule for f in first}) == [
        "api/mutable-default", "det/set-iteration",
    ]
    fixed = apply_fixes([mod], first)[CORE]
    assert "sorted(pending)" in fixed
    assert "acc=None" in fixed and "if acc is None:" in fixed

    mod2 = parse_module(CORE, fixed)
    second = run_passes([mod2])
    assert [f for f in second if f.rule in FIXABLE_RULES] == []
    # --fix twice is a no-op: nothing left to rewrite
    assert apply_fixes([mod2], second) == {}


def test_autofix_only_touches_flagged_sites():
    from repro.analysis.fix import apply_fixes

    src = """\
def order(names, keep):
    for n in sorted(set(names)):
        keep.append(n)
    return keep
"""
    mod = parse_module(CORE, src)
    findings = run_passes([mod])
    assert findings == []
    assert apply_fixes([mod], findings) == {}


def test_sarif_payload_shape():
    from repro.analysis.sarif import SARIF_VERSION, sarif_payload

    mod = parse_module(CORE, "def f(t_ms, n_bytes):\n    return t_ms + n_bytes\n")
    findings = run_passes([mod])
    assert findings
    doc = sarif_payload(findings)
    assert doc["version"] == SARIF_VERSION == "2.1.0"
    assert doc["$schema"].endswith("sarif-2.1.0.json")
    run = doc["runs"][0]
    driver = run["tool"]["driver"]
    assert driver["name"] == "repro.analysis"
    rule_ids = [r["id"] for r in driver["rules"]]
    assert rule_ids == sorted(all_rules())
    for res in run["results"]:
        # ruleIndex must agree with the driver rule table
        assert rule_ids[res["ruleIndex"]] == res["ruleId"]
        assert res["level"] == "error"
        loc = res["locations"][0]["physicalLocation"]
        assert loc["artifactLocation"]["uriBaseId"] == "%SRCROOT%"
        assert loc["region"]["startLine"] >= 1
        assert loc["region"]["startColumn"] >= 1
        fp = res["partialFingerprints"]["reproAnalysisFingerprint/v1"]
        assert fp == f"{res['ruleId']}:{CORE}:{loc['region']['startLine']}"
    # round-trips through JSON (what --sarif writes)
    assert json.loads(json.dumps(doc)) == doc


@pytest.mark.slow
def test_cli_fix_and_sarif(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    target = tmp_path / "src" / "repro" / "core" / "dirty.py"
    target.parent.mkdir(parents=True)
    target.write_text(FIXABLE_SRC)
    sarif_out = tmp_path / "out.sarif"

    first = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--sarif", str(sarif_out),
         str(target)],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert first.returncode == 1
    doc = json.loads(sarif_out.read_text())
    assert {r["ruleId"] for r in doc["runs"][0]["results"]} == {
        "api/mutable-default", "det/set-iteration",
    }

    fix = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--fix", str(target)],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    # the rewrite clears every finding, so the re-lint exits clean
    assert fix.returncode == 0, fix.stdout + fix.stderr
    assert "fixed:" in fix.stderr
    assert "sorted(pending)" in target.read_text()

    again = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--fix", str(target)],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert again.returncode == 0
    assert "fixed:" not in again.stderr  # idempotent: no second rewrite


@pytest.mark.slow
def test_whole_tree_is_clean():
    """The lint gate itself: src/ + tests/ carry zero findings."""
    findings = analyze_paths([os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    assert findings == [], "\n".join(f.render() for f in findings)


@pytest.mark.slow
def test_cli_exit_codes(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--baseline",
         "analysis_baseline.json", "src", "tests"],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = tmp_path / "dirty.py"
    dirty.write_text("def f(t_ms, n_bytes):\n    return t_ms + n_bytes\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(dirty)],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert r.returncode == 1
    assert "units/mixed-units" in r.stdout
