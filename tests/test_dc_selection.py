"""Algorithm 1 (DC selection / what-if) — paper §4.5 + Fig 12."""
import math

import pytest

from repro.core import wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan, what_if

JOB = JobModel(
    t_fwd_ms=10.0,
    act_bytes=2 * 10.0e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,  # C = 2
    partition_param_bytes=800e6 * 2,
    microbatches=60,
)


def test_comm_compute_ratio():
    assert JOB.comm_compute_ratio == pytest.approx(2.0)


def test_fig12_small_increment_rejected():
    """F=10%: Algorithm 1 falls back to DC1 only — no throughput gain."""
    base = best_plan(algorithm1(JOB, {"dc1": 600}, P=60, C=2))
    plus10 = best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 60}, P=60, C=2))
    assert plus10.partitions.get("dc2", 0) == 0
    assert plus10.throughput == pytest.approx(base.throughput)


def test_fig12_balanced_distribution_helps():
    """F=100%: two equal DCs ~2x one DC's throughput."""
    base = best_plan(algorithm1(JOB, {"dc1": 600}, P=60, C=2))
    both = best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 600}, P=60, C=2))
    assert both.throughput / base.throughput > 1.8


def test_throughput_monotone_in_gpus():
    """Adding GPUs never hurts (Algorithm 1 can always ignore them)."""
    prev = 0.0
    for f in range(0, 11):
        b = best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 60 * f}, P=60, C=2))
        assert b.throughput >= prev - 1e-12
        prev = b.throughput


def test_staircase_plateaus():
    """Fig 12's staircase: gains arrive in discrete D increments."""
    thr = [
        best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 60 * f}, P=60, C=2)).throughput
        for f in range(0, 11)
    ]
    distinct = len({round(t, 9) for t in thr})
    assert distinct < len(thr)  # at least one plateau


def test_infeasible_when_not_enough_gpus():
    plans = algorithm1(JOB, {"dc1": 60}, P=60, C=2, D_max=2)
    assert all(math.isinf(p.total_ms) or p.D * 2 * 60 <= 60 for p in plans)
    # D=1 needs 1*2*60=120 GPUs > 60 => infeasible
    assert math.isinf(plans[0].total_ms)


def test_partitions_follow_dc_order_greedy():
    plans = algorithm1(
        JOB, {"big": 600, "small": 240}, P=60, C=2, dc_order=["big", "small"]
    )
    p1 = plans[0]  # D=1: per-DC partitions = gpus // (D*C)
    assert p1.partitions["big"] == 60  # 600//2 = 300 >= 60 partitions
    assert p1.partitions.get("small", 0) == 0


def test_what_if_reports_cost():
    out = what_if(JOB, {"one": {"a": 600}, "two": {"a": 600, "b": 600}}, P=60, C=2)
    assert set(out) == {"one", "two"}
    for v in out.values():
        assert v["cost_per_iteration"] > 0
        assert v["throughput"] > 0
    assert out["two"]["throughput"] > out["one"]["throughput"]


def test_algorithm1_fast():
    """Paper: 5 DCs × 600 GPUs sweeps in <1 min; ours is near-instant."""
    import time

    t0 = time.time()
    algorithm1(JOB, {f"dc{i}": 600 for i in range(5)}, P=60, C=2)
    assert time.time() - t0 < 5.0
