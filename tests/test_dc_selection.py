"""Algorithm 1 (DC selection / what-if) — paper §4.5 + Fig 12."""
import math

import pytest

from repro.core import wan
from repro.core.dc_selection import JobModel, algorithm1, best_plan, what_if

JOB = JobModel(
    t_fwd_ms=10.0,
    act_bytes=2 * 10.0e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,  # C = 2
    partition_param_bytes=800e6 * 2,
    microbatches=60,
)


def test_comm_compute_ratio():
    assert JOB.comm_compute_ratio == pytest.approx(2.0)


def test_fig12_small_increment_rejected():
    """F=10%: Algorithm 1 falls back to DC1 only — no throughput gain."""
    base = best_plan(algorithm1(JOB, {"dc1": 600}, P=60, C=2))
    plus10 = best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 60}, P=60, C=2))
    assert plus10.partitions.get("dc2", 0) == 0
    assert plus10.throughput == pytest.approx(base.throughput)


def test_fig12_balanced_distribution_helps():
    """F=100%: two equal DCs ~2x one DC's throughput."""
    base = best_plan(algorithm1(JOB, {"dc1": 600}, P=60, C=2))
    both = best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 600}, P=60, C=2))
    assert both.throughput / base.throughput > 1.8


def test_throughput_monotone_in_gpus():
    """Adding GPUs never hurts (Algorithm 1 can always ignore them)."""
    prev = 0.0
    for f in range(0, 11):
        b = best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 60 * f}, P=60, C=2))
        assert b.throughput >= prev - 1e-12
        prev = b.throughput


def test_staircase_plateaus():
    """Fig 12's staircase: gains arrive in discrete D increments."""
    thr = [
        best_plan(algorithm1(JOB, {"dc1": 600, "dc2": 60 * f}, P=60, C=2)).throughput
        for f in range(0, 11)
    ]
    distinct = len({round(t, 9) for t in thr})
    assert distinct < len(thr)  # at least one plateau


def test_infeasible_when_not_enough_gpus():
    plans = algorithm1(JOB, {"dc1": 60}, P=60, C=2, D_max=2)
    assert all(math.isinf(p.total_ms) or p.D * 2 * 60 <= 60 for p in plans)
    # D=1 needs 1*2*60=120 GPUs > 60 => infeasible
    assert math.isinf(plans[0].total_ms)


def test_partitions_follow_dc_order_greedy():
    plans = algorithm1(
        JOB, {"big": 600, "small": 240}, P=60, C=2, dc_order=["big", "small"]
    )
    p1 = plans[0]  # D=1: per-DC partitions = gpus // (D*C)
    assert p1.partitions["big"] == 60  # 600//2 = 300 >= 60 partitions
    assert p1.partitions.get("small", 0) == 0


def test_what_if_reports_cost():
    out = what_if(JOB, {"one": {"a": 600}, "two": {"a": 600, "b": 600}}, P=60, C=2)
    assert set(out) == {"one", "two"}
    for v in out.values():
        assert v["cost_per_iteration"] > 0
        assert v["throughput"] > 0
    assert out["two"]["throughput"] > out["one"]["throughput"]


def test_algorithm1_fast():
    """Paper: 5 DCs × 600 GPUs sweeps in <1 min; ours is near-instant."""
    import time

    t0 = time.time()
    algorithm1(JOB, {f"dc{i}": 600 for i in range(5)}, P=60, C=2)
    assert time.time() - t0 < 5.0


# --------------------------------------------------- placement-order search


def _named_job(topo, M=24):
    import dataclasses

    return dataclasses.replace(JOB, microbatches=M, topology=topo)


def _random_named_topo(n, seed):
    import random

    from repro.core import topology as tp

    rng = random.Random(seed)
    lat = [[0.0] * n for _ in range(n)]
    for a in range(n):
        for b in range(a + 1, n):
            lat[a][b] = lat[b][a] = float(rng.choice([5, 10, 20, 40, 80, 150]))
    return tp.TopologyMatrix.from_latency(
        lat, multi_tcp=True, dc_names=tuple(f"dc{i}" for i in range(n))
    )


def test_bnb_matches_exhaustive_on_presets():
    """The branch-and-bound order search must return the same best plan
    as the exhaustive permutation scan on every named preset topology."""
    from repro.core import topology as tp

    cases = [
        (tp.skewed_3dc(), {"dc0": 8, "dc1": 8, "dc2": 10}, 12),
        (tp.azure_testbed(), {n: 8 for n in tp.azure_testbed().dc_names}, 12),
        (tp.TopologyMatrix.uniform(3, 10.0, dc_names=("dc0", "dc1", "dc2")),
         {"dc0": 8, "dc1": 8, "dc2": 10}, 12),
    ]
    for topo, fleet, P in cases:
        job = _named_job(topo)
        pb = algorithm1(job, fleet, P=P, C=2, search_orders=True, order_search="bnb")
        pe = algorithm1(job, fleet, P=P, C=2, search_orders=True,
                        order_search="exhaustive")
        for b, e in zip(pb, pe):
            if math.isinf(e.total_ms):
                assert math.isinf(b.total_ms)
                continue
            assert b.total_ms == pytest.approx(e.total_ms, rel=1e-9)
            nzb = {d: k for d, k in b.partitions.items() if k}
            nze = {d: k for d, k in e.partitions.items() if k}
            assert nzb == nze, (topo.name, b.dc_order, e.dc_order)


def test_bnb_matches_exhaustive_on_random_wans():
    """Negative-control sweep: random ≤6-DC WAN matrices with uneven
    fleets — branch-and-bound and exhaustive must agree on cost and on
    the (nonzero) partition placement."""
    import random

    rng = random.Random(7)
    for trial in range(12):
        n = rng.choice([3, 4, 5, 6])
        topo = _random_named_topo(n, seed=100 + trial)
        fleet = {f"dc{i}": rng.choice([0, 4, 8, 12]) for i in range(n)}
        P = rng.choice([6, 9, 12])
        job = _named_job(topo, M=rng.choice([16, 60]))
        pb = algorithm1(job, fleet, P=P, C=2, search_orders=True, order_search="bnb")
        pe = algorithm1(job, fleet, P=P, C=2, search_orders=True,
                        order_search="exhaustive")
        for b, e in zip(pb, pe):
            if math.isinf(e.total_ms):
                assert math.isinf(b.total_ms), trial
                continue
            assert b.total_ms == pytest.approx(e.total_ms, rel=1e-9), trial
            nzb = {d: k for d, k in b.partitions.items() if k}
            nze = {d: k for d, k in e.partitions.items() if k}
            assert nzb == nze, (trial, b.dc_order, e.dc_order)


def test_bnb_handles_8_dcs_under_a_second():
    """Acceptance: 8 named DCs, every DC required, in < 1 s (the
    exhaustive scan would evaluate 40320 permutations per D)."""
    import time

    topo = _random_named_topo(8, seed=1)
    fleet = {f"dc{i}": 4 for i in range(8)}
    job = _named_job(topo, M=60)
    t0 = time.perf_counter()
    plans = algorithm1(job, fleet, P=16, C=2, search_orders=True)
    dt = time.perf_counter() - t0
    assert dt < 1.0, dt
    assert best_plan(plans).total_ms < float("inf")
    used = [d for d in best_plan(plans).dc_order
            if best_plan(plans).partitions.get(d, 0)]
    assert len(used) == 8  # the fleet forces a full 8-DC span


def test_order_search_caps_and_errors():
    topo = _random_named_topo(6, seed=3)
    job = _named_job(topo)
    fleet = {f"dc{i}": 8 for i in range(6)}
    with pytest.raises(ValueError):
        algorithm1(job, fleet, P=12, C=2, search_orders=True, order_search="nope")
    big = _random_named_topo(13, seed=4)
    big_fleet = {f"dc{i}": 8 for i in range(13)}
    with pytest.raises(ValueError):
        algorithm1(_named_job(big), big_fleet, P=12, C=2, search_orders=True)


def test_latency_pp_memoized():
    from repro.core import dc_selection as dcs
    from repro.core.dc_selection import get_latency_pp

    topo = _random_named_topo(3, seed=9)
    job = _named_job(topo)
    part = {"dc0": 4, "dc1": 4, "dc2": 4}
    v1 = get_latency_pp(job, part, ("dc0", "dc1", "dc2"), 2)
    n = len(dcs._PP_MEMO)
    v2 = get_latency_pp(job, dict(part), ["dc0", "dc1", "dc2"], 2)
    assert v1 == v2
    assert len(dcs._PP_MEMO) == n  # second call was a cache hit
