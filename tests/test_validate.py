"""Schedule-invariant checker: positive runs on all four policies and
negative runs on deliberately corrupted schedules."""
import copy

import pytest

from repro.core import temporal
from repro.core import topology as tp
from repro.core import validate as V
from repro.core.simulator import GeoTopology, PipelineSpec, simulate

POLICIES = ("gpipe", "megatron", "varuna", "atlas")


def _spec(stage_dc=(0, 0, 1, 2), M=8, **kw):
    return PipelineSpec(
        num_stages=len(stage_dc), microbatches=M, t_fwd_ms=10.0,
        act_bytes=1.5e8, stage_dc=tuple(stage_dc), stage_param_bytes=8e8,
        **kw,
    )


TOPOS = {
    "uniform": GeoTopology(wan_latency_ms=40.0, multi_tcp=True),
    "skewed": tp.skewed_3dc(),
    "azure": tp.azure_testbed(),
}


# ---------------------------------------------------------------- positive


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("topo_name", list(TOPOS))
def test_all_policies_pass_invariants(policy, topo_name):
    spec = _spec()
    res = simulate(spec, TOPOS[topo_name], policy=policy, n_pipelines=2,
                   validate=True)
    V.check_sim_result(res, spec, policy=policy)  # pytest-helper form
    assert 0.0 <= res.utilization <= 1.0


def test_validate_flag_equivalent_to_helper():
    spec = _spec()
    topo = TOPOS["uniform"]
    r1 = simulate(spec, topo, policy="varuna", validate=True)
    r2 = simulate(spec, topo, policy="varuna", validate=True)
    assert r1.iteration_ms == r2.iteration_ms


def test_atlas_schedule_passes_and_agrees_with_simulator():
    spec = _spec()
    for topo in TOPOS.values():
        sched = temporal.atlas_schedule(spec, topo, 2)
        V.check_schedule(sched, spec, topo)
        V.check_atlas_consistency(spec, topo, n_pipelines=2, dp_replicas=2)


def test_inflight_cap_respected_by_atlas():
    spec = _spec(M=8, inflight_cap=2)
    topo = TOPOS["uniform"]
    sched = temporal.atlas_schedule(spec, topo, 1, inflight_cap=2)
    V.check_schedule(sched, spec, topo, inflight_cap=2)


# ---------------------------------------------------------------- negative


def _valid_result(policy="varuna"):
    spec = _spec()
    res = simulate(spec, TOPOS["uniform"], policy=policy, validate=True)
    return spec, res


def test_detects_gpu_overlap():
    spec, res = _valid_result()
    g = (0, 1)
    ivs = sorted(res.busy[g], key=lambda iv: iv.start)
    ivs[1].start = ivs[0].start  # two tasks at once on one GPU
    ivs[1].end = ivs[0].end
    with pytest.raises(V.InvariantViolation):
        V.check_sim_result(res, spec, policy="varuna")


def test_detects_backward_before_forward():
    spec, res = _valid_result()
    g = (0, spec.num_stages - 1)
    bwd = next(iv for iv in res.busy[g] if iv.kind == "bwd")
    fwd = next(iv for iv in res.busy[g] if iv.kind == "fwd" and iv.micro == bwd.micro)
    bwd.start, bwd.end = fwd.start - 30.0, fwd.start - 10.0
    with pytest.raises(V.InvariantViolation):
        V.check_sim_result(res, spec, policy="varuna")


def test_detects_missing_task():
    spec, res = _valid_result()
    res.busy[(0, 0)].pop()
    with pytest.raises(V.InvariantViolation):
        V.check_sim_result(res, spec, policy="varuna")


def test_detects_bogus_utilization():
    spec, res = _valid_result()
    res.utilization = 1.7
    with pytest.raises(V.InvariantViolation):
        V.check_sim_result(res, spec, policy="varuna")


def test_detects_transfer_beating_bandwidth():
    """A transfer occupying the channel for less than bytes/bandwidth is
    physically impossible and must be flagged."""
    spec = _spec()
    topo = TOPOS["uniform"]
    sched = temporal.atlas_schedule(spec, topo, 2)
    wan_trs = [tr for tr in sched.transfers
               if spec.stage_dc[tr.boundary] != spec.stage_dc[tr.boundary + 1]]
    tr = wan_trs[0]
    tr.end = tr.start + (tr.end - tr.start) * 0.25  # 4x the link speed
    with pytest.raises(V.InvariantViolation):
        V.check_schedule(sched, spec, topo)


def test_detects_channel_double_booking():
    spec = _spec()
    topo = TOPOS["uniform"]
    sched = temporal.atlas_schedule(spec, topo, 2)
    wan_b = next(b for b in range(spec.num_stages - 1)
                 if spec.stage_dc[b] != spec.stage_dc[b + 1])
    trs = sorted((tr for tr in sched.transfers
                  if tr.boundary == wan_b and tr.direction == "act"),
                 key=lambda tr: tr.start)
    a, b = trs[0], trs[1]
    dur = b.end - b.start
    shift = b.start - a.start  # slide b fully onto a's occupancy window
    b.start, b.end, b.arrive = a.start, a.start + dur, b.arrive - shift
    with pytest.raises(V.InvariantViolation):
        V.check_schedule(sched, spec, topo)


def test_detects_makespan_mismatch():
    spec = _spec()
    topo = TOPOS["uniform"]
    sched = temporal.atlas_schedule(spec, topo, 1)
    sched = copy.deepcopy(sched)
    sched.makespan *= 0.5
    with pytest.raises(V.InvariantViolation):
        V.check_schedule(sched, spec, topo)
