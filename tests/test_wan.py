"""WAN transport model — paper Table 1 / Fig 5 / §4.1."""
import pytest

from repro.core import wan


def test_table1_single_tcp_bandwidth():
    """Model must match the paper's measured Table 1 within 10%."""
    for latency_ms, mbps in wan.PAPER_TABLE1.items():
        got = wan.tcp_single_bw_gbps(latency_ms) * 1e3
        assert abs(got - mbps) / mbps < 0.10, (latency_ms, got, mbps)


def test_single_tcp_monotone_in_latency():
    prev = float("inf")
    for lat in (5, 10, 20, 30, 40, 80, 160):
        bw = wan.tcp_single_bw_gbps(lat)
        assert bw <= prev
        prev = bw


def test_multi_tcp_caps_at_5gbps():
    """Fig 5: aggregate grows ~linearly then clamps at the node-pair cap,
    irrespective of distance."""
    for lat in (10, 40, 100, 200):
        n = wan.connections_for_cap(lat)
        assert wan.tcp_multi_bw_gbps(lat, n) == pytest.approx(wan.NODE_PAIR_CAP_GBPS)
        # one fewer connection is below the cap
        assert wan.tcp_multi_bw_gbps(lat, n - 1) < wan.NODE_PAIR_CAP_GBPS or n == 1
    # scaling is linear pre-cap
    assert wan.tcp_multi_bw_gbps(40, 2) == pytest.approx(
        2 * wan.tcp_single_bw_gbps(40)
    )


def test_multi_tcp_speedup_magnitude():
    """§4.1: ~250 Mbps -> 5 Gbps cuts transfer latency ~20x."""
    single = wan.tcp_single_bw_gbps(47)  # ~0.25 Gbps
    assert wan.NODE_PAIR_CAP_GBPS / single == pytest.approx(20, rel=0.15)


def test_allreduce_formula():
    # 2·P·(N-1)/N bytes at BW; N=2, 1GB, 100 Gbps
    ms = wan.allreduce_ms(1e9, 2, 100.0)
    assert ms == pytest.approx(1e9 * 8 / 100e9 * 1e3, rel=1e-6)
    assert wan.allreduce_ms(1e9, 1, 100.0) == 0.0


def test_activation_bytes():
    # B·L·H·2 (fp16) — paper §3.2 footnote 2
    assert wan.activation_bytes(1, 6144, 8192) == 6144 * 8192 * 2
