"""Pallas kernel allclose tests: shape/dtype sweeps against the pure-jnp
oracles in repro.kernels.ref (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# full shape sweep in f32; bf16 tolerance is covered on one shape per
# causal mode (each cell is a separate pallas-interpret compile)
@pytest.mark.parametrize("T,Hq,Hkv,D,dtype,causal", [
    (128, 4, 4, 64, jnp.float32, True),
    (128, 4, 4, 64, jnp.float32, False),
    (128, 8, 2, 64, jnp.float32, True),
    (128, 8, 2, 64, jnp.float32, False),
    (128, 6, 1, 32, jnp.float32, True),
    (128, 6, 1, 32, jnp.float32, False),
    (128, 4, 4, 64, jnp.bfloat16, True),
    (128, 8, 2, 64, jnp.bfloat16, False),
])
def test_flash_attention(T, Hq, Hkv, D, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, T, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("S,Hq,Hkv,D,window,dtype", [
    (256, 4, 4, 64, None, jnp.float32),
    (256, 8, 2, 64, 128, jnp.float32),
    (256, 4, 1, 32, 64, jnp.float32),
    (256, 4, 4, 64, None, jnp.bfloat16),
])
def test_decode_attention(S, Hq, Hkv, D, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B = 3
    q = jax.random.normal(ks[0], (B, 1, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    # ring-buffer-like positions with empty slots
    kv_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    kv_pos = jnp.where(kv_pos < S - 37, kv_pos, -1)
    q_pos = jnp.full((B, 1), S - 40, jnp.int32)
    o = ops.decode_attention(q, k, v, q_pos, kv_pos, window=window, block_kv=128)
    o_ref = ref.decode_attention_ref(q, k, v, q_pos, kv_pos, window=window)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", [(512, 128), (3, 256, 64), (2, 4, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), shape, dtype)
    sc = jax.random.normal(jax.random.PRNGKey(3), shape[-1:], jnp.float32)
    o = ops.rmsnorm(x, sc, block_rows=64)
    o_ref = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("T,H,D,chunk", [(128, 2, 64, 32), (96, 4, 32, 32), (128, 1, 64, 64)])
def test_wkv6_vs_sequential(T, H, D, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    B = 2
    r = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, D)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    o = ops.wkv6(r, k, v, logw, u, chunk=chunk)
    o_ref = ref.wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-4, rtol=2e-4)


@pytest.mark.slow  # default 128/256 block sizes need larger (slower) shapes
def test_flash_attention_multiblock_default_blocks():
    """Cross-block online-softmax carry with the kernels' DEFAULT block
    sizes (the fast-tier sweep exercises multi-block grids via explicit
    64-wide blocks)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    o = ops.flash_attention(q, k, v, causal=True)  # default blocks
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)


@pytest.mark.slow
def test_decode_attention_multiblock_default_blocks():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 64))
    k = jax.random.normal(ks[1], (2, 512, 2, 64))
    v = jax.random.normal(ks[2], (2, 512, 2, 64))
    kv_pos = jnp.broadcast_to(jnp.arange(512)[None], (2, 512)).astype(jnp.int32)
    q_pos = jnp.full((2, 1), 511, jnp.int32)
    o = ops.decode_attention(q, k, v, q_pos, kv_pos)  # default block_kv
    o_ref = ref.decode_attention_ref(q, k, v, q_pos, kv_pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_fallback_on_ragged_shapes():
    """Non-divisible block shapes must fall back to the reference path."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 100, 2, 32))
    k = jax.random.normal(ks[1], (1, 100, 2, 32))
    v = jax.random.normal(ks[2], (1, 100, 2, 32))
    o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5, rtol=1e-5)
