"""End-to-end system behaviour: the paper's full story on one box.

Plan a deployment with Algorithm 1, simulate it (Atlas vs Varuna),
schedule BubbleTea prefills into the simulated bubbles, then run the
actual JAX substrate (train + serve) on the same config family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.bubbletea import (
    BubbleTeaController,
    InferenceModelSpec,
    PrefillLatencyModel,
    PrefillRequest,
    utilization_with_prefills,
)
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.simulator import GeoTopology, simulate
from repro.core.simulator import testbed_spec as make_spec
from repro.core import wan
from repro.data.pipeline import DataConfig, make_batches
from repro.models.transformer import build_model
from repro.optim.optimizer import OptimizerConfig, init_opt_state, make_train_step
from repro.serving.engine import Request, ServingEngine


@pytest.mark.slow  # trains + serves a real (smoke) model
def test_end_to_end_geo_training_story():
    # 1) plan the deployment with Algorithm 1 (what-if, no hardware)
    job = JobModel(
        t_fwd_ms=10.0,
        act_bytes=2 * 10e-3 * wan.NODE_PAIR_CAP_GBPS * 1e9 / 8,
        partition_param_bytes=800e6 * 2,
        microbatches=12,
    )
    plan = best_plan(algorithm1(job, {"dc1": 96, "dc2": 96}, P=12, C=2))
    assert plan.throughput > 0 and plan.gpus_used <= 192

    # 2) simulate the chosen deployment: Atlas vs single-TCP Varuna
    stage_dc = []
    for i, (dc, n) in enumerate(sorted(plan.partitions.items())):
        stage_dc += [i] * n
    spec = make_spec(
        hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
        layer_params=412e6, num_stages=len(stage_dc), microbatches=12,
        stage_dc=stage_dc,
    )
    topo = GeoTopology(wan_latency_ms=40.0, multi_tcp=True)
    atlas = simulate(spec, topo, policy="atlas", n_pipelines=2, validate=True)
    varuna = simulate(
        spec, GeoTopology(wan_latency_ms=40.0, multi_tcp=False), policy="varuna"
    , validate=True)
    assert atlas.iteration_ms < varuna.iteration_ms

    # 3) BubbleTea fills the bubbles
    lm = PrefillLatencyModel(InferenceModelSpec("llama3-8b", 8e9))
    ctrl = BubbleTeaController(
        [list(atlas.bubbles[g]) for g in sorted(atlas.bubbles)], lm
    )
    rng = np.random.default_rng(0)
    t = 0.0
    while t < atlas.iteration_ms:
        t += rng.exponential(1.5)
        ctrl.submit(PrefillRequest(int(t * 1e3), t, int(rng.choice([128, 256, 512]))))
    busy = sum(iv.end - iv.start for ivs in atlas.busy.values() for iv in ivs)
    total = atlas.iteration_ms * len(atlas.busy)
    assert utilization_with_prefills(busy, total, ctrl) > atlas.utilization

    # 4) the actual JAX substrate trains and serves the same config family
    cfg = get_smoke_config("gpt_a")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(
        make_train_step(
            model.loss, OptimizerConfig(peak_lr=3e-3, warmup_steps=3, total_steps=15)
        )
    )
    st = init_opt_state(params)
    losses = []
    for b in make_batches(cfg, DataConfig(batch_size=8, seq_len=64), num_steps=15):
        params, st, met = step(params, st, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0]

    eng = ServingEngine(cfg, params, max_batch=2, max_len=64)
    out = eng.generate([Request(0, np.arange(8, dtype=np.int32), max_new_tokens=4)])
    assert len(out[0].generated) == 4
