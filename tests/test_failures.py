"""Failure & elasticity engine: seeded traces, forced failovers,
checkpoint-aware recovery, and the negative-checkable invariants
(corrupting a post-outage horizon must be *caught* by validate)."""
import copy
import dataclasses
import math

import pytest

from repro.core.control import (
    ControlConfig,
    MigrationModel,
    simulate_horizon,
)
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.failures import (
    CheckpointPolicy,
    FailureEvent,
    FailureTrace,
)
from repro.core.fleet import ChannelReservation, FleetJob, simulate_fleet
from repro.core.topology import TopologyMatrix
from repro.core.validate import InvariantViolation, check_fleet, check_horizon

NAMES = ("use", "ussc", "usw", "asia")
LAT = [
    [0, 30, 60, 150],
    [30, 0, 40, 170],
    [60, 40, 0, 120],
    [150, 170, 120, 0],
]


def _world():
    return TopologyMatrix.from_latency(LAT, dc_names=NAMES)


def _job():
    return JobModel(
        t_fwd_ms=10.0, act_bytes=1e7, partition_param_bytes=4e8, microbatches=64
    )


def _fleet():
    return {n: 8 for n in NAMES}


def _outage_trace(residual=0.02, recover_ms=None):
    return FailureTrace(events=(
        FailureEvent(at_ms=60_000.0, kind="dc_outage", dc="ussc",
                     recover_ms=recover_ms, residual_frac=residual),
    ))


def _ckpt():
    return CheckpointPolicy(
        interval_ms=20_000.0, placement=("use", "usw"), write_bw_gbps=2.0
    )


_KW = dict(P=12, n_iterations=64, C=2)


# ---------------------------------------------------------------------------
# trace model
# ---------------------------------------------------------------------------


def test_trace_sorted_and_timeline_monotone():
    tr = FailureTrace(events=(
        FailureEvent(at_ms=50_000.0, kind="dc_join", dc="asia", gpus=4),
        FailureEvent(at_ms=10_000.0, kind="dc_outage", dc="use",
                     recover_ms=5_000.0),
    ))
    assert [e.at_ms for e in tr.events] == [10_000.0, 50_000.0]
    tl = tr.timeline()
    assert [t for t, _, _ in tl] == sorted(t for t, _, _ in tl)
    # the recovering outage contributes a heal step at t + recover_ms
    assert ("heal", 15_000.0) in [(op, t) for t, op, _ in tl]


def test_generate_is_seed_deterministic():
    a = FailureTrace.generate(NAMES, seed=7, horizon_ms=300_000.0, n_events=5)
    b = FailureTrace.generate(NAMES, seed=7, horizon_ms=300_000.0, n_events=5)
    c = FailureTrace.generate(NAMES, seed=8, horizon_ms=300_000.0, n_events=5)
    assert a.events == b.events
    assert a.events != c.events


def test_generate_same_seed_same_cascade():
    """Two runs of the same seeded trace must produce the *identical*
    migration cascade — modes, reasons, totals."""
    tr = FailureTrace.generate(
        NAMES, seed=13, horizon_ms=250_000.0, n_events=3,
        kinds=("dc_outage", "slice_preemption"),
    )
    kw = dict(
        live_topo=_world(), planned_topo=_world(),
        migration=MigrationModel(checkpoint=_ckpt()),
        control=ControlConfig(), failures=tr, **_KW,
    )
    r1 = simulate_horizon(_job(), _fleet(), **kw, validate=True)
    r2 = simulate_horizon(_job(), _fleet(), **kw, validate=True)
    assert r1.total_ms == r2.total_ms
    assert [(m.mode, m.reason, m.at_ms) for m in r1.migrations] == [
        (m.mode, m.reason, m.at_ms) for m in r2.migrations
    ]


def test_apply_to_topology_degrades_and_heals():
    world = _world()
    tr = _outage_trace(residual=0.05, recover_ms=30_000.0)
    degraded = tr.apply_to_topology(world)
    i = world.index_of("use")
    j = world.index_of("ussc")
    base = world.link(i, j).bw_gbps
    sched = degraded.bandwidth_schedule(i, j)
    assert sched is not None
    assert sched.bw_at(0.0) == pytest.approx(base)
    assert sched.bw_at(70_000.0) == pytest.approx(0.05 * base)
    assert sched.bw_at(100_000.0) == pytest.approx(base)  # healed
    # untouched pairs keep static physics
    k = world.index_of("usw")
    m = world.index_of("asia")
    assert degraded.bandwidth_schedule(k, m) is None


def test_dead_dcs_at():
    tr = _outage_trace(recover_ms=30_000.0)
    assert tr.dead_dcs_at(30_000.0) == frozenset()
    assert tr.dead_dcs_at(70_000.0) == frozenset({"ussc"})
    assert tr.dead_dcs_at(100_000.0) == frozenset()


# ---------------------------------------------------------------------------
# engine: forced failovers and checkpoint-aware recovery
# ---------------------------------------------------------------------------


def _run(trace, *, checkpoint=None):
    world = _world()
    return simulate_horizon(
        _job(), _fleet(),
        live_topo=world, planned_topo=world,
        migration=MigrationModel(checkpoint=checkpoint),
        control=ControlConfig(), failures=trace, **_KW,
     validate=True)


def test_dc_outage_forces_failover_off_dead_dc():
    tr = _outage_trace()
    hr = _run(tr)
    forced = [m for m in hr.migrations if m.reason == "dc_outage:ussc"]
    assert forced, "outage must force a re-plan"
    dead = _world().index_of("ussc")
    # every epoch opened after the failover avoids the dead DC
    after = [ep for ep in hr.epochs if ep.start_ms >= forced[0].at_ms]
    assert after and all(dead not in set(ep.spec.stage_dc) for ep in after)
    assert hr.stats["replans_forced"] >= 1
    check_horizon(hr, live_topo=tr.apply_to_topology(_world()))


def test_checkpoint_restore_beats_live_shipment():
    """The acceptance ordering at fixed samples: checkpoint-aware
    recovery < ship-live-weights < static (no reaction)."""
    tr = _outage_trace()
    world = _world()
    ship = _run(tr)
    ckpt = _run(tr, checkpoint=_ckpt())
    static = simulate_horizon(
        _job(), _fleet(), live_topo=tr.apply_to_topology(world),
        planned_topo=world, **_KW,
     validate=True)
    assert ship.samples == ckpt.samples == static.samples
    assert ckpt.total_ms < ship.total_ms < static.total_ms
    restores = [m for m in ckpt.migrations if m.mode == "restore"]
    assert restores and restores[0].replay_samples > 0.0
    # replay is priced, not free: the restore rolled progress back
    assert ckpt.replay_samples == sum(m.replay_samples for m in ckpt.migrations)
    check_horizon(ckpt, live_topo=tr.apply_to_topology(world))


def test_slice_preemption_forces_replan_when_capacity_lost():
    tr = FailureTrace(events=(
        FailureEvent(at_ms=60_000.0, kind="slice_preemption", dc="use", gpus=8),
    ))
    hr = _run(tr, checkpoint=_ckpt())
    forced = [m for m in hr.migrations
              if m.reason == "slice_preemption:use"]
    assert forced and hr.stats["replans_forced"] >= 1
    use = _world().index_of("use")
    after = [ep for ep in hr.epochs if ep.start_ms >= forced[0].at_ms]
    assert after and all(use not in set(ep.spec.stage_dc) for ep in after)


def test_dc_join_is_opportunistic_not_forced():
    tr = FailureTrace(events=(
        FailureEvent(at_ms=60_000.0, kind="dc_join", dc="use", gpus=8),
    ))
    hr = _run(tr, checkpoint=_ckpt())
    assert hr.stats["replans_forced"] == 0
    for m in hr.migrations:
        assert m.reason in ("elasticity", "drift")
    check_horizon(hr, live_topo=_world())


def test_exclude_dcs_filters_fleet_and_incumbent():
    world = _world()
    job = dataclasses.replace(_job(), topology=world)
    full = best_plan(algorithm1(job, _fleet(), 12, C=2))
    surv = best_plan(
        algorithm1(job, _fleet(), 12, C=2, exclude_dcs=["ussc"],
                   incumbent_order=full.dc_order)
    )
    assert math.isfinite(surv.total_ms)
    assert "ussc" not in surv.dc_order
    with pytest.raises(ValueError):
        algorithm1(job, _fleet(), 12, C=2, exclude_dcs=list(NAMES))


# ---------------------------------------------------------------------------
# negative tests: the invariants must be *falsifiable*
# ---------------------------------------------------------------------------


def test_negative_gpu_busy_in_dead_dc_is_caught():
    """Stretch the outage window back to t=0 so the pre-failover epoch
    (which legitimately ran on the soon-to-die DC) suddenly sits inside
    it — check_horizon must indict the overlap."""
    tr = _outage_trace()
    hr = _run(tr, checkpoint=_ckpt())
    topo = tr.apply_to_topology(_world())
    check_horizon(hr, live_topo=topo)  # clean before corruption
    bad = copy.deepcopy(hr)
    bad.outages[0].t0_ms = 0.0
    with pytest.raises(InvariantViolation, match="dead DC"):
        check_horizon(bad, live_topo=topo)


def test_negative_understated_replay_is_caught():
    tr = _outage_trace()
    hr = _run(tr, checkpoint=_ckpt())
    topo = tr.apply_to_topology(_world())
    bad = copy.deepcopy(hr)
    restore = next(m for m in bad.migrations if m.mode == "restore")
    restore.replay_samples -= 128.0  # hide some of the rollback debt
    with pytest.raises(InvariantViolation, match="replay"):
        check_horizon(bad, live_topo=topo)


def test_negative_wrong_restart_sample_is_caught():
    tr = _outage_trace()
    hr = _run(tr, checkpoint=_ckpt())
    topo = tr.apply_to_topology(_world())
    bad = copy.deepcopy(hr)
    restore = next(m for m in bad.migrations if m.mode == "restore")
    nxt = next(ep for ep in bad.epochs if ep.start_ms >= restore.at_ms)
    nxt.start_sample += 512.0  # pretend the rollback never happened
    with pytest.raises(InvariantViolation):
        check_horizon(bad, live_topo=topo)


def test_negative_reservation_on_dead_resources_is_caught():
    world = _world()
    tr = _outage_trace()
    jobs = [FleetJob(
        name="a", job=_job(), gpus=_fleet(), P=12, n_iterations=48, C=2,
        control=ControlConfig(), checkpoint=_ckpt(),
    )]
    fr = simulate_fleet(jobs, world, failures=tr, validate=True)
    topo = tr.apply_to_topology(world)
    check_fleet(fr, topo)  # clean before corruption
    dead = world.index_of("ussc")
    w = fr.jobs["a"].outages[0]
    bad = copy.deepcopy(fr)
    bad.reservations.append(ChannelReservation(
        job="a", pair=(world.index_of("use"), dead),
        t0_ms=w.t0_ms + 1_000.0, t1_ms=w.t0_ms + 5_000.0,
        rate_gbps=1.0, mult=1.0,
    ))
    with pytest.raises(InvariantViolation, match="dead resources"):
        check_fleet(bad, topo)


def test_link_failure_trace_degrades_both_directions():
    world = _world()
    tr = FailureTrace(events=(
        FailureEvent(at_ms=40_000.0, kind="link_failure",
                     pair=("use", "usw"), recover_ms=20_000.0,
                     residual_frac=0.1),
    ))
    degraded = tr.apply_to_topology(world)
    i, j = world.index_of("use"), world.index_of("usw")
    for a, b in ((i, j), (j, i)):
        s = degraded.bandwidth_schedule(a, b)
        base = world.link(a, b).bw_gbps
        assert s.bw_at(50_000.0) == pytest.approx(0.1 * base)
        assert s.bw_at(70_000.0) == pytest.approx(base)


# ---------------------------------------------------------------------------
# hash-order / seed stability (ISSUE 8: planners must not iterate sets in
# hash order — failures.apply_to_topology walks its touched-pair set via
# sorted(), and the whole plan->bake path must be PYTHONHASHSEED-stable)
# ---------------------------------------------------------------------------


def test_apply_to_topology_stable_under_event_permutation():
    """Same events, any submission order (ties included): identical baked
    topology.  Guards the sorted() walk over the touched-pair set."""
    world = _world()
    events = [
        FailureEvent(at_ms=40_000.0, kind="link_failure",
                     pair=("use", "usw"), recover_ms=20_000.0,
                     residual_frac=0.1),
        FailureEvent(at_ms=40_000.0, kind="link_failure",
                     pair=("ussc", "asia"), recover_ms=10_000.0,
                     residual_frac=0.2),
        FailureEvent(at_ms=40_000.0, kind="dc_outage", dc="use",
                     recover_ms=30_000.0, residual_frac=0.05),
    ]
    baked = [
        FailureTrace(events=tuple(perm)).apply_to_topology(world)
        for perm in (events, events[::-1], [events[1], events[2], events[0]])
    ]
    for other in baked[1:]:
        assert set(other.bw_schedules) == set(baked[0].bw_schedules)
        for pair, sched in baked[0].bw_schedules.items():
            assert other.bw_schedules[pair] == sched, pair


_HASHSEED_PROBE = r"""
import json
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.failures import CheckpointPolicy, FailureEvent, FailureTrace
from repro.core.topology import TopologyMatrix

NAMES = ("use", "ussc", "usw", "asia")
LAT = [[0, 30, 60, 150], [30, 0, 40, 170],
       [60, 40, 0, 120], [150, 170, 120, 0]]
world = TopologyMatrix.from_latency(LAT, dc_names=NAMES)
tr = FailureTrace(events=(
    FailureEvent(at_ms=40_000.0, kind="link_failure", pair=("use", "usw"),
                 recover_ms=20_000.0, residual_frac=0.1),
    FailureEvent(at_ms=40_000.0, kind="dc_outage", dc="ussc",
                 recover_ms=30_000.0, residual_frac=0.05),
))
live = tr.apply_to_topology(world)
job = JobModel(t_fwd_ms=10.0, act_bytes=1e7, partition_param_bytes=4e8,
               microbatches=64, topology=live)
plan = best_plan(algorithm1(job, {n: 6 for n in NAMES}, P=12, C=2))
sched_digest = sorted(
    (a, b, s.times_ms, s.bw_gbps) for (a, b), s in live.bw_schedules.items()
)
print(json.dumps({
    "order": list(plan.dc_order),
    "partitions": dict(sorted(plan.partitions.items())),
    "total_ms": plan.total_ms,
    "schedules": sched_digest,
}, sort_keys=True))
"""


@pytest.mark.slow
def test_plan_and_bake_stable_across_hash_seeds():
    """The full trace->bake->Algorithm-1 path emits byte-identical output
    under different PYTHONHASHSEED values (string DC names would expose
    any remaining hash-order set walk)."""
    import os
    import subprocess
    import sys

    outs = []
    for seed in ("0", "1", "31337"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (env.get("PYTHONPATH"), "src") if p
        )
        r = subprocess.run(
            [sys.executable, "-c", _HASHSEED_PROBE],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1] == outs[2]
