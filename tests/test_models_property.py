"""Property-based tests (hypothesis) on model-layer invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models import attention as attn
from repro.models.modules import cross_entropy_loss
from repro.models.transformer import LOSS_CHUNK, _lm_loss_chunked
from repro.configs import get_smoke_config

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(
    seed=st.integers(0, 2**31 - 1),
    T=st.integers(2, 33),
    D=st.sampled_from([16, 32, 64]),
    theta=st.sampled_from([1e4, 1e6]),
)
@settings(**SETTINGS)
def test_rope_preserves_norm_and_relative_positions(seed, T, D, theta):
    """RoPE is a rotation: preserves per-head norms, and q·k depends only
    on relative position."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (1, T, 2, D))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (1, T))
    r = attn.apply_rope(x, pos, theta)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5, atol=1e-5,
    )
    # relative-position property: shifting both positions by c leaves
    # inner products unchanged
    q = jax.random.normal(k2, (1, T, 2, D))
    c = 7
    r0 = attn.apply_rope(q, pos, theta)
    k0 = attn.apply_rope(x, pos, theta)
    r1 = attn.apply_rope(q, pos + c, theta)
    k1_ = attn.apply_rope(x, pos + c, theta)
    ip0 = np.einsum("bthd,bshd->bhts", np.asarray(r0), np.asarray(k0))
    ip1 = np.einsum("bthd,bshd->bhts", np.asarray(r1), np.asarray(k1_))
    np.testing.assert_allclose(ip0, ip1, rtol=2e-4, atol=2e-4)


@given(
    seed=st.integers(0, 2**31 - 1),
    B=st.integers(1, 3),
    T=st.integers(1, 2 * LOSS_CHUNK + 7),
    V=st.sampled_from([11, 64, 257]),
)
@settings(**SETTINGS)
def test_chunked_ce_equals_direct(seed, B, T, V):
    """The memory-bounded chunked CE must equal the direct computation."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    d = 8
    x = jax.random.normal(ks[0], (B, T, d))
    w = jax.random.normal(ks[1], (d, V))
    labels = jax.random.randint(ks[2], (B, T), 0, V)
    mask = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (B, T)) > 0.3).astype(
        jnp.float32
    )
    if float(mask.sum()) == 0:
        mask = mask.at[0, 0].set(1.0)

    class Cfg:  # minimal cfg stand-in
        pass

    got = _lm_loss_chunked(Cfg(), x, w, labels, mask)
    logits = x @ w
    want = cross_entropy_loss(logits, labels, mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-5)


@given(
    seed=st.integers(0, 2**31 - 1),
    window=st.sampled_from([4, 8, 16]),
)
@settings(**SETTINGS)
def test_sliding_window_equals_truncated_context(seed, window):
    """Windowed attention at position t must equal full attention over
    the last `window` tokens only."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, T, H, D = 1, 24, 2, 16
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    out_w = attn.sdpa(q, k, v, pos, pos, causal=True, window=window)
    t = T - 1
    lo = t - window + 1
    out_full = attn.sdpa(
        q[:, t:], k[:, lo : t + 1], v[:, lo : t + 1],
        pos[:, t:], pos[:, lo : t + 1], causal=True,
    )
    np.testing.assert_allclose(
        np.asarray(out_w[:, t]), np.asarray(out_full[:, 0]), rtol=1e-4, atol=1e-4
    )


@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([8, 16, 32]))
@settings(**SETTINGS)
def test_mamba_chunked_invariant_to_chunk_size(seed, chunk):
    """SSD output must not depend on the chunk size (associativity)."""
    import dataclasses

    from repro.models import ssm as ssm_lib

    cfg0 = get_smoke_config("zamba2_2p7b")
    cfg = dataclasses.replace(cfg0, ssm=dataclasses.replace(cfg0.ssm, chunk=chunk))
    cfg_ref = dataclasses.replace(cfg0, ssm=dataclasses.replace(cfg0.ssm, chunk=64))
    p = ssm_lib.mamba2_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 64, cfg.d_model)) * 0.1
    y1, s1 = ssm_lib.mamba2_apply(p, cfg, x.astype(cfg.dtype))
    y2, s2 = ssm_lib.mamba2_apply(p, cfg_ref, x.astype(cfg.dtype))
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=3e-2, rtol=3e-2
    )
    np.testing.assert_allclose(
        np.asarray(s1["ssm"]), np.asarray(s2["ssm"]), atol=1e-3, rtol=1e-3
    )


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_moe_output_finite_and_capacity_bounded(seed):
    from repro.models import moe as moe_lib

    cfg = get_smoke_config("qwen2_moe_a2p7b")
    p = moe_lib.moe_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 32, cfg.d_model), jnp.bfloat16)
    y, aux = moe_lib.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 0.0
