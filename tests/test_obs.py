"""Unified observability layer (ISSUE 9) — structured tracing, export,
crosscheck, metrics, schema.

Nets:
  * Null-tracer differential: attaching a ``NullTracer`` changes no
    engine output (iteration-identical to the no-tracer call shape).
  * Second witness: for every engine (single-iteration sim, control
    horizon, multi-job fleet + prefill) the busy/bubble/allreduce/
    utilization/wan_bits totals re-derived from the emitted spans agree
    with the engine's own ``SimResult.stats`` accounting
    (``obs.verify_trace`` / ``validate.check_trace``) — and a corrupted
    span set *fails* it (the witness is falsifiable).
  * Byte determinism: the exported Chrome trace is byte-identical
    across two in-process runs and across a ``PYTHONHASHSEED``-varied
    subprocess; ``read_chrome_trace`` round-trips event counts.
  * CLI: ``python -m repro.obs validate`` accepts a good trace, rejects
    a busy span planted inside a dead-DC outage window; ``report``
    emits deterministic JSON metrics.
  * Schema: the stats-key registry conforms to the units-suffix grammar
    and every key each engine actually emits is registered — including
    the PR-9 ``ttft_p{50,95,99}_ms`` rename (regression-tested).
"""
import dataclasses
import hashlib
import json
import os
import subprocess
import sys

import pytest

from repro import obs
from repro.core import control, fleet as fl
from repro.core import topology as tp
from repro.core import validate as V
from repro.core import wan
from repro.core.bubbletea import (ArrivalProcess, InferenceModelSpec,
                                  PromptMix)
from repro.core.dc_selection import JobModel, algorithm1, best_plan
from repro.core.simulator import simulate


def _world():
    lat = [[0.0, 20.0, 20.0], [20.0, 0.0, 20.0], [20.0, 20.0, 0.0]]
    return tp.TopologyMatrix.from_latency(
        lat, multi_tcp=True, dc_names=("a", "b", "c"))


def _job(**kw):
    kw.setdefault("t_fwd_ms", 10.0)
    kw.setdefault("act_bytes", 1e7)
    kw.setdefault("partition_param_bytes", 2e8)
    kw.setdefault("microbatches", 24)
    return JobModel(**kw)


def _spec(job, world):
    plan = best_plan(algorithm1(
        dataclasses.replace(job, topology=world),
        {d: 4 for d in world.dc_names}, P=6, C=1))
    return control.plan_spec(job, plan, world)


def _outage_live(world, start_ms=10_000.0, end_ms=200_000.0, factor=10.0):
    bw = world.link(0, 1).bw_gbps
    return world.with_bandwidth_schedules({
        (0, 1): wan.BandwidthSchedule.outage(bw, start_ms, end_ms, bw / factor),
        (1, 0): wan.BandwidthSchedule.flat(bw),
    })


def _traced_sim(world=None, tracer=None, label="sim"):
    world = world or _world()
    tracer = tracer or obs.RecordingTracer()
    job = _job()
    res = simulate(_spec(job, world), world, validate=True,
                   tracer=tracer, trace_label=label)
    return tracer, res


def _traced_fleet(tracer=None, n_iterations=4):
    """Host + contender + prefill service: the busiest emission path."""
    world = _world()
    tracer = tracer or obs.RecordingTracer()
    job = _job(act_bytes=6e7)
    arr = ArrivalProcess(rate_per_s=15.0, horizon_ms=15_000.0, seed=7)
    reqs = arr.generate(PromptMix(lengths=(512, 1024), weights=(0.5, 0.5)),
                        tiers={"gold": 0.3, "best_effort": 0.7})
    svc = fl.PrefillService(
        host_job="A", arrivals=reqs,
        model=InferenceModelSpec("m", num_params=8e9,
                                 kv_bytes_per_token=16384.0),
        decode_dc="c", tiers={"gold": 1_200.0, "best_effort": 8_000.0})
    fr = fl.simulate_fleet(
        [fl.FleetJob("A", job, {"a": 2, "b": 2, "c": 2}, P=6,
                     n_iterations=n_iterations, C=1),
         fl.FleetJob("B", job, {"a": 2, "b": 2}, P=4,
                     n_iterations=n_iterations, C=1)],
        world, prefill=svc, validate=True, tracer=tracer)
    return tracer, fr


# ------------------------------------------------------------- tracer core


def test_null_tracer_is_differentially_invisible():
    world = _world()
    spec = _spec(_job(), world)
    bare = simulate(spec, world, validate=True)
    nulled = simulate(spec, world, validate=True, tracer=obs.NullTracer())
    assert nulled.iteration_ms == bare.iteration_ms
    assert nulled.stats["wan_bits"] == bare.stats["wan_bits"]
    assert nulled.transfers is None  # no silent recording


def test_recording_does_not_change_the_answer():
    world = _world()
    spec = _spec(_job(), world)
    bare = simulate(spec, world, validate=True, fast_forward=False)
    tr = obs.RecordingTracer()
    rec = simulate(spec, world, validate=True, tracer=tr)
    assert rec.iteration_ms == bare.iteration_ms
    assert tr.n_events > 0 and rec.transfers is not None


def test_sim_second_witness_passes():
    tr, res = _traced_sim()
    assert obs.verify_trace(tr) == 1
    # the registered expectation is the engine's own accounting
    (exp,) = tr.expectations
    assert exp.t1_ms - exp.t0_ms == pytest.approx(res.iteration_ms)


def test_horizon_second_witness_and_control_instants():
    world = _world()
    tr = obs.RecordingTracer()
    hz = control.simulate_horizon(
        _job(), {d: 4 for d in world.dc_names}, P=10,
        live_topo=_outage_live(world), planned_topo=world,
        n_iterations=30, C=1, control=control.ControlConfig(),
        validate=True, tracer=tr, trace_label="jobA")
    assert obs.verify_trace(tr) == 30
    names = {i.name for i in tr.instants}
    assert "drift" in names and "migrated" in names
    if hz.migrations:
        stalls = [s for s in tr.spans if s.name == "migration-stall"]
        migs = [s for s in tr.spans if s.name.startswith("migration:")]
        assert stalls and len(migs) == len(hz.migrations)


def test_fleet_second_witness_ledger_and_prefill_spans():
    tr, fr = _traced_fleet()
    assert obs.verify_trace(tr) > 0
    ledger = [s for s in tr.spans if s.pid == "fleet/wan"]
    assert len(ledger) == len(fr.reservations)
    placed = [s for s in tr.spans if s.pid == "prefill" and s.name == "prefill"]
    assert len(placed) == fr.stats["prefill"]["placed"]
    kv = [i for i in tr.instants if i.name == "kv_handoff"]
    assert len(kv) == fr.stats["prefill"]["kv_wan_transfers"]


def test_corrupted_span_fails_the_crosscheck():
    tr, _ = _traced_sim()
    victim = next(i for i, s in enumerate(tr.spans)
                  if s.name in obs.BUSY_KINDS)
    sp = tr.spans[victim]
    tr.spans[victim] = dataclasses.replace(sp, t1_ms=sp.t1_ms + 7.0)
    with pytest.raises(obs.TraceMismatch):
        obs.verify_trace(tr)
    with pytest.raises(V.InvariantViolation):
        V.check_trace(tr)


# ---------------------------------------------------------------- export


def test_export_is_byte_identical_across_runs():
    a = obs.dump_chrome_trace(_traced_sim()[0], label="golden")
    b = obs.dump_chrome_trace(_traced_sim()[0], label="golden")
    assert a == b


def test_export_is_byte_identical_across_hashseeds(tmp_path):
    prog = (
        "import dataclasses, hashlib, sys\n"
        "from repro import obs\n"
        "from repro.core import control, topology as tp\n"
        "from repro.core.dc_selection import JobModel, algorithm1, best_plan\n"
        "from repro.core.simulator import simulate\n"
        "lat = [[0.0, 20.0, 20.0], [20.0, 0.0, 20.0], [20.0, 20.0, 0.0]]\n"
        "world = tp.TopologyMatrix.from_latency(\n"
        "    lat, multi_tcp=True, dc_names=('a', 'b', 'c'))\n"
        "job = JobModel(t_fwd_ms=10.0, act_bytes=1e7,\n"
        "               partition_param_bytes=2e8, microbatches=24,\n"
        "               topology=world)\n"
        "plan = best_plan(algorithm1(job, {d: 4 for d in world.dc_names},\n"
        "                            P=6, C=1))\n"
        "tr = obs.RecordingTracer()\n"
        "simulate(control.plan_spec(job, plan, world), world, validate=True,\n"
        "         tracer=tr, trace_label='sim')\n"
        "payload = obs.dump_chrome_trace(tr, label='golden')\n"
        "sys.stdout.write(hashlib.sha256(payload.encode()).hexdigest())\n"
    )
    digests = set()
    for seed in ("0", "1234"):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH=os.pathsep.join(sys.path))
        out = subprocess.run([sys.executable, "-c", prog], env=env,
                             capture_output=True, text=True, check=True)
        digests.add(out.stdout.strip())
    assert len(digests) == 1
    # and the subprocesses agree with this process
    local = obs.dump_chrome_trace(_traced_sim()[0], label="golden")
    assert hashlib.sha256(local.encode()).hexdigest() in digests


def test_read_chrome_trace_round_trip(tmp_path):
    tr, _ = _traced_sim()
    path = str(tmp_path / "t.json")
    obs.write_chrome_trace(tr, path)
    back = obs.read_chrome_trace(path)
    assert len(back.spans) == len(tr.spans)
    assert len(back.instants) == len(tr.instants)
    assert len(back.counters) == len(tr.counters)
    assert {s.pid for s in back.spans} == {s.pid for s in tr.spans}


# ------------------------------------------------------------------- CLI


def test_cli_validate_and_report(tmp_path, capsys):
    from repro.obs.__main__ import main as cli, report
    tr, _ = _traced_fleet(n_iterations=2)
    path = str(tmp_path / "fleet.json")
    obs.write_chrome_trace(tr, path)
    assert cli(["validate", path]) == 0
    assert cli(["report", path]) == 0
    capsys.readouterr()
    # report twice -> identical bytes (deterministic summary)
    assert report(path) == report(path)
    snap = json.loads(report(path))
    assert any(k.endswith("/busy_ms") for k in snap["counters"])


def test_cli_validate_rejects_busy_span_in_outage(tmp_path):
    from repro.obs.__main__ import validate_trace_file
    tr = obs.RecordingTracer()
    # a dead-DC window and a busy span planted fully inside it
    tr.span("outage:dc_outage", obs.CAT_CONTROL, "job/control", "failures",
            1000.0, 5000.0, dc="b", dc_index=1)
    tr.span("fwd", obs.CAT_GPU, "job/gpu", "p0/s0", 2000.0, 2500.0,
            pipeline=0, stage=0, dc=1)
    path = str(tmp_path / "bad.json")
    obs.write_chrome_trace(tr, path)
    errors = validate_trace_file(path)
    assert errors and any("dead dc" in e for e in errors)


# ---------------------------------------------------------------- schema


def test_schema_registry_conforms_to_units_grammar():
    assert obs.conformance_errors() == []


def test_sim_stats_keys_all_registered():
    world = _world()
    res = simulate(_spec(_job(), world), world, validate=True)
    assert obs.unregistered_keys(res.stats, "sim") == []
    # the fast-forward path emits extra keys — they must be registered too
    big = _job(microbatches=256)
    res_ff = simulate(_spec(big, world), world, validate=True,
                      fast_forward=True)
    assert res_ff.stats["fast_forward"] is True
    assert obs.unregistered_keys(res_ff.stats, "sim") == []


def test_horizon_stats_keys_all_registered():
    world = _world()
    hz = control.simulate_horizon(
        _job(), {d: 4 for d in world.dc_names}, P=10,
        live_topo=_outage_live(world), planned_topo=world,
        n_iterations=20, C=1, control=control.ControlConfig(),
        validate=True)
    assert obs.unregistered_keys(hz.stats, "horizon") == []


def test_fleet_stats_keys_all_registered_and_ttft_units_fixed():
    _, fr = _traced_fleet(n_iterations=2)
    assert obs.unregistered_keys(fr.stats, "fleet") == []
    for tier in fr.stats["prefill"]["per_tier"].values():
        # PR-9 rename: TTFT percentiles carry their unit suffix now
        assert {"ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms"} <= set(tier)
        assert not {"ttft_p50", "ttft_p95", "ttft_p99"} & set(tier)


def test_unregistered_key_is_reported():
    assert obs.unregistered_keys({"definitely_not_a_key": 1}, "sim") == [
        "definitely_not_a_key"
    ]


# --------------------------------------------------------------- metrics


def test_metrics_snapshot_and_diff():
    tr, res = _traced_sim()
    snap = obs.metrics_from_tracer(tr).snapshot()
    label_busy = dict(snap.counters)["sim/gpu/busy_ms"]
    assert label_busy > 0
    frac = dict(snap.gauges)["sim/gpu/bubble_frac"]
    assert 0.0 <= frac <= 1.0
    # diff against a second identical run is empty
    tr2, _ = _traced_sim()
    snap2 = obs.metrics_from_tracer(tr2).snapshot()
    assert snap.diff(snap2) == {}  # unchanged entries are omitted
    # diff against a perturbed registry localizes the change
    reg = obs.MetricsRegistry()
    reg.count("sim/gpu/busy_ms", label_busy + 5.0)
    d2 = snap.diff(reg.snapshot())
    assert "sim/gpu/busy_ms" in d2["counters"]
