"""BubbleTea controller + TTFT model — paper §5 / Fig 13 / Fig 14."""
import time

import numpy as np
import pytest

from repro.core.bubbletea import (
    PIPE_HOP_MS,
    BubbleTeaController,
    InferenceModelSpec,
    PrefillLatencyModel,
    PrefillRequest,
    intersect_bubbles,
    prefill_stage_busy_ms,
    utilization_with_prefills,
)
from repro.core.simulator import GeoTopology, simulate
from repro.core.simulator import testbed_spec as make_spec

LLAMA = InferenceModelSpec("llama3-8b", num_params=8e9)
LM = PrefillLatencyModel(LLAMA)


def test_fig14_calibration_anchors():
    """PP=8 inflates TTFT +29% at 512 tokens; PP=1 is +67% at 8K."""
    small = LM.ttft_ms(512, 8) / LM.ttft_ms(512, 1) - 1
    large = LM.ttft_ms(8192, 1) / LM.ttft_ms(8192, 8) - 1
    assert small == pytest.approx(0.29, abs=0.05)
    assert large == pytest.approx(0.67, abs=0.08)


def test_fig14_crossover():
    """Low PP wins for small prompts; high PP wins for large prompts."""
    assert LM.ttft_ms(512, 1) < LM.ttft_ms(512, 8)
    assert LM.ttft_ms(8192, 8) < LM.ttft_ms(8192, 1)


def test_prefill_duration_deterministic_and_monotone():
    prev = 0.0
    for L in (128, 256, 512, 1024, 2048, 4096):
        d = LM.prefill_ms(L, 1)
        assert d == LM.prefill_ms(L, 1)
        assert d > prev
        prev = d


def _atlas_bubbles():
    spec = make_spec(
        hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
        layer_params=412e6, num_stages=4, microbatches=4, stage_dc=[0, 0, 1, 2],
    )
    res = simulate(spec, GeoTopology(wan_latency_ms=40, multi_tcp=True),
                   policy="atlas", n_pipelines=3, validate=True)
    return res


def test_placements_fit_inside_bubbles():
    res = _atlas_bubbles()
    raw = [list(res.bubbles[g]) for g in sorted(res.bubbles)]
    ctrl = BubbleTeaController(raw, LM, pp_degree=1)
    rng = np.random.default_rng(0)
    t = 0.0
    for rid in range(200):
        t += rng.exponential(2.0)
        ctrl.submit(PrefillRequest(rid, t, int(rng.choice([128, 256, 512]))))
    assert ctrl.placements, "nothing placed"
    for p in ctrl.placements:
        pipe_bubbles = raw[p.pipeline]
        inside = any(
            s - 1e-9 <= p.start_ms and p.start_ms + p.duration_ms <= e + 1e-9
            for s, e in pipe_bubbles
        )
        assert inside, p
        assert p.start_ms >= 0


def test_no_placement_overlap_within_pipeline():
    res = _atlas_bubbles()
    ctrl = BubbleTeaController([list(res.bubbles[g]) for g in sorted(res.bubbles)], LM)
    rng = np.random.default_rng(1)
    t = 0.0
    for rid in range(300):
        t += rng.exponential(1.0)
        ctrl.submit(PrefillRequest(rid, t, 256))
    by_pipe = {}
    for p in ctrl.placements:
        by_pipe.setdefault(p.pipeline, []).append((p.start_ms, p.start_ms + p.duration_ms))
    for ivs in by_pipe.values():
        ivs.sort()
        for (s0, e0), (s1, e1) in zip(ivs, ivs[1:]):
            assert s1 >= e0 - 1e-9


def test_rejection_when_no_capacity():
    ctrl = BubbleTeaController([[(0.0, 10.0)]], LM, pp_degree=1)
    # a prefill needing more than 10 ms must be rejected
    big = PrefillRequest(0, 0.0, 8192)
    assert LM.prefill_ms(8192, 1) > 10.0
    assert ctrl.submit(big) is None
    assert ctrl.rejected == [0]
    assert ctrl.acceptance_rate() == 0.0


def test_utilization_improves_fig13():
    res = _atlas_bubbles()
    ctrl = BubbleTeaController([list(res.bubbles[g]) for g in sorted(res.bubbles)], LM)
    rng = np.random.default_rng(2)
    t = 0.0
    while t < res.iteration_ms:
        t += rng.exponential(1.0)
        ctrl.submit(PrefillRequest(int(t * 100), t, int(rng.choice([128, 256, 512, 1024]))))
    busy = sum(iv.end - iv.start for ivs in res.busy.values() for iv in ivs)
    total = res.iteration_ms * len(res.busy)
    before = busy / total
    after = utilization_with_prefills(busy, total, ctrl)
    assert after > before + 0.3  # paper: 45% -> 94%
    assert after <= 1.0


def test_utilization_pp_sharded_not_overcounted_fig13():
    """The Fig-13 bugfix: a PP-sharded prefill keeps each of the pp
    stages busy only for its own pipeline wave (≈ duration/pp + hop),
    not the full duration.  The added busy time must stay within the
    analytic per-stage bound — the bubble time the placements actually
    reserved — where the old duration × pp accounting exceeds it."""
    res = _atlas_bubbles()
    # one inference pipeline per DP-cell: same-rank GPUs' common idle
    pp = 4
    pipes = [
        intersect_bubbles([res.bubbles[(p, s)] for s in range(4)])
        for p in range(res.n_pipelines)
    ]
    ctrl = BubbleTeaController(pipes, LM, pp_degree=pp)
    rng = np.random.default_rng(3)
    t = 0.0
    while t < res.iteration_ms:
        t += rng.exponential(1.0)
        ctrl.submit(PrefillRequest(int(t * 100), t, int(rng.choice([128, 256, 512]))))
    assert ctrl.placements, "nothing placed"
    # per-stage wave accounting: busy per stage is duration/pp + hop,
    # capped at the window the placement reserved
    for p in ctrl.placements:
        stage = prefill_stage_busy_ms(p.duration_ms, pp)
        assert stage <= p.duration_ms + 1e-9
        assert stage == pytest.approx(
            min(p.duration_ms, p.duration_ms / pp + PIPE_HOP_MS))
    # the fillable ceiling per placement is duration × pp (every member
    # stage idle for the whole window); the corrected extra busy sits
    # strictly below it, the old accounting sat exactly at it
    fillable = sum(p.duration_ms for p in ctrl.placements) * pp
    extra = ctrl.prefill_gpu_busy_ms()
    old_extra = ctrl.prefill_busy_ms() * pp
    assert extra < old_extra
    assert extra <= fillable + 1e-9
    busy = sum(iv.end - iv.start for ivs in res.busy.values() for iv in ivs)
    total = res.iteration_ms * len(res.busy)
    after = utilization_with_prefills(busy, total, ctrl)
    # analytic upper bound: busy + the bubble time actually fillable
    # per stage — the placements' reserved windows on their pp stages
    assert after <= (busy + fillable) / total + 1e-9
    assert after > busy / total  # prefills still add useful work


def test_prefill_stage_busy_pp1_is_full_duration():
    assert prefill_stage_busy_ms(42.0, 1) == 42.0
    # tiny prefill on a deep pipeline: capped at the window itself
    assert prefill_stage_busy_ms(2.0, 8) == 2.0


def test_controller_search_fast():
    """Paper §6.5: bubble lookup well under a millisecond."""
    res = _atlas_bubbles()
    ctrl = BubbleTeaController(
        [list(res.bubbles[g]) for g in sorted(res.bubbles)],
        LM,
        clock=time.perf_counter,
    )
    for rid in range(50):
        ctrl.submit(PrefillRequest(rid, float(rid), 256))
    assert np.percentile(ctrl.search_time_us, 50) < 1000


def test_intersect_bubbles():
    a = [(0, 10), (20, 30)]
    b = [(5, 25)]
    assert intersect_bubbles([a, b]) == [(5, 10), (20, 25)]
    assert intersect_bubbles([a]) == a
    assert intersect_bubbles([a, [(50, 60)]]) == []


def test_intersect_bubbles_edge_cases():
    # touching-but-not-overlapping windows share only a zero-length
    # point: no usable window may be emitted
    assert intersect_bubbles([[(0, 10)], [(10, 20)]]) == []
    assert intersect_bubbles([[(0, 10), (10, 20)], [(5, 15)]]) == [(5, 10), (10, 15)]
    # unequal list lengths: the shorter list simply bounds the result
    a = [(0, 100)]
    b = [(10, 20), (30, 40), (50, 60)]
    assert intersect_bubbles([a, b]) == b
    assert intersect_bubbles([b, a]) == b
    # an empty GPU list anywhere means the pipeline has no common idle
    assert intersect_bubbles([a, []]) == []
    assert intersect_bubbles([[], a]) == []
    # no GPUs at all: no windows
    assert intersect_bubbles([]) == []
    # three-way with a middle list that splits both neighbours
    c = [(0, 12), (14, 100)]
    assert intersect_bubbles([a, c, b]) == [
        (10, 12), (14, 20), (30, 40), (50, 60)]


def test_reset_windows_after_replan_epoch():
    """The control-plane hook (ISSUE 4): after a re-plan the bubble
    geometry changes wholesale — stale windows must not serve, new
    ones must, and accounting carries across the epoch boundary."""
    ctrl = BubbleTeaController([[(0.0, 500.0)]], LM, pp_degree=1)
    p0 = ctrl.submit(PrefillRequest(0, 0.0, 128))
    assert p0 is not None
    # re-plan at t=600: the new epoch's bubbles live elsewhere — the old
    # window must not serve; the earliest feasible start is the new one
    ctrl.reset_windows([[(1_000.0, 1_500.0)]])
    p1 = ctrl.submit(PrefillRequest(1, 600.0, 128))
    assert p1 is not None and p1.start_ms == 1_000.0
    p2 = ctrl.submit(PrefillRequest(2, 1_050.0, 128))
    assert p2 is not None and p2.start_ms >= 1_050.0
    assert len(ctrl.placements) == 3  # accounting survived the reset
    # cursors restarted: a later reset with earlier windows still works
    ctrl.reset_windows([[(2_000.0, 2_400.0)], [(1_900.0, 2_300.0)]])
    p3 = ctrl.submit(PrefillRequest(3, 1_950.0, 128))
    assert p3 is not None and p3.pipeline == 1  # earliest-start pipeline wins


def test_utilization_with_prefills_guards_zero_span():
    ctrl = BubbleTeaController([[(0.0, 10.0)]], LM)
    assert utilization_with_prefills(0.0, 0.0, ctrl) == 0.0
    assert utilization_with_prefills(5.0, -1.0, ctrl) == 0.0


# -------------------------------------------- pruning + SLO (ISSUE 3)


def test_dead_windows_pruned_over_trace():
    """Windows that ended before the current arrival are skipped via the
    live cursor — first-fit must not rescan them for every request."""
    spacing_ms = 30.0
    wins = [(i * spacing_ms, i * spacing_ms + 20.0) for i in range(500)]
    ctrl = BubbleTeaController([wins], LM, pp_degree=1)
    need = LM.prefill_ms(128, 1) + ctrl.guard
    assert need < 20.0  # each window fits one 128-token prefill
    for rid in range(400):
        p = ctrl.submit(PrefillRequest(rid, rid * spacing_ms, 128))
        assert p is not None
        assert p.start_ms >= rid * spacing_ms
    # the cursor advanced past the dead prefix instead of rescanning it
    assert ctrl._live[0] >= 350


def _naive_first_fit(pipelines, reqs, lat, pp, guard):
    """Independent re-implementation of the pre-pruning controller: scan
    *every* window of every pipeline from index 0, earliest feasible
    start wins, split the chosen window."""
    windows = [sorted([list(w) for w in pipe]) for pipe in pipelines]
    out = []
    for r in reqs:
        need = lat.prefill_ms(r.prompt_tokens, pp) + guard
        best = None
        for pi, wins in enumerate(windows):
            for wi, (s, e) in enumerate(wins):
                start = max(s, r.arrival_ms)
                if e - start >= need:
                    if best is None or start < best[0]:
                        best = (start, pi, wi)
                    break
        if best is None:
            out.append(None)
            continue
        start, pi, wi = best
        s, e = windows[pi][wi]
        new = []
        if start - s > 1e-9:
            new.append([s, start])
        if e - (start + need) > 1e-9:
            new.append([start + need, e])
        windows[pi][wi : wi + 1] = new
        out.append((pi, start))
    return out


def test_pruning_preserves_first_fit_results():
    """The pruned scan must place exactly like a naive full scan (dead
    windows were never feasible: their end precedes the arrival)."""
    res = _atlas_bubbles()
    raw = [list(res.bubbles[g]) for g in sorted(res.bubbles)]
    pruned = BubbleTeaController(raw, LM, pp_degree=1)
    rng = np.random.default_rng(7)
    t = 0.0
    reqs = []
    for rid in range(300):
        t += rng.exponential(1.0)
        reqs.append(PrefillRequest(rid, t, int(rng.choice([128, 256, 512]))))
    got = [pruned.submit(r) for r in reqs]
    want = _naive_first_fit(raw, reqs, LM, 1, pruned.guard)
    assert [(p.pipeline, p.start_ms) if p else None for p in got] == want
    # and some cursor really advanced (downstream stages idle early: their
    # first windows end before the late arrivals)
    assert any(lo > 0 for lo in pruned._live)


def test_submit_requires_arrival_order():
    ctrl = BubbleTeaController([[(0.0, 1e6)]], LM)
    ctrl.submit(PrefillRequest(0, 100.0, 128))
    with pytest.raises(AssertionError):
        ctrl.submit(PrefillRequest(1, 50.0, 128))


def test_ttft_slo_admission_rejects_late_placements():
    """§5: a prefill whose *earliest* feasible start already blows the
    TTFT SLO is rejected back to the dedicated fleet, not placed late."""
    # only window opens 60 s after arrival -> queue delay 60 s
    far = [[(60_000.0, 120_000.0)]]
    no_slo = BubbleTeaController(far, LM, pp_degree=1)
    assert no_slo.submit(PrefillRequest(0, 0.0, 256)) is not None

    slo = BubbleTeaController(far, LM, pp_degree=1, ttft_slo_ms=5_000.0)
    assert slo.submit(PrefillRequest(0, 0.0, 256)) is None
    assert slo.rejected == [0] and slo.rejected_slo == [0]
    assert slo.acceptance_rate() == 0.0
    assert slo.slo_rejection_rate() == 1.0
    # a request arriving when the window is open passes the SLO
    p = slo.submit(PrefillRequest(1, 60_000.0, 256))
    assert p is not None and p.ttft_ms <= 5_000.0
    assert slo.slo_rejection_rate() == 0.5


# ---------------------------------------------------------------------------
# arrival processes, SLO tiers, KV quotes (fleet-scale serving layer)
# ---------------------------------------------------------------------------


def test_arrivals_seeded_deterministic_and_ordered():
    from repro.core.bubbletea import ArrivalProcess, PromptMix

    arr = ArrivalProcess(rate_per_s=30.0, horizon_ms=20_000.0, seed=11,
                         diurnal_amplitude=0.4, diurnal_period_ms=10_000.0,
                         burst_rate_mult=3.0, mean_on_ms=500.0,
                         mean_off_ms=2_000.0)
    mix = PromptMix(lengths=(128, 512), weights=(0.7, 0.3))
    a = arr.generate(mix, tiers={"gold": 0.5, "bronze": 0.5})
    b = arr.generate(mix, tiers={"gold": 0.5, "bronze": 0.5})
    assert [(r.req_id, r.arrival_ms, r.prompt_tokens, r.tier) for r in a] == \
           [(r.req_id, r.arrival_ms, r.prompt_tokens, r.tier) for r in b]
    assert len(a) > 100  # ~30/s over 20 s, modulo modulation
    ts = [r.arrival_ms for r in a]
    assert ts == sorted(ts) and all(0 <= t < 20_000.0 for t in ts)
    assert [r.req_id for r in a] == list(range(len(a)))
    assert {r.prompt_tokens for r in a} <= {128, 512}
    assert {r.tier for r in a} <= {"gold", "bronze"}
    # a different seed yields a different trace
    c = ArrivalProcess(rate_per_s=30.0, horizon_ms=20_000.0, seed=12,
                       diurnal_amplitude=0.4, diurnal_period_ms=10_000.0,
                       burst_rate_mult=3.0, mean_on_ms=500.0,
                       mean_off_ms=2_000.0).generate(mix)
    assert [r.arrival_ms for r in c] != ts


def test_arrivals_diurnal_wave_shifts_mass():
    from repro.core.bubbletea import ArrivalProcess

    # one full sine period: first half (sin > 0) runs above the base
    # rate, second half below — the counts must reflect that
    arr = ArrivalProcess(rate_per_s=50.0, horizon_ms=60_000.0, seed=3,
                         diurnal_amplitude=0.8, diurnal_period_ms=60_000.0)
    reqs = arr.generate()
    first = sum(1 for r in reqs if r.arrival_ms < 30_000.0)
    second = len(reqs) - first
    assert first > 1.5 * second


def test_arrivals_bursty_more_dispersed_than_poisson():
    from repro.core.bubbletea import ArrivalProcess

    def fano(reqs, horizon_ms, bin_ms=1_000.0):
        bins = [0] * int(horizon_ms / bin_ms)
        for r in reqs:
            bins[min(int(r.arrival_ms / bin_ms), len(bins) - 1)] += 1
        m = sum(bins) / len(bins)
        var = sum((b - m) ** 2 for b in bins) / len(bins)
        return var / m

    plain = ArrivalProcess(rate_per_s=40.0, horizon_ms=120_000.0, seed=5)
    burst = ArrivalProcess(rate_per_s=40.0, horizon_ms=120_000.0, seed=5,
                           burst_rate_mult=6.0, mean_on_ms=1_000.0,
                           mean_off_ms=4_000.0)
    # Poisson counts have Fano ~1; the MMPP modulation must over-disperse
    assert fano(plain.generate(), 120_000.0) < 2.0
    assert fano(burst.generate(), 120_000.0) > 2.0


def test_tier_acceptance_monotone_in_slo_slack():
    """Within one run over a shared request stream, a tier with more
    TTFT slack accepts (weakly) more of its share — tiers differ only
    in budget, and a placement feasible under a tight budget is feasible
    under a looser one."""
    from repro.core.bubbletea import ArrivalProcess, PromptMix

    arr = ArrivalProcess(rate_per_s=60.0, horizon_ms=20_000.0, seed=9)
    slos = {"tight": 150.0, "mid": 500.0, "loose": 5_000.0}
    reqs = arr.generate(PromptMix(lengths=(128, 256), weights=(0.7, 0.3)),
                        tiers={t: 1.0 for t in slos})
    bubbles = [[(i * 500.0, i * 500.0 + 220.0) for i in range(40)]]
    ctrl = BubbleTeaController(bubbles, LM, tiers=slos)
    for r in reqs:
        ctrl.submit(r)
    rep = ctrl.tier_report()
    assert sum(rep[t]["offered"] for t in slos) == len(reqs)
    for t, slo in slos.items():
        assert rep[t]["ttft_p50_ms"] <= rep[t]["ttft_p95_ms"] <= rep[t]["ttft_p99_ms"]
        if rep[t]["placed"]:
            assert rep[t]["ttft_p99_ms"] <= slo
    assert (rep["tight"]["acceptance"] <= rep["mid"]["acceptance"]
            <= rep["loose"]["acceptance"])
    assert rep["tight"]["acceptance"] < rep["loose"]["acceptance"]


def test_arrival_order_invariant_across_reset_epochs():
    """reset_windows carries the arrival clock across epochs: a stream
    split at an epoch boundary equals the same stream fed continuously
    only if ordering is enforced — and out-of-order submits must raise."""
    from repro.core.bubbletea import ArrivalProcess

    arr = ArrivalProcess(rate_per_s=20.0, horizon_ms=8_000.0, seed=2)
    reqs = arr.generate()
    epoch1 = [[(0.0, 4_000.0)]]
    epoch2 = [[(4_000.0, 8_000.0)]]
    ctrl = BubbleTeaController(epoch1, LM)
    for r in (x for x in reqs if x.arrival_ms < 4_000.0):
        ctrl.submit(r)
    ctrl.reset_windows(epoch2)
    rest = [x for x in reqs if x.arrival_ms >= 4_000.0]
    for r in rest:
        ctrl.submit(r)
    assert len(ctrl.placements) + len(ctrl.rejected) == len(reqs)
    with pytest.raises(AssertionError):
        ctrl.submit(PrefillRequest(req_id=10_000, arrival_ms=0.0,
                                   prompt_tokens=128))


def test_local_kv_quote_enters_ttft_and_slo_gate():
    from repro.core.bubbletea import LocalKVHandoff

    heavy = InferenceModelSpec("kv-heavy", num_params=8e9,
                               kv_bytes_per_token=2e8)
    lm = PrefillLatencyModel(heavy)
    req = PrefillRequest(req_id=0, arrival_ms=0.0, prompt_tokens=512)
    kv = LocalKVHandoff(heavy)
    quote = kv.price(512, None, 0.0)
    # done = ready + kv is assembled exactly this way in price(); the
    # identity is structural, not float arithmetic
    assert quote.kv_ms > 0 and quote.done_ms == quote.ready_ms + quote.kv_ms  # lint: ok[api/float-eq-ms]
    windows = [[(0.0, 10_000.0)]]
    base = lm.prefill_ms(512, 1)
    # budget covers prefill + overhead but not the (huge) KV move
    slo = base + 100.0
    ctrl = BubbleTeaController(windows, lm, ttft_slo_ms=slo, kv=kv)
    assert ctrl.submit(req) is None and ctrl.rejected_slo == [0]
    ctrl2 = BubbleTeaController(windows, lm, ttft_slo_ms=slo + quote.kv_ms, kv=kv)
    p = ctrl2.submit(PrefillRequest(req_id=1, arrival_ms=0.0, prompt_tokens=512))
    assert p is not None and p.kv_ms == pytest.approx(quote.kv_ms)


def test_sub_guard_fragments_dropped_no_degradation():
    """Regression: splitting used to leave < guard_ms fragments in the
    window list; over a long trace first-fit rescanned them forever.
    They can never host a placement (need = prefill + guard > guard), so
    the live window count must stay bounded by placements, and search
    time must not trend upward."""
    from repro.core.bubbletea import ArrivalProcess

    guard_ms = 1.0
    # windows sized so a 128-token prefill leaves a sub-guard tail
    need = LM.prefill_ms(128, 1) + guard_ms
    w = need + guard_ms + 0.5  # split leaves a 0.5ms (< guard) tail fragment
    spacing_ms = 400.0
    bubbles = [[(i * spacing_ms, i * spacing_ms + w) for i in range(400)]]
    ctrl = BubbleTeaController(bubbles, LM, guard_ms=guard_ms,
                               clock=time.perf_counter)
    arr = ArrivalProcess(rate_per_s=15.0, horizon_ms=160_000.0, seed=4)
    mix_reqs = arr.generate()
    for r in mix_reqs:
        r = PrefillRequest(r.req_id, r.arrival_ms, 128)
        ctrl.submit(r)
    assert len(ctrl.placements) > 300
    # every surviving window is still >= guard wide: no fragment debris
    for wins in ctrl.windows:
        assert all(win.end - win.start > guard_ms for win in wins)
    # search cost stays flat: late-trace searches no slower than 4x early
    early = np.mean(ctrl.search_time_us[:50])
    late = np.mean(ctrl.search_time_us[-50:])
    assert late < max(4.0 * early, 50.0)
