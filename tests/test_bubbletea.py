"""BubbleTea controller + TTFT model — paper §5 / Fig 13 / Fig 14."""
import numpy as np
import pytest

from repro.core.bubbletea import (
    BubbleTeaController,
    InferenceModelSpec,
    PrefillLatencyModel,
    PrefillRequest,
    intersect_bubbles,
    utilization_with_prefills,
)
from repro.core.simulator import GeoTopology, simulate
from repro.core.simulator import testbed_spec as make_spec

LLAMA = InferenceModelSpec("llama3-8b", num_params=8e9)
LM = PrefillLatencyModel(LLAMA)


def test_fig14_calibration_anchors():
    """PP=8 inflates TTFT +29% at 512 tokens; PP=1 is +67% at 8K."""
    small = LM.ttft_ms(512, 8) / LM.ttft_ms(512, 1) - 1
    large = LM.ttft_ms(8192, 1) / LM.ttft_ms(8192, 8) - 1
    assert small == pytest.approx(0.29, abs=0.05)
    assert large == pytest.approx(0.67, abs=0.08)


def test_fig14_crossover():
    """Low PP wins for small prompts; high PP wins for large prompts."""
    assert LM.ttft_ms(512, 1) < LM.ttft_ms(512, 8)
    assert LM.ttft_ms(8192, 8) < LM.ttft_ms(8192, 1)


def test_prefill_duration_deterministic_and_monotone():
    prev = 0.0
    for L in (128, 256, 512, 1024, 2048, 4096):
        d = LM.prefill_ms(L, 1)
        assert d == LM.prefill_ms(L, 1)
        assert d > prev
        prev = d


def _atlas_bubbles():
    spec = make_spec(
        hidden=4096, seq_len=4096, micro_batch=1, layers_per_stage=1,
        layer_params=412e6, num_stages=4, microbatches=4, stage_dc=[0, 0, 1, 2],
    )
    res = simulate(spec, GeoTopology(wan_latency_ms=40, multi_tcp=True),
                   policy="atlas", n_pipelines=3)
    return res


def test_placements_fit_inside_bubbles():
    res = _atlas_bubbles()
    raw = [list(res.bubbles[g]) for g in sorted(res.bubbles)]
    ctrl = BubbleTeaController(raw, LM, pp_degree=1)
    rng = np.random.default_rng(0)
    t = 0.0
    for rid in range(200):
        t += rng.exponential(2.0)
        ctrl.submit(PrefillRequest(rid, t, int(rng.choice([128, 256, 512]))))
    assert ctrl.placements, "nothing placed"
    for p in ctrl.placements:
        pipe_bubbles = raw[p.pipeline]
        inside = any(
            s - 1e-9 <= p.start_ms and p.start_ms + p.duration_ms <= e + 1e-9
            for s, e in pipe_bubbles
        )
        assert inside, p
        assert p.start_ms >= 0


def test_no_placement_overlap_within_pipeline():
    res = _atlas_bubbles()
    ctrl = BubbleTeaController([list(res.bubbles[g]) for g in sorted(res.bubbles)], LM)
    rng = np.random.default_rng(1)
    t = 0.0
    for rid in range(300):
        t += rng.exponential(1.0)
        ctrl.submit(PrefillRequest(rid, t, 256))
    by_pipe = {}
    for p in ctrl.placements:
        by_pipe.setdefault(p.pipeline, []).append((p.start_ms, p.start_ms + p.duration_ms))
    for ivs in by_pipe.values():
        ivs.sort()
        for (s0, e0), (s1, e1) in zip(ivs, ivs[1:]):
            assert s1 >= e0 - 1e-9


def test_rejection_when_no_capacity():
    ctrl = BubbleTeaController([[(0.0, 10.0)]], LM, pp_degree=1)
    # a prefill needing more than 10 ms must be rejected
    big = PrefillRequest(0, 0.0, 8192)
    assert LM.prefill_ms(8192, 1) > 10.0
    assert ctrl.submit(big) is None
    assert ctrl.rejected == [0]
    assert ctrl.acceptance_rate() == 0.0


def test_utilization_improves_fig13():
    res = _atlas_bubbles()
    ctrl = BubbleTeaController([list(res.bubbles[g]) for g in sorted(res.bubbles)], LM)
    rng = np.random.default_rng(2)
    t = 0.0
    while t < res.iteration_ms:
        t += rng.exponential(1.0)
        ctrl.submit(PrefillRequest(int(t * 100), t, int(rng.choice([128, 256, 512, 1024]))))
    busy = sum(iv.end - iv.start for ivs in res.busy.values() for iv in ivs)
    total = res.iteration_ms * len(res.busy)
    before = busy / total
    after = utilization_with_prefills(busy, total, ctrl)
    assert after > before + 0.3  # paper: 45% -> 94%
    assert after <= 1.0


def test_controller_search_fast():
    """Paper §6.5: bubble lookup well under a millisecond."""
    res = _atlas_bubbles()
    ctrl = BubbleTeaController([list(res.bubbles[g]) for g in sorted(res.bubbles)], LM)
    for rid in range(50):
        ctrl.submit(PrefillRequest(rid, float(rid), 256))
    assert np.percentile(ctrl.search_time_us, 50) < 1000


def test_intersect_bubbles():
    a = [(0, 10), (20, 30)]
    b = [(5, 25)]
    assert intersect_bubbles([a, b]) == [(5, 10), (20, 25)]
    assert intersect_bubbles([a]) == a
    assert intersect_bubbles([a, [(50, 60)]]) == []
