"""Sanctioned unit-conversion helpers — the only place conversion
arithmetic is allowed to live.

Every quantity in the simulator carries its unit in its identifier
suffix (``_ms``, ``_s``, ``_bytes``, ``_bits``, ``_gbps``, ...;
see ROADMAP "Static analysis").  Crossing between units requires the
constants 8 (bits per byte), 1e6 (bits/ms per Gbit/s) and 1e9
(bits/s per Gbit/s) — exactly the factors that silently go missing in
WAN cost models.  ``repro.analysis`` forbids those constants next to a
dimensioned operand anywhere in ``repro.core`` *except* inside this
module (rule ``units/inline-conversion``), so a conversion either goes
through a helper below or trips the lint.

Numerical note: each helper preserves the exact floating-point
operation order of the inline expression it replaced, so extracting
the arithmetic is bit-identical — the differential tests against the
frozen ``reference`` engine still compare equal, not merely close.
"""
from __future__ import annotations

BITS_PER_BYTE = 8.0
#: 1 Gbit/s delivers 1e6 bits per millisecond.
BITS_PER_MS_PER_GBPS = 1e6
#: 1 Gbit/s delivers 1e9 bits per second.
BITS_PER_S_PER_GBPS = 1e9
MS_PER_S = 1e3
MS_PER_HOUR = 3.6e6


def bytes_to_bits(nbytes: float) -> float:
    """Payload size in bits."""
    return nbytes * 8.0


def bits_to_bytes(bits: float) -> float:
    """Payload size in bytes."""
    return bits / 8.0


def gb_to_bytes(size_gb: float) -> float:
    """Decimal gigabytes (1 GB = 1e9 bytes) to bytes."""
    return size_gb * 1e9


def serialization_ms(nbytes: float, bw_gbps: float) -> float:
    """Wire time of ``nbytes`` at ``bw_gbps`` (no propagation latency).

    The canonical ``bytes -> ms`` conversion: x8 for bits, /1e9 for
    seconds at Gbit/s, x1e3 for milliseconds.
    """
    return (nbytes * 8.0) / (bw_gbps * 1e9) * 1e3


def bits_serialization_ms(bits: float, bw_gbps: float) -> float:
    """Wire time of ``bits`` at ``bw_gbps``."""
    return bits / (bw_gbps * 1e9) * 1e3


def serialization_ms_gbytes(nbytes: float, bw_gbytes_per_s: float) -> float:
    """Wire time of ``nbytes`` over a byte-rated local link (GB/s, as
    NVLink/PCIe are quoted) — no x8, the rate is already in bytes."""
    return nbytes / (bw_gbytes_per_s * 1e9) * 1e3


def window_bits(duration_ms: float, bw_gbps: float, rate_mult: float = 1.0) -> float:
    """Link capacity over a window: bits deliverable in ``duration_ms``
    at ``bw_gbps`` (optionally scaled by a contention multiplier)."""
    if rate_mult == 1.0:
        return duration_ms * bw_gbps * 1e6
    return duration_ms * bw_gbps * rate_mult * 1e6


def bits_rate_gbps(bits: float, duration_ms: float) -> float:
    """Mean rate, in Gbit/s, that moves ``bits`` in ``duration_ms``."""
    return bits / duration_ms / 1e6
