"""Cross-pod pipeline parallelism — the paper's "PP across DCs" mapped to
TPU (DESIGN.md §2).

The ``pod`` mesh axis carries pipeline stages; ``data`` carries DP within a
pod; ``model`` carries TP.  The step is a circular-rotation microbatch
pipeline inside a *partial-auto* shard_map: manual over {pod, data}
(``lax.ppermute`` moves stage-boundary activations across the inter-pod
DCN; per-data-shard token work is local, which also sidesteps XLA SPMD
partitioner failures around MoE gather/scatter in manual subgroups),
while GSPMD keeps handling the ``model`` axis (TP) automatically.
Autodiff through the scan+ppermute yields the reversed-permutation
backward pipeline for free; the psum over ``data`` in the loss transposes
into the DP gradient all-reduce.

Boundary modes — the TPU-native reading of the paper's two transports:
  * ``direct``  (Varuna / PyTorch-one-TCP analogue): the activation is
    model-axis *replicated* when it crosses the pod boundary, so all 16
    chips of a model group send identical bytes over the thin DCN — 16×
    redundant traffic.
  * ``striped`` (Atlas multi-TCP + temporal-sharing analogue): constrain
    the activation to be model-sharded before the ppermute (a local slice,
    no comm), so each chip carries 1/16 of the unique bytes over DCN, and
    all-gather it back over the fast intra-pod ICI on the receiving side.
  The dry-run roofline's collective-bytes term makes the 16× visible.

Non-divisible layer counts (deepseek-v2-lite: 27, zamba2: 9 groups) are
padded with exact-identity zero layers (residual blocks with zero weights
add exactly 0; zamba2's shared block is disabled by its zero-padded
per-group gate), keeping stages structurally uniform.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.compat import shard_map

from repro.models.modules import ModelConfig
from repro.models.transformer import PipelineParts, build_pipeline_parts
from repro.parallel.sharding import constrain, constraints_disabled


def pad_layer_stack(layers: Any, num_stages: int) -> Any:
    """Zero-pad the leading (layer) axis to a multiple of num_stages.

    Zero weights make a residual block an exact identity (attn/FFN/Mamba
    deltas are 0), so padding does not change the function.
    """

    def pad(leaf):
        L = leaf.shape[0]
        pad_n = (-L) % num_stages
        if pad_n == 0:
            return leaf
        return jnp.concatenate(
            [leaf, jnp.zeros((pad_n,) + leaf.shape[1:], leaf.dtype)], 0
        )

    return jax.tree.map(pad, layers)


def padded_num_layers(num_layers: int, num_stages: int) -> int:
    return num_layers + ((-num_layers) % num_stages)


def make_pipeline_loss(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    n_micro: int = 4,
    boundary: str = "striped",
) -> Callable[[Dict, Dict], jax.Array]:
    """Build loss(params, batch) running PP over the mesh's ``pod`` axis."""
    assert boundary in ("striped", "direct")
    assert not cfg.tie_embeddings, (
        "pipeline requires untied embeddings: a tied table is consumed in "
        "both the GSPMD and manual regions, which XLA's partitioner rejects"
    )
    parts = build_pipeline_parts(cfg)
    S = mesh.shape["pod"]
    DP = mesh.shape["data"]

    def loss_fn(params: Dict, batch: Dict) -> jax.Array:
        # ---- static input prep (ints only; differentiable inputs and the
        # embedding lookup live INSIDE the manual region — a `take` whose
        # cotangent crosses the GSPMD/manual boundary trips an XLA SPMD
        # partitioner CHECK) ----
        if "embeds" in batch:
            B, T = batch["embeds"].shape[:2]
        else:
            B, T = batch["tokens"].shape
        assert B % (n_micro * DP) == 0, (B, n_micro, DP)
        mb = B // n_micro

        if "positions" in batch:
            positions = batch["positions"]
        elif cfg.mrope_sections is not None:
            pos2 = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
            positions = jnp.broadcast_to(pos2[None], (3, B, T))
        else:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        if positions.ndim == 3:  # (3, B, T) M-RoPE
            pos_mb = positions.reshape(3, n_micro, mb, T).transpose(1, 0, 2, 3)
            pos_spec = P(None, None, "data", None)
        else:
            pos_mb = positions.reshape(n_micro, mb, T)
            pos_spec = P(None, "data", None)

        targets = batch.get("labels")
        if targets is None:
            targets = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
            mask = jnp.ones_like(targets, jnp.float32).at[:, -1].set(0.0)
        else:
            mask = batch.get("mask")
            if mask is None:
                mask = jnp.ones_like(targets, jnp.float32)
        t_mb = targets.reshape(n_micro, mb, T)
        m_mb = mask.reshape(n_micro, mb, T)

        if "embeds" in batch:
            inp_mb = batch["embeds"].astype(cfg.dtype).reshape(n_micro, mb, T, -1)
            inp_spec = P(None, "data", None, None)
            token_input = False
        else:
            inp_mb = batch["tokens"].reshape(n_micro, mb, T)
            inp_spec = P(None, "data", None)
            token_input = True

        layers = pad_layer_stack(params[parts.layer_key], S)
        rest = {k: v for k, v in params.items() if k != parts.layer_key}

        inner = functools.partial(
            _pipeline_inner,
            parts=parts,
            cfg=cfg,
            S=S,
            DP=DP,
            n_micro=n_micro,
            boundary=boundary,
            token_input=token_input,
        )
        sm = shard_map(
            inner,
            mesh=mesh,
            in_specs=(
                P("pod"),
                jax.tree.map(lambda _: P("pod"), layers),
                jax.tree.map(lambda _: P(), rest),
                inp_spec,
                pos_spec,
                P(None, "data", None),
                P(None, "data", None),
            ),
            out_specs=(P(), P()),
            # partial-auto (GSPMD keeps handling TP on ``model``) where
            # supported; otherwise fully manual with the model axis
            # carrying replicas — same numerics, no TP overlap.
            axis_names={"pod", "data"}
            if compat.PARTIAL_AUTO_SUPPORTED
            else set(mesh.axis_names),
            check_vma=False,
        )
        # stage id travels as a pod-sharded iota: lax.axis_index lowers to
        # a PartitionId instruction old XLA cannot SPMD-partition in a
        # partial-auto region, while a sliced input partitions trivially.
        stage_ids = jnp.arange(S, dtype=jnp.int32)
        loss, aux = sm(stage_ids, layers, rest, inp_mb, pos_mb, t_mb, m_mb)
        return loss + aux

    return loss_fn


def _pipeline_inner(
    stage_ids, layers, rest, inp_mb, pos_mb, t_mb, m_mb, *, parts, cfg, S, DP,
    n_micro, boundary, token_input,
):
    """Manual over {pod, data}: ``layers`` is this stage's (L/S, ...) slice;
    token arrays are this data-shard's slice."""
    my = stage_ids[0]  # this pod's stage index (see caller)
    steps = n_micro + S - 1
    if token_input:
        # embedding lookup with device-local indices: the VJP scatter-add
        # stays inside the manual region (no partitioned scatter).
        x_mb = jnp.take(rest["embed"], inp_mb, axis=0).astype(cfg.dtype)
    else:
        x_mb = inp_mb
    mb, T, Dm = x_mb.shape[1:]

    def stage_fn(x, positions):
        def body(h, lp):
            # model-internal sharding constraints reference the (manual)
            # data axis; drop them here — GSPMD still propagates the
            # model-axis (TP) shardings from the parameters.
            with constraints_disabled():
                h, aux = parts.layer(lp, rest, h, positions)
            return h, aux

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, layers)
        return x, jnp.sum(auxs)

    idx = lambda arr, i: jax.lax.dynamic_index_in_dim(arr, i, 0, keepdims=False)

    def rot(carry, t):
        buf = carry  # activation arriving from the previous stage
        m_in = jnp.clip(t, 0, n_micro - 1)  # stage-0 microbatch index
        x0 = idx(x_mb, m_in)
        inp = jnp.where(my == 0, x0, buf)
        m_mine = t - my  # microbatch this stage works on (may be invalid)
        pos = idx(pos_mb, jnp.clip(m_mine, 0, n_micro - 1))
        y, aux = stage_fn(inp, pos)
        valid_mine = jnp.logical_and(m_mine >= 0, m_mine < n_micro)
        aux = aux * valid_mine.astype(jnp.float32)

        # ---- stage boundary: direct (replicated) vs striped (sharded) ----
        # NB: must force the sharding even when it is full replication
        # (repro.parallel.sharding.constrain treats all-None as a no-op),
        # otherwise GSPMD propagation picks its own layout and the two
        # modes become indistinguishable.  On old jax the constraint is
        # unsupported inside the manual region (compat.constrain_auto
        # no-ops) and GSPMD stripes on its own.
        if boundary == "striped":
            y_send = compat.constrain_auto(y, P(None, None, "model"))
            buf_next = jax.lax.ppermute(
                y_send, "pod", [(i, i + 1) for i in range(S - 1)]
            )
        else:
            # naive transport: the model-replicated activation crosses the
            # pod DCN as-is.  The optimization_barrier pins the layout —
            # without it XLA's partitioner reshards before the permute and
            # re-gathers after, i.e. GSPMD performs the Atlas striping
            # automatically (see EXPERIMENTS.md §Perf B).
            y_send = compat.constrain_auto(y, P(None, None, None))
            y_send = jax.lax.optimization_barrier(y_send)
            buf_next = jax.lax.ppermute(
                y_send, "pod", [(i, i + 1) for i in range(S - 1)]
            )
            buf_next = jax.lax.optimization_barrier(buf_next)
        buf_next = compat.constrain_auto(buf_next, P(None, None, None))

        # ---- loss on the last stage ----
        m_out = t - (S - 1)
        mo = jnp.clip(m_out, 0, n_micro - 1)
        with constraints_disabled():
            ce = parts.final_loss(rest, y, idx(t_mb, mo), idx(m_mb, mo))
        valid_out = jnp.logical_and(m_out >= 0, m_out < n_micro)
        is_last = my == (S - 1)
        ce = ce * valid_out.astype(jnp.float32) * is_last.astype(jnp.float32)
        return buf_next, (ce, aux)

    buf0 = jnp.zeros((mb, T, Dm), x_mb.dtype)
    _, (ces, auxs) = jax.lax.scan(rot, buf0, jnp.arange(steps))
    # psum over pod picks up the (single) last stage; psum over data
    # averages DP shards — its transpose is the DP gradient all-reduce.
    loss = jax.lax.psum(jnp.sum(ces), ("pod", "data")) / (n_micro * DP)
    aux = jax.lax.psum(jnp.sum(auxs), ("pod", "data")) / (n_micro * DP)
    return loss, aux
