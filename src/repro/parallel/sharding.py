"""Sharding rules and divisibility-aware constraint helpers.

The production layout (DESIGN.md §5) follows the paper's placement:
  - ``pod``   axis: pipeline stages (paper: PP across DCs)
  - ``data``  axis: data parallelism (paper: DP rings intra-DC)
  - ``model`` axis: tensor/expert parallelism (paper: TP/EP on NVLink)

``constrain`` is safe to call from model code unconditionally: it no-ops
outside a mesh context and drops mesh axes that do not divide the
corresponding dimension (e.g. granite's kv=1 heads on a 16-way model axis,
or qwen2-moe's 60 experts).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


import contextlib
import threading

_TLS = threading.local()


@contextlib.contextmanager
def constraints_disabled():
    """Temporarily no-op ``constrain`` — used inside the manual-pod
    shard_map pipeline where XLA's SPMD partitioner cannot handle some
    constrained gather/scatter patterns (MoE dispatch)."""
    prev = getattr(_TLS, "off", False)
    _TLS.off = True
    try:
        yield
    finally:
        _TLS.off = prev


def _ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - very old jax
        return None
    if m is None or getattr(m, "empty", True):
        return None
    return m


def _fit_spec(shape: Tuple[int, ...], spec: P, mesh) -> Optional[P]:
    """Drop axes that don't divide the dim; None if nothing remains."""
    axes = dict(mesh.shape)
    fitted = []
    changed = False
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            fitted.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        ok = []
        size = 1
        for n in names:
            if n in axes:
                size *= axes[n]
                ok.append(n)
        if ok and dim % size == 0:
            fitted.append(tuple(ok) if len(ok) > 1 else ok[0])
        else:
            fitted.append(None)
            changed = True
    if all(f is None for f in fitted):
        return None
    return P(*fitted)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that degrades gracefully.

    No-op when there is no ambient mesh (plain CPU tests) or when no axis
    of ``spec`` fits the array's shape.
    """
    if getattr(_TLS, "off", False):
        return x
    mesh = _ambient_mesh()
    if mesh is None or not hasattr(x, "shape"):
        return x
    fitted = _fit_spec(x.shape, spec, mesh)
    if fitted is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))


# ---------------------------------------------------------------------------
# canonical specs for the training/serving state
# ---------------------------------------------------------------------------

# logical rules: tensor-name suffix -> PartitionSpec (applied by best effort)
PARAM_RULES: Dict[str, P] = {
    # attention projections: shard the head (output-feature) dim
    "wq": P(None, "model"),
    "wk": P(None, "model"),
    "wv": P(None, "model"),
    "wo": P("model", None),
    # MLA
    "w_dkv": P(None, None),
    "w_uk": P(None, "model"),
    "w_uv": P(None, "model"),
    # FFN
    "w_gate": P(None, "model"),
    "w_up": P(None, "model"),
    "w_down": P("model", None),
    # embedding table: shard the feature dim (gather over an unsharded
    # vocab dim partitions trivially, incl. inside the pipeline's manual
    # region); LM head: shard the vocab dim (big-vocab CE memory)
    "embed": P(None, "model"),
    "lm_head": P(None, "model"),
    "router": P(None, None),
    # mamba2: head-sharded TP (see repro.models.ssm)
    "w_z": P(None, "model"),
    "w_x": P(None, "model"),
    "w_bc": P(None, None),
    "w_dt": P(None, None),
    "conv_x": P(None, "model"),
    "conv_bc": P(None, None),
    "w_out": P("model", None),
    "norm_scale": P("model"),
    # rwkv6: head-sharded time-mix, model-sharded channel-mix
    "wr": P(None, "model"),
    "wg": P(None, "model"),
    "w0": P("model"),
    "w_lora_a": P(None, None),
    "w_lora_b": P(None, "model"),
    "u": P("model", None),
    "ck": P(None, "model"),
    "cv": P("model", None),
    "cr": P(None, "model"),
    # norms / scalars replicated
}

MOE_RULES: Dict[str, Tuple[P, ...]] = {
    # routed experts: shard the expert dim (EP); when the expert count
    # does not divide the model axis (qwen2-moe: 60 experts on 16), fall
    # back to sharding the FFN feature dim so the weights never replicate
    "w_gate": (P("model", None, None), P(None, None, "model")),
    "w_up": (P("model", None, None), P(None, None, "model")),
    "w_down": (P("model", None, None), P(None, "model", None)),
}


def param_spec_candidates(
    path: Tuple[str, ...], shape: Tuple[int, ...], stacked: bool
) -> Tuple[P, ...]:
    """Candidate specs for a parameter leaf, best first.  ``stacked`` =>
    leading layer axis.  The caller picks the first that fits the mesh."""
    name = path[-1]
    in_moe = (
        any(p in ("moe", "experts") for p in path[:-1])
        and name in MOE_RULES
        and len(shape) >= 3
    )
    cands = MOE_RULES[name] if in_moe else (PARAM_RULES.get(name, P()),)
    if stacked:
        cands = tuple(P(None, *tuple(c)) for c in cands)
    return cands


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], stacked: bool) -> P:
    return param_spec_candidates(path, shape, stacked)[0]


def _tree_paths(tree: Any):
    return jax.tree_util.tree_flatten_with_path(tree)


def _add_fsdp_axis(spec: P, shape: Tuple[int, ...], mesh: Mesh, min_bytes=2**22) -> P:
    """ZeRO/FSDP-style 2D sharding: also shard a large, still-unsharded dim
    of big matrices over the ``data`` axis (weights are all-gathered on
    use; params + Adam state memory drops by the data-axis size)."""
    if "data" not in mesh.shape:
        return spec
    n = 1
    for d in shape:
        n *= d
    if n * 4 < min_bytes or len(shape) < 2:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    dp = mesh.shape["data"]
    # pick the largest unsharded dim divisible by the data axis
    cands = [
        (shape[i], i) for i, e in enumerate(entries) if e is None and shape[i] % dp == 0 and shape[i] > 1
    ]
    if not cands:
        return spec
    _, i = max(cands)
    entries[i] = "data"
    return P(*entries)


def make_param_shardings(
    params_shape: Any,
    mesh: Mesh,
    stacked_prefixes=("layers", "groups"),
    *,
    fsdp: bool = False,
):
    """Build a NamedSharding pytree for a params(-shape) pytree."""

    def one(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path if hasattr(p, "key")
        )
        stacked = any(n in stacked_prefixes for n in names)
        for spec in param_spec_candidates(names or ("",), leaf.shape, stacked):
            fitted = _fit_spec(leaf.shape, spec, mesh)
            if fitted is not None:
                if fsdp:
                    fitted2 = _fit_spec(
                        leaf.shape, _add_fsdp_axis(fitted, leaf.shape, mesh), mesh
                    )
                    if fitted2 is not None:
                        return NamedSharding(mesh, fitted2)
                return NamedSharding(mesh, fitted)
        return NamedSharding(mesh, P())

    leaves, treedef = _tree_paths(params_shape)
    shardings = [one(path, leaf) for path, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def batch_spec(ndim: int) -> P:
    """Shard the batch (dim 0) over data; rest replicated."""
    return P("data", *([None] * (ndim - 1)))


def make_batch_shardings(batch_shape: Any, mesh: Mesh):
    def one(leaf):
        spec = batch_spec(len(leaf.shape))
        # VLM positions are (3, B, T): batch is dim 1
        if len(leaf.shape) == 3 and leaf.shape[0] == 3 and leaf.dtype == jnp.int32:
            spec = P(None, "data", None)
        fitted = _fit_spec(leaf.shape, spec, mesh)
        return NamedSharding(mesh, fitted if fitted is not None else P())

    return jax.tree_util.tree_map(one, batch_shape)


def make_cache_shardings(cache_shape: Any, mesh: Mesh):
    """KV caches: batch on data, head/feature dims on model where they fit."""

    def one(leaf):
        if len(leaf.shape) == 5:  # (L, B, S, Hkv, Dh)
            spec = P(None, "data", None, "model", None)
        elif len(leaf.shape) == 4:  # (L, B, S, d) latent / conv state
            spec = P(None, "data", None, None)
        elif len(leaf.shape) == 3:  # (L, B, S) positions
            spec = P(None, "data", None)
        else:
            spec = P()
        fitted = _fit_spec(leaf.shape, spec, mesh)
        return NamedSharding(mesh, fitted if fitted is not None else P())

    return jax.tree_util.tree_map(one, cache_shape)
