"""Fused RMSNorm — Pallas kernel (memory-bound hot-spot).

Grid over row blocks; each block loads (block_rows, d) into VMEM, computes
the f32 variance on-chip and writes the scaled rows back once — one HBM
round-trip instead of the unfused norm's several.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_rows(
    x: jax.Array,  # (N, d)
    scale: jax.Array,  # (d,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    N, d = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, scale)
