"""Single-token (decode) attention over a long KV cache — Pallas kernel.

One query per (batch, head); the kernel streams KV blocks through VMEM and
keeps the online-softmax accumulators in scratch.  Validity/causality/
sliding-window masking is driven by the cache's per-slot position array
(ring-buffer caches leave ``pos`` in arbitrary slot order, so masking by
value — not by index — is required).

Grid: (B·H, kv_blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _decode_kernel(
    q_ref,  # (1, 1, D)
    k_ref,  # (1, bkv, D)
    v_ref,
    kvpos_ref,  # (1, bkv)
    qpos_ref,  # (1, 1)
    o_ref,  # (1, 1, D)
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    window: int,
    num_kv_blocks: int,
):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (D,)
    k = k_ref[0].astype(jnp.float32)  # (bkv, D)
    v = v_ref[0].astype(jnp.float32)
    kv_pos = kvpos_ref[0]  # (bkv,)
    q_pos = qpos_ref[0, 0]

    s = jnp.dot(k, q)  # (bkv,)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window > 0:
        valid = valid & (kv_pos > q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[0] = l_prev * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)[None]
    m_ref[0] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[0] / denom).astype(o_ref.dtype)


def decode_attention_bhsd(
    q: jax.Array,  # (BH, 1, D)
    k: jax.Array,  # (BKv, S, D)
    v: jax.Array,
    q_pos: jax.Array,  # (BH, 1) int32
    kv_pos: jax.Array,  # (BKv, S) int32
    *,
    group: int,
    scale: float,
    window: int = 0,
    block_kv: int = 256,
    interpret: bool = True,
) -> jax.Array:
    BH, _, D = q.shape
    S = k.shape[1]
    block_kv = min(block_kv, S)
    assert S % block_kv == 0
    nkv = S // block_kv

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, num_kv_blocks=nkv
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda i, k_: (i, 0, 0)),
            pl.BlockSpec((1, block_kv, D), lambda i, k_, g=group: (i // g, k_, 0)),
            pl.BlockSpec((1, block_kv, D), lambda i, k_, g=group: (i // g, k_, 0)),
            pl.BlockSpec((1, block_kv), lambda i, k_, g=group: (i // g, k_)),
            pl.BlockSpec((1, 1), lambda i, k_: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda i, k_: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_pos, q_pos)
