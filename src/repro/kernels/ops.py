"""jit'd public wrappers around the Pallas kernels.

These accept the model-layer layouts ((B, T, H, D) etc.), reshape to the
kernel layouts, pick interpret mode automatically (interpret=True anywhere
but real TPU), and fall back to the jnp reference for shapes the kernels
do not support (e.g. non-divisible blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rms
from repro.kernels import wkv6 as _wkv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_kv"))
def flash_attention(
    q: jax.Array,  # (B, T, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else D**-0.5
    bq, bkv = min(block_q, T), min(block_kv, S)
    if T % bq or S % bkv:
        return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    group = Hq // Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    o = _fa.flash_attention_bhsd(
        qr, kr, vr, group=group, scale=scale, causal=causal,
        block_q=bq, block_kv=bkv, interpret=_interpret(),
    )
    return o.reshape(B, Hq, T, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("window", "scale", "block_kv"))
def decode_attention(
    q: jax.Array,  # (B, 1, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, 1)
    kv_pos: jax.Array,  # (B, S)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_kv: int = 256,
) -> jax.Array:
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert T == 1
    scale = scale if scale is not None else D**-0.5
    bkv = min(block_kv, S)
    if S % bkv:
        return _ref.decode_attention_ref(q, k, v, q_pos, kv_pos, window=window, scale=scale)
    group = Hq // Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, 1, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    qp = jnp.repeat(q_pos, Hq, axis=0).reshape(B * Hq, 1)
    kp = jnp.repeat(kv_pos, Hkv, axis=0).reshape(B * Hkv, S)
    o = _dec.decode_attention_bhsd(
        qr, kr, vr, qp, kp, group=group, scale=scale,
        window=window or 0, block_kv=bkv, interpret=_interpret(),
    )
    return o.reshape(B, Hq, 1, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6, block_rows: int = 256):
    shape = x.shape
    N = 1
    for s in shape[:-1]:
        N *= s
    xr = x.reshape(N, shape[-1])
    br = min(block_rows, N)
    if N % br:
        return _ref.rmsnorm_ref(x, scale, eps)
    o = _rms.rmsnorm_rows(xr, scale, eps=eps, block_rows=br, interpret=_interpret())
    return o.reshape(shape)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(
    r: jax.Array,  # (B, T, H, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # (H, D)
    *,
    chunk: int = 64,
) -> jax.Array:
    B, T, H, D = r.shape
    c = min(chunk, T)
    if T % c:
        return _ref.wkv6_ref(r, k, v, logw, u)
    tr = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    ub = jnp.tile(u, (B, 1))  # (B*H, D)
    o = _wkv.wkv6_bhtd(
        tr(r), tr(k), tr(v), tr(logw.astype(jnp.float32)), ub, chunk=c,
        interpret=_interpret(),
    )
    return o.reshape(B, H, T, D).transpose(0, 2, 1, 3)
