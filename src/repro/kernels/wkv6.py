"""RWKV-6 WKV chunked recurrence — Pallas kernel.

Per (batch·head) row, the kv axis of the grid walks chunks *sequentially*
and carries the (D_k × D_v) state in VMEM scratch — the TPU-native shape
of a linear-attention recurrence (state never leaves VMEM between chunks).

    S_t   = diag(w_t) S_{t-1} + k_tᵀ v_t
    out_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

Grid: (B·H, num_chunks).  Within a chunk the intra-term is an MXU-friendly
masked (chunk × chunk) matmul on decay-rescaled r/k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref,  # (1, C, D)
    k_ref,
    v_ref,
    lw_ref,  # (1, C, D) log decay (<= 0), f32
    u_ref,  # (1, D)
    o_ref,  # (1, C, D)
    state_ref,  # scratch (D, D) f32  [key-dim x value-dim]
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)  # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (D,)

    lcum_inc = jnp.cumsum(lw, axis=0)  # inclusive
    lcum = lcum_inc - lw  # exclusive
    ltot = lcum_inc[-1]  # (D,)

    r_sc = r * jnp.exp(lcum)
    k_sc = k * jnp.exp(-lcum_inc)
    scores = jnp.dot(r_sc, k_sc.T)  # (C, C)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(t_idx > s_idx, scores, 0.0)  # strictly causal
    y = jnp.dot(scores, v)
    # current-token bonus
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (C,)
    y = y + diag[:, None] * v
    # inter-chunk from carried state
    y = y + jnp.dot(r_sc, state_ref[...])

    o_ref[0] = y.astype(o_ref.dtype)

    # update state: S = diag(prod w) S + sum_s exp(ltot - lcum_inc_s) k_s v_sᵀ
    kw = k * jnp.exp(ltot[None, :] - lcum_inc)
    state_ref[...] = state_ref[...] * jnp.exp(ltot)[:, None] + jnp.dot(kw.T, v)


def wkv6_bhtd(
    r: jax.Array,  # (BH, T, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (BH, T, D), <= 0, f32
    u: jax.Array,  # (BH, D)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jax.Array:
    BH, T, D = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, D), lambda i, c: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, D), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), r.dtype),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
