"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
used by the shape/dtype sweep tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def flash_attention_ref(
    q: jax.Array,  # (B, T, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool,
    scale: Optional[float] = None,
) -> jax.Array:
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D**-0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, T, Hkv, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, 1, Hq, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, 1)
    kv_pos: jax.Array,  # (B, S)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D**-0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, T, Hkv, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qf, k.astype(jnp.float32))
    mask = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, D).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def wkv6_ref(
    r: jax.Array,  # (B, T, H, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (B, T, H, D) log decay (<= 0)
    u: jax.Array,  # (H, D)
) -> jax.Array:
    """Sequential (exact) recurrence — O(T) scan, the gold reference."""
    B, T, H, D = r.shape

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp  # (B,H,D) each
        kv = jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        y = jnp.einsum("bhd,bhde->bhe", r_t, S + u[None, :, :, None] * kv)
        S = S * jnp.exp(lw_t)[..., None] + kv
        return S, y

    sw = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    _, ys = jax.lax.scan(step, S0, (sw(r), sw(k), sw(v), sw(logw)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)  # (B, T, H, D)
