"""Pallas TPU kernels (interpret-mode validated on CPU).

Modules: flash_attention, decode_attention, wkv6, rmsnorm — each with a
pure-jnp oracle in ``ref`` and a jit'd public wrapper in ``ops``.
"""
