"""Blocked (flash) attention forward — Pallas TPU kernel.

Target: TPU MXU.  Grid (batch·heads, q_blocks, kv_blocks) with the kv axis
innermost so the f32 accumulators live in VMEM scratch across kv steps
(online softmax).  Block shapes are MXU-aligned (multiples of 128 on the
contraction/lane dims where shapes allow).  Validated on CPU with
``interpret=True`` against ``ref.flash_attention_ref``.

GQA is handled in the BlockSpec index maps: query row ``b·H + h`` reads
kv row ``b·Hkv + h // group``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bkv, d)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T)  # (bq, bkv)
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        s = jnp.where(q_pos >= kv_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        # rows with no valid kv (fully masked) produce l == 0; emit zeros
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (BH, T, D)
    k: jax.Array,  # (BKv, S, D)
    v: jax.Array,
    *,
    group: int,
    scale: float,
    causal: bool,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, T, D = q.shape
    S = k.shape[1]
    block_q = min(block_q, T)
    block_kv = min(block_kv, S)
    assert T % block_q == 0 and S % block_kv == 0, (T, S, block_q, block_kv)
    nq, nkv = T // block_q, S // block_kv

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda i, j, k_: (i, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda i, j, k_, g=group: (i // g, k_, 0)),
            pl.BlockSpec((1, block_kv, D), lambda i, j, k_, g=group: (i // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda i, j, k_: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),  # acc
            pltpu.VMEM((block_q,), jnp.float32),  # running max
            pltpu.VMEM((block_q,), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
