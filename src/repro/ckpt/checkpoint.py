"""Checkpointing: pytree <-> npz with an async writer thread.

The paper (§4.3) defers WAN-aware checkpointing to future work and uses
standard async/in-memory checkpointing; we provide exactly that: the
train loop hands a (params, opt_state, step) snapshot to a background
thread, which serializes to ``<dir>/step_<n>.npz`` + a JSON manifest and
maintains a ``latest`` pointer.  Restore is synchronous.
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: Any, metadata: Optional[Dict] = None) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".json", "w") as f:
            json.dump(metadata, f)


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with np.load(path) as z:
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for p, leaf in leaves_like:
            key = _SEP.join(
                str(q.key) if hasattr(q, "key") else str(q.idx) if hasattr(q, "idx") else str(q)
                for q in p
            )
            arr = z[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    )


class AsyncCheckpointer:
    """Background-thread checkpoint writer (non-blocking ``save``)."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        os.makedirs(directory, exist_ok=True)

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                path = os.path.join(self.dir, f"step_{step:08d}.npz")
                save_pytree(path, tree, meta)
                with open(os.path.join(self.dir, "latest"), "w") as f:
                    f.write(os.path.basename(path))
                self._gc()
            except BaseException as e:  # surfaced on next save/close
                self._err = e

    def _gc(self):
        ckpts = sorted(
            f for f in os.listdir(self.dir) if f.startswith("step_") and f.endswith(".npz")
        )
        for old in ckpts[: -self.keep]:
            os.remove(os.path.join(self.dir, old))
            j = os.path.join(self.dir, old + ".json")
            if os.path.exists(j):
                os.remove(j)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> None:
        if self._err:
            raise self._err
        # snapshot to host memory NOW (donated/updated buffers must not
        # be serialized later): device_get is the "in-memory copy" phase
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, metadata or {}))

    def wait(self) -> None:
        import time

        while not self._q.empty():
            time.sleep(0.01)
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=30)

    def latest_path(self) -> Optional[str]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return os.path.join(self.dir, f.read().strip())
