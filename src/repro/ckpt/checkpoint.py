"""Checkpointing: pytree <-> npz with an async writer thread.

The paper (§4.3) defers WAN-aware checkpointing to future work and uses
standard async/in-memory checkpointing; we provide exactly that: the
train loop hands a (params, opt_state, step) snapshot to a background
thread, which serializes to ``<dir>/step_<n>.npz`` + a JSON manifest and
maintains a ``latest`` pointer.  Restore is synchronous.
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _atomic_write_text(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save_pytree(path: str, tree: Any, metadata: Optional[Dict] = None) -> None:
    """Serialize ``tree`` to ``path`` crash-atomically: a reader (or a
    restore after a mid-write crash) either sees the complete archive or
    nothing — never a truncated ``.npz``.  The temp file must be an open
    file object, not a path: ``np.savez`` appends ``.npz`` to string
    paths, which would defeat the rename."""
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if metadata is not None:
        _atomic_write_text(path + ".json", json.dumps(metadata))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with np.load(path) as z:
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for p, leaf in leaves_like:
            key = _SEP.join(
                str(q.key) if hasattr(q, "key") else str(q.idx) if hasattr(q, "idx") else str(q)
                for q in p
            )
            arr = z[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    )


class AsyncCheckpointer:
    """Background-thread checkpoint writer (non-blocking ``save``)."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        os.makedirs(directory, exist_ok=True)

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree, meta = item
                try:
                    path = os.path.join(self.dir, f"step_{step:08d}.npz")
                    save_pytree(path, tree, meta)
                    _atomic_write_text(
                        os.path.join(self.dir, "latest"), os.path.basename(path)
                    )
                    self._gc()
                except BaseException as e:  # surfaced on next save/wait/close
                    self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        ckpts = sorted(
            f for f in os.listdir(self.dir) if f.startswith("step_") and f.endswith(".npz")
        )
        for old in ckpts[: -self.keep]:
            os.remove(os.path.join(self.dir, old))
            j = os.path.join(self.dir, old + ".json")
            if os.path.exists(j):
                os.remove(j)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> None:
        if self._err:
            raise self._err
        # snapshot to host memory NOW (donated/updated buffers must not
        # be serialized later): device_get is the "in-memory copy" phase
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, metadata or {}))

    def wait(self) -> None:
        """Block until every enqueued snapshot is durable (or failed).

        ``Queue.join()`` waits for ``task_done`` — i.e. the *write*
        finishing — where the old ``empty()`` poll returned as soon as
        the worker had merely dequeued the item, racing the serializer.
        """
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        """Drain, stop the worker thread, and surface any writer error.

        The sentinel is enqueued even when ``wait()`` raises a pending
        write error — otherwise the worker thread would be leaked alive.
        """
        try:
            self.wait()
        finally:
            self._q.put(None)
            self._thread.join(timeout=30)

    def latest_path(self) -> Optional[str]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return os.path.join(self.dir, f.read().strip())
