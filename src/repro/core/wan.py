"""WAN transport model — paper §3/§4.1.

Reproduces Table 1 (single-TCP bandwidth vs latency), Fig 5 (multi-TCP
scaling to the ~5 Gbps per-node-pair hypervisor cap) and the transfer-time
arithmetic used throughout the simulator and Algorithm 1.

Single-connection TCP throughput is inversely proportional to RTT
(cwnd-limited); we calibrate the constant to the paper's Table 1:
    10 ms -> 1220 Mbps   20 ms -> 600   30 ms -> 396   40 ms -> 293
(products 12.2, 12.0, 11.9, 11.7 Gbit·ms — an almost perfect K/RTT law).
"""
from __future__ import annotations

import dataclasses
import zlib
from bisect import bisect_right
from typing import Optional, Sequence, Tuple

from repro import units

# calibration constants (paper Table 1 / Fig 5 / §4.1)
TCP_THROUGHPUT_K = 12.0  # single-connection bw ≈ K / latency_ms; K in Gbit/s·ms
SINGLE_CONN_MAX_GBPS = 1.22  # Table 1 @ 10 ms; NIC-side cap for short RTT
NODE_PAIR_CAP_GBPS = 5.0  # hypervisor rate limit (paper §4.1, AWS/Azure)
INTRA_DC_GBPS = 100.0  # paper §6.1 testbed intra-DC cap
INTRA_DC_LATENCY_MS = 0.1
PAPER_TABLE1 = {10: 1220.0, 20: 600.0, 30: 396.0, 40: 293.0}  # latency->Mbps


def tcp_single_bw_gbps(latency_ms: float) -> float:
    """Achievable single-TCP-connection bandwidth (Gbit/s) over the WAN."""
    if latency_ms <= 0:
        return SINGLE_CONN_MAX_GBPS
    return min(SINGLE_CONN_MAX_GBPS, TCP_THROUGHPUT_K / latency_ms)


def tcp_multi_bw_gbps(latency_ms: float, num_connections: int) -> float:
    """Aggregate bandwidth with ``num_connections`` parallel TCP flows —
    linear scaling until the per-node-pair hypervisor cap (paper Fig 5)."""
    return min(NODE_PAIR_CAP_GBPS, num_connections * tcp_single_bw_gbps(latency_ms))


def connections_for_cap(latency_ms: float) -> int:
    """How many connections Atlas spawns to saturate the node-pair cap."""
    single = tcp_single_bw_gbps(latency_ms)
    n = 1
    while n * single < NODE_PAIR_CAP_GBPS and n < 1024:
        n += 1
    return n


@dataclasses.dataclass(frozen=True)
class Link:
    """A (directed) node-pair path between two DCs (or within one)."""

    latency_ms: float
    bw_gbps: float

    def transfer_ms(self, nbytes: float) -> float:
        return self.latency_ms + units.serialization_ms(nbytes, self.bw_gbps)


def wan_link(latency_ms: float, multi_tcp: bool) -> Link:
    bw = NODE_PAIR_CAP_GBPS if multi_tcp else tcp_single_bw_gbps(latency_ms)
    return Link(latency_ms=latency_ms, bw_gbps=bw)


def intra_dc_link() -> Link:
    return Link(latency_ms=INTRA_DC_LATENCY_MS, bw_gbps=INTRA_DC_GBPS)


# ---------------------------------------------------------------------------
# time-varying bandwidth (paper Fig 7: measured 24-h inter-DC traces)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BandwidthSchedule:
    """Piecewise-constant bandwidth of one *directed* link over time.

    ``bw_gbps[i]`` is in force on ``[times_ms[i], times_ms[i+1])``; the
    last segment extends to infinity, and ``times_ms[0]`` must be 0.  A
    transfer that spans a segment boundary integrates bytes across the
    segments (``transfer_ms``) — there is no memoizable constant transfer
    time on a time-varying link.

    ``period_ms`` makes the profile wrap around: the pattern on
    ``[0, period_ms)`` repeats forever (day 2 of a 24-h diurnal trace
    looks like day 1, not like its last sample frozen in time).
    ``diurnal``/``from_trace`` set it to their natural cycle; ``flat``/
    ``step``/``outage`` model one-shot events and do not.

    Built from a measured/synthetic sample trace (``from_samples`` /
    ``from_trace``) or from analytic profiles (``flat`` / ``step`` /
    ``outage`` / ``diurnal``).  Attach to ``TopologyMatrix.bw_schedules``
    to drive the simulator, scheduler, validator and Algorithm 1.
    """

    times_ms: Tuple[float, ...]
    bw_gbps: Tuple[float, ...]
    period_ms: Optional[float] = None

    def __post_init__(self):
        assert len(self.times_ms) == len(self.bw_gbps) >= 1
        assert self.times_ms[0] == 0.0, "first segment must start at t=0"
        for a, b in zip(self.times_ms, self.times_ms[1:]):
            assert b > a, "segment starts must be strictly increasing"
        assert all(bw > 0 for bw in self.bw_gbps), "bandwidth must be positive"
        if self.period_ms is not None:
            assert self.period_ms > self.times_ms[-1], (
                "period must exceed the last segment start"
            )
            # whole-cycle capacity at rate_mult=1, precomputed once: the
            # periodic transfer loop must not re-sum every segment of a
            # 1440-sample trace per priced transfer (object.__setattr__
            # because the dataclass is frozen; not a field, so eq/hash
            # semantics are untouched)
            n = len(self.times_ms)
            object.__setattr__(
                self,
                "_cycle_bits",
                sum(
                    units.window_bits(
                        (self.times_ms[j + 1] if j + 1 < n else self.period_ms)
                        - self.times_ms[j],
                        self.bw_gbps[j],
                    )
                    for j in range(n)
                ),
            )

    # --- queries ----------------------------------------------------------
    def is_flat(self) -> bool:
        return all(bw == self.bw_gbps[0] for bw in self.bw_gbps)

    def bw_at(self, t_ms: float) -> float:
        """Bandwidth (Gbit/s) in force at time ``t_ms`` (clamped to 0)."""
        t = max(0.0, t_ms)
        if self.period_ms is not None:
            t = t % self.period_ms
        i = bisect_right(self.times_ms, t) - 1
        return self.bw_gbps[i]

    def min_bw_gbps(self) -> float:
        """Worst-segment bandwidth — the planning-time pessimistic rate."""
        return min(self.bw_gbps)

    def max_bw_gbps(self) -> float:
        return max(self.bw_gbps)

    def min_bw_over(self, t0_ms: float, t1_ms: float) -> float:
        """Lowest rate in force anywhere on ``[t0_ms, t1_ms)`` — the
        pointwise capacity floor the fleet invariant checker compares
        aggregate channel reservations against."""
        t0 = max(0.0, t0_ms)
        assert t1_ms > t0, (t0_ms, t1_ms)
        lo = float("inf")
        for bw, _s0, s1 in self._segments_from(t0):
            lo = min(lo, bw)
            if s1 >= t1_ms:
                break
        return lo

    def scaled(self, mult: float) -> "BandwidthSchedule":
        """This schedule with every segment's rate multiplied by
        ``mult`` — the *contended* view of a shared channel: a job
        granted a fair-share fraction of the link sees the same shape
        (segments, period) at ``mult ×`` the rate.  ``mult == 1``
        returns ``self`` so uncontended paths keep object identity
        (engine memo keys and schedule-dedup rely on it)."""
        if mult == 1.0:
            return self
        assert mult > 0.0, mult
        return BandwidthSchedule(
            self.times_ms,
            tuple(bw * mult for bw in self.bw_gbps),
            self.period_ms,
        )

    def transfer_ms(self, nbytes: float, start_ms: float, rate_mult: float = 1.0) -> float:
        """Serialization time of ``nbytes`` starting at ``start_ms``,
        integrating the bits across segment boundaries.  ``rate_mult``
        scales the rate (Atlas temporal sharing sends at D× node-pair
        bandwidth).  On a flat schedule this reduces to the static
        ``bytes·8 / bw`` formula exactly."""
        rem = units.bytes_to_bits(nbytes)
        t = max(0.0, start_ms)
        if self.period_ms is None:
            i = bisect_right(self.times_ms, t) - 1
            n = len(self.times_ms)
            while True:
                bw = self.bw_gbps[i] * rate_mult
                if i + 1 >= n:
                    return (t - start_ms) + units.bits_serialization_ms(rem, bw)
                seg_ms = self.times_ms[i + 1] - t
                cap_bits = units.window_bits(seg_ms, bw)
                if rem <= cap_bits:
                    return (t - start_ms) + units.bits_serialization_ms(rem, bw)
                rem -= cap_bits
                t = self.times_ms[i + 1]
                i += 1
        # periodic profile: walk segments cyclically, skipping whole
        # cycles in O(1) so a transfer many cycles long stays cheap
        period = self.period_ms
        n = len(self.times_ms)
        base = (t // period) * period
        tau = t - base
        i = bisect_right(self.times_ms, tau) - 1
        cycle_bits = self._cycle_bits * rate_mult
        while True:
            bw = self.bw_gbps[i] * rate_mult
            nxt = self.times_ms[i + 1] if i + 1 < n else period
            cap_bits = units.window_bits(nxt - tau, bw)
            if rem <= cap_bits:
                return (base + tau - start_ms) + units.bits_serialization_ms(rem, bw)
            rem -= cap_bits
            tau = nxt
            i += 1
            if i >= n:
                base += period
                tau = 0.0
                i = 0
                if rem > cycle_bits:
                    k = int(rem // cycle_bits)
                    rem -= k * cycle_bits
                    base += k * period

    def _segments_from(self, t_ms: float):
        """Yield ``(bw_gbps, seg_start_abs, seg_end_abs)`` from ``t_ms``
        on (the caller breaks out; the last segment of an aperiodic
        schedule ends at +inf, a periodic one yields forever)."""
        import math

        t = max(0.0, t_ms)
        n = len(self.times_ms)
        if self.period_ms is None:
            i = bisect_right(self.times_ms, t) - 1
            while True:
                end = self.times_ms[i + 1] if i + 1 < n else math.inf
                yield self.bw_gbps[i], t, end
                t = end
                i += 1
        else:
            period = self.period_ms
            base = (t // period) * period
            tau = t - base
            i = bisect_right(self.times_ms, tau) - 1
            while True:
                nxt = self.times_ms[i + 1] if i + 1 < n else period
                yield self.bw_gbps[i], base + tau, base + nxt
                tau = nxt
                i += 1
                if i >= n:
                    base += period
                    tau = 0.0
                    i = 0

    def bits_sent(
        self, nbytes: float, start_ms: float, until_ms: float, rate_mult: float = 1.0
    ) -> float:
        """Bits of an ``nbytes`` transfer begun at ``start_ms`` that are
        on the wire by ``until_ms`` (capped at the transfer size) — the
        preemption primitive: integrate the rate over the elapsed window
        instead of assuming any single segment's bandwidth."""
        total = units.bytes_to_bits(nbytes)
        t0 = max(0.0, start_ms)
        if until_ms <= t0:
            return 0.0
        sent = 0.0
        for bw, s0, s1 in self._segments_from(t0):
            hi = min(s1, until_ms)
            sent += units.window_bits(hi - max(s0, t0), bw, rate_mult)
            if sent >= total:
                return total
            if s1 >= until_ms:
                break
        return sent

    def preempt(
        self, nbytes: float, start_ms: float, at_ms: float, rate_mult: float = 1.0
    ) -> Tuple[float, float]:
        """Cut an in-flight transfer at ``at_ms``: the bits already sent
        are kept, the remainder re-integrates at whatever rate rules
        from ``at_ms`` on (``transfer_ms(remaining, at_ms)``).  Returns
        ``(sent_bytes, remaining_bytes)``.  Splitting at any point and
        resuming immediately reproduces the unsplit ``transfer_ms``
        exactly — the differential identity the tests pin down."""
        sent = units.bits_to_bytes(self.bits_sent(nbytes, start_ms, at_ms, rate_mult))
        return sent, nbytes - sent

    def mean_bw_gbps(self, t0_ms: float, t1_ms: float) -> float:
        """Average bandwidth actually delivered over ``[t0_ms, t1_ms)`` —
        what the drift detector compares against the plan's assumption."""
        t0 = max(0.0, t0_ms)
        assert t1_ms > t0, (t0_ms, t1_ms)
        acc = 0.0
        for bw, s0, s1 in self._segments_from(t0):
            hi = min(s1, t1_ms)
            acc += (hi - max(s0, t0)) * bw
            if s1 >= t1_ms:
                break
        return acc / (t1_ms - t0)

    def constant_over(self, t0_ms: float, t1_ms: float) -> bool:
        """Is the rate constant over ``[t0_ms, t1_ms)``?  (The horizon
        simulator may reuse an iteration result only inside such a
        window.)"""
        if self.is_flat():
            return True
        for _bw, _s0, s1 in self._segments_from(max(0.0, t0_ms)):
            return s1 >= t1_ms
        return False

    # --- constructors -----------------------------------------------------
    @classmethod
    def flat(cls, bw_gbps: float) -> "BandwidthSchedule":
        return cls((0.0,), (float(bw_gbps),))

    @classmethod
    def from_samples(
        cls,
        samples_gbps: Sequence[float],
        sample_ms: float,
        *,
        period_ms: Optional[float] = None,
    ) -> "BandwidthSchedule":
        """A measured trace, one sample per ``sample_ms`` — consecutive
        equal samples are coalesced into one segment.  ``period_ms``
        (typically ``len(samples) * sample_ms``) wraps the trace so
        horizons longer than the measurement replay it cyclically."""
        assert samples_gbps and sample_ms > 0
        times = [0.0]
        bws = [float(samples_gbps[0])]
        for k, s in enumerate(samples_gbps[1:], start=1):
            if s != bws[-1]:
                times.append(k * sample_ms)
                bws.append(float(s))
        return cls(tuple(times), tuple(bws), period_ms)

    @classmethod
    def from_trace(
        cls,
        link: Link,
        *,
        hours: float = 24.0,
        samples_per_hour: int = 60,
        seed: int = 0,
    ) -> "BandwidthSchedule":
        """The Fig-7 AR(1) stability trace of ``link`` as a schedule,
        wrapping at the trace length (day 2 replays day 1 instead of
        holding the last sample forever)."""
        trace = bandwidth_trace_for_link(
            link, hours=hours, samples_per_hour=samples_per_hour, seed=seed
        )
        return cls.from_samples(
            trace, 3.6e6 / samples_per_hour, period_ms=hours * 3.6e6
        )

    @classmethod
    def step(cls, bw0_gbps: float, bw1_gbps: float, at_ms: float) -> "BandwidthSchedule":
        """One step change at ``at_ms`` (e.g. a 2:1 degradation)."""
        return cls((0.0, float(at_ms)), (float(bw0_gbps), float(bw1_gbps)))

    @classmethod
    def outage(
        cls,
        bw_gbps: float,
        start_ms: float,
        end_ms: float,
        degraded_gbps: float,
    ) -> "BandwidthSchedule":
        """Nominal bandwidth with a degraded window [start, end) — link
        failures reroute over slow paths rather than dropping to zero."""
        assert 0.0 < start_ms < end_ms
        return cls(
            (0.0, float(start_ms), float(end_ms)),
            (float(bw_gbps), float(degraded_gbps), float(bw_gbps)),
        )

    @classmethod
    def diurnal(
        cls,
        peak_gbps: float,
        trough_gbps: float,
        period_ms: float = 24 * 3.6e6,
        steps: int = 24,
        cycles: int = 1,
    ) -> "BandwidthSchedule":
        """Piecewise-constant approximation of a diurnal cosine: capacity
        peaks mid-cycle (off-peak hours) and bottoms at the cycle edges.
        The schedule wraps at ``cycles * period_ms`` — diurnal congestion
        repeats every day, it does not freeze at the last step."""
        import math

        assert steps >= 2 and cycles >= 1
        mid = (peak_gbps + trough_gbps) / 2.0
        amp = (peak_gbps - trough_gbps) / 2.0
        times, bws = [], []
        for c in range(cycles):
            for k in range(steps):
                times.append(c * period_ms + k * period_ms / steps)
                phase = 2.0 * math.pi * (k + 0.5) / steps
                bws.append(mid - amp * math.cos(phase))
        return cls(tuple(times), tuple(bws), cycles * period_ms)


# ---------------------------------------------------------------------------
# analytic communication times (paper §3 footnotes)
# ---------------------------------------------------------------------------


def bandwidth_trace_gbps(
    latency_ms: float,
    *,
    hours: float = 24.0,
    samples_per_hour: int = 60,
    seed: int = 0,
    multi_tcp: bool = True,
) -> "list[float]":
    """Paper Fig 7: 24-h bandwidth stability between Azure DCs.

    WANs are well-provisioned; the paper measured a coefficient of
    variation of just 0.8% (US-East↔SE-Asia) and 2.3% (US-East↔US-West) —
    counter-intuitively, the *longer* path is steadier.  We model CoV as
    decreasing with distance (long-haul paths are dedicated/underutilized)
    and emit a deterministic AR(1) trace around the mean.
    """
    link = Link(latency_ms, NODE_PAIR_CAP_GBPS if multi_tcp else tcp_single_bw_gbps(latency_ms))
    return bandwidth_trace_for_link(
        link, hours=hours, samples_per_hour=samples_per_hour, seed=seed
    )


def bandwidth_trace_for_link(
    link: Link,
    *,
    hours: float = 24.0,
    samples_per_hour: int = 60,
    seed: int = 0,
) -> "list[float]":
    """Fig-7 stability trace for an arbitrary (heterogeneous) link: a
    deterministic AR(1) fluctuation around the link's bandwidth with CoV
    decreasing in distance (~2.3% short-haul, ~0.8% long-haul).

    The RNG seed folds in the link's full-precision latency AND its
    bandwidth: two heterogeneous links that merely share an integer
    latency (or a single-TCP vs multi-TCP pair at the same RTT) must not
    emit correlated fluctuation patterns.  Deterministic for a fixed
    (link, seed)."""
    import math
    import random

    cov = 0.023 * math.exp(-link.latency_ms / 80.0) + 0.008
    link_key = zlib.crc32(f"{link.latency_ms!r}|{link.bw_gbps!r}".encode())
    rng = random.Random(seed * 100003 + link_key)
    n = int(hours * samples_per_hour)
    out = []
    x = 0.0
    x_std = 0.1 / math.sqrt(1 - 0.9**2)  # stationary std of the AR(1)
    for _ in range(n):
        x = 0.9 * x + 0.1 * rng.gauss(0.0, 1.0)
        out.append(link.bw_gbps * (1.0 + cov * x / x_std))
    return out


def trace_cov(trace: "list[float]") -> float:
    m = sum(trace) / len(trace)
    var = sum((x - m) ** 2 for x in trace) / len(trace)
    return (var ** 0.5) / m


# --- §6.7: semantics-altering compression (the paper's negative result) ---

COMPRESSION_RATIO = 0.25  # SVD/Top-K activation compression factor
COMPRESSION_COMPUTE_MULT = 2.0  # extra compute to reach the same loss (§6.7)


def allreduce_ms(param_bytes: float, n_nodes: int, bw_gbps: float) -> float:
    """Ring all-reduce time (paper §3.1 footnote 1): 4·P·(N−1)/(N·BW),
    with P in bytes fp16 already accounted by the caller's byte count —
    the paper's factor 4 = 2 traversals × 2 bytes/param; here we take raw
    bytes and use the 2·(N−1)/N traversal volume."""
    if n_nodes <= 1:
        return 0.0
    vol = 2.0 * param_bytes * (n_nodes - 1) / n_nodes
    return units.serialization_ms(vol, bw_gbps)


def activation_bytes(micro_batch: int, seq_len: int, hidden: int, bytes_per: int = 2) -> float:
    """Paper §3.2 footnote 2: activation (and gradient) size = B·L·H."""
    return float(micro_batch) * seq_len * hidden * bytes_per
