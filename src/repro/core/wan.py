"""WAN transport model — paper §3/§4.1.

Reproduces Table 1 (single-TCP bandwidth vs latency), Fig 5 (multi-TCP
scaling to the ~5 Gbps per-node-pair hypervisor cap) and the transfer-time
arithmetic used throughout the simulator and Algorithm 1.

Single-connection TCP throughput is inversely proportional to RTT
(cwnd-limited); we calibrate the constant to the paper's Table 1:
    10 ms -> 1220 Mbps   20 ms -> 600   30 ms -> 396   40 ms -> 293
(products 12.2, 12.0, 11.9, 11.7 Gbit·ms — an almost perfect K/RTT law).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# calibration constants (paper Table 1 / Fig 5 / §4.1)
TCP_K_GBIT_MS = 12.0  # single-connection bw ≈ K / latency_ms (Gbit/s·ms)
SINGLE_CONN_MAX_GBPS = 1.22  # Table 1 @ 10 ms; NIC-side cap for short RTT
NODE_PAIR_CAP_GBPS = 5.0  # hypervisor rate limit (paper §4.1, AWS/Azure)
INTRA_DC_GBPS = 100.0  # paper §6.1 testbed intra-DC cap
INTRA_DC_LATENCY_MS = 0.1
PAPER_TABLE1 = {10: 1220.0, 20: 600.0, 30: 396.0, 40: 293.0}  # latency->Mbps


def tcp_single_bw_gbps(latency_ms: float) -> float:
    """Achievable single-TCP-connection bandwidth (Gbit/s) over the WAN."""
    if latency_ms <= 0:
        return SINGLE_CONN_MAX_GBPS
    return min(SINGLE_CONN_MAX_GBPS, TCP_K_GBIT_MS / latency_ms)


def tcp_multi_bw_gbps(latency_ms: float, num_connections: int) -> float:
    """Aggregate bandwidth with ``num_connections`` parallel TCP flows —
    linear scaling until the per-node-pair hypervisor cap (paper Fig 5)."""
    return min(NODE_PAIR_CAP_GBPS, num_connections * tcp_single_bw_gbps(latency_ms))


def connections_for_cap(latency_ms: float) -> int:
    """How many connections Atlas spawns to saturate the node-pair cap."""
    single = tcp_single_bw_gbps(latency_ms)
    n = 1
    while n * single < NODE_PAIR_CAP_GBPS and n < 1024:
        n += 1
    return n


@dataclasses.dataclass(frozen=True)
class Link:
    """A (directed) node-pair path between two DCs (or within one)."""

    latency_ms: float
    bw_gbps: float

    def transfer_ms(self, nbytes: float) -> float:
        return self.latency_ms + (nbytes * 8.0) / (self.bw_gbps * 1e9) * 1e3


def wan_link(latency_ms: float, multi_tcp: bool) -> Link:
    bw = NODE_PAIR_CAP_GBPS if multi_tcp else tcp_single_bw_gbps(latency_ms)
    return Link(latency_ms=latency_ms, bw_gbps=bw)


def intra_dc_link() -> Link:
    return Link(latency_ms=INTRA_DC_LATENCY_MS, bw_gbps=INTRA_DC_GBPS)


# ---------------------------------------------------------------------------
# analytic communication times (paper §3 footnotes)
# ---------------------------------------------------------------------------


def bandwidth_trace_gbps(
    latency_ms: float,
    *,
    hours: float = 24.0,
    samples_per_hour: int = 60,
    seed: int = 0,
    multi_tcp: bool = True,
) -> "list[float]":
    """Paper Fig 7: 24-h bandwidth stability between Azure DCs.

    WANs are well-provisioned; the paper measured a coefficient of
    variation of just 0.8% (US-East↔SE-Asia) and 2.3% (US-East↔US-West) —
    counter-intuitively, the *longer* path is steadier.  We model CoV as
    decreasing with distance (long-haul paths are dedicated/underutilized)
    and emit a deterministic AR(1) trace around the mean.
    """
    link = Link(latency_ms, NODE_PAIR_CAP_GBPS if multi_tcp else tcp_single_bw_gbps(latency_ms))
    return bandwidth_trace_for_link(
        link, hours=hours, samples_per_hour=samples_per_hour, seed=seed
    )


def bandwidth_trace_for_link(
    link: Link,
    *,
    hours: float = 24.0,
    samples_per_hour: int = 60,
    seed: int = 0,
) -> "list[float]":
    """Fig-7 stability trace for an arbitrary (heterogeneous) link: a
    deterministic AR(1) fluctuation around the link's bandwidth with CoV
    decreasing in distance (~2.3% short-haul, ~0.8% long-haul)."""
    import math
    import random

    cov = 0.023 * math.exp(-link.latency_ms / 80.0) + 0.008
    rng = random.Random(seed * 100003 + int(link.latency_ms))
    n = int(hours * samples_per_hour)
    out = []
    x = 0.0
    x_std = 0.1 / math.sqrt(1 - 0.9**2)  # stationary std of the AR(1)
    for _ in range(n):
        x = 0.9 * x + 0.1 * rng.gauss(0.0, 1.0)
        out.append(link.bw_gbps * (1.0 + cov * x / x_std))
    return out


def trace_cov(trace: "list[float]") -> float:
    m = sum(trace) / len(trace)
    var = sum((x - m) ** 2 for x in trace) / len(trace)
    return (var ** 0.5) / m


# --- §6.7: semantics-altering compression (the paper's negative result) ---

COMPRESSION_RATIO = 0.25  # SVD/Top-K activation compression factor
COMPRESSION_COMPUTE_MULT = 2.0  # extra compute to reach the same loss (§6.7)


def allreduce_ms(param_bytes: float, n_nodes: int, bw_gbps: float) -> float:
    """Ring all-reduce time (paper §3.1 footnote 1): 4·P·(N−1)/(N·BW),
    with P in bytes fp16 already accounted by the caller's byte count —
    the paper's factor 4 = 2 traversals × 2 bytes/param; here we take raw
    bytes and use the 2·(N−1)/N traversal volume."""
    if n_nodes <= 1:
        return 0.0
    vol = 2.0 * param_bytes * (n_nodes - 1) / n_nodes
    return (vol * 8.0) / (bw_gbps * 1e9) * 1e3


def activation_bytes(micro_batch: int, seq_len: int, hidden: int, bytes_per: int = 2) -> float:
    """Paper §3.2 footnote 2: activation (and gradient) size = B·L·H."""
    return float(micro_batch) * seq_len * hidden * bytes_per
