"""Discrete-event simulator for cross-DC pipeline training — paper §3/§6.

Faithfully models the paper's setting:
  - P pipeline stages placed in DCs (contiguous stages per DC, §3.2);
  - M microbatches per minibatch; forward t_f, backward 2·t_f, optional
    recomputation t_f before backward (Varuna semantics, §2);
  - activation/gradient transfers of B·L·H bytes per stage boundary
    (§3.2 fn. 2), serialized per (node-pair, direction) — activations and
    gradients travel in opposite directions and do not compete (§3.2 obs e);
  - WAN node-pair bandwidth from ``repro.core.wan`` (single- vs multi-TCP);
  - schedulers: "gpipe" (all-F then all-B, recompute), "megatron" (1F1B,
    no recompute), "varuna" (1F1B + recompute + backward priority), and
    "atlas" (= varuna compute rules + *temporal bandwidth sharing*: the D
    pipelines of a DP-cell pool their per-node-pair WAN allocations so one
    transfer runs at D× bandwidth, serialized within the cell — §4.3/4.4).

Outputs per-GPU busy intervals (Fig 4 / Fig 13-style timelines), bubbles,
utilization, and iteration time; the DP all-reduce is added analytically
(intra-DC rings, §4.2).

Event-driven, pure Python; deterministic.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import wan
from repro.core.topology import TopologyMatrix


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    num_stages: int
    microbatches: int
    t_fwd_ms: float  # forward time per stage per microbatch
    act_bytes: float  # activation (= gradient) bytes per boundary
    stage_dc: Tuple[int, ...]  # DC index of each stage
    stage_param_bytes: float = 0.0  # per-stage parameter bytes (for DP all-reduce)
    recompute: bool = True
    bwd_mult: float = 2.0  # t_bwd = bwd_mult · t_fwd
    inflight_cap: Optional[int] = None  # max forwards ahead of backwards


@dataclasses.dataclass(frozen=True)
class GeoTopology:
    """Backward-compatible *uniform* topology: one latency/transport for
    every DC pair.  Heterogeneous WANs use ``repro.core.topology
    .TopologyMatrix``, which exposes the same ``link``/``intra_bw_gbps``
    interface; ``simulate`` and the Atlas scheduler accept either."""

    wan_latency_ms: float = 40.0
    multi_tcp: bool = True
    intra_bw_gbps: float = wan.INTRA_DC_GBPS
    intra_latency_ms: float = wan.INTRA_DC_LATENCY_MS

    def link(self, dc_a: int, dc_b: int) -> wan.Link:
        if dc_a == dc_b:
            return wan.Link(self.intra_latency_ms, self.intra_bw_gbps)
        return wan.wan_link(self.wan_latency_ms, self.multi_tcp)

    def is_wan(self, dc_a: int, dc_b: int) -> bool:
        return dc_a != dc_b

    def matrix(self, n_dcs: int) -> "TopologyMatrix":
        """The equivalent (uniform) ``TopologyMatrix``."""
        return TopologyMatrix.uniform(
            n_dcs,
            wan_latency_ms=self.wan_latency_ms,
            multi_tcp=self.multi_tcp,
            intra_bw_gbps=self.intra_bw_gbps,
            intra_latency_ms=self.intra_latency_ms,
        )


@dataclasses.dataclass
class Interval:
    start: float
    end: float
    kind: str  # 'fwd' | 'rec' | 'bwd' | 'prefill'
    micro: int = -1


@dataclasses.dataclass
class SimResult:
    iteration_ms: float
    busy: Dict[Tuple[int, int], List[Interval]]  # (pipeline, stage) -> intervals
    utilization: float
    bubbles: Dict[Tuple[int, int], List[Tuple[float, float]]]
    allreduce_ms: float
    n_pipelines: int

    def stage_bubbles(self, pipeline: int, stage: int) -> List[Tuple[float, float]]:
        return self.bubbles[(pipeline, stage)]


# ---------------------------------------------------------------------------


def _priority(kind: str, micro: int, pipeline: int) -> Tuple:
    # backward (incl. its recompute) preempts queued forwards (paper §4.4
    # rule 4); earlier microbatches first; lower rank first.
    order = {"bwd": 0, "fwd": 1}
    return (order[kind], micro, pipeline)


def simulate(
    spec: PipelineSpec,
    topo,  # GeoTopology | repro.core.topology.TopologyMatrix
    *,
    policy: str = "varuna",
    n_pipelines: int = 1,
    dp_replicas_for_allreduce: int = 1,
    validate: bool = False,
) -> SimResult:
    """Simulate one minibatch (iteration) of ``n_pipelines`` DP pipelines.

    policy: gpipe | megatron | varuna | atlas.  Only "atlas" coordinates
    the pipelines (temporal bandwidth sharing); the baselines run
    identical, independent schedules and compete for nothing (each has its
    own node-pair allocation — the paper's *spatial* sharing).

    ``topo`` is anything exposing ``link(dc_a, dc_b)`` and
    ``intra_bw_gbps`` — the uniform ``GeoTopology`` or a heterogeneous
    ``TopologyMatrix``.  ``validate=True`` runs the physical-invariant
    checker (``repro.core.validate``) on the result before returning.
    """
    assert policy in ("gpipe", "megatron", "varuna", "atlas")
    if policy == "atlas":
        res = _simulate_atlas(spec, topo, n_pipelines, dp_replicas_for_allreduce)
        return _maybe_validate(res, spec, policy, validate)
    P, M = spec.num_stages, spec.microbatches
    temporal = False
    recompute = spec.recompute and policy in ("gpipe", "varuna", "atlas")
    inflight_cap = spec.inflight_cap
    if inflight_cap is None:
        inflight_cap = M if policy == "gpipe" else P
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * spec.t_fwd_ms

    D = n_pipelines
    pipes = range(D)

    # --- channels: (pipeline-or-cell, boundary, dir) ---
    # temporal sharing pools the D per-pair allocations => D× bandwidth for
    # a single transfer, one transfer at a time per cell (paper §4.3), plus
    # the intra-DC scatter/gather hop.  A channel is a priority queue
    # (paper §4.4 rule 3: transfers are *scheduled*, not FIFO): earliest
    # microbatch first, gradients before activations (rule 4), then rank.
    chan_free: Dict[Tuple, float] = {}
    chan_pending: Dict[Tuple, List[Tuple]] = {}

    def transfer_times(s_from: int, s_to: int) -> Tuple[float, float]:
        """(channel occupancy ms, extra delivery delay ms).

        Occupancy = serialization time (the bandwidth resource); the
        propagation latency delays delivery but does not hold the link —
        back-to-back transfers pipeline through the WAN.
        """
        dc_a, dc_b = spec.stage_dc[s_from], spec.stage_dc[s_to]
        link = topo.link(dc_a, dc_b)
        ser = (spec.act_bytes * 8.0) / (link.bw_gbps * 1e9) * 1e3
        if dc_a == dc_b:  # intra-DC hop
            return ser, link.latency_ms
        if temporal:
            ser = ser / D
            # scatter to / gather from the D-1 peer nodes over intra-DC
            # links (paper §4.3); the hops STREAM with the WAN send, so
            # they add delivery latency but do not occupy the shared
            # channel ((D-1)/D of the bytes make each hop).
            hop = (spec.act_bytes * (D - 1) / D * 8.0) / (topo.intra_bw_gbps * 1e9) * 1e3
            return ser, link.latency_ms + 2.0 * hop
        return ser, link.latency_ms

    def chan_key(p: int, boundary: int, direction: str) -> Tuple:
        if temporal:
            return ("cell", boundary, direction)
        return (p, boundary, direction)

    # --- state ---
    gpu_free = {(p, s): 0.0 for p in pipes for s in range(P)}
    ready: Dict[Tuple[int, int], List[Tuple]] = {g: [] for g in gpu_free}
    busy: Dict[Tuple[int, int], List[Interval]] = {g: [] for g in gpu_free}
    fwd_done = {(p, s): 0 for p in pipes for s in range(P)}
    bwd_done = {(p, s): 0 for p in pipes for s in range(P)}
    fwd_barrier_release: Dict[int, float] = {}  # gpipe: pipeline -> all-F time

    events: List[Tuple[float, int, str, Tuple]] = []
    seq = itertools.count()

    def push(t: float, kind: str, payload: Tuple):
        heapq.heappush(events, (t, next(seq), kind, payload))

    # seed: microbatch m ready at stage 0 at t=0
    for p in pipes:
        for m in range(M):
            ready[(p, 0)].append(_priority("fwd", m, p) + ("fwd", m))

    def try_dispatch(g: Tuple[int, int], now: float):
        p, s = g
        if gpu_free[g] > now or not ready[g]:
            return
        ready[g].sort()
        for i, item in enumerate(ready[g]):
            kind, m = item[-2], item[-1]
            if kind == "fwd":
                if fwd_done[g] - bwd_done[g] >= inflight_cap:
                    continue
            if kind == "bwd" and policy == "gpipe":
                if fwd_barrier_release.get(p) is None:
                    continue  # wait until all forwards of this pipeline done
            ready[g].pop(i)
            if kind == "fwd":
                dur = t_f
            else:
                dur = t_b + (t_f if (recompute and s != P - 1) else 0.0)
            gpu_free[g] = now + dur
            busy[g].append(Interval(now, now + dur, kind, m))
            push(now + dur, "gpu_done", (p, s, kind, m))
            return

    def on_gpu_done(now: float, p: int, s: int, kind: str, m: int):
        g = (p, s)
        if kind == "fwd":
            fwd_done[g] += 1
            if s < P - 1:
                request_transfer(now, p, s, s + 1, "act", m)
            else:
                # last stage: backward immediately eligible
                ready[g].append(_priority("bwd", m, p) + ("bwd", m))
            if policy == "gpipe" and s == P - 1 and fwd_done[g] == M:
                fwd_barrier_release[p] = now
                try_dispatch((p, P - 1), now)
        else:  # bwd
            bwd_done[g] += 1
            if s > 0:
                request_transfer(now, p, s, s - 1, "grad", m)
        try_dispatch(g, now)

    def request_transfer(now: float, p: int, s_from: int, s_to: int, direction: str, m: int):
        boundary = min(s_from, s_to)
        key = chan_key(p, boundary, direction)
        prio = (m, 0 if direction == "grad" else 1, p)
        chan_pending.setdefault(key, []).append(prio + (p, s_from, s_to, direction, m))
        pump_channel(key, now)

    def pump_channel(key: Tuple, now: float):
        pend = chan_pending.get(key)
        if not pend or chan_free.get(key, 0.0) > now + 1e-12:
            return
        pend.sort()
        _, _, _, p, s_from, s_to, direction, m = pend.pop(0)
        ser, delay = transfer_times(s_from, s_to)
        chan_free[key] = now + ser
        push(now + ser + delay, "arrive", (p, s_to, direction, m))
        push(now + ser, "chan_free", (key,))

    def on_arrive(now: float, p: int, s: int, direction: str, m: int):
        g = (p, s)
        kind = "fwd" if direction == "act" else "bwd"
        ready[g].append(_priority(kind, m, p) + (kind, m))
        try_dispatch(g, now)

    # kick off
    for p in pipes:
        try_dispatch((p, 0), 0.0)

    while events:
        now, _, ev, payload = heapq.heappop(events)
        if ev == "gpu_done":
            on_gpu_done(now, *payload)
        elif ev == "arrive":
            on_arrive(now, *payload)
        elif ev == "chan_free":
            pump_channel(payload[0], now)

    pp_end = max((iv.end for ivs in busy.values() for iv in ivs), default=0.0)

    # --- DP all-reduce (intra-DC rings, paper §4.2) ---
    ar = wan.allreduce_ms(
        spec.stage_param_bytes, dp_replicas_for_allreduce, topo.intra_bw_gbps
    )
    total = pp_end + ar

    # --- bubbles & utilization ---
    bubbles: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    busy_sum = 0.0
    for g, ivs in busy.items():
        ivs.sort(key=lambda iv: iv.start)
        gaps = []
        cur = 0.0
        for iv in ivs:
            if iv.start > cur + 1e-9:
                gaps.append((cur, iv.start))
            cur = max(cur, iv.end)
            busy_sum += iv.end - iv.start
        if cur < total - 1e-9:
            gaps.append((cur, total))
        bubbles[g] = gaps
    util = busy_sum / (total * len(gpu_free)) if total > 0 else 0.0

    res = SimResult(
        iteration_ms=total,
        busy=busy,
        utilization=util,
        bubbles=bubbles,
        allreduce_ms=ar,
        n_pipelines=D,
    )
    return _maybe_validate(res, spec, policy, validate)


def _maybe_validate(res: SimResult, spec: PipelineSpec, policy: str, validate: bool) -> SimResult:
    if validate:
        from repro.core import validate as _validate

        _validate.check_sim_result(res, spec, policy=policy)
    return res


def _simulate_atlas(
    spec: PipelineSpec,
    topo,  # GeoTopology | TopologyMatrix
    n_pipelines: int,
    dp_replicas_for_allreduce: int,
) -> SimResult:
    """Atlas = precomputed §4.4 schedule (repro.core.temporal) wrapped into
    the same SimResult shape as the reactive baselines."""
    from repro.core import temporal

    sched = temporal.atlas_schedule(
        spec, topo, n_pipelines, inflight_cap=spec.inflight_cap
    )
    ar = wan.allreduce_ms(
        spec.stage_param_bytes, dp_replicas_for_allreduce, topo.intra_bw_gbps
    )
    total = sched.makespan + ar
    busy: Dict[Tuple[int, int], List[Interval]] = {
        (p, s): [] for p in range(n_pipelines) for s in range(spec.num_stages)
    }
    for t in sched.tasks:
        busy[(t.pipeline, t.stage)].append(Interval(t.start, t.end, t.kind, t.micro))
    bubbles: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    busy_sum = 0.0
    for g, ivs in busy.items():
        ivs.sort(key=lambda iv: iv.start)
        gaps = []
        cur = 0.0
        for iv in ivs:
            if iv.start > cur + 1e-9:
                gaps.append((cur, iv.start))
            cur = max(cur, iv.end)
            busy_sum += iv.end - iv.start
        if cur < total - 1e-9:
            gaps.append((cur, total))
        bubbles[g] = gaps
    util = busy_sum / (total * len(busy)) if total > 0 else 0.0
    return SimResult(
        iteration_ms=total,
        busy=busy,
        utilization=util,
        bubbles=bubbles,
        allreduce_ms=ar,
        n_pipelines=n_pipelines,
    )


# ---------------------------------------------------------------------------
# analytic DP-only iteration (paper §3.1, Fig 2)
# ---------------------------------------------------------------------------


def dp_iteration_ms(
    compute_ms: float,
    param_bytes: float,
    n_nodes: int,
    latency_ms: float,
    *,
    multi_tcp: bool = False,
    intra_dc: bool = False,
) -> float:
    """One DP iteration: compute + ring all-reduce over the given network."""
    if intra_dc:
        bw = wan.INTRA_DC_GBPS
    else:
        bw = (
            wan.NODE_PAIR_CAP_GBPS
            if multi_tcp
            else wan.tcp_single_bw_gbps(latency_ms)
        )
    return compute_ms + wan.allreduce_ms(param_bytes, n_nodes, bw)


# ---------------------------------------------------------------------------
# convenience: paper §6.1 testbed-style spec builders
# ---------------------------------------------------------------------------


def testbed_spec(
    *,
    hidden: int,
    seq_len: int,
    micro_batch: int,
    layers_per_stage: int,
    layer_params: float,
    num_stages: int,
    microbatches: int,
    stage_dc: Sequence[int],
    gpu_tflops: float = 312.0,  # A100 bf16 dense
    recompute: bool = True,
) -> PipelineSpec:
    """Derive compute/comm times from model dims (paper §4.2 big-O terms)."""
    # forward FLOPs per microbatch per stage ≈ 6·params·tokens  (fwd=2·,
    # bwd=4· => bwd_mult 2); attention term folded into the constant.
    tokens = micro_batch * seq_len
    stage_params = layers_per_stage * layer_params
    flops_fwd = 2.0 * stage_params * tokens
    t_fwd_ms = flops_fwd / (gpu_tflops * 1e12) * 1e3
    return PipelineSpec(
        num_stages=num_stages,
        microbatches=microbatches,
        t_fwd_ms=t_fwd_ms,
        act_bytes=wan.activation_bytes(micro_batch, seq_len, hidden),
        stage_dc=tuple(stage_dc),
        stage_param_bytes=stage_params * 2.0,  # fp16
        recompute=recompute,
    )
