"""Discrete-event simulator for cross-DC pipeline training — paper §3/§6.

Faithfully models the paper's setting:
  - P pipeline stages placed in DCs (contiguous stages per DC, §3.2);
  - M microbatches per minibatch; forward t_f, backward 2·t_f, optional
    recomputation t_f before backward (Varuna semantics, §2);
  - activation/gradient transfers of B·L·H bytes per stage boundary
    (§3.2 fn. 2), serialized per (node-pair, direction) — activations and
    gradients travel in opposite directions and do not compete (§3.2 obs e);
  - WAN node-pair bandwidth from ``repro.core.wan`` (single- vs multi-TCP);
  - schedulers: "gpipe" (all-F then all-B, recompute), "megatron" (1F1B,
    no recompute), "varuna" (1F1B + recompute + backward priority), and
    "atlas" (= varuna compute rules + *temporal bandwidth sharing*: the D
    pipelines of a DP-cell pool their per-node-pair WAN allocations so one
    transfer runs at D× bandwidth, serialized within the cell — §4.3/4.4).

Outputs per-GPU busy intervals (Fig 4 / Fig 13-style timelines), bubbles,
utilization, and iteration time; the DP all-reduce is added analytically
(intra-DC rings, §4.2).

Engine notes (the fast path — see ``repro.core.reference`` for the
original engine these results are differentially tested against):

  * per-GPU ready queues and per-channel pending queues are heaps (the
    original sorted a list per dispatch/pump);
  * per-boundary transfer times are memoized;
  * the baseline policies run their D pipelines with *zero* shared state
    (per-pipeline channels, GPUs, barriers), so one pipeline is simulated
    and replicated D× (each replica gets its own ``Interval`` objects);
  * for large M, ``repro.core.fastforward`` detects the periodic steady
    state from two short probe runs and emits the middle microbatches
    analytically (interval-identical to full replay, else it falls back);
  * bubble/utilization accounting is a single shared pass
    (``_finalize``) over intervals that are already start-sorted.

Event-driven, pure Python; deterministic.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro import units
from repro.core import wan
from repro.core.topology import TopologyMatrix


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    num_stages: int
    microbatches: int
    t_fwd_ms: float  # forward time per stage per microbatch
    act_bytes: float  # activation (= gradient) bytes per boundary
    stage_dc: Tuple[int, ...]  # DC index of each stage
    stage_param_bytes: float = 0.0  # per-stage parameter bytes (for DP all-reduce)
    recompute: bool = True
    bwd_mult: float = 2.0  # t_bwd = bwd_mult · t_fwd
    inflight_cap: Optional[int] = None  # max forwards ahead of backwards


@dataclasses.dataclass(frozen=True)
class GeoTopology:
    """Backward-compatible *uniform* topology: one latency/transport for
    every DC pair.  Heterogeneous WANs use ``repro.core.topology
    .TopologyMatrix``, which exposes the same ``link``/``intra_bw_gbps``
    interface; ``simulate`` and the Atlas scheduler accept either."""

    wan_latency_ms: float = 40.0
    multi_tcp: bool = True
    intra_bw_gbps: float = wan.INTRA_DC_GBPS
    intra_latency_ms: float = wan.INTRA_DC_LATENCY_MS

    def link(self, dc_a: int, dc_b: int) -> wan.Link:
        if dc_a == dc_b:
            return wan.Link(self.intra_latency_ms, self.intra_bw_gbps)
        return wan.wan_link(self.wan_latency_ms, self.multi_tcp)

    def is_wan(self, dc_a: int, dc_b: int) -> bool:
        return dc_a != dc_b

    def bandwidth_schedule(self, dc_a: int, dc_b: int) -> None:
        """Uniform topologies are static; time-varying bandwidth lives on
        ``TopologyMatrix.bw_schedules``."""
        return None

    def matrix(self, n_dcs: int) -> "TopologyMatrix":
        """The equivalent (uniform) ``TopologyMatrix``."""
        return TopologyMatrix.uniform(
            n_dcs,
            wan_latency_ms=self.wan_latency_ms,
            multi_tcp=self.multi_tcp,
            intra_bw_gbps=self.intra_bw_gbps,
            intra_latency_ms=self.intra_latency_ms,
        )


@dataclasses.dataclass
class Interval:
    start: float
    end: float
    kind: str  # 'fwd' | 'rec' | 'bwd' | 'prefill'
    micro: int = -1


@dataclasses.dataclass
class SimResult:
    iteration_ms: float
    busy: Dict[Tuple[int, int], List[Interval]]  # (pipeline, stage) -> intervals
    utilization: float
    # schedulable idle windows within the pipeline span [0, iteration_ms -
    # allreduce_ms]; the trailing DP all-reduce is busy communication, not
    # a bubble (BubbleTea must not place prefills there)
    bubbles: Dict[Tuple[int, int], List[Tuple[float, float]]]
    allreduce_ms: float
    n_pipelines: int
    stats: Optional[Dict] = None  # engine accounting: events, fast_forward, ...
    # per-transfer WAN channel log (``temporal.Transfer`` records,
    # iteration-local times), recorded only when a tracer is attached or
    # ``record_transfers=True`` — the raw material for channel-lane spans
    # and the ``repro.obs`` second-witness wan_bits cross-check.  For the
    # replicated baseline path the log covers the one simulated pipeline;
    # ``stats["replicated_pipelines"]`` scales its accounting.
    transfers: Optional[List] = None

    def stage_bubbles(self, pipeline: int, stage: int) -> List[Tuple[float, float]]:
        return self.bubbles[(pipeline, stage)]


POLICIES = ("gpipe", "megatron", "varuna", "atlas")


def boundary_schedule(topo, spec: PipelineSpec, s_from: int, s_to: int):
    """The ``wan.BandwidthSchedule`` governing the ``s_from -> s_to``
    transfer, or ``None`` when that directed DC pair is static (uniform
    topologies, intra-DC hops, pairs without an attached schedule)."""
    get = getattr(topo, "bandwidth_schedule", None)
    if get is None:
        return None
    return get(spec.stage_dc[s_from], spec.stage_dc[s_to])


def iteration_wan_bits(spec: PipelineSpec, n_pipelines: int) -> Dict[Tuple[int, int], float]:
    """Bits one iteration puts on each *directed* WAN DC pair (all
    ``n_pipelines`` pipelines, both directions).  Analytic and exact for
    every engine path — event replay, Atlas precompute, fast-forward —
    because every microbatch crosses every boundary exactly once per
    direction.  Recorded in ``SimResult.stats["wan_bits"]`` and used by
    the fleet allocator (``repro.core.fleet.pair_demand_rates``) as the
    per-iteration channel demand."""
    out: Dict[Tuple[int, int], float] = {}
    per_boundary = units.bytes_to_bits(spec.microbatches * spec.act_bytes) * n_pipelines
    for s in range(spec.num_stages - 1):
        a, b = spec.stage_dc[s], spec.stage_dc[s + 1]
        if a == b:
            continue
        out[(a, b)] = out.get((a, b), 0.0) + per_boundary
        out[(b, a)] = out.get((b, a), 0.0) + per_boundary
    return out


def has_time_varying_wan(spec: PipelineSpec, topo) -> bool:
    """Does any stage boundary of ``spec`` cross a WAN pair whose
    bandwidth schedule is non-flat (in either direction)?  Gates the
    steady-state fast-forward: a bandwidth change anywhere in the
    iteration breaks the periodicity the extrapolation relies on, and
    the probes (short-M replays) cannot see changes beyond their own
    horizon — so the engine must fall back to full replay."""
    for s in range(spec.num_stages - 1):
        for a, b in ((s, s + 1), (s + 1, s)):
            sched = boundary_schedule(topo, spec, a, b)
            if sched is not None and not sched.is_flat():
                return True
    return False


# ---------------------------------------------------------------------------


def simulate(
    spec: PipelineSpec,
    topo,  # GeoTopology | repro.core.topology.TopologyMatrix
    *,
    policy: str = "varuna",
    n_pipelines: int = 1,
    dp_replicas_for_allreduce: int = 1,
    validate: bool = False,
    fast_forward: Optional[bool] = None,
    start_ms: float = 0.0,
    tracer=None,
    trace_label: str = "sim",
    record_transfers: Optional[bool] = None,
) -> SimResult:
    """Simulate one minibatch (iteration) of ``n_pipelines`` DP pipelines.

    policy: gpipe | megatron | varuna | atlas.  Only "atlas" coordinates
    the pipelines (temporal bandwidth sharing); the baselines run
    identical, independent schedules and compete for nothing (each has its
    own node-pair allocation — the paper's *spatial* sharing).

    ``topo`` is anything exposing ``link(dc_a, dc_b)`` and
    ``intra_bw_gbps`` — the uniform ``GeoTopology`` or a heterogeneous
    ``TopologyMatrix``.  ``validate=True`` runs the physical-invariant
    checker (``repro.core.validate``) on the result before returning.

    ``fast_forward``: ``None`` engages the steady-state fast-forward
    automatically once M is large enough to amortize its two probe runs;
    ``True`` attempts it whenever the probes fit below M; ``False``
    disables it (full event replay).  Whenever detection fails the engine
    silently falls back to full replay — the result is bit-compatible
    either way (``res.stats["fast_forward"]`` records what happened).
    Time-varying bandwidth (a non-flat ``TopologyMatrix`` schedule on a
    WAN boundary) breaks steady-state periodicity, so the fast-forward
    is gated off even under ``fast_forward=True``;
    ``res.stats["fast_forward_gate"]`` records the reason.

    ``start_ms`` places the iteration at an absolute wall-clock offset:
    every time-varying transfer is priced against the bandwidth segments
    in force at ``start_ms + (local start)``, so an in-flight transfer
    straddling a segment boundary keeps the bits already sent and
    re-integrates the remainder at the new rate.  Intervals stay in
    iteration-local time; static and flat pairs are offset-invariant.
    The horizon co-simulator (``repro.core.control``) drives this.

    ``tracer`` (``repro.obs.Tracer``) records the run as structured
    sim-time events: GPU spans per busy interval / bubble / allreduce
    on ``{trace_label}/gpu`` lanes and one channel span per WAN
    transfer on ``{trace_label}/wan`` lanes, anchored at ``start_ms``.
    A recording tracer (or ``record_transfers=True``) keeps the
    per-transfer log on ``SimResult.transfers`` and disables the
    fast-forward — its analytic extrapolation synthesizes intervals
    without replaying transfers, and the emitted timeline must show
    what actually moved on the wire (results are interval-identical by
    design either way).  ``None``/``NullTracer`` leave the hot path
    untouched (see the ``trace_overhead`` bench cell).
    """
    assert policy in POLICIES
    recording = tracer is not None and getattr(tracer, "enabled", False)
    if record_transfers is None:
        record_transfers = recording
    D = n_pipelines
    # Baselines: the D pipelines share nothing (per-pipeline channels,
    # GPUs, barriers) — simulate one and replicate.  Atlas pipelines pool
    # WAN channels per cell and must be simulated together.
    replicate = D if (policy != "atlas" and D > 1) else 1
    engine_D = 1 if policy != "atlas" else D
    transfer_log: Optional[List] = [] if record_transfers else None

    def run_raw(s: PipelineSpec):
        if policy == "atlas":
            return _run_atlas(s, topo, D, start_ms, transfer_log=transfer_log)
        return _run_events(
            s, topo, policy, engine_D, start_ms, transfer_log=transfer_log
        )

    raw = None
    ff_gate = None
    if fast_forward is not False and not record_transfers:
        from repro.core import fastforward

        ff_gate = fastforward.fast_forward_gate(spec, topo)
        if ff_gate is None:
            raw = fastforward.try_fast_forward(
                spec, run_raw, n_pipelines=engine_D, force=fast_forward is True
            )
    if raw is None:
        busy, pp_end, stats = run_raw(spec)
        stats["fast_forward"] = False
        if ff_gate is not None:
            stats["fast_forward_gate"] = ff_gate
    else:
        busy, pp_end, stats = raw
    stats["replicated_pipelines"] = replicate
    if replicate > 1:
        # fresh Interval objects per replica: SimResult consumers may
        # mutate intervals (the validator's negative tests do), and
        # aliased replicas would corrupt each other
        busy = {
            (p, s): (
                ivs if p == 0 else
                [Interval(iv.start, iv.end, iv.kind, iv.micro) for iv in ivs]
            )
            for p in range(replicate)
            for (_, s), ivs in busy.items()
        }
    res = _finalize(spec, topo, busy, pp_end, D, dp_replicas_for_allreduce, stats)
    res.transfers = transfer_log
    res = _maybe_validate(res, spec, policy, validate)
    if recording:
        from repro import obs

        obs.trace_sim_result(
            tracer,
            res,
            spec,
            label=trace_label,
            t0_ms=start_ms,
            dc_names=getattr(topo, "dc_names", None),
        )
    return res


# ---------------------------------------------------------------------------
# heap-based event engine (gpipe / megatron / varuna)
# ---------------------------------------------------------------------------


def _run_events(
    spec: PipelineSpec,
    topo,
    policy: str,
    D: int,
    start_ms: float = 0.0,
    transfer_log: Optional[List] = None,
) -> Tuple[Dict, float, Dict]:
    """Raw event replay: returns (busy, pipeline end time, engine stats).

    ``transfer_log`` (a list, or ``None`` to skip) collects one
    ``temporal.Transfer`` per channel occupancy — the hot path pays one
    ``is not None`` test per transfer when disabled."""
    if transfer_log is not None:
        from repro.core.temporal import Transfer as _Transfer
    P, M = spec.num_stages, spec.microbatches
    recompute = spec.recompute and policy in ("gpipe", "varuna", "atlas")
    inflight_cap = spec.inflight_cap
    if inflight_cap is None:
        inflight_cap = M if policy == "gpipe" else P
    gpipe = policy == "gpipe"
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * spec.t_fwd_ms
    pipes = range(D)

    # --- memoized per-boundary transfer times --------------------------------
    # (channel occupancy ms, extra delivery delay ms, bandwidth schedule):
    # occupancy is the serialization time (the bandwidth resource);
    # propagation latency delays delivery but does not hold the link —
    # back-to-back transfers pipeline through the WAN.  On a static pair
    # the occupancy is a constant, computed once per (s_from, s_to); a
    # time-varying pair carries its schedule instead and integrates the
    # bytes across segment boundaries at each transfer's actual start.
    ttimes: Dict[Tuple[int, int], Tuple[float, float, Optional[object]]] = {}
    for s in range(P - 1):
        for s_from, s_to in ((s, s + 1), (s + 1, s)):
            link = topo.link(spec.stage_dc[s_from], spec.stage_dc[s_to])
            bw = link.bw_gbps
            sched = boundary_schedule(topo, spec, s_from, s_to)
            if sched is not None and sched.is_flat():
                # a flat schedule is a constant rate: keep the memoized
                # fast path (at the schedule's rate, which may override
                # the static link's)
                bw, sched = sched.bw_gbps[0], None
            ser = units.serialization_ms(spec.act_bytes, bw)
            ttimes[(s_from, s_to)] = (ser, link.latency_ms, sched)

    # --- channels: (pipeline, boundary, dir), a heap ordered by (micro,
    # rank) — transfers are *scheduled*, not FIFO (paper §4.4 rule 3):
    # earliest microbatch first (gradients and activations never share a
    # channel — direction is part of the key).
    chan_free: Dict[Tuple, float] = {}
    chan_pending: Dict[Tuple, List[Tuple]] = {}

    # --- state ---
    gpu_free = {(p, s): 0.0 for p in pipes for s in range(P)}
    ready_f: Dict[Tuple[int, int], List[int]] = {g: [] for g in gpu_free}
    ready_b: Dict[Tuple[int, int], List[int]] = {g: [] for g in gpu_free}
    busy: Dict[Tuple[int, int], List[Interval]] = {g: [] for g in gpu_free}
    fwd_done = {g: 0 for g in gpu_free}
    bwd_done = {g: 0 for g in gpu_free}
    fwd_barrier_release: Dict[int, float] = {}  # gpipe: pipeline -> all-F time

    events: List[Tuple[float, int, str, Tuple]] = []
    seq = itertools.count()
    n_events = 0

    def push(t: float, kind: str, payload: Tuple):
        heapq.heappush(events, (t, next(seq), kind, payload))

    # seed: microbatch m ready at stage 0 at t=0
    for p in pipes:
        ready_f[(p, 0)] = list(range(M))  # already a valid heap

    def try_dispatch(g: Tuple[int, int], now: float):
        # backward (incl. its recompute) preempts queued forwards (paper
        # §4.4 rule 4); gpipe holds every backward until the pipeline's
        # forward barrier; the in-flight cap holds every forward alike.
        p, s = g
        if gpu_free[g] > now:
            return
        rb = ready_b[g]
        if rb and not (gpipe and fwd_barrier_release.get(p) is None):
            m = heapq.heappop(rb)
            kind = "bwd"
            dur = t_b + (t_f if (recompute and s != P - 1) else 0.0)
        else:
            rf = ready_f[g]
            if not rf or fwd_done[g] - bwd_done[g] >= inflight_cap:
                return
            m = heapq.heappop(rf)
            kind = "fwd"
            dur = t_f
        gpu_free[g] = now + dur
        busy[g].append(Interval(now, now + dur, kind, m))
        push(now + dur, "gpu_done", (p, s, kind, m))

    def on_gpu_done(now: float, p: int, s: int, kind: str, m: int):
        g = (p, s)
        if kind == "fwd":
            fwd_done[g] += 1
            if s < P - 1:
                request_transfer(now, p, s, s + 1, "act", m)
            else:
                # last stage: backward immediately eligible
                heapq.heappush(ready_b[g], m)
            if gpipe and s == P - 1 and fwd_done[g] == M:
                fwd_barrier_release[p] = now
                try_dispatch((p, P - 1), now)
        else:  # bwd
            bwd_done[g] += 1
            if s > 0:
                request_transfer(now, p, s, s - 1, "grad", m)
        try_dispatch(g, now)

    def request_transfer(now: float, p: int, s_from: int, s_to: int, direction: str, m: int):
        boundary = min(s_from, s_to)
        key = (p, boundary, direction)
        heapq.heappush(
            chan_pending.setdefault(key, []), (m, p, s_from, s_to, direction)
        )
        pump_channel(key, now)

    def pump_channel(key: Tuple, now: float):
        pend = chan_pending.get(key)
        if not pend or chan_free.get(key, 0.0) > now + 1e-12:
            return
        m, p, s_from, s_to, direction = heapq.heappop(pend)
        ser, delay, sched = ttimes[(s_from, s_to)]
        if sched is not None:
            ser = sched.transfer_ms(spec.act_bytes, start_ms + now)
        chan_free[key] = now + ser
        if transfer_log is not None:
            transfer_log.append(
                _Transfer(
                    p, min(s_from, s_to), direction, m,
                    now, now + ser, now + ser + delay,
                )
            )
        push(now + ser + delay, "arrive", (p, s_to, direction, m))
        push(now + ser, "chan_free", (key,))

    def on_arrive(now: float, p: int, s: int, direction: str, m: int):
        g = (p, s)
        if direction == "act":
            heapq.heappush(ready_f[g], m)
        else:
            heapq.heappush(ready_b[g], m)
        try_dispatch(g, now)

    # kick off
    for p in pipes:
        try_dispatch((p, 0), 0.0)

    while events:
        now, _, ev, payload = heapq.heappop(events)
        n_events += 1
        if ev == "gpu_done":
            on_gpu_done(now, *payload)
        elif ev == "arrive":
            on_arrive(now, *payload)
        else:  # chan_free
            pump_channel(payload[0], now)

    pp_end = max((ivs[-1].end for ivs in busy.values() if ivs), default=0.0)
    stats = {"engine": "event-heap", "events": n_events}
    return busy, pp_end, stats


# ---------------------------------------------------------------------------
# Atlas (precomputed §4.4 schedule wrapped into the SimResult shape)
# ---------------------------------------------------------------------------


def _run_atlas(
    spec: PipelineSpec,
    topo,
    n_pipelines: int,
    start_ms: float = 0.0,
    transfer_log: Optional[List] = None,
) -> Tuple[Dict, float, Dict]:
    from repro.core import temporal

    sched = temporal.atlas_schedule(
        spec, topo, n_pipelines, inflight_cap=spec.inflight_cap, start_ms=start_ms
    )
    if transfer_log is not None:
        transfer_log.extend(sched.transfers)
    busy: Dict[Tuple[int, int], List[Interval]] = {
        (p, s): [] for p in range(n_pipelines) for s in range(spec.num_stages)
    }
    for t in sched.tasks:
        busy[(t.pipeline, t.stage)].append(Interval(t.start, t.end, t.kind, t.micro))
    stats = {
        "engine": "atlas-precomputed",
        "events": len(sched.tasks) + len(sched.transfers),
    }
    return busy, sched.makespan, stats


# ---------------------------------------------------------------------------
# shared result assembly: all-reduce, bubbles, utilization
# ---------------------------------------------------------------------------


def _finalize(
    spec: PipelineSpec,
    topo,
    busy: Dict[Tuple[int, int], List[Interval]],
    pp_end: float,
    n_pipelines: int,
    dp_replicas: int,
    stats: Optional[Dict] = None,
) -> SimResult:
    """Wrap raw busy intervals into a SimResult: add the analytic DP
    all-reduce (intra-DC rings, §4.2) and run the single-pass bubble /
    utilization accounting shared by every engine path.

    Bubble extraction is capped at ``pp_end``: the trailing
    ``[pp_end, pp_end + allreduce_ms]`` span is the DP all-reduce, during
    which every GPU is busy communicating — it is *not* schedulable idle
    time, and recording it as a bubble let BubbleTea place prefills on
    GPUs mid-all-reduce.  Utilization stays busy-compute over the whole
    iteration (including the all-reduce span in the denominator)."""
    ar = wan.allreduce_ms(spec.stage_param_bytes, dp_replicas, topo.intra_bw_gbps)
    total = pp_end + ar
    if stats is not None:
        stats["wan_bits"] = iteration_wan_bits(spec, n_pipelines)
    bubbles: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    busy_sum = 0.0
    for g, ivs in busy.items():
        # the event engine appends in dispatch (= start) order; the atlas
        # list-scheduler may interleave — sort only when actually needed
        for i in range(1, len(ivs)):
            if ivs[i].start < ivs[i - 1].start:
                ivs.sort(key=lambda iv: iv.start)
                break
        gaps = []
        cur = 0.0
        for iv in ivs:
            if iv.start > cur + 1e-9:
                gaps.append((cur, iv.start))
            if iv.end > cur:
                cur = iv.end
            busy_sum += iv.end - iv.start
        if cur < pp_end - 1e-9:
            gaps.append((cur, pp_end))
        bubbles[g] = gaps
    util = busy_sum / (total * len(busy)) if total > 0 else 0.0
    return SimResult(
        iteration_ms=total,
        busy=busy,
        utilization=util,
        bubbles=bubbles,
        allreduce_ms=ar,
        n_pipelines=n_pipelines,
        stats=stats,
    )


def _maybe_validate(res: SimResult, spec: PipelineSpec, policy: str, validate: bool) -> SimResult:
    if validate:
        from repro.core import validate as _validate

        _validate.check_sim_result(res, spec, policy=policy)
    return res


# ---------------------------------------------------------------------------
# analytic DP-only iteration (paper §3.1, Fig 2)
# ---------------------------------------------------------------------------


def dp_iteration_ms(
    compute_ms: float,
    param_bytes: float,
    n_nodes: int,
    latency_ms: float,
    *,
    multi_tcp: bool = False,
    intra_dc: bool = False,
) -> float:
    """One DP iteration: compute + ring all-reduce over the given network."""
    if intra_dc:
        bw = wan.INTRA_DC_GBPS
    else:
        bw = (
            wan.NODE_PAIR_CAP_GBPS
            if multi_tcp
            else wan.tcp_single_bw_gbps(latency_ms)
        )
    return compute_ms + wan.allreduce_ms(param_bytes, n_nodes, bw)


# ---------------------------------------------------------------------------
# convenience: paper §6.1 testbed-style spec builders
# ---------------------------------------------------------------------------


def testbed_spec(
    *,
    hidden: int,
    seq_len: int,
    micro_batch: int,
    layers_per_stage: int,
    layer_params: float,
    num_stages: int,
    microbatches: int,
    stage_dc: Sequence[int],
    gpu_tflops: float = 312.0,  # A100 bf16 dense
    recompute: bool = True,
) -> PipelineSpec:
    """Derive compute/comm times from model dims (paper §4.2 big-O terms)."""
    # forward FLOPs per microbatch per stage ≈ 6·params·tokens  (fwd=2·,
    # bwd=4· => bwd_mult 2); attention term folded into the constant.
    tokens = micro_batch * seq_len
    stage_params = layers_per_stage * layer_params
    flops_fwd = 2.0 * stage_params * tokens
    t_fwd_ms = flops_fwd / (gpu_tflops * 1e12) * 1e3
    return PipelineSpec(
        num_stages=num_stages,
        microbatches=microbatches,
        t_fwd_ms=t_fwd_ms,
        act_bytes=wan.activation_bytes(micro_batch, seq_len, hidden),
        stage_dc=tuple(stage_dc),
        stage_param_bytes=stage_params * 2.0,  # fp16
        recompute=recompute,
    )
