"""Algorithm 1 — DC selection and what-if performance/cost modeling (§4.5).

Given per-DC GPU availability, the comm/compute ratio C and the partition
count P, sweep the number of DP-cells D, greedily pack PP partitions into
DCs (in the given DC order — cost, distance, or availability), and report
``total_time[D] = PP_time + all_reduce_time``.  Users pick D by
throughput = D·C / total_time[D] (paper §4.5), or run exhaustive what-if
sweeps over DC sets without any deployment.

``get_latency_pp`` uses the closed-form pipeline model validated against
the event simulator (see tests/test_dc_selection.py):
    PP_time = fill + (M−1)·slot + drain
    slot    = max(GPU work per microbatch, WAN channel time per microbatch)
with temporal sharing shrinking the per-transfer time by the cell's DP
factor (C) on the fill/drain paths.  Evaluations are memoized — what-if
sweeps and the D loop revisit the same (partitions, order) points.

Placement-order search: with a heterogeneous *named* topology the DC
order matters (slow pairs must stay off the stage boundaries).  The
original search enumerated every permutation (O(n!), capped at 6 DCs);
the default is now branch-and-bound over partial orders — a partial
placement's cost is lower-bounded by the cheapest boundary links that
could still be appended, the slot term by the boundaries already placed
— which prunes permutations sharing a dominated prefix and lifts the
cap to 12 DCs (8 named DCs search in well under a second).  The
exhaustive search is kept behind ``order_search="exhaustive"`` as the
differential-testing reference: both must return the same best plan.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro import units
from repro.core import wan
from repro.core.topology import TopologyMatrix

MAX_SEARCH_DCS = 12  # branch-and-bound order search
MAX_EXHAUSTIVE_DCS = 8  # reference O(n!) search (tests only, realistically)
AUTO_SEARCH_DCS = 10  # auto-enable threshold for named topologies


@dataclasses.dataclass(frozen=True)
class JobModel:
    """Workload constants feeding Algorithm 1.

    ``topology`` (optional) switches the model from a uniform WAN to a
    per-DC-pair ``TopologyMatrix``: every pipeline boundary then pays its
    *own* link's serialization + latency, and Algorithm 1 searches DC
    *orders* so the slow pairs stay off the stage boundaries.  DC names
    resolve to matrix indices via ``topology.dc_names`` when present,
    otherwise by position in the order under evaluation.
    """

    t_fwd_ms: float  # forward time per partition per microbatch
    act_bytes: float  # activation/gradient bytes per boundary
    partition_param_bytes: float  # parameter bytes per partition
    microbatches: int
    recompute: bool = True
    bwd_mult: float = 2.0
    wan_latency_ms: float = 40.0
    multi_tcp: bool = True
    intra_bw_gbps: float = wan.INTRA_DC_GBPS
    topology: Optional[TopologyMatrix] = None

    def pair_link(self, idx_a: int, idx_b: int) -> wan.Link:
        if self.topology is not None:
            return self.topology.link(idx_a, idx_b)
        if idx_a == idx_b:
            return wan.Link(wan.INTRA_DC_LATENCY_MS, self.intra_bw_gbps)
        return wan.wan_link(self.wan_latency_ms, self.multi_tcp)

    def pair_bw_gbps(self, idx_a: int, idx_b: int) -> float:
        """Planning-time bandwidth of the *directed* pair: the worst
        segment of its time-varying schedule when one is attached, else
        the static link rate.  Algorithm 1 prices every boundary by what
        the direction can guarantee across the whole iteration — this is
        what makes placements bandwidth-asymmetric (a link degraded in
        one direction repels only the schedules that would cross it that
        way), not merely latency-aware."""
        if self.topology is not None:
            return self.topology.effective_bw_gbps(idx_a, idx_b)
        return self.pair_link(idx_a, idx_b).bw_gbps

    @property
    def comm_compute_ratio(self) -> float:
        """C — WAN serialization time of one boundary transfer over t_fwd.

        Heterogeneous topologies size C from the *best* WAN pair (by
        worst-segment bandwidth when schedules are attached): the
        placement-order search keeps the slow pairs off the stage
        boundaries, so the best link is what a cell actually crosses —
        sizing from the bottleneck would inflate C until no DC can hold
        a partition (every plan infeasible) on exactly the skewed WANs
        the search handles."""
        if self.topology is not None and self.topology.n_dcs > 1:
            bw = max(
                self.topology.effective_bw_gbps(a, b)
                for a, b in self.topology.wan_pairs()
            )
        else:
            bw = (
                wan.NODE_PAIR_CAP_GBPS
                if self.multi_tcp
                else wan.tcp_single_bw_gbps(self.wan_latency_ms)
            )
        ser_ms = units.serialization_ms(self.act_bytes, bw)
        return ser_ms / self.t_fwd_ms


@dataclasses.dataclass
class PlanEntry:
    D: int
    partitions: Dict[str, int]
    pp_time_ms: float
    allreduce_ms: float
    total_ms: float
    throughput: float  # pipelines·microbatches / ms  (relative units)
    gpus_used: int
    dc_order: Tuple[str, ...] = ()  # placement order the stages follow


def _stage_dc_from_partitions(partitions: Dict[str, int], dc_order: Sequence[str]) -> List[int]:
    stage_dc: List[int] = []
    for i, dc in enumerate(dc_order):
        stage_dc.extend([i] * partitions.get(dc, 0))
    return stage_dc


# --------------------------------------------------------------------------
# closed-form pipeline latency (memoized)
# --------------------------------------------------------------------------

_PP_MEMO: Dict[Tuple, float] = {}
_PP_MEMO_MAX = 200_000
# structural job fingerprints, cached per live JobModel object (the weakref
# identity check guards against id() reuse after garbage collection; the
# JobModel itself is unhashable whenever its topology carries a link dict)
_JOB_KEY_CACHE: Dict[int, Tuple[object, Tuple]] = {}
_JOB_KEY_CACHE_MAX = 4096


def _job_memo_key(job: JobModel) -> Tuple:
    import weakref

    hit = _JOB_KEY_CACHE.get(id(job))
    if hit is not None and hit[0]() is job:
        return hit[1]
    topo = job.topology
    tkey: Optional[Tuple] = None
    if topo is not None:
        tkey = (
            topo.n_dcs,
            tuple(sorted(topo.links.items())),
            # schedules change planning-time bandwidth: topologies that
            # differ only in bw_schedules must not share memo entries
            tuple(sorted(topo.bw_schedules.items())),
            topo.intra_bw_gbps,
            topo.intra_latency_ms,
            topo.default_latency_ms,
            topo.multi_tcp,
            topo.dc_names,
        )
    key = (
        job.t_fwd_ms,
        job.act_bytes,
        job.microbatches,
        job.recompute,
        job.bwd_mult,
        job.wan_latency_ms,
        job.multi_tcp,
        job.intra_bw_gbps,
        tkey,
    )
    if len(_JOB_KEY_CACHE) >= _JOB_KEY_CACHE_MAX:
        _JOB_KEY_CACHE.clear()
    _JOB_KEY_CACHE[id(job)] = (weakref.ref(job), key)
    return key


def get_latency_pp(
    job: JobModel,
    partitions: Dict[str, int],
    dc_order: Sequence[str],
    dp_per_cell: int,
) -> float:
    """Closed-form pipeline latency with temporal bandwidth sharing.

    Heterogeneity-aware: each WAN boundary pays its *own* link's
    serialization and propagation latency, and the steady-state slot is
    set by the slowest boundary (every microbatch must traverse every
    boundary; channels are independent, so the pipeline's rate is the
    bottleneck channel's).  Results are memoized per (job, partitions,
    order, cell): the order search and what-if sweeps re-evaluate the
    same placements many times."""
    key = (
        _job_memo_key(job),
        tuple(sorted(partitions.items())),
        tuple(dc_order),
        dp_per_cell,
    )
    hit = _PP_MEMO.get(key)
    if hit is not None:
        return hit
    val = _latency_pp_impl(job, partitions, dc_order, dp_per_cell)
    if len(_PP_MEMO) >= _PP_MEMO_MAX:
        _PP_MEMO.clear()
    _PP_MEMO[key] = val
    return val


def _pair_terms(
    job: JobModel, idx_a: int, idx_b: int, D: int, hop: float
) -> Tuple[float, float, float]:
    """(fill term, drain term, channel occupancy) of one WAN boundary
    a -> b: activations ride the forward link, gradients the reverse one,
    the scatter/gather hops stream with the WAN send.  Each direction is
    priced at its own *worst-segment* bandwidth (``pair_bw_gbps``) when a
    time-varying schedule is attached — placements must survive the
    slowest hour, and the two directions may degrade independently.  The
    single pricing point shared by the closed form and the
    branch-and-bound search — change the model here and both stay in
    lock-step."""
    fwd = job.pair_link(idx_a, idx_b)
    rev = job.pair_link(idx_b, idx_a)
    ser_f = units.serialization_ms(job.act_bytes, job.pair_bw_gbps(idx_a, idx_b))
    ser_r = units.serialization_ms(job.act_bytes, job.pair_bw_gbps(idx_b, idx_a))
    fill = ser_f / D + 2.0 * hop + fwd.latency_ms
    drain = ser_r / D + 2.0 * hop + rev.latency_ms
    return fill, drain, max(ser_f, ser_r)


def _latency_pp_impl(
    job: JobModel,
    partitions: Dict[str, int],
    dc_order: Sequence[str],
    dp_per_cell: int,
) -> float:
    stage_dc = _stage_dc_from_partitions(partitions, dc_order)
    P = len(stage_dc)
    if P == 0:
        return math.inf
    M = job.microbatches
    t_f = job.t_fwd_ms
    t_b = job.bwd_mult * t_f
    t_r = t_f if job.recompute else 0.0
    D = max(1, dp_per_cell)

    # map a position in dc_order to a topology DC index: by name when the
    # matrix carries names (unknown names are an error — a silent
    # positional fallback would price the wrong link), by position in the
    # given order otherwise
    if job.topology is not None and job.topology.dc_names:
        idx = [job.topology.index_of(dc) for dc in dc_order]
    else:
        idx = list(range(len(dc_order)))

    intra_bw = (
        job.topology.intra_bw_gbps if job.topology is not None else job.intra_bw_gbps
    )
    hop = units.serialization_ms(job.act_bytes * (D - 1) / D, intra_bw)
    intra_ms = units.serialization_ms(job.act_bytes, intra_bw)

    # temporal sharing: channel occupancy ser/D; scatter/gather hops stream
    # with the WAN send and only add delivery delay (see _pair_terms)
    wan_fill_ms = 0.0  # per-boundary fill terms (activation direction)
    wan_drain_ms = 0.0  # per-boundary drain terms (gradient direction)
    max_ser = 0.0  # slowest channel's per-microbatch occupancy
    n_intra = 0
    for a, b in zip(stage_dc, stage_dc[1:]):
        if a == b:
            n_intra += 1
            continue
        fill, drain, ser = _pair_terms(job, idx[a], idx[b], D, hop)
        wan_fill_ms += fill
        wan_drain_ms += drain
        max_ser = max(max_ser, ser)

    # steady-state slot: per-microbatch GPU work vs per-microbatch WAN
    # channel occupancy of the bottleneck boundary (the cell's channel
    # carries D transfers of ser/D each per microbatch index => ser)
    slot = max(t_f + t_r + t_b, max_ser)
    fill = P * t_f + wan_fill_ms + n_intra * intra_ms
    drain = P * (t_r + t_b) + wan_drain_ms + n_intra * intra_ms
    return fill + (M - 1) * slot + drain


def get_latency_dp(job: JobModel, n_replicas: int) -> float:
    """All-reduce across the DP replicas of one layer — intra-DC ring
    (§4.2: replicas of a layer always live in the same DC)."""
    return wan.allreduce_ms(job.partition_param_bytes, n_replicas, job.intra_bw_gbps)


def _pack_partitions(
    num_gpu: Dict[str, int], order: Sequence[str], P: int, gpus_per_partition: int
) -> Tuple[Dict[str, int], int]:
    part_left = P
    partitions: Dict[str, int] = {}
    for dc in order:
        pp_gpu = num_gpu[dc] // gpus_per_partition
        assigned = min(part_left, pp_gpu)
        partitions[dc] = assigned
        part_left -= assigned
        if part_left == 0:
            break
    return partitions, part_left


# --------------------------------------------------------------------------
# placement-order search: branch-and-bound over partial orders
# --------------------------------------------------------------------------


def _bnb_best_order(
    job: JobModel,
    num_gpu: Dict[str, int],
    P: int,
    dc_order: Sequence[str],
    cell: int,
    gpus_per_partition: int,
    incumbent: Optional[Sequence[str]] = None,
) -> Optional[Tuple[str, ...]]:
    """Best placement order for one D (None = infeasible for this D).

    Search over *used-DC prefixes* only: once P partitions are packed the
    relative order of the remaining DCs is irrelevant (they hold no
    stage), and zero-capacity DCs never hold a stage — two symmetry
    classes the exhaustive permutation scan re-visits factorially often.
    A partial order is cut when a lower bound on its completion — the
    boundary terms already placed, plus the fewest possible future WAN
    boundaries priced at the cheapest remaining link, plus the (M−1)·slot
    term of the boundaries placed so far — cannot beat the incumbent.
    Children are expanded in ``dc_order`` sequence and the incumbent only
    replaced on strict improvement, so ties resolve to the same
    (lexicographically first) order the exhaustive reference returns.

    ``incumbent`` warm-starts the search with a known-good order (the
    control plane's currently-deployed placement): its cost becomes the
    initial bound, so partial orders dominated by the deployed plan are
    pruned immediately, and — because replacement requires *strict*
    improvement — a tie returns the incumbent itself, keeping the
    re-planner from proposing a cost-equal migration."""
    topo = job.topology
    assert topo is not None and topo.dc_names, "order search needs a named topology"
    caps = {dc: num_gpu.get(dc, 0) // gpus_per_partition for dc in dc_order}
    usable = [dc for dc in dc_order if caps[dc] > 0]
    if sum(caps[dc] for dc in usable) < P:
        return None

    M = job.microbatches
    t_f = job.t_fwd_ms
    t_b = job.bwd_mult * t_f
    t_r = t_f if job.recompute else 0.0
    D = max(1, cell)
    comp_slot = t_f + t_r + t_b
    const = P * t_f + P * (t_r + t_b)
    intra_bw = topo.intra_bw_gbps
    hop = units.serialization_ms(job.act_bytes * (D - 1) / D, intra_bw)
    intra_cost = 2.0 * units.serialization_ms(job.act_bytes, intra_bw)  # fill+drain

    idx = {dc: topo.index_of(dc) for dc in usable}
    pair_cost: Dict[Tuple[str, str], float] = {}
    pair_ser: Dict[Tuple[str, str], float] = {}
    for a in usable:
        for b in usable:
            if a == b:
                continue
            fill, drain, ser = _pair_terms(job, idx[a], idx[b], D, hop)
            pair_cost[(a, b)] = fill + drain
            pair_ser[(a, b)] = ser
    cheapest_pair = min(pair_cost.values()) if pair_cost else 0.0

    best_cost = math.inf
    best_order: Optional[Tuple[str, ...]] = None

    if incumbent is not None:
        # evaluate the deployed order through the same packing/cost walk
        # the dfs uses; an infeasible incumbent (fleet shrank) seeds nothing
        prefix: List[str] = []
        placed = 0
        acc = acc_ser = 0.0
        for dc in incumbent:
            if placed >= P:
                break
            if dc not in idx or dc in prefix:
                continue
            k = min(caps[dc], P - placed)
            acc += (k - 1) * intra_cost
            if prefix:
                acc += pair_cost[(prefix[-1], dc)]
                acc_ser = max(acc_ser, pair_ser[(prefix[-1], dc)])
            prefix.append(dc)
            placed += k
        if placed >= P:
            best_cost = const + acc + (M - 1) * max(comp_slot, acc_ser)
            best_order = tuple(prefix)

    def boundary_lb(left: int, remaining: List[str]) -> float:
        """Cheapest possible cost of the `left` boundaries still to come:
        at least `fewest DCs that can hold them` WAN hops, the rest
        intra-DC."""
        if left <= 0:
            return 0.0
        rem_caps = sorted((caps[dc] for dc in remaining), reverse=True)
        need, n_more = left, 0
        for c in rem_caps:
            if need <= 0:
                break
            need -= c
            n_more += 1
        if cheapest_pair >= intra_cost:
            return n_more * cheapest_pair + (left - n_more) * intra_cost
        return left * min(cheapest_pair, intra_cost)

    def dfs(order: List[str], used: set, placed: int, acc: float, acc_ser: float):
        nonlocal best_cost, best_order
        # ties (within float noise, relative) keep the earlier — i.e.
        # lexicographically-first — order, matching the exhaustive scan
        if placed >= P:
            total = const + acc + (M - 1) * max(comp_slot, acc_ser)
            if best_order is None or total < best_cost - 1e-9 * (1.0 + best_cost):
                best_cost = total
                best_order = tuple(order)
            return
        left = P - placed
        remaining = [dc for dc in usable if dc not in used]
        if sum(caps[dc] for dc in remaining) < left:
            return
        if best_order is not None:
            lb = const + acc + boundary_lb(left, remaining) \
                + (M - 1) * max(comp_slot, acc_ser)
            if lb >= best_cost - 1e-9 * (1.0 + best_cost):
                return
        last = order[-1] if order else None
        for dc in remaining:
            k = min(caps[dc], left)
            step = (k - 1) * intra_cost
            ser = acc_ser
            if last is not None:
                step += pair_cost[(last, dc)]
                ser = max(ser, pair_ser[(last, dc)])
            order.append(dc)
            used.add(dc)
            dfs(order, used, placed + k, acc + step, ser)
            order.pop()
            used.remove(dc)

    dfs([], set(), 0, 0.0, 0.0)
    if best_order is None:
        return None
    rest = [dc for dc in dc_order if dc not in best_order]
    return best_order + tuple(rest)


# --------------------------------------------------------------------------
# Algorithm 1
# --------------------------------------------------------------------------


def algorithm1(
    job: JobModel,
    num_gpu: Dict[str, int],
    P: int,
    *,
    C: Optional[int] = None,
    D_max: Optional[int] = None,
    dc_order: Optional[Sequence[str]] = None,
    search_orders: Optional[bool] = None,
    order_search: str = "bnb",
    incumbent_order: Optional[Sequence[str]] = None,
    exclude_dcs: Optional[Sequence[str]] = None,
) -> List[PlanEntry]:
    """Paper Algorithm 1. Returns one PlanEntry per DP-cell count D.

    With a heterogeneous *named* ``job.topology`` every DC *placement
    order* is evaluated per D and the fastest wins — on a skewed WAN the
    slow pair must not become a stage boundary, which a fixed
    availability-sorted order cannot guarantee.  The search needs DC
    names on the matrix (fleet keys must resolve to fixed topology
    sites; permuting a positional mapping would re-site the fleet).
    ``order_search`` picks the engine: "bnb" (default) prunes partial
    orders with admissible lower bounds and handles up to 12 DCs;
    "exhaustive" enumerates permutations (the differential-testing
    reference, ≤ 8 DCs) — both return the same best plan.

    ``incumbent_order`` (bnb only) warm-starts every per-D search with
    the currently-deployed placement: the re-planner
    (``repro.core.control``) passes the live plan's order so the search
    starts from a tight bound and ties resolve to "stay put".

    ``exclude_dcs`` plans over the *surviving* set: the named DCs are
    removed from the fleet (and from any explicit ``dc_order``) before
    anything is packed — the forced-failover path of the control plane
    (``repro.core.failures``) re-runs Algorithm 1 with the dead DC
    excluded rather than trusting degraded link pricing to route a
    placement off GPUs that no longer exist.  ``D_max`` (when left
    automatic) and the availability order follow the surviving fleet.
    """
    if order_search not in ("bnb", "exhaustive"):
        raise ValueError(f"unknown order_search {order_search!r}")
    if exclude_dcs:
        dead = set(exclude_dcs)
        num_gpu = {dc: g for dc, g in num_gpu.items() if dc not in dead}
        if not num_gpu:
            raise ValueError(f"exclude_dcs={sorted(dead)} leaves no fleet")
        if dc_order is not None:
            dc_order = [dc for dc in dc_order if dc not in dead]
        if incumbent_order is not None:
            incumbent_order = [dc for dc in incumbent_order if dc not in dead]
    explicit_order = dc_order is not None
    if dc_order is None:  # default: decreasing GPU availability (§4.5)
        dc_order = sorted(num_gpu, key=lambda d: -num_gpu[d])
    if C is None:
        C = max(1, round(job.comm_compute_ratio))
    total_gpus = sum(num_gpu.values())
    if D_max is None:
        D_max = max(1, total_gpus // (C * P))
    named = (
        job.topology is not None
        and job.topology.dc_names
        and all(dc in job.topology.dc_names for dc in dc_order)
    )
    if search_orders is None:
        # an explicitly supplied order (cost, distance, ... — §4.5) is a
        # caller decision; only auto-search the default availability order
        search_orders = (
            bool(named) and not explicit_order and len(dc_order) <= AUTO_SEARCH_DCS
        )
    if search_orders:
        if not named:
            raise ValueError(
                "search_orders needs a topology with dc_names covering every "
                "fleet DC (a positional mapping cannot be permuted)"
            )
        cap_dcs = MAX_SEARCH_DCS if order_search == "bnb" else MAX_EXHAUSTIVE_DCS
        if len(dc_order) > cap_dcs:
            raise ValueError(
                f"{order_search} order search is capped at {cap_dcs} DCs "
                f"(got {len(dc_order)}); pass an explicit dc_order instead"
            )

    orders: Optional[List[Tuple[str, ...]]] = None
    if not (search_orders and order_search == "bnb"):
        if search_orders:
            orders = [tuple(o) for o in itertools.permutations(dc_order)]
        else:
            orders = [tuple(dc_order)]
    plans: List[PlanEntry] = []
    for D in range(1, D_max + 1):
        if orders is None:
            best = _plan_for_order_bnb(job, num_gpu, P, C, D, dc_order,
                                       incumbent=incumbent_order)
        else:
            best = None
            for order in orders:
                entry = _plan_entry(job, num_gpu, P, C, D, order)
                if best is None or entry.total_ms < best.total_ms:
                    best = entry
        plans.append(best)
    return plans


def _plan_entry(
    job: JobModel,
    num_gpu: Dict[str, int],
    P: int,
    C: int,
    D: int,
    order: Tuple[str, ...],
) -> PlanEntry:
    partitions, part_left = _pack_partitions(num_gpu, order, P, D * C)
    if part_left > 0:
        pp_time = math.inf
        ar = 0.0
    else:
        pp_time = get_latency_pp(job, partitions, order, C)
        ar = get_latency_dp(job, D * C)
    total = pp_time + ar
    thr = (D * C * job.microbatches) / total if math.isfinite(total) else 0.0
    return PlanEntry(
        D=D,
        partitions=dict(partitions),
        pp_time_ms=pp_time,
        allreduce_ms=ar,
        total_ms=total,
        throughput=thr,
        gpus_used=D * C * sum(partitions.values()),
        dc_order=order,
    )


def _plan_for_order_bnb(
    job: JobModel,
    num_gpu: Dict[str, int],
    P: int,
    C: int,
    D: int,
    dc_order: Sequence[str],
    incumbent: Optional[Sequence[str]] = None,
) -> PlanEntry:
    order = _bnb_best_order(job, num_gpu, P, dc_order, C, D * C,
                            incumbent=incumbent)
    if order is None:  # infeasible: report the input order, like exhaustive
        return _plan_entry(job, num_gpu, P, C, D, tuple(dc_order))
    return _plan_entry(job, num_gpu, P, C, D, order)


def best_plan(plans: List[PlanEntry]) -> PlanEntry:
    return max(plans, key=lambda p: p.throughput)


def what_if(
    job: JobModel,
    scenarios: Dict[str, Dict[str, int]],
    P: int,
    *,
    C: Optional[int] = None,
    gpu_cost_per_hour: float = 2.0,
) -> Dict[str, Dict]:
    """Cost/performance what-if sweep across candidate DC sets (§4.5):
    for each scenario, the best plan, its throughput, and the $/iteration
    estimate — all without any deployment."""
    out: Dict[str, Dict] = {}
    for name, gpus in scenarios.items():
        plans = algorithm1(job, gpus, P, C=C)
        best = best_plan(plans)
        iter_hours = best.total_ms / 3.6e6
        out[name] = {
            "best_D": best.D,
            "throughput": best.throughput,
            "total_ms": best.total_ms,
            "gpus_used": best.gpus_used,
            "cost_per_iteration": best.gpus_used * gpu_cost_per_hour * iter_hours,
            "partitions": best.partitions,
        }
    return out
