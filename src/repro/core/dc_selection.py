"""Algorithm 1 — DC selection and what-if performance/cost modeling (§4.5).

Given per-DC GPU availability, the comm/compute ratio C and the partition
count P, sweep the number of DP-cells D, greedily pack PP partitions into
DCs (in the given DC order — cost, distance, or availability), and report
``total_time[D] = PP_time + all_reduce_time``.  Users pick D by
throughput = D·C / total_time[D] (paper §4.5), or run exhaustive what-if
sweeps over DC sets without any deployment.

``get_latency_pp`` uses the closed-form pipeline model validated against
the event simulator (see tests/test_dc_selection.py):
    PP_time = fill + (M−1)·slot + drain
    slot    = max(GPU work per microbatch, WAN channel time per microbatch)
with temporal sharing shrinking the per-transfer time by the cell's DP
factor (C) on the fill/drain paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import wan


@dataclasses.dataclass(frozen=True)
class JobModel:
    """Workload constants feeding Algorithm 1."""

    t_fwd_ms: float  # forward time per partition per microbatch
    act_bytes: float  # activation/gradient bytes per boundary
    partition_param_bytes: float  # parameter bytes per partition
    microbatches: int
    recompute: bool = True
    bwd_mult: float = 2.0
    wan_latency_ms: float = 40.0
    multi_tcp: bool = True
    intra_bw_gbps: float = wan.INTRA_DC_GBPS

    @property
    def comm_compute_ratio(self) -> float:
        """C — WAN serialization time of one boundary transfer over t_fwd."""
        bw = (
            wan.NODE_PAIR_CAP_GBPS
            if self.multi_tcp
            else wan.tcp_single_bw_gbps(self.wan_latency_ms)
        )
        ser_ms = self.act_bytes * 8.0 / (bw * 1e9) * 1e3
        return ser_ms / self.t_fwd_ms


@dataclasses.dataclass
class PlanEntry:
    D: int
    partitions: Dict[str, int]
    pp_time_ms: float
    allreduce_ms: float
    total_ms: float
    throughput: float  # pipelines·microbatches / ms  (relative units)
    gpus_used: int


def _stage_dc_from_partitions(partitions: Dict[str, int], dc_order: Sequence[str]) -> List[int]:
    stage_dc: List[int] = []
    for i, dc in enumerate(dc_order):
        stage_dc.extend([i] * partitions.get(dc, 0))
    return stage_dc


def get_latency_pp(
    job: JobModel,
    partitions: Dict[str, int],
    dc_order: Sequence[str],
    dp_per_cell: int,
) -> float:
    """Closed-form pipeline latency with temporal bandwidth sharing."""
    stage_dc = _stage_dc_from_partitions(partitions, dc_order)
    P = len(stage_dc)
    if P == 0:
        return math.inf
    M = job.microbatches
    t_f = job.t_fwd_ms
    t_b = job.bwd_mult * t_f
    t_r = t_f if job.recompute else 0.0
    D = max(1, dp_per_cell)

    bw = (
        wan.NODE_PAIR_CAP_GBPS
        if job.multi_tcp
        else wan.tcp_single_bw_gbps(job.wan_latency_ms)
    )
    ser = job.act_bytes * 8.0 / (bw * 1e9) * 1e3  # one-pipe serialization
    hop = job.act_bytes * (D - 1) / D * 8.0 / (job.intra_bw_gbps * 1e9) * 1e3
    # temporal sharing: channel occupancy ser/D; scatter/gather hops stream
    # with the WAN send and only add delivery delay
    ser_cell = ser / D + 2.0 * hop
    n_wan = sum(1 for a, b in zip(stage_dc, stage_dc[1:]) if a != b)
    intra_ms = job.act_bytes * 8.0 / (job.intra_bw_gbps * 1e9) * 1e3
    n_intra = (P - 1) - n_wan

    # steady-state slot: per-microbatch GPU work vs per-microbatch WAN
    # channel occupancy (the cell's channel carries D transfers of ser/D
    # each per microbatch index => ser per microbatch per boundary)
    slot = max(t_f + t_r + t_b, ser)
    fill = P * t_f + n_wan * (ser_cell + job.wan_latency_ms) + n_intra * intra_ms
    drain = P * (t_r + t_b) + n_wan * (ser_cell + job.wan_latency_ms) + n_intra * intra_ms
    return fill + (M - 1) * slot + drain


def get_latency_dp(job: JobModel, n_replicas: int) -> float:
    """All-reduce across the DP replicas of one layer — intra-DC ring
    (§4.2: replicas of a layer always live in the same DC)."""
    return wan.allreduce_ms(job.partition_param_bytes, n_replicas, job.intra_bw_gbps)


def algorithm1(
    job: JobModel,
    num_gpu: Dict[str, int],
    P: int,
    *,
    C: Optional[int] = None,
    D_max: Optional[int] = None,
    dc_order: Optional[Sequence[str]] = None,
) -> List[PlanEntry]:
    """Paper Algorithm 1. Returns one PlanEntry per DP-cell count D."""
    if dc_order is None:  # default: decreasing GPU availability (§4.5)
        dc_order = sorted(num_gpu, key=lambda d: -num_gpu[d])
    if C is None:
        C = max(1, round(job.comm_compute_ratio))
    total_gpus = sum(num_gpu.values())
    if D_max is None:
        D_max = max(1, total_gpus // (C * P))

    plans: List[PlanEntry] = []
    for D in range(1, D_max + 1):
        part_left = P
        partitions: Dict[str, int] = {}
        for dc in dc_order:
            pp_gpu = num_gpu[dc] // (D * C)
            assigned = min(part_left, pp_gpu)
            partitions[dc] = assigned
            part_left -= assigned
            if part_left == 0:
                break
        if part_left > 0:
            pp_time = math.inf
            ar = 0.0
        else:
            pp_time = get_latency_pp(job, partitions, dc_order, C)
            ar = get_latency_dp(job, D * C)
        total = pp_time + ar
        thr = (D * C * job.microbatches) / total if math.isfinite(total) else 0.0
        plans.append(
            PlanEntry(
                D=D,
                partitions=dict(partitions),
                pp_time_ms=pp_time,
                allreduce_ms=ar,
                total_ms=total,
                throughput=thr,
                gpus_used=D * C * sum(partitions.values()),
            )
        )
    return plans


def best_plan(plans: List[PlanEntry]) -> PlanEntry:
    return max(plans, key=lambda p: p.throughput)


def what_if(
    job: JobModel,
    scenarios: Dict[str, Dict[str, int]],
    P: int,
    *,
    C: Optional[int] = None,
    gpu_cost_per_hour: float = 2.0,
) -> Dict[str, Dict]:
    """Cost/performance what-if sweep across candidate DC sets (§4.5):
    for each scenario, the best plan, its throughput, and the $/iteration
    estimate — all without any deployment."""
    out: Dict[str, Dict] = {}
    for name, gpus in scenarios.items():
        plans = algorithm1(job, gpus, P, C=C)
        best = best_plan(plans)
        iter_hours = best.total_ms / 3.6e6
        out[name] = {
            "best_D": best.D,
            "throughput": best.throughput,
            "total_ms": best.total_ms,
            "gpus_used": best.gpus_used,
            "cost_per_iteration": best.gpus_used * gpu_cost_per_hour * iter_hours,
            "partitions": best.partitions,
        }
    return out
