"""Algorithm 1 — DC selection and what-if performance/cost modeling (§4.5).

Given per-DC GPU availability, the comm/compute ratio C and the partition
count P, sweep the number of DP-cells D, greedily pack PP partitions into
DCs (in the given DC order — cost, distance, or availability), and report
``total_time[D] = PP_time + all_reduce_time``.  Users pick D by
throughput = D·C / total_time[D] (paper §4.5), or run exhaustive what-if
sweeps over DC sets without any deployment.

``get_latency_pp`` uses the closed-form pipeline model validated against
the event simulator (see tests/test_dc_selection.py):
    PP_time = fill + (M−1)·slot + drain
    slot    = max(GPU work per microbatch, WAN channel time per microbatch)
with temporal sharing shrinking the per-transfer time by the cell's DP
factor (C) on the fill/drain paths.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import wan
from repro.core.topology import TopologyMatrix


@dataclasses.dataclass(frozen=True)
class JobModel:
    """Workload constants feeding Algorithm 1.

    ``topology`` (optional) switches the model from a uniform WAN to a
    per-DC-pair ``TopologyMatrix``: every pipeline boundary then pays its
    *own* link's serialization + latency, and Algorithm 1 searches DC
    *orders* so the slow pairs stay off the stage boundaries.  DC names
    resolve to matrix indices via ``topology.dc_names`` when present,
    otherwise by position in the order under evaluation.
    """

    t_fwd_ms: float  # forward time per partition per microbatch
    act_bytes: float  # activation/gradient bytes per boundary
    partition_param_bytes: float  # parameter bytes per partition
    microbatches: int
    recompute: bool = True
    bwd_mult: float = 2.0
    wan_latency_ms: float = 40.0
    multi_tcp: bool = True
    intra_bw_gbps: float = wan.INTRA_DC_GBPS
    topology: Optional[TopologyMatrix] = None

    def pair_link(self, idx_a: int, idx_b: int) -> wan.Link:
        if self.topology is not None:
            return self.topology.link(idx_a, idx_b)
        if idx_a == idx_b:
            return wan.Link(wan.INTRA_DC_LATENCY_MS, self.intra_bw_gbps)
        return wan.wan_link(self.wan_latency_ms, self.multi_tcp)

    @property
    def comm_compute_ratio(self) -> float:
        """C — WAN serialization time of one boundary transfer over t_fwd.

        Heterogeneous topologies size C from the *best* WAN pair: the
        placement-order search keeps the slow pairs off the stage
        boundaries, so the best link is what a cell actually crosses —
        sizing from the bottleneck would inflate C until no DC can hold
        a partition (every plan infeasible) on exactly the skewed WANs
        the search handles."""
        if self.topology is not None and self.topology.n_dcs > 1:
            bw = self.topology.best_link().bw_gbps
        else:
            bw = (
                wan.NODE_PAIR_CAP_GBPS
                if self.multi_tcp
                else wan.tcp_single_bw_gbps(self.wan_latency_ms)
            )
        ser_ms = self.act_bytes * 8.0 / (bw * 1e9) * 1e3
        return ser_ms / self.t_fwd_ms


@dataclasses.dataclass
class PlanEntry:
    D: int
    partitions: Dict[str, int]
    pp_time_ms: float
    allreduce_ms: float
    total_ms: float
    throughput: float  # pipelines·microbatches / ms  (relative units)
    gpus_used: int
    dc_order: Tuple[str, ...] = ()  # placement order the stages follow


def _stage_dc_from_partitions(partitions: Dict[str, int], dc_order: Sequence[str]) -> List[int]:
    stage_dc: List[int] = []
    for i, dc in enumerate(dc_order):
        stage_dc.extend([i] * partitions.get(dc, 0))
    return stage_dc


def get_latency_pp(
    job: JobModel,
    partitions: Dict[str, int],
    dc_order: Sequence[str],
    dp_per_cell: int,
) -> float:
    """Closed-form pipeline latency with temporal bandwidth sharing.

    Heterogeneity-aware: each WAN boundary pays its *own* link's
    serialization and propagation latency, and the steady-state slot is
    set by the slowest boundary (every microbatch must traverse every
    boundary; channels are independent, so the pipeline's rate is the
    bottleneck channel's)."""
    stage_dc = _stage_dc_from_partitions(partitions, dc_order)
    P = len(stage_dc)
    if P == 0:
        return math.inf
    M = job.microbatches
    t_f = job.t_fwd_ms
    t_b = job.bwd_mult * t_f
    t_r = t_f if job.recompute else 0.0
    D = max(1, dp_per_cell)

    # map a position in dc_order to a topology DC index: by name when the
    # matrix carries names (unknown names are an error — a silent
    # positional fallback would price the wrong link), by position in the
    # given order otherwise
    if job.topology is not None and job.topology.dc_names:
        idx = [job.topology.index_of(dc) for dc in dc_order]
    else:
        idx = list(range(len(dc_order)))

    intra_bw = (
        job.topology.intra_bw_gbps if job.topology is not None else job.intra_bw_gbps
    )
    hop = job.act_bytes * (D - 1) / D * 8.0 / (intra_bw * 1e9) * 1e3
    intra_ms = job.act_bytes * 8.0 / (intra_bw * 1e9) * 1e3

    # temporal sharing: channel occupancy ser/D; scatter/gather hops stream
    # with the WAN send and only add delivery delay.  Activations ride the
    # forward a -> b link, gradients the reverse b -> a link (asymmetric
    # topologies price them differently, like the event simulator).
    wan_fill_ms = 0.0  # per-boundary fill terms (activation direction)
    wan_drain_ms = 0.0  # per-boundary drain terms (gradient direction)
    max_ser = 0.0  # slowest channel's per-microbatch occupancy
    n_intra = 0
    for a, b in zip(stage_dc, stage_dc[1:]):
        if a == b:
            n_intra += 1
            continue
        fwd = job.pair_link(idx[a], idx[b])
        rev = job.pair_link(idx[b], idx[a])
        ser_f = job.act_bytes * 8.0 / (fwd.bw_gbps * 1e9) * 1e3
        ser_r = job.act_bytes * 8.0 / (rev.bw_gbps * 1e9) * 1e3
        wan_fill_ms += ser_f / D + 2.0 * hop + fwd.latency_ms
        wan_drain_ms += ser_r / D + 2.0 * hop + rev.latency_ms
        max_ser = max(max_ser, ser_f, ser_r)

    # steady-state slot: per-microbatch GPU work vs per-microbatch WAN
    # channel occupancy of the bottleneck boundary (the cell's channel
    # carries D transfers of ser/D each per microbatch index => ser)
    slot = max(t_f + t_r + t_b, max_ser)
    fill = P * t_f + wan_fill_ms + n_intra * intra_ms
    drain = P * (t_r + t_b) + wan_drain_ms + n_intra * intra_ms
    return fill + (M - 1) * slot + drain


def get_latency_dp(job: JobModel, n_replicas: int) -> float:
    """All-reduce across the DP replicas of one layer — intra-DC ring
    (§4.2: replicas of a layer always live in the same DC)."""
    return wan.allreduce_ms(job.partition_param_bytes, n_replicas, job.intra_bw_gbps)


def _pack_partitions(
    num_gpu: Dict[str, int], order: Sequence[str], P: int, gpus_per_partition: int
) -> Tuple[Dict[str, int], int]:
    part_left = P
    partitions: Dict[str, int] = {}
    for dc in order:
        pp_gpu = num_gpu[dc] // gpus_per_partition
        assigned = min(part_left, pp_gpu)
        partitions[dc] = assigned
        part_left -= assigned
        if part_left == 0:
            break
    return partitions, part_left


def algorithm1(
    job: JobModel,
    num_gpu: Dict[str, int],
    P: int,
    *,
    C: Optional[int] = None,
    D_max: Optional[int] = None,
    dc_order: Optional[Sequence[str]] = None,
    search_orders: Optional[bool] = None,
) -> List[PlanEntry]:
    """Paper Algorithm 1. Returns one PlanEntry per DP-cell count D.

    With a heterogeneous *named* ``job.topology`` every DC *placement
    order* is evaluated per D and the fastest wins — on a skewed WAN the
    slow pair must not become a stage boundary, which a fixed
    availability-sorted order cannot guarantee.  The search needs DC
    names on the matrix (fleet keys must resolve to fixed topology
    sites; permuting a positional mapping would re-site the fleet) and
    is exhaustive, so it caps at 6 DCs — pass ``search_orders=False``
    with an explicit ``dc_order`` beyond that.
    """
    explicit_order = dc_order is not None
    if dc_order is None:  # default: decreasing GPU availability (§4.5)
        dc_order = sorted(num_gpu, key=lambda d: -num_gpu[d])
    if C is None:
        C = max(1, round(job.comm_compute_ratio))
    total_gpus = sum(num_gpu.values())
    if D_max is None:
        D_max = max(1, total_gpus // (C * P))
    named = (
        job.topology is not None
        and job.topology.dc_names
        and all(dc in job.topology.dc_names for dc in dc_order)
    )
    if search_orders is None:
        # an explicitly supplied order (cost, distance, ... — §4.5) is a
        # caller decision; only auto-search the default availability order
        search_orders = bool(named) and not explicit_order and len(dc_order) <= 6
    if search_orders:
        if not named:
            raise ValueError(
                "search_orders needs a topology with dc_names covering every "
                "fleet DC (a positional mapping cannot be permuted)"
            )
        if len(dc_order) > 6:
            raise ValueError(
                f"search_orders is exhaustive and capped at 6 DCs "
                f"(got {len(dc_order)}); pass an explicit dc_order instead"
            )
        orders = [tuple(o) for o in itertools.permutations(dc_order)]
    else:
        orders = [tuple(dc_order)]

    plans: List[PlanEntry] = []
    for D in range(1, D_max + 1):
        best: Optional[PlanEntry] = None
        for order in orders:
            partitions, part_left = _pack_partitions(num_gpu, order, P, D * C)
            if part_left > 0:
                pp_time = math.inf
                ar = 0.0
            else:
                pp_time = get_latency_pp(job, partitions, order, C)
                ar = get_latency_dp(job, D * C)
            total = pp_time + ar
            thr = (D * C * job.microbatches) / total if math.isfinite(total) else 0.0
            entry = PlanEntry(
                D=D,
                partitions=dict(partitions),
                pp_time_ms=pp_time,
                allreduce_ms=ar,
                total_ms=total,
                throughput=thr,
                gpus_used=D * C * sum(partitions.values()),
                dc_order=order,
            )
            if best is None or entry.total_ms < best.total_ms:
                best = entry
        plans.append(best)
    return plans


def best_plan(plans: List[PlanEntry]) -> PlanEntry:
    return max(plans, key=lambda p: p.throughput)


def what_if(
    job: JobModel,
    scenarios: Dict[str, Dict[str, int]],
    P: int,
    *,
    C: Optional[int] = None,
    gpu_cost_per_hour: float = 2.0,
) -> Dict[str, Dict]:
    """Cost/performance what-if sweep across candidate DC sets (§4.5):
    for each scenario, the best plan, its throughput, and the $/iteration
    estimate — all without any deployment."""
    out: Dict[str, Dict] = {}
    for name, gpus in scenarios.items():
        plans = algorithm1(job, gpus, P, C=C)
        best = best_plan(plans)
        iter_hours = best.total_ms / 3.6e6
        out[name] = {
            "best_D": best.D,
            "throughput": best.throughput,
            "total_ms": best.total_ms,
            "gpus_used": best.gpus_used,
            "cost_per_iteration": best.gpus_used * gpu_cost_per_hour * iter_hours,
            "partitions": best.partitions,
        }
    return out
