"""Heterogeneous WAN topologies — per-DC-pair latency/bandwidth matrices.

The paper's testbed (§6.1) and every real multi-DC WAN have a *different*
latency/bandwidth for every DC pair (Fig 5: 2 ms us-east↔us-east vs 95 ms
us-east↔se-asia), while the original ``GeoTopology`` modelled a single
uniform ``wan_latency_ms``/``multi_tcp`` for all pairs.  ``TopologyMatrix``
generalizes it: an explicit per-pair ``wan.Link`` table (asymmetric pairs
allowed), with the same ``link(dc_a, dc_b)`` / ``intra_bw_gbps`` interface
the simulator, the Atlas scheduler (``repro.core.temporal``) and Algorithm
1 (``repro.core.dc_selection``) consume — so a ``TopologyMatrix`` drops in
anywhere a ``GeoTopology`` was accepted.

Presets model the paper's Azure testbed plus synthetic skewed/star/chain
WANs used by the scheduler tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core import wan

Pair = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class TopologyMatrix:
    """Per-DC-pair WAN model.

    ``links`` maps a directed ``(src, dst)`` DC pair to its ``wan.Link``;
    a missing ``(a, b)`` falls back to ``(b, a)`` (symmetric networks need
    only one triangle), and pairs absent from both directions use the
    uniform default built from ``default_latency_ms``/``multi_tcp``.

    ``bw_schedules`` optionally attaches a time-varying
    ``wan.BandwidthSchedule`` to a directed WAN pair (same reverse-pair
    fallback as ``links``; asymmetric conditions need both directions).
    A pair without a schedule keeps its static ``Link.bw_gbps`` forever —
    ``bandwidth_schedule`` then returns ``None`` so engines can keep the
    memoized constant-transfer fast path.
    """

    n_dcs: int
    links: Mapping[Pair, wan.Link] = dataclasses.field(default_factory=dict)
    intra_bw_gbps: float = wan.INTRA_DC_GBPS
    intra_latency_ms: float = wan.INTRA_DC_LATENCY_MS
    default_latency_ms: float = 40.0
    multi_tcp: bool = True
    dc_names: Tuple[str, ...] = ()
    name: str = ""
    bw_schedules: Mapping[Pair, wan.BandwidthSchedule] = dataclasses.field(
        default_factory=dict
    )

    def __post_init__(self):
        assert self.n_dcs >= 1
        for (a, b), l in self.links.items():
            assert 0 <= a < self.n_dcs and 0 <= b < self.n_dcs and a != b, (a, b)
            assert l.bw_gbps > 0 and l.latency_ms >= 0, l
        for (a, b), sched in self.bw_schedules.items():
            assert 0 <= a < self.n_dcs and 0 <= b < self.n_dcs and a != b, (a, b)
            assert isinstance(sched, wan.BandwidthSchedule), sched
        if self.dc_names:
            assert len(self.dc_names) == self.n_dcs

    # --- the interface the simulator/scheduler consume -------------------
    def link(self, dc_a: int, dc_b: int) -> wan.Link:
        if not (0 <= dc_a < self.n_dcs and 0 <= dc_b < self.n_dcs):
            raise IndexError(
                f"DC pair ({dc_a}, {dc_b}) outside topology with {self.n_dcs} DCs"
            )
        if dc_a == dc_b:
            return wan.Link(self.intra_latency_ms, self.intra_bw_gbps)
        l = self.links.get((dc_a, dc_b))
        if l is None:
            l = self.links.get((dc_b, dc_a))
        if l is None:
            l = wan.wan_link(self.default_latency_ms, self.multi_tcp)
        return l

    def is_wan(self, dc_a: int, dc_b: int) -> bool:
        return dc_a != dc_b

    def bandwidth_schedule(
        self, dc_a: int, dc_b: int
    ) -> Optional[wan.BandwidthSchedule]:
        """Time-varying bandwidth of the directed ``dc_a -> dc_b`` link,
        or ``None`` when the pair is static (intra-DC pairs always are)."""
        if dc_a == dc_b:
            return None
        s = self.bw_schedules.get((dc_a, dc_b))
        if s is None:
            s = self.bw_schedules.get((dc_b, dc_a))
        return s

    def time_varying(self) -> bool:
        """Does any WAN pair carry a non-flat bandwidth schedule?"""
        return any(not s.is_flat() for s in self.bw_schedules.values())

    def effective_bw_gbps(self, dc_a: int, dc_b: int) -> float:
        """Planning-time bandwidth of the directed pair: the *worst
        segment* of its schedule when one is attached, else the static
        link rate.  Placement decisions price a link by what it can
        guarantee, not by its best hour."""
        sched = self.bandwidth_schedule(dc_a, dc_b)
        if sched is not None:
            return sched.min_bw_gbps()
        return self.link(dc_a, dc_b).bw_gbps

    # --- schedule attachment ---------------------------------------------
    def with_bandwidth_schedules(
        self, schedules: Mapping[Pair, wan.BandwidthSchedule]
    ) -> "TopologyMatrix":
        """A copy with ``schedules`` attached (replacing any existing)."""
        return dataclasses.replace(self, bw_schedules=dict(schedules))

    def with_trace_schedules(
        self,
        *,
        hours: float = 24.0,
        samples_per_hour: int = 60,
        seed: int = 0,
    ) -> "TopologyMatrix":
        """Attach a Fig-7 measured-style trace schedule to every directed
        WAN pair.  The seed folds in the pair (and, inside the trace
        generator, the link's full-precision latency and bandwidth), so
        distinct pairs fluctuate independently while a fixed topology
        stays deterministic."""
        scheds = {
            (a, b): wan.BandwidthSchedule.from_trace(
                self.link(a, b),
                hours=hours,
                samples_per_hour=samples_per_hour,
                seed=seed * 10007 + a * self.n_dcs + b,
            )
            for a, b in self.wan_pairs()
        }
        return self.with_bandwidth_schedules(scheds)

    def with_rate_multipliers(
        self, mults: Mapping[Pair, float]
    ) -> "TopologyMatrix":
        """The *contended* view of this WAN: every directed pair in
        ``mults`` delivers ``mult ×`` its nominal rate — what one job of
        a fleet observes after the channel allocator (``repro.core
        .fleet``) grants it a fraction of each shared channel.  Latencies
        and pairs absent from ``mults`` are unchanged; an empty/identity
        ``mults`` returns ``self`` so the uncontended path keeps object
        identity (a single-job fleet must be differentially identical to
        ``control.simulate_horizon`` on the live topology).

        Every directed WAN link (and every scheduled direction) is
        materialized explicitly in the copy: the reverse-pair fallback of
        ``links``/``bw_schedules`` would otherwise alias a scaled entry
        onto its unscaled reverse direction."""
        eff = {p: m for p, m in mults.items() if m != 1.0}
        if not eff:
            return self
        assert all(m > 0.0 for m in eff.values()), eff
        links: Dict[Pair, wan.Link] = {}
        scheds: Dict[Pair, wan.BandwidthSchedule] = {}
        for a, b in self.wan_pairs():
            m = eff.get((a, b), 1.0)
            link = self.link(a, b)
            links[(a, b)] = (
                link if m == 1.0 else wan.Link(link.latency_ms, link.bw_gbps * m)
            )
            sched = self.bandwidth_schedule(a, b)
            if sched is not None:
                scheds[(a, b)] = sched.scaled(m)
        return dataclasses.replace(
            self,
            links=links,
            bw_schedules=scheds,
            name=(self.name or "topology") + "+contended",
        )

    def snapshot(self, t_ms: float, window_ms: float = 0.0) -> "TopologyMatrix":
        """The WAN as *observed* at wall time ``t_ms``: a static matrix
        whose link bandwidths are what each schedule actually delivers —
        the rate in force at ``t_ms``, or the mean over the trailing
        ``[t_ms - window_ms, t_ms)`` window when ``window_ms > 0`` (a
        short window smooths trace jitter without hiding an outage).
        Schedules are dropped: the re-planner (``repro.core.control``)
        plans on current conditions, not on a trace it has no forecast
        for.  Latencies and unscheduled pairs are unchanged."""
        links: Dict[Pair, wan.Link] = dict(self.links)
        for a, b in self.wan_pairs():
            sched = self.bandwidth_schedule(a, b)
            if sched is None:
                continue
            if window_ms > 0.0 and t_ms > 0.0:
                bw = sched.mean_bw_gbps(max(0.0, t_ms - window_ms), t_ms)
            else:
                bw = sched.bw_at(t_ms)
            links[(a, b)] = wan.Link(self.link(a, b).latency_ms, bw)
        return dataclasses.replace(
            self,
            links=links,
            bw_schedules={},
            name=(self.name or "topology") + f"@{t_ms:g}ms",
        )

    # --- helpers ---------------------------------------------------------
    def index_of(self, dc_name: str, fallback: Optional[int] = None) -> int:
        if self.dc_names and dc_name in self.dc_names:
            return self.dc_names.index(dc_name)
        if fallback is None:
            raise KeyError(dc_name)
        return fallback

    def wan_pairs(self) -> Sequence[Pair]:
        return [(a, b) for a in range(self.n_dcs) for b in range(self.n_dcs) if a != b]

    def bottleneck(self) -> wan.Link:
        """Slowest (lowest-bandwidth; ties: highest-latency) WAN link."""
        return min(
            (self.link(a, b) for a, b in self.wan_pairs()),
            key=lambda l: (l.bw_gbps, -l.latency_ms),
        )

    def best_link(self) -> wan.Link:
        """Fastest (highest-bandwidth; ties: lowest-latency) WAN link."""
        return max(
            (self.link(a, b) for a, b in self.wan_pairs()),
            key=lambda l: (l.bw_gbps, -l.latency_ms),
        )

    # --- constructors ----------------------------------------------------
    @classmethod
    def uniform(
        cls,
        n_dcs: int,
        wan_latency_ms: float = 40.0,
        multi_tcp: bool = True,
        **kw,
    ) -> "TopologyMatrix":
        return cls(
            n_dcs=n_dcs,
            default_latency_ms=wan_latency_ms,
            multi_tcp=multi_tcp,
            name=kw.pop("name", f"uniform{n_dcs}@{wan_latency_ms:g}ms"),
            **kw,
        )

    @classmethod
    def from_latency(
        cls,
        latency_ms: Sequence[Sequence[float]],
        multi_tcp: bool = True,
        **kw,
    ) -> "TopologyMatrix":
        """Square per-pair latency matrix -> per-pair links, bandwidth from
        the TCP model (multi-TCP saturates the node-pair cap; single-TCP is
        cwnd-limited by each pair's RTT — Table 1)."""
        n = len(latency_ms)
        links: Dict[Pair, wan.Link] = {}
        for a in range(n):
            assert len(latency_ms[a]) == n, "latency matrix must be square"
            for b in range(n):
                if a == b:
                    continue
                links[(a, b)] = wan.wan_link(float(latency_ms[a][b]), multi_tcp)
        return cls(n_dcs=n, links=links, multi_tcp=multi_tcp, **kw)

    @classmethod
    def from_links(cls, n_dcs: int, links: Mapping[Pair, wan.Link], **kw) -> "TopologyMatrix":
        return cls(n_dcs=n_dcs, links=dict(links), **kw)


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


def azure_testbed(multi_tcp: bool = True) -> TopologyMatrix:
    """The paper's Azure WAN (Fig 5 cities): us-east, us-south-central,
    us-west, se-asia.  Pairwise latencies from the measured distances;
    intra-US pairs are short, trans-Pacific pairs dominate."""
    #           use  ussc usw  asia
    lat = [
        [0.0, 16.0, 34.0, 95.0],
        [16.0, 0.0, 20.0, 105.0],
        [34.0, 20.0, 0.0, 85.0],
        [95.0, 105.0, 85.0, 0.0],
    ]
    return TopologyMatrix.from_latency(
        lat,
        multi_tcp=multi_tcp,
        dc_names=("us-east", "us-south-central", "us-west", "se-asia"),
        name="azure-testbed",
    )


def skewed_3dc(
    fast_ms: float = 10.0,
    slow_ms: float = 150.0,
    multi_tcp: bool = True,
) -> TopologyMatrix:
    """Three DCs where exactly one pair (0<->2) is much slower — the
    minimal heterogeneous WAN: placement must keep the slow pair off the
    pipeline's stage boundaries."""
    lat = [
        [0.0, fast_ms, slow_ms],
        [fast_ms, 0.0, fast_ms],
        [slow_ms, fast_ms, 0.0],
    ]
    # the slow pair is also single-TCP-limited: long-haul cwnd collapse
    links: Dict[Pair, wan.Link] = {}
    for a in range(3):
        for b in range(3):
            if a == b:
                continue
            slow = {a, b} == {0, 2}
            links[(a, b)] = wan.wan_link(lat[a][b], multi_tcp and not slow)
    return TopologyMatrix.from_links(
        3, links, dc_names=("dc0", "dc1", "dc2"), name="skewed-3dc"
    )


def star(n_dcs: int = 4, hub_ms: float = 15.0, multi_tcp: bool = True) -> TopologyMatrix:
    """Hub-and-spoke: DC 0 is the hub; spoke<->spoke traffic transits the
    hub (2x latency, same node-pair cap)."""
    links: Dict[Pair, wan.Link] = {}
    for a in range(n_dcs):
        for b in range(n_dcs):
            if a == b:
                continue
            ms = hub_ms if 0 in (a, b) else 2.0 * hub_ms
            links[(a, b)] = wan.wan_link(ms, multi_tcp)
    return TopologyMatrix.from_links(n_dcs, links, name=f"star{n_dcs}")


def chain(n_dcs: int = 4, hop_ms: float = 20.0, multi_tcp: bool = True) -> TopologyMatrix:
    """Linear chain (e.g. DCs along a coast): latency grows with hop
    distance, bandwidth of distant pairs decays to the single-TCP law."""
    links: Dict[Pair, wan.Link] = {}
    for a in range(n_dcs):
        for b in range(n_dcs):
            if a == b:
                continue
            d = abs(a - b)
            links[(a, b)] = wan.wan_link(d * hop_ms, multi_tcp and d == 1)
    return TopologyMatrix.from_links(n_dcs, links, name=f"chain{n_dcs}")


PRESETS = {
    "azure": azure_testbed,
    "skewed": skewed_3dc,
    "star": star,
    "chain": chain,
}


def preset(name: str, **kw) -> TopologyMatrix:
    if name.startswith("uniform"):
        return TopologyMatrix.uniform(int(name[len("uniform"):] or 3), **kw)
    return PRESETS[name](**kw)
