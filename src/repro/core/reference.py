"""Pre-refactor scheduling engine — the differential-testing reference.

This module is a verbatim snapshot of the event-driven simulator and the
Atlas list-scheduler as they stood before the fast-path rebuild
(heap-based event core, steady-state fast-forward, lazy-heap list
scheduler).  It is deliberately *slow* — per-dispatch ``ready.sort()``,
per-pump ``pend.sort()``, O(n·|avail|) scans — and deliberately frozen:

  * ``tests/test_engine_equiv.py`` asserts the optimized engine in
    ``repro.core.simulator`` produces *interval-identical* ``SimResult``s
    against this reference across a (policy × topology × M) grid;
  * ``benchmarks/sim_bench.py`` times it as the perf baseline for the
    speedup trajectory recorded in ``BENCH_sim.json``.

Do not optimize this file.  If the modelled physics change, change both
engines and the invariant checker together.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Tuple

from repro.core import wan
from repro.core.simulator import Interval, PipelineSpec, SimResult


def _priority(kind: str, micro: int, pipeline: int) -> Tuple:
    # backward (incl. its recompute) preempts queued forwards (paper §4.4
    # rule 4); earlier microbatches first; lower rank first.
    order = {"bwd": 0, "fwd": 1}
    return (order[kind], micro, pipeline)


def simulate(
    spec: PipelineSpec,
    topo,  # GeoTopology | repro.core.topology.TopologyMatrix
    *,
    policy: str = "varuna",
    n_pipelines: int = 1,
    dp_replicas_for_allreduce: int = 1,
) -> SimResult:
    """One minibatch of ``n_pipelines`` DP pipelines, pre-refactor engine."""
    assert policy in ("gpipe", "megatron", "varuna", "atlas")
    if policy == "atlas":
        return _simulate_atlas(spec, topo, n_pipelines, dp_replicas_for_allreduce)
    P, M = spec.num_stages, spec.microbatches
    recompute = spec.recompute and policy in ("gpipe", "varuna", "atlas")
    inflight_cap = spec.inflight_cap
    if inflight_cap is None:
        inflight_cap = M if policy == "gpipe" else P
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * spec.t_fwd_ms

    D = n_pipelines
    pipes = range(D)

    chan_free: Dict[Tuple, float] = {}
    chan_pending: Dict[Tuple, List[Tuple]] = {}

    def transfer_times(s_from: int, s_to: int) -> Tuple[float, float]:
        dc_a, dc_b = spec.stage_dc[s_from], spec.stage_dc[s_to]
        link = topo.link(dc_a, dc_b)
        ser = (spec.act_bytes * 8.0) / (link.bw_gbps * 1e9) * 1e3  # lint: ok[units/inline-conversion]
        return ser, link.latency_ms

    def chan_key(p: int, boundary: int, direction: str) -> Tuple:
        return (p, boundary, direction)

    gpu_free = {(p, s): 0.0 for p in pipes for s in range(P)}
    ready: Dict[Tuple[int, int], List[Tuple]] = {g: [] for g in gpu_free}
    busy: Dict[Tuple[int, int], List[Interval]] = {g: [] for g in gpu_free}
    fwd_done = {(p, s): 0 for p in pipes for s in range(P)}
    bwd_done = {(p, s): 0 for p in pipes for s in range(P)}
    fwd_barrier_release: Dict[int, float] = {}

    events: List[Tuple[float, int, str, Tuple]] = []
    seq = itertools.count()

    def push(t: float, kind: str, payload: Tuple):
        heapq.heappush(events, (t, next(seq), kind, payload))

    for p in pipes:
        for m in range(M):
            ready[(p, 0)].append(_priority("fwd", m, p) + ("fwd", m))

    def try_dispatch(g: Tuple[int, int], now: float):
        p, s = g
        if gpu_free[g] > now or not ready[g]:
            return
        ready[g].sort()
        for i, item in enumerate(ready[g]):
            kind, m = item[-2], item[-1]
            if kind == "fwd":
                if fwd_done[g] - bwd_done[g] >= inflight_cap:
                    continue
            if kind == "bwd" and policy == "gpipe":
                if fwd_barrier_release.get(p) is None:
                    continue
            ready[g].pop(i)
            if kind == "fwd":
                dur = t_f
            else:
                dur = t_b + (t_f if (recompute and s != P - 1) else 0.0)
            gpu_free[g] = now + dur
            busy[g].append(Interval(now, now + dur, kind, m))
            push(now + dur, "gpu_done", (p, s, kind, m))
            return

    def on_gpu_done(now: float, p: int, s: int, kind: str, m: int):
        g = (p, s)
        if kind == "fwd":
            fwd_done[g] += 1
            if s < P - 1:
                request_transfer(now, p, s, s + 1, "act", m)
            else:
                ready[g].append(_priority("bwd", m, p) + ("bwd", m))
            if policy == "gpipe" and s == P - 1 and fwd_done[g] == M:
                fwd_barrier_release[p] = now
                try_dispatch((p, P - 1), now)
        else:
            bwd_done[g] += 1
            if s > 0:
                request_transfer(now, p, s, s - 1, "grad", m)
        try_dispatch(g, now)

    def request_transfer(now: float, p: int, s_from: int, s_to: int, direction: str, m: int):
        boundary = min(s_from, s_to)
        key = chan_key(p, boundary, direction)
        prio = (m, 0 if direction == "grad" else 1, p)
        chan_pending.setdefault(key, []).append(prio + (p, s_from, s_to, direction, m))
        pump_channel(key, now)

    def pump_channel(key: Tuple, now: float):
        pend = chan_pending.get(key)
        if not pend or chan_free.get(key, 0.0) > now + 1e-12:
            return
        pend.sort()
        _, _, _, p, s_from, s_to, direction, m = pend.pop(0)
        ser, delay = transfer_times(s_from, s_to)
        chan_free[key] = now + ser
        push(now + ser + delay, "arrive", (p, s_to, direction, m))
        push(now + ser, "chan_free", (key,))

    def on_arrive(now: float, p: int, s: int, direction: str, m: int):
        g = (p, s)
        kind = "fwd" if direction == "act" else "bwd"
        ready[g].append(_priority(kind, m, p) + (kind, m))
        try_dispatch(g, now)

    for p in pipes:
        try_dispatch((p, 0), 0.0)

    while events:
        now, _, ev, payload = heapq.heappop(events)
        if ev == "gpu_done":
            on_gpu_done(now, *payload)
        elif ev == "arrive":
            on_arrive(now, *payload)
        elif ev == "chan_free":
            pump_channel(payload[0], now)

    pp_end = max((iv.end for ivs in busy.values() for iv in ivs), default=0.0)
    return _finish(spec, topo, busy, pp_end, D, dp_replicas_for_allreduce)


def _finish(spec, topo, busy, pp_end, D, dp_replicas) -> SimResult:
    # bubble semantics changed with the engines (see the module rule: if
    # the modelled physics change, both engines and the checker move
    # together): gaps are capped at pp_end — the trailing DP all-reduce
    # span is busy communication, not schedulable idle time
    ar = wan.allreduce_ms(
        spec.stage_param_bytes, dp_replicas, topo.intra_bw_gbps
    )
    total = pp_end + ar
    bubbles: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    busy_sum = 0.0
    for g, ivs in busy.items():
        ivs.sort(key=lambda iv: iv.start)
        gaps = []
        cur = 0.0
        for iv in ivs:
            if iv.start > cur + 1e-9:
                gaps.append((cur, iv.start))
            cur = max(cur, iv.end)
            busy_sum += iv.end - iv.start
        if cur < pp_end - 1e-9:
            gaps.append((cur, pp_end))
        bubbles[g] = gaps
    util = busy_sum / (total * len(busy)) if total > 0 else 0.0
    return SimResult(
        iteration_ms=total,
        busy=busy,
        utilization=util,
        bubbles=bubbles,
        allreduce_ms=ar,
        n_pipelines=D,
    )


def _simulate_atlas(spec, topo, n_pipelines, dp_replicas) -> SimResult:
    sched = atlas_schedule(spec, topo, n_pipelines, inflight_cap=spec.inflight_cap)
    busy: Dict[Tuple[int, int], List[Interval]] = {
        (p, s): [] for p in range(n_pipelines) for s in range(spec.num_stages)
    }
    for t in sched.tasks:
        busy[(t.pipeline, t.stage)].append(Interval(t.start, t.end, t.kind, t.micro))
    return _finish(spec, topo, busy, sched.makespan, n_pipelines, dp_replicas)


# ---------------------------------------------------------------------------
# pre-refactor Atlas list-scheduler (O(n · |avail|) full scan per pick)
# ---------------------------------------------------------------------------


def atlas_schedule(
    spec,
    topo,
    n_pipelines: int,
    *,
    inflight_cap: Optional[int] = None,
):
    from repro.core.temporal import Schedule, Task, Transfer, is_wan_boundary

    P, M, D = spec.num_stages, spec.microbatches, n_pipelines
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * t_f
    cap = inflight_cap if inflight_cap is not None else P

    def boundary_times(b: int, direction: str = "act") -> Tuple[float, float]:
        dc_a, dc_b = spec.stage_dc[b], spec.stage_dc[b + 1]
        link = topo.link(dc_a, dc_b) if direction == "act" else topo.link(dc_b, dc_a)
        ser = (spec.act_bytes * 8.0) / (link.bw_gbps * 1e9) * 1e3  # lint: ok[units/inline-conversion]
        if dc_a == dc_b:
            return ser, link.latency_ms
        hop = (spec.act_bytes * (D - 1) / D * 8.0) / (topo.intra_bw_gbps * 1e9) * 1e3  # lint: ok[units/inline-conversion]
        return ser / D, link.latency_ms + 2.0 * hop

    is_wan = [spec.stage_dc[b] != spec.stage_dc[b + 1] for b in range(P - 1)]

    gpu_free = {(p, s): 0.0 for p in range(D) for s in range(P)}
    chan_free: Dict[Tuple[int, str], float] = {}
    wan_sers = [
        boundary_times(b, d)[0]
        for b in range(P - 1)
        if is_wan_boundary(spec, topo, b)
        for d in ("act", "grad")
    ]
    slot = max(wan_sers) if wan_sers else 0.0
    avail: Dict[Tuple[str, int, int, int], float] = {}
    for p in range(D):
        for m in range(M):
            avail[("fwd", p, 0, m)] = p * slot
    fwd_sched = {(p, s): 0 for p in range(D) for s in range(P)}
    bwd_sched = {(p, s): 0 for p in range(D) for s in range(P)}

    tasks: List = []
    transfers: List = []
    n_total = D * P * M * 2
    done = 0

    def task_dur(kind: str, s: int) -> float:
        if kind == "fwd":
            return t_f
        rec = t_f if (spec.recompute and s != P - 1) else 0.0
        return t_b + rec

    def feasible_start(kind: str, p: int, s: int, m: int) -> Optional[float]:
        key = (kind, p, s, m)
        if key not in avail:
            return None
        if kind == "fwd" and fwd_sched[(p, s)] - bwd_sched[(p, s)] >= cap:
            return None
        t0 = max(avail[key], gpu_free[(p, s)])
        dur = task_dur(kind, s)
        out_b = s if kind == "fwd" else s - 1
        has_out = (kind == "fwd" and s < P - 1) or (kind == "bwd" and s > 0)
        if has_out and is_wan[out_b]:
            direction = "act" if kind == "fwd" else "grad"
            cf = chan_free.get((out_b, direction), 0.0)
            t0 = max(t0, cf - dur)
        return t0

    def emit_transfer(p, b, direction, m, ready):
        ser, delay = boundary_times(b, direction)
        if is_wan[b]:
            start = max(ready, chan_free.get((b, direction), 0.0))
            chan_free[(b, direction)] = start + ser
        else:
            start = ready
        arrive = start + ser + delay
        transfers.append(Transfer(p, b, direction, m, start, start + ser, arrive))
        dst = b + 1 if direction == "act" else b
        kind = "fwd" if direction == "act" else "bwd"
        avail[(kind, p, dst, m)] = arrive

    while done < n_total:
        best = None
        for key in list(avail.keys()):
            kind, p, s, m = key
            t0 = feasible_start(kind, p, s, m)
            if t0 is None:
                continue
            rank = (t0, 0 if kind == "bwd" else 1, m, p)
            if best is None or rank < best[0]:
                best = (rank, key, t0)
        assert best is not None, "deadlock in atlas schedule (cap too small?)"
        _, (kind, p, s, m), t0 = best
        del avail[(kind, p, s, m)]
        dur = task_dur(kind, s)
        end = t0 + dur
        gpu_free[(p, s)] = end
        tasks.append(Task(p, s, m, kind, t0, end))
        if kind == "fwd":
            fwd_sched[(p, s)] += 1
            if s < P - 1:
                emit_transfer(p, s, "act", m, end)
            else:
                avail[("bwd", p, s, m)] = end
        else:
            bwd_sched[(p, s)] += 1
            if s > 0:
                emit_transfer(p, s - 1, "grad", m, end)
        done += 1

    makespan = max(t.end for t in tasks)
    if transfers:
        makespan = max(makespan, max(tr.arrive for tr in transfers))
    return Schedule(tasks, transfers, makespan, P, D)
