"""Steady-state fast-forward — analytic skip of the periodic middle.

After pipeline fill, every schedule this repo produces (1F1B variants,
GPipe's two phases, the precomputed Atlas schedule) settles into a
*periodic* steady state: per (pipeline, stage, kind) stream the interval
of microbatch m+k is the interval of microbatch m shifted by a constant
Λ, for some small period k (k = 1 for GPipe's phases; k = the in-flight
cap for 1F1B-family schedules, whose forwards run in cap-sized bursts).
A full event replay spends O(M·P·D) events re-deriving a pattern that is
fixed after O(P·D) of them.  This module detects the pattern from short
*probe* replays of the real engine and emits the middle microbatches
analytically — the result is interval-identical to full replay
(differentially tested in ``tests/test_engine_equiv.py``), so
M=4096-microbatch GPT-3-scale specs simulate in milliseconds.

Model.  Write ``start(m | M)`` for the start of microbatch m's interval
in an M-microbatch iteration of one stream.  With a global period K (the
lcm of the per-stream periods) and probes at M1 ≡ M (mod K) and
M2 = M1 + K, the schedule fast-forwards iff every stream decomposes as::

    start(m | M) = A[m]                                  m < a     (head:
                                                         fill, M-invariant)
                 = A[a+r] + j·Λ + n·γ   r=(m-a)%K,       a ≤ m < M-t (mid:
                                        j=(m-a)//K       periodic)
                 = A[m-(M-M1)] + n·σ                     m ≥ M-t   (tail:
                                                         drain, end-anchored)

where n = (M - M1)/K extra periods, σ = makespan(M2) - makespan(M1) is
the global per-period makespan growth, Λ the stream's per-period
advance, and γ the per-extra-period shift of the whole mid block (0 for
1F1B — the mid is M-invariant; the forward-phase slot for GPipe
backwards — the barrier moves with M).  Consistency requires σ = Λ + γ
wherever a stream has both a mid and a tail.  Everything — k, a, t, Λ,
γ — is *measured* from the probes, never assumed from policy semantics,
and every constraint (head equality across probes, the periodic mid in
both probes, the σ-shifted tail) is checked explicitly.  Any mismatch —
an aperiodic schedule, a period too long for the probes, M too small to
amortize them — returns ``None`` and the caller falls back to full
event replay.

Time-varying bandwidth (``TopologyMatrix.bw_schedules``) invalidates
the whole model: a segment boundary anywhere in the iteration breaks
the constant-Λ steady state, and the short probes cannot observe
changes beyond their own horizon — ``fast_forward_gate`` therefore
refuses to probe at all when any WAN boundary carries a non-flat
schedule (recorded by the caller in ``stats["fast_forward_gate"]``);
flat schedules are interval-identical to the static engine and pass.

Probing at M ≡ M1 (mod K) matters: the drain's shape depends on where
the last microbatch lands in the period, so probes are phase-aligned
with the target before the tail is compared.  Durations are taken
verbatim from probe intervals (per-stream constants), so generated
intervals carry exactly the event engine's task durations; only starts
are extrapolated, anchored at measured probe values so float error
stays far below the invariant checker's 1e-6 EPS.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.simulator import Interval, PipelineSpec

MIN_MID = 6  # minimum mid-window length (starts) per stream
MIN_HEADROOM = 8  # auto mode: M must exceed the probes by at least this
K_MAX = 32  # give up on periods longer than this

GATE_TIME_VARYING = "time-varying-bandwidth"
GATE_REPLAN_EPOCH = "replan-epoch-boundary"


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= 1e-7 + 1e-9 * max(abs(a), abs(b))


def fast_forward_gate(
    spec: PipelineSpec, topo, *, epoch_boundary: bool = False
) -> Optional[str]:
    """A reason the fast-forward must not even be *attempted* for this
    (spec, topo), or ``None`` when probing is sound.

    Time-varying bandwidth is a hard gate rather than a detection
    failure: the probes are short-M replays whose events all land early
    in the timeline, so a bandwidth change beyond the probe horizon
    (e.g. an outage at hour 3 of a 6-hour iteration) would be invisible
    to them — the probes would "detect" a period and extrapolate
    through the change, silently diverging from full replay.  Flat
    schedules (and schedule-free topologies) keep the static engine's
    periodicity and pass.  The caller records the gate in
    ``stats["fast_forward_gate"]``.

    ``epoch_boundary`` gates the first iteration after a control-plane
    re-plan (``repro.core.control``): the placement, D, and channel
    state just changed under the job, so no steady state measured before
    the migration may be extrapolated across it — the horizon simulator
    full-replays that iteration and records ``GATE_REPLAN_EPOCH``."""
    from repro.core.simulator import has_time_varying_wan

    if epoch_boundary:
        return GATE_REPLAN_EPOCH
    if has_time_varying_wan(spec, topo):
        return GATE_TIME_VARYING
    return None


def probe_sizes(spec: PipelineSpec, n_pipelines: int) -> Tuple[int, int]:
    """(first-probe microbatches, worst-case second-probe microbatches).

    The probe must contain the fill (≈P slots + the Atlas DP stagger),
    the drain, an explicit in-flight cap's transient, and at least two
    full periods of the steady state (period ≤ max(cap, P))."""
    P = spec.num_stages
    cap = spec.inflight_cap if spec.inflight_cap is not None else P
    base = max(5 * P + 2 * n_pipelines, 3 * cap)
    m1 = base + 24
    return m1, m1 + 2 * K_MAX  # second probe is m1 + K for the detected K


def try_fast_forward(
    spec: PipelineSpec,
    run: Callable[[PipelineSpec], Tuple[Dict, float, Dict]],
    *,
    n_pipelines: int,
    force: bool = False,
) -> Optional[Tuple[Dict, float, Dict]]:
    """Attempt the fast-forward; ``None`` means: do a full replay.

    ``run(spec)`` is the raw engine — returns (busy, pipeline end, stats)
    for any microbatch count.  ``force`` attempts whenever the probes fit
    below M (used by tests); the default additionally requires enough
    headroom for the probes to be a clear win.
    """
    M = spec.microbatches
    m1a, m2_worst = probe_sizes(spec, n_pipelines)
    needed = m1a + 1 if force else m2_worst + MIN_HEADROOM
    if M < needed:
        return None

    # some schedules settle only after a long transient (e.g. 1F1B at
    # P=8 becomes period-16 around microbatch ~50): when the first probe
    # sees no period, retry once with a doubled window before giving up
    attempt = 0
    for m1a in (m1a, 2 * m1a + 32):
        attempt += 1
        needed = m1a + 1 if force else m1a + 2 * K_MAX + MIN_HEADROOM
        if M < needed:
            return None
        busy1, pp1, st1 = run(dataclasses.replace(spec, microbatches=m1a))
        streams1 = _streams(busy1, m1a)
        if streams1 is None:
            return None

        # global period K = lcm of the per-stream periods found in probe 1
        K: Optional[int] = 1
        for starts, _dur in streams1.values():
            k = _detect_period(starts)
            if k is None or K * k // math.gcd(K, k) > K_MAX:
                K = None
                break
            K = K * k // math.gcd(K, k)
        if K is not None:
            break
    if K is None:
        return None

    # phase-align: the drain's shape depends on M mod K, so compare
    # probes whose microbatch counts are congruent to the target's
    m1 = m1a + (M - m1a) % K
    m2 = m1 + K
    if M <= m2:
        return None
    if m1 != m1a:
        busy1, pp1, st1 = run(dataclasses.replace(spec, microbatches=m1))
        streams1 = _streams(busy1, m1)
        if streams1 is None:
            return None
    busy2, pp2, st2 = run(dataclasses.replace(spec, microbatches=m2))
    streams2 = _streams(busy2, m2)
    if streams2 is None or streams1.keys() != streams2.keys():
        return None
    sigma = pp2 - pp1  # makespan growth per extra period (K microbatches)

    fits: Dict[Tuple[int, int, str], Tuple[int, int, float, float]] = {}
    for skey, (starts1, dur1) in streams1.items():
        starts2, dur2 = streams2[skey]
        if not _close(dur1, dur2):
            return None
        fit = _fit_stream(starts1, starts2, K, sigma)
        if fit is None:
            return None
        fits[skey] = fit

    # generate the full-M result stream by stream, then merge per GPU
    n_extra = (M - m1) // K  # whole periods inserted into the mid
    busy: Dict[Tuple[int, int], List[List[Interval]]] = {g: [] for g in busy1}
    max_end = 0.0
    for (p, s, kind), (a, t, lam, gam) in fits.items():
        starts1, dur = streams1[(p, s, kind)]
        tail_shift = n_extra * sigma
        mid_shift = n_extra * gam
        out = []
        for m in range(M):
            if m < a:
                start = starts1[m]
            elif m < M - t:
                q, r = divmod(m - a, K)
                start = starts1[a + r] + q * lam + mid_shift
            else:
                start = starts1[m - (M - m1)] + tail_shift
            out.append(Interval(start, start + dur, kind, m))
        if out and out[-1].end > max_end:
            max_end = out[-1].end
        busy[(p, s)].append(out)

    merged = {g: _merge_streams(pair) for g, pair in busy.items()}

    # pipeline end: baselines define it as the last interval end; Atlas
    # adds trailing transfer arrivals — extrapolate those linearly.
    maxend1 = max(iv.end for ivs in busy1.values() for iv in ivs)
    if _close(pp1, maxend1):
        pp_full = max_end
    else:
        pp_full = pp1 + n_extra * sigma
        if max_end > pp_full + 1e-7:
            return None  # generated compute outruns the extrapolated makespan

    stats = {
        "engine": st1.get("engine", "?"),
        "events": st1.get("events", 0) + st2.get("events", 0),
        "fast_forward": True,
        "period": K,
        "probe_attempts": attempt,
        "probe_microbatches": (m1, m2),
        "extrapolated_microbatches": n_extra * K,
    }
    return merged, pp_full, stats


# ---------------------------------------------------------------------------


def _streams(
    busy: Dict, M: int
) -> Optional[Dict[Tuple[int, int, str], Tuple[List[float], float]]]:
    """busy -> {(p, s, kind): (starts indexed by micro, duration)}.

    Requires each stream to hold exactly microbatches 0..M-1 once, with
    starts nondecreasing in m and a constant duration — anything else is
    not a schedule we know how to extrapolate."""
    out: Dict[Tuple[int, int, str], Tuple[List[float], float]] = {}
    for (p, s), ivs in busy.items():
        per_kind: Dict[str, List[Optional[Interval]]] = {}
        for iv in ivs:
            slots = per_kind.setdefault(iv.kind, [None] * M)
            if not (0 <= iv.micro < M) or slots[iv.micro] is not None:
                return None
            slots[iv.micro] = iv
        for kind, slots in per_kind.items():
            if any(iv is None for iv in slots):
                return None
            dur = slots[0].end - slots[0].start
            starts = []
            prev = -math.inf
            for iv in slots:
                if iv.start < prev or not _close(iv.end - iv.start, dur):
                    return None
                prev = iv.start
                starts.append(iv.start)
            out[(p, s, kind)] = (starts, dur)
    return out


def _window_for_period(s: List[float], k: int) -> Optional[Tuple[int, int]]:
    """Longest contiguous window [a, b) of starts with constant k-lag
    differences (later windows win ties — the steady state sits after the
    fill).  None unless the window holds ≥ max(2k+2, MIN_MID) starts and
    leaves at most a third of the stream as drain."""
    m1 = len(s)
    n_e = m1 - k  # k-lag difference count
    if n_e < 2:
        return None
    best = (0, 0)
    lo = 0
    for i in range(1, n_e):
        if not _close(s[i + k] - s[i], s[lo + k] - s[lo]):
            if i - lo >= best[1] - best[0]:
                best = (lo, i)
            lo = i
    if n_e - lo >= best[1] - best[0]:
        best = (lo, n_e)
    a, b = best[0], best[1] + k  # starts s[a..b) follow the period
    if b - a < max(2 * k + 2, MIN_MID):
        return None
    if m1 - b > m1 // 3:
        return None  # "steady state" nowhere near the end: not a mid
    return a, b


def _detect_period(s: List[float]) -> Optional[int]:
    """Smallest period k whose k-lag differences are constant over a
    window long enough to extrapolate from."""
    for k in range(1, K_MAX + 1):
        if len(s) - k < MIN_MID:
            return None
        if _window_for_period(s, k) is not None:
            return k
    return None


def _fit_stream(
    s1: List[float], s2: List[float], K: int, sigma: float
) -> Optional[Tuple[int, int, float, float]]:
    """Fit (a, t, Λ, γ) for one stream at global period K; None = no fit."""
    m1, m2 = len(s1), len(s2)
    win = _window_for_period(s1, K)
    if win is None:
        return None
    a, b = win
    t = m1 - b
    # per-period advance Λ from the window endpoints of residue class 0
    n_per = (b - 1 - a) // K
    if n_per < 1:
        return None
    lam = (s1[a + n_per * K] - s1[a]) / n_per
    gamma = s2[a] - s1[a]  # mid-block shift per extra period (Δ = K)

    # (A) probe-1 mid is exactly the periodic pattern anchored at [a, a+K)
    for m in range(a, b):
        q, r = divmod(m - a, K)
        if not _close(s1[m], s1[a + r] + q * lam):
            return None
    # (B) probe-2 mid: same pattern, whole block shifted by γ, and it
    # extends by exactly one period
    for m in range(a, m2 - t):
        q, r = divmod(m - a, K)
        if not _close(s2[m], s1[a + r] + q * lam + gamma):
            return None
    # (C) head is M-invariant
    for m in range(a):
        if not _close(s2[m], s1[m]):
            return None
    # (D) tail is anchored to the end, shifted by the global σ
    for j in range(t):
        if not _close(s2[m2 - 1 - j], s1[m1 - 1 - j] + sigma):
            return None
    # (E) mid growth and tail shift must agree: one extra period pushes
    # the drain by exactly one mid period
    if t > 0 and not _close(sigma, lam + gamma):
        return None
    return a, t, lam, gamma


def _merge_streams(streams: List[List[Interval]]) -> List[Interval]:
    """Merge per-kind interval lists (each start-sorted) into one
    start-sorted list — any number of kinds per GPU."""
    if len(streams) == 1:
        return streams[0]
    import heapq

    return list(heapq.merge(*streams, key=lambda iv: iv.start))
