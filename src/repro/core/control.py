"""Reactive control plane — mid-training re-planning under live WAN drift.

Atlas (paper §4) plans a placement *once*, pricing every link at its
worst-segment bandwidth — but the paper's own Fig 7 premise is that WAN
bandwidth drifts over 24 h, and a static plan holds exactly as long as
the WAN resembles what the planner assumed.  This module closes the
loop: it co-simulates training over a long multi-iteration horizon
against the *live* WAN (``TopologyMatrix.bw_schedules``) and reacts when
delivery deviates from the plan:

  * ``DriftDetector`` — after each iteration, compares the bandwidth
    each monitored link actually delivered (``BandwidthSchedule
    .mean_bw_gbps`` over the iteration's wall-clock span) against what
    the incumbent plan assumed for that link.  It fires only on
    *sustained* deviation: ``hysteresis`` consecutive drifted iterations
    arm it, and a post-fire ``cooldown`` stops thrash — planned diurnal
    wiggle (live trace == planned trace) produces zero deviation and
    never fires.

  * re-planner — on a fire, snapshots the WAN as currently observed
    (``TopologyMatrix.snapshot``), re-runs Algorithm 1 on the snapshot
    (re-picking D; the branch-and-bound order search is warm-started
    from the incumbent order so ties resolve to "stay put"), and prices
    the **migration**: moving every relocated stage's weights plus
    optimizer shards over the live WAN (per directed pair the moves
    serialize on the channel and integrate across bandwidth segments;
    DP replica fan-out rides the intra-DC fabric).  The switch happens
    only when ``remaining_samples × per-sample gain > migration cost +
    margin`` — a re-plan that cannot amortize its own migration is
    declined.

  * ``simulate_horizon`` — the horizon co-simulator: every iteration is
    priced by the event engines at its absolute wall-clock offset
    (``simulate(..., start_ms=t)``), so a transfer in flight when a
    bandwidth segment flips keeps its sent bits and re-integrates the
    remainder at the new rate.  Within an epoch, an iteration whose
    full span sits inside constant-bandwidth segments (for every pair
    the placement crosses) reuses the previous simulation of the same
    rates — the horizon-level steady-state fast-forward.  The reuse is
    gated off across segment boundaries and across re-plan epoch
    boundaries (``fastforward.GATE_REPLAN_EPOCH``), so complexity is
    O((bandwidth segments + re-plans) · sim + iterations), not
    O(iterations · sim).

Progress is tracked in *samples* (one iteration of a D-cell plan
consumes ``D·C·M`` microbatches), so plans with different D remain
comparable and the horizon ends when the static plan's sample budget is
exhausted — reactive and static totals are end-to-end comparable,
migration stalls included.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs, units
from repro.core import fastforward
from repro.core.dc_selection import JobModel, PlanEntry, algorithm1, best_plan
from repro.core.failures import CheckpointPolicy, FailureTrace, OutageWindow
from repro.core.simulator import PipelineSpec, simulate
from repro.core.topology import TopologyMatrix


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Knobs of the reactive control plane (defaults are deliberately
    conservative: fire on a sustained ≥20% delivery miss, wait three
    iterations, and require the projected gain to cover the migration)."""

    drift_threshold: float = 0.2  # relative |delivered − assumed| that arms
    hysteresis: int = 3  # consecutive drifted iterations before a fire
    cooldown_iterations: int = 8  # min iterations between re-plan attempts
    min_gain_ms: float = 0.0  # extra margin the switch must clear
    snapshot_window_ms: Optional[float] = None  # None: the last iteration's span


@dataclasses.dataclass(frozen=True)
class MigrationModel:
    """What moving one pipeline stage costs.

    A stage relocation ships its weights plus the optimizer shards —
    ``opt_state_mult`` bytes of optimizer state per parameter byte
    (Adam's two moments at parameter precision by default) — over the
    live WAN via the existing transfer pricing.  Replica fan-out
    (``dp_replicas`` copies of a stage live in its DC, §4.2) streams
    over the intra-DC fabric after the WAN copy lands.

    ``checkpoint`` makes recovery checkpoint-aware: when set, every
    re-plan also prices *restore from the nearest durable checkpoint
    plus lost-work replay* (``plan_restore``) against live weight
    shipment and takes the cheaper — the only recovery path at all when
    the source DC is dead enough that shipment cannot amortize, and the
    only one that exists when a forced re-plan must shrink P (live
    shards cannot be re-partitioned in flight).
    """

    opt_state_mult: float = 2.0
    checkpoint: Optional[CheckpointPolicy] = None

    def stage_bytes(self, param_bytes: float) -> float:
        return param_bytes * (1.0 + self.opt_state_mult)


@dataclasses.dataclass
class MigrationEvent:
    """One executed re-plan: the stall window and what moved.

    ``mode`` records *how* state reached the new placement: ``"ship"``
    moves live weights stage-to-stage; ``"restore"`` pulls every stage
    from a checkpoint placement DC and forfeits ``replay_samples`` of
    progress (the samples since the ``ckpt_ms``-stamped snapshot whose
    progress was ``ckpt_samples``).  ``reason`` is ``"drift"`` for
    detector-triggered re-plans, ``"elasticity"`` for opportunistic
    post-heal/join ones, and ``"dc_outage:…"``/``"slice_preemption:…"``/
    ``"link_failure:…"`` for forced failovers."""

    at_ms: float  # wall time training paused
    duration_ms: float  # stall: max over links of WAN serialization + fan-out
    bytes_per_stage: float
    moves: List[Tuple[int, int, int]]  # (stage, src_dc, dst_dc)
    transfers: List[Tuple[int, int, float, float]]  # (src, dst, start, end)
    projected_gain_ms: float
    remaining_samples: float
    from_D: int
    to_D: int
    mode: str = "ship"
    reason: str = "drift"
    replay_samples: float = 0.0
    ckpt_ms: float = math.nan
    ckpt_samples: float = math.nan

    @property
    def wan_bytes(self) -> float:
        return self.bytes_per_stage * len(self.moves)


@dataclasses.dataclass
class EpochRecord:
    """One span of the horizon governed by a single plan."""

    index: int
    start_ms: float
    start_sample: float
    plan: PlanEntry
    spec: PipelineSpec
    n_pipelines: int  # pipelines per DP-cell (the Atlas temporal-sharing D)
    dp_replicas: int  # total DP replicas (cells × pipelines per cell)
    assumed: TopologyMatrix  # the WAN the plan priced (drift reference)
    iterations: int = 0
    end_ms: float = math.nan

    @property
    def samples_per_iteration(self) -> float:
        return float(self.dp_replicas * self.spec.microbatches)


@dataclasses.dataclass
class HorizonResult:
    total_ms: float
    samples: float
    policy: str
    epochs: List[EpochRecord]
    migrations: List[MigrationEvent]
    iteration_times: List[float]
    stats: Dict
    outages: List[OutageWindow] = dataclasses.field(default_factory=list)

    @property
    def replans(self) -> int:
        return len(self.migrations)

    @property
    def migration_ms(self) -> float:
        return sum(m.duration_ms for m in self.migrations)

    @property
    def replay_samples(self) -> float:
        return sum(m.replay_samples for m in self.migrations)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


class DriftDetector:
    """Sustained-deviation trigger with hysteresis.

    Feed it the worst per-link relative deviation of each completed
    iteration; it returns True once ``hysteresis`` consecutive
    observations exceeded ``drift_threshold`` (then resets, so the next
    fire needs a fresh streak).  One calm iteration clears the streak —
    a transient trace spike shorter than the hysteresis never fires.
    """

    def __init__(self, cfg: ControlConfig):
        self.cfg = cfg
        self.streak = 0
        self.fires = 0

    def observe(self, deviation: float) -> bool:
        if deviation > self.cfg.drift_threshold:
            self.streak += 1
        else:
            self.streak = 0
        if self.streak >= self.cfg.hysteresis:
            self.streak = 0
            self.fires += 1
            return True
        return False

    def reset(self) -> None:
        self.streak = 0


def link_deviation(
    live: TopologyMatrix, assumed, t0_ms: float, t1_ms: float
) -> float:
    """Worst relative |delivered − assumed| bandwidth across all WAN
    pairs over ``[t0_ms, t1_ms)``.  Delivery is the live schedule's
    window mean; the reference is what the incumbent plan's topology
    assumed for the same window (its own schedule's mean when the plan
    *knew* a trace — so a planned diurnal cycle deviates by exactly 0 —
    else its static link rate)."""
    worst = 0.0
    for a, b in live.wan_pairs():
        sched = live.bandwidth_schedule(a, b)
        delivered = (
            sched.mean_bw_gbps(t0_ms, t1_ms) if sched else live.link(a, b).bw_gbps
        )
        asm_sched = assumed.bandwidth_schedule(a, b)
        asm = (
            asm_sched.mean_bw_gbps(t0_ms, t1_ms)
            if asm_sched
            else assumed.link(a, b).bw_gbps
        )
        worst = max(worst, abs(delivered - asm) / asm)
    return worst


# ---------------------------------------------------------------------------
# plan -> spec, migration pricing
# ---------------------------------------------------------------------------


def plan_spec(job: JobModel, plan: PlanEntry, topo: TopologyMatrix) -> PipelineSpec:
    """The ``PipelineSpec`` a ``PlanEntry`` deploys: stages laid out in
    the plan's DC order, mapped to *topology* indices (the control plane
    requires a named topology — fleet keys are fixed WAN sites)."""
    assert topo.dc_names, "control plane needs a named topology"
    stage_dc: List[int] = []
    for dc in plan.dc_order:
        stage_dc.extend([topo.index_of(dc)] * plan.partitions.get(dc, 0))
    return PipelineSpec(
        num_stages=len(stage_dc),
        microbatches=job.microbatches,
        t_fwd_ms=job.t_fwd_ms,
        act_bytes=job.act_bytes,
        stage_dc=tuple(stage_dc),
        stage_param_bytes=job.partition_param_bytes,
        recompute=job.recompute,
        bwd_mult=job.bwd_mult,
    )


def plan_migration(
    old_stage_dc: Sequence[int],
    new_stage_dc: Sequence[int],
    *,
    param_bytes: float,
    dp_replicas_old: int,
    dp_replicas_new: int,
    topo: TopologyMatrix,
    at_ms: float,
    model: MigrationModel,
) -> MigrationEvent:
    """Price moving from one placement to another at wall time ``at_ms``.

    Every relocated stage ships ``stage_bytes`` (weights + optimizer
    shards) over its ``src → dst`` link; moves sharing a directed pair
    serialize on that channel, each priced by the bandwidth schedule in
    force at its own start (segments integrate — migrating *during* an
    outage is expensive, which is exactly the trade-off the re-planner
    weighs).  Distinct pairs run in parallel.  After the WAN copy, the
    destination DC fans the stage out to its ``dp_replicas_new``
    replicas over the intra-DC fabric; a pure D change (no relocation)
    pays only the fan-out for the extra replicas.  The stall is the
    slowest link's completion plus the slowest DC's fan-out — training
    is paused for the whole window (GPUs and links are occupied;
    ``validate.check_horizon`` asserts nothing overlaps it)."""
    stage_bytes = model.stage_bytes(param_bytes)
    moves = [
        (i, src, dst)
        for i, (src, dst) in enumerate(zip(old_stage_dc, new_stage_dc))
        if src != dst
    ]
    by_pair: Dict[Tuple[int, int], List[int]] = {}
    for i, src, dst in moves:
        by_pair.setdefault((src, dst), []).append(i)

    transfers: List[Tuple[int, int, float, float]] = []
    wan_done = 0.0
    for (src, dst), stages in sorted(by_pair.items()):
        link = topo.link(src, dst)
        sched = topo.bandwidth_schedule(src, dst)
        cur = at_ms
        for _ in stages:
            if sched is not None:
                occ = sched.transfer_ms(stage_bytes, cur)
            else:
                occ = units.serialization_ms(stage_bytes, link.bw_gbps)
            transfers.append((src, dst, cur, cur + occ))
            cur += occ
        wan_done = max(wan_done, (cur - at_ms) + link.latency_ms)

    intra_ms_one = units.serialization_ms(stage_bytes, topo.intra_bw_gbps)
    fan: Dict[int, float] = {}
    for _i, _src, dst in moves:
        fan[dst] = fan.get(dst, 0.0) + (dp_replicas_new - 1) * intra_ms_one
    if dp_replicas_new > dp_replicas_old:
        extra = dp_replicas_new - dp_replicas_old
        for i, (src, dst) in enumerate(zip(old_stage_dc, new_stage_dc)):
            if src == dst:  # unmoved stages still need the new replicas
                fan[dst] = fan.get(dst, 0.0) + extra * intra_ms_one
    fan_ms = max(fan.values(), default=0.0)

    return MigrationEvent(
        at_ms=at_ms,
        duration_ms=wan_done + fan_ms,
        bytes_per_stage=stage_bytes,
        moves=moves,
        transfers=transfers,
        projected_gain_ms=0.0,
        remaining_samples=0.0,
        from_D=dp_replicas_old,
        to_D=dp_replicas_new,
    )


def plan_restore(
    new_stage_dc: Sequence[int],
    *,
    placement_idx: Sequence[int],
    param_bytes: float,
    dp_replicas_old: int,
    dp_replicas_new: int,
    topo: TopologyMatrix,
    at_ms: float,
    model: MigrationModel,
) -> MigrationEvent:
    """Price restoring the *new* placement from checkpoint at ``at_ms``.

    Unlike ``plan_migration`` nothing moves stage-to-stage: every stage
    of the new placement pulls its ``stage_bytes`` (weights + optimizer
    shards) from the nearest *alive* checkpoint placement DC — nearest
    by a one-transfer estimate at the rate in force at ``at_ms``, so a
    placement DC behind a degraded link loses to a farther healthy one.
    Pulls sharing a directed pair serialize on the channel with full
    schedule integration (same physics ``validate.check_horizon``
    re-prices); a stage restored *in* a placement DC loads locally and
    pays only intra-DC fabric.  Fan-out mirrors ``plan_migration``:
    WAN-pulled stages replicate to the remaining ``dp_replicas_new - 1``
    replicas, local loads stream all ``dp_replicas_new`` from in-DC
    storage.  The replay debt (samples since the checkpoint) is *not*
    in the stall — the caller debits progress and the horizon re-earns
    it at the new plan's rate."""
    stage_bytes = model.stage_bytes(param_bytes)
    intra_ms_one = units.serialization_ms(stage_bytes, topo.intra_bw_gbps)
    placement = sorted(set(placement_idx))
    assert placement, "restore needs at least one alive placement DC"

    def pull_est(src: int, dst: int) -> float:
        link = topo.link(src, dst)
        sched = topo.bandwidth_schedule(src, dst)
        bw = sched.bw_at(at_ms) if sched is not None else link.bw_gbps
        return link.latency_ms + units.serialization_ms(stage_bytes, bw)

    moves: List[Tuple[int, int, int]] = []
    by_pair: Dict[Tuple[int, int], List[int]] = {}
    fan: Dict[int, float] = {}
    for i, dst in enumerate(new_stage_dc):
        if dst in placement:
            fan[dst] = fan.get(dst, 0.0) + dp_replicas_new * intra_ms_one
            continue
        src = min(placement, key=lambda p: (pull_est(p, dst), p))
        moves.append((i, src, dst))
        by_pair.setdefault((src, dst), []).append(i)
        fan[dst] = fan.get(dst, 0.0) + (dp_replicas_new - 1) * intra_ms_one

    transfers: List[Tuple[int, int, float, float]] = []
    wan_done = 0.0
    for (src, dst), stages in sorted(by_pair.items()):
        link = topo.link(src, dst)
        sched = topo.bandwidth_schedule(src, dst)
        cur = at_ms
        for _ in stages:
            if sched is not None:
                occ = sched.transfer_ms(stage_bytes, cur)
            else:
                occ = units.serialization_ms(stage_bytes, link.bw_gbps)
            transfers.append((src, dst, cur, cur + occ))
            cur += occ
        wan_done = max(wan_done, (cur - at_ms) + link.latency_ms)
    fan_ms = max(fan.values(), default=0.0)

    return MigrationEvent(
        at_ms=at_ms,
        duration_ms=wan_done + fan_ms,
        bytes_per_stage=stage_bytes,
        moves=moves,
        transfers=transfers,
        projected_gain_ms=0.0,
        remaining_samples=0.0,
        from_D=dp_replicas_old,
        to_D=dp_replicas_new,
        mode="restore",
    )


# ---------------------------------------------------------------------------
# the horizon co-simulator
# ---------------------------------------------------------------------------


def _crossing_schedules(spec: PipelineSpec, topo: TopologyMatrix):
    """Bandwidth schedules governing any directed pair this placement's
    boundaries cross (deduped, deterministic order) — the set whose
    segment boundaries invalidate iteration reuse."""
    out = []
    seen = set()
    for s in range(spec.num_stages - 1):
        for a, b in ((spec.stage_dc[s], spec.stage_dc[s + 1]),
                     (spec.stage_dc[s + 1], spec.stage_dc[s])):
            if a == b:
                continue
            sched = topo.bandwidth_schedule(a, b)
            # dedup by schedule identity, not directed pair: the
            # reverse-pair fallback hands both directions one object
            if sched is None or sched.is_flat() or id(sched) in seen:
                continue
            seen.add(id(sched))
            out.append(sched)
    return out


class HorizonRunner:
    """Stepwise horizon co-simulator — one job, one iteration per call.

    ``simulate_horizon`` drives a runner to completion against the live
    topology; the multi-job fleet (``repro.core.fleet``) interleaves N
    runners in wall-clock order and injects a *contended* topology view
    (``set_topology``) whenever the channel allocator re-partitions the
    shared WAN — every engine underneath (event simulator, Atlas
    list-scheduler, the invariant checker) then prices this job's
    transfers at contended effective bandwidth, and the drift detector
    compares contended delivery against the plan's assumption, which is
    what lets one job's re-plan trigger another's (the cascade).

    ``advance()`` runs exactly one iteration plus the control-plane
    decision for it and returns an event tag:

      ``"done"``       the sample budget is exhausted (partial last
                       iteration included);
      ``"iter"``       a plain iteration (no detector, or no deviation);
      ``"drift"``      deviation above threshold, streak still arming;
      ``"calm"``       deviation below threshold (streak cleared);
      ``"cooldown"``   the detector fired inside the cooldown window;
      ``"suppressed"`` the detector fired but the caller disallowed
                       re-planning (the fleet's cascade guard);
      ``"declined"``   a re-plan was evaluated and rejected (infeasible
                       or the migration cannot amortize);
      ``"noop"``       the re-plan kept the deployment and re-anchored
                       the drift reference;
      ``"migrated"``   a migration executed and a new epoch opened.
    """

    def __init__(
        self,
        job: JobModel,
        fleet: Dict[str, int],
        P: int,
        live_topo: TopologyMatrix,
        *,
        n_iterations: int,
        planned_topo: Optional[TopologyMatrix] = None,
        control: Optional[ControlConfig] = None,
        migration: Optional[MigrationModel] = None,
        C: Optional[int] = None,
        policy: str = "atlas",
        validate: bool = False,
        failures: Optional[FailureTrace] = None,
        checkpoint: Optional[CheckpointPolicy] = None,
        tracer=None,
        trace_label: str = "job",
    ):
        assert live_topo.dc_names, "control plane needs a named topology"
        planned = planned_topo if planned_topo is not None else live_topo
        self.job = job
        self.fleet = fleet
        self.P = P
        self.live_topo = live_topo
        self.topo = live_topo  # current pricing view (fleet may contend it)
        self.control = control
        self.mig_model = migration if migration is not None else MigrationModel()
        self.policy = policy
        self.validate = validate

        # --- tracing: iteration spans are emitted from last_result as
        # each iteration is booked (reused iterations replay the
        # representative result's intervals at their own offset);
        # migration / outage spans wait for _trace_flush because the
        # fleet's admission barrier (defer_epoch_start) can extend a
        # stall after advance() returned
        self.tracer = tracer
        self.trace_label = trace_label
        self._tracing = tracer is not None and getattr(tracer, "enabled", False)
        self._trace_flushed = False
        self._last_dev: Optional[float] = None
        self._last_tag: Optional[str] = None

        job0 = dataclasses.replace(job, topology=planned)
        if C is None:
            C = max(1, round(job0.comm_compute_ratio))
        self.C = C
        plan0 = best_plan(algorithm1(job0, fleet, P, C=C))
        if not math.isfinite(plan0.total_ms):
            raise ValueError("initial plan infeasible for this fleet/P/C")

        self.epoch = self._open_epoch(0, 0.0, 0.0, plan0, planned)
        self.epochs: List[EpochRecord] = [self.epoch]
        self.migrations: List[MigrationEvent] = []
        self.iteration_times: List[float] = []
        self.detector = DriftDetector(control) if control is not None else None
        self.stats: Dict = {
            "iter_sims": 0,
            "iter_reused": 0,
            "drift_iterations": 0,
            "drift_fires": 0,
            "replans_declined": 0,
            "replans_noop": 0,
            "replans_suppressed": 0,
            "replans_forced": 0,
            "fast_forward_gates": {},
        }
        self.samples_total = float(n_iterations) * self.epoch.samples_per_iteration
        self.t = 0.0
        self.samples = 0.0
        self.k = 0  # completed full iterations (cooldown clock)
        self.last_replan_k = -(10 ** 9)
        self._cache: Dict[Tuple, object] = {}
        self.last_result = None  # SimResult of the latest _run_iteration
        # (cache hits reuse the representative result: its busy/bubble
        # intervals are relative to iteration start, so they re-anchor at
        # any wall-clock offset — the fleet's BubbleTea loop relies on
        # this to read *contended* bubbles per iteration window)
        self._crossing = _crossing_schedules(self.epoch.spec, self.topo)
        # an empty budget is already exhausted — advance() must never
        # simulate a phantom iteration for n_iterations=0
        self._done = self.samples_total <= 1e-9

        # --- failure & elasticity state (inert when failures is None;
        # the caller is responsible for running on a live topology with
        # the trace's bandwidth consequences baked in — simulate_horizon
        # and simulate_fleet apply trace.apply_to_topology themselves)
        self.failures = failures
        self.fleet_now: Dict[str, int] = dict(fleet)
        self.dead_dcs: set = set()
        self.dead_pairs: set = set()
        self.outages: List[OutageWindow] = []
        self._timeline = failures.timeline() if failures is not None else []
        self._fail_i = 0
        self._forced_handled: Optional[str] = None  # noop'd forced reason
        self._P0 = P  # original partition count (P-fallback scales from it)
        self._job0 = job

        # --- checkpoint state: the newest *durable* snapshot is what a
        # restore rolls back to (t=0 initial weights are durable by
        # definition); stamps are wall-clock periodic, writes land
        # write_ms later (async — training does not stall for them)
        self.checkpoint = (
            checkpoint if checkpoint is not None else self.mig_model.checkpoint
        )
        if self.checkpoint is not None:
            self._ck_bytes = float(P) * self.mig_model.stage_bytes(
                job.partition_param_bytes
            )
            self._ck_write_ms = self.checkpoint.write_ms(self._ck_bytes)
            self._last_durable = (0.0, 0.0)  # (stamp_ms, samples)
            self._next_ck = self.checkpoint.interval_ms
            self._pending_cks: List[Tuple[float, float, float]] = []

    # -- plumbing ----------------------------------------------------------

    def _open_epoch(self, index, t, samples, plan, assumed) -> EpochRecord:
        spec = plan_spec(self.job, plan, self.live_topo)
        return EpochRecord(
            index=index,
            start_ms=t,
            start_sample=samples,
            plan=plan,
            spec=spec,
            n_pipelines=self.C,
            dp_replicas=plan.D * self.C,
            assumed=assumed,
        )

    @property
    def done(self) -> bool:
        return self._done

    def set_topology(self, topo: TopologyMatrix) -> None:
        """Swap the pricing view (the fleet's contended topology).  The
        iteration-reuse cache and the crossing-schedule set are tied to
        the old view and are rebuilt; passing the current view is a
        no-op so the single-job path keeps its cache across calls."""
        if topo is self.topo:
            return
        self.topo = topo
        self._cache = {}
        self._crossing = _crossing_schedules(self.epoch.spec, topo)

    def _run_iteration(self) -> float:
        t = self.t
        key = tuple(s.bw_at(t) for s in self._crossing)
        hit = self._cache.get(key)
        if hit is not None and all(
            s.constant_over(t, t + hit.iteration_ms) for s in self._crossing
        ):
            self.stats["iter_reused"] += 1
            self.last_result = hit
            return hit.iteration_ms
        # first iteration after a re-plan never extrapolates across the
        # migration (the epoch-boundary gate); otherwise the single-
        # iteration fast-forward engages whenever its own gates allow
        boundary = self.epoch.index > 0 and self.epoch.iterations == 0
        gate = fastforward.fast_forward_gate(
            self.epoch.spec, self.topo, epoch_boundary=boundary
        )
        res = simulate(
            self.epoch.spec,
            self.topo,
            policy=self.policy,
            n_pipelines=self.epoch.n_pipelines,
            dp_replicas_for_allreduce=self.epoch.dp_replicas,
            start_ms=t,
            fast_forward=False if gate is not None else None,
            validate=self.validate,
            # tracing wants every result to carry its transfer log so a
            # (possibly cache-reused) iteration re-anchors channel spans;
            # the tracer itself is NOT passed down — emission happens
            # once per *booked* iteration in advance(), not per sim call
            record_transfers=True if self._tracing else None,
        )
        self.stats["iter_sims"] += 1
        if gate is not None:
            self.stats["fast_forward_gates"][gate] = (
                self.stats["fast_forward_gates"].get(gate, 0) + 1
            )
        if all(s.constant_over(t, t + res.iteration_ms) for s in self._crossing):
            self._cache[key] = res
        self.last_result = res
        return res.iteration_ms

    # -- one iteration + its control decision ------------------------------

    def advance(self, *, allow_replan: bool = True) -> str:
        t0 = self.t
        # the iteration runs under the *incumbent* epoch's placement —
        # capture it now, a "migrated" tag swaps self.epoch before the
        # trace is emitted
        spec0 = self.epoch.spec
        self._last_dev = None
        tag = self._advance_inner(allow_replan=allow_replan)
        if self._tracing:
            self._trace_advance(t0, spec0, tag)
        self._last_tag = tag
        return tag

    def _trace_advance(self, t0: float, spec0, tag: str) -> None:
        """Emit the iteration just booked at its wall-clock start —
        GPU / bubble / allreduce spans plus channel spans from the
        result's transfer log — and the control-plane decision for it.
        The final fractional iteration emits its full window: the
        sample budget ends mid-flight, the spans show the flight."""
        res = self.last_result
        lbl = self.trace_label
        obs.trace_sim_result(
            self.tracer, res, spec0,
            label=lbl, t0_ms=t0, dc_names=self.live_topo.dc_names,
        )
        pid = f"{lbl}/control"
        t_end = t0 + res.iteration_ms  # decision time (pre-stall on "migrated")
        self.tracer.counter("iteration_ms", pid, t_end, res.iteration_ms)
        self.tracer.counter("utilization", pid, t_end, res.utilization)
        emit = tag
        if tag == "iter":
            return
        if tag == "calm":
            if self._last_tag != "drift":
                return  # plain calm iteration, not a drift streak clearing
            emit = "drift_clear"
        args: Dict = {}
        if self._last_dev is not None:
            args["deviation"] = self._last_dev
        if tag == "migrated":
            mig = self.migrations[-1]
            args.update(
                mode=mig.mode, reason=mig.reason, at_ms=mig.at_ms,
                from_D=mig.from_D, to_D=mig.to_D,
            )
        self.tracer.instant(emit, obs.CAT_CONTROL, pid, "decisions", t_end, **args)

    def _advance_inner(self, *, allow_replan: bool = True) -> str:
        assert not self._done, "horizon already exhausted"
        iter_ms = self._run_iteration()
        spi = self.epoch.samples_per_iteration
        if self.samples + spi >= self.samples_total - 1e-9:
            frac = (self.samples_total - self.samples) / spi
            self.t += iter_ms * frac
            self.samples = self.samples_total
            self.epoch.iterations += 1
            self.iteration_times.append(iter_ms)
            self._done = True
            return "done"
        self.t += iter_ms
        self.samples += spi
        self.k += 1
        self.epoch.iterations += 1
        self.iteration_times.append(iter_ms)
        self._note_checkpoints(spi)
        if self._fail_i < len(self._timeline) and (
            self._timeline[self._fail_i][0] <= self.t
        ):
            tag = self._handle_failures(allow_replan=allow_replan, iter_ms=iter_ms)
            if tag is not None:
                return tag
        if self.detector is None:
            return "iter"

        control = self.control
        dev = link_deviation(self.topo, self.epoch.assumed, self.t - iter_ms, self.t)
        self._last_dev = dev
        drifted = dev > control.drift_threshold
        self.stats["drift_iterations"] += int(drifted)
        if not self.detector.observe(dev):
            return "drift" if drifted else "calm"
        self.stats["drift_fires"] += 1
        if self.k - self.last_replan_k < control.cooldown_iterations:
            return "cooldown"
        if not allow_replan:
            # the fleet's cascade guard: the fire is real but this round
            # of the cascade is over budget — treat like a declined
            # attempt (the cooldown clock resets, the budget pressure
            # cannot re-fire every iteration)
            self.last_replan_k = self.k
            self.stats["replans_suppressed"] += 1
            return "suppressed"
        self.last_replan_k = self.k
        return self._attempt_replan(iter_ms=iter_ms, forced=False, reason="drift")

    # -- failure & elasticity ----------------------------------------------

    def _alive_fleet(self) -> Dict[str, int]:
        """The per-DC slices with capacity right now; dead DCs are
        excluded at the Algorithm-1 layer (``exclude_dcs``), not here —
        their GPUs are unreachable, not merely shrunk."""
        return {dc: g for dc, g in self.fleet_now.items() if g > 0}

    def _close_window(self, kind: str, *, dc=None, pair=None) -> None:
        for w in reversed(self.outages):
            if (
                w.kind == kind and w.dc == dc and w.pair == pair
                and math.isinf(w.t1_ms)
            ):
                w.t1_ms = self.t
                return

    def _forced_reason(self) -> Optional[str]:
        """Why the incumbent deployment can no longer run, or None.
        Checked against the *current* epoch: a dead DC hosting stages, a
        preempted slice below the plan's per-DC GPU need (partitions ×
        D × C), or a stage boundary riding a failed link."""
        spec = self.epoch.spec
        used = set(spec.stage_dc)
        for dc in sorted(self.dead_dcs):
            if self.live_topo.index_of(dc) in used:
                return f"dc_outage:{dc}"
        for dc, parts in sorted(self.epoch.plan.partitions.items()):
            if parts <= 0 or dc in self.dead_dcs:
                continue
            if self.fleet_now.get(dc, 0) < parts * self.epoch.dp_replicas:
                return f"slice_preemption:{dc}"
        for fs in sorted(self.dead_pairs, key=sorted):
            a, b = sorted(fs)
            ia, ib = self.live_topo.index_of(a), self.live_topo.index_of(b)
            for s in range(spec.num_stages - 1):
                if {spec.stage_dc[s], spec.stage_dc[s + 1]} == {ia, ib}:
                    return f"link_failure:{a}-{b}"
        return None

    def _handle_failures(self, *, allow_replan: bool, iter_ms: float) -> Optional[str]:
        """Consume every timeline step due by now, then react once: a
        forced failover if the incumbent can no longer run (ignores the
        cascade guard and cooldown — survival is not optional), else an
        opportunistic re-plan after a heal/join (control plane only,
        normal gain gating).  Outage windows open/close at *handled*
        time — iteration granularity, matching what actually ran.
        Returns an event tag for ``advance`` or None to fall through to
        drift detection."""
        healed = joined = False
        while self._fail_i < len(self._timeline) and (
            self._timeline[self._fail_i][0] <= self.t
        ):
            _te, phase, ev = self._timeline[self._fail_i]
            self._fail_i += 1
            self._forced_handled = None
            if phase == "apply":
                if ev.kind == "dc_outage":
                    self.dead_dcs.add(ev.dc)
                    self.outages.append(
                        OutageWindow("dc_outage", t0_ms=self.t, dc=ev.dc)
                    )
                elif ev.kind == "link_failure":
                    self.dead_pairs.add(frozenset(ev.pair))
                    self.outages.append(
                        OutageWindow("link_failure", t0_ms=self.t,
                                     pair=tuple(ev.pair))
                    )
                elif ev.kind == "slice_preemption":
                    self.fleet_now[ev.dc] = max(
                        0, self.fleet_now.get(ev.dc, 0) - ev.gpus
                    )
                else:  # dc_join
                    self.fleet_now[ev.dc] = self.fleet_now.get(ev.dc, 0) + ev.gpus
                    joined = True
            else:  # heal
                healed = True
                if ev.kind == "dc_outage":
                    self.dead_dcs.discard(ev.dc)
                    self._close_window("dc_outage", dc=ev.dc)
                elif ev.kind == "link_failure":
                    self.dead_pairs.discard(frozenset(ev.pair))
                    self._close_window("link_failure", pair=tuple(ev.pair))
                else:  # slice_preemption returns
                    self.fleet_now[ev.dc] = self.fleet_now.get(ev.dc, 0) + ev.gpus

        reason = self._forced_reason()
        if reason is not None and reason != self._forced_handled:
            self.stats["replans_forced"] += 1
            self.last_replan_k = self.k
            tag = self._attempt_replan(iter_ms=iter_ms, forced=True, reason=reason)
            if tag == "noop":
                # bnb kept the incumbent (no viable alternative, e.g. a
                # failed link on a two-DC WAN): remember so the forced
                # path doesn't re-run Algorithm 1 every iteration until
                # the failure state actually changes
                self._forced_handled = reason
            return tag
        if (healed or joined) and self.control is not None:
            if not allow_replan:
                self.stats["replans_suppressed"] += 1
                self.last_replan_k = self.k
                return "suppressed"
            self.last_replan_k = self.k
            return self._attempt_replan(
                iter_ms=iter_ms, forced=False, reason="elasticity"
            )
        return None

    def _note_checkpoints(self, spi: float) -> None:
        """Stamp the checkpoints due by now and promote landed writes.
        A stamp strictly inside the just-finished iteration captures the
        *previous* optimizer step (``samples − spi``: no mid-iteration
        state exists); the async write lands ``write_ms`` later, and
        only a landed write is a restore point."""
        ck = self.checkpoint
        if ck is None:
            return
        while self._next_ck <= self.t + 1e-9:
            stamp = self._next_ck
            snap_samples = (
                self.samples - spi if stamp < self.t - 1e-9 else self.samples
            )
            self._pending_cks.append(
                (stamp + self._ck_write_ms, stamp, max(0.0, snap_samples))
            )
            if self._tracing:
                self.tracer.instant(
                    "checkpoint_stamp", obs.CAT_CONTROL,
                    f"{self.trace_label}/control", "checkpoints", stamp,
                    samples=max(0.0, snap_samples),
                )
            self._next_ck += ck.interval_ms
        while self._pending_cks and self._pending_cks[0][0] <= self.t + 1e-9:
            durable_at, stamp, s = self._pending_cks.pop(0)
            self._last_durable = (stamp, s)
            if self._tracing:
                self.tracer.instant(
                    "checkpoint_durable", obs.CAT_CONTROL,
                    f"{self.trace_label}/control", "checkpoints", durable_at,
                    stamp_ms=stamp, samples=s,
                )

    # -- the re-plan attempt (drift, elasticity, and forced failover) ------

    def _job_for_P(self, P_try: int) -> JobModel:
        """The job re-partitioned into ``P_try`` layer-partitions: each
        partition holds ``P0/P_try ×`` the layers, so per-partition
        weights and forward time scale together; boundary activations
        and the microbatch count are partition-size-independent."""
        if P_try == self.P:
            return self.job
        scale = self._P0 / P_try
        return dataclasses.replace(
            self._job0,
            partition_param_bytes=self._job0.partition_param_bytes * scale,
            t_fwd_ms=self._job0.t_fwd_ms * scale,
        )

    def _attempt_replan(self, *, iter_ms: float, forced: bool, reason: str) -> str:
        """Re-run Algorithm 1 on the observed WAN over the surviving
        fleet and execute the cheaper of live-weight shipment vs
        checkpoint restore (+ replay debt) when the switch pays for
        itself — forced failovers skip the gain test (the incumbent
        cannot run at all) and may shrink P when no placement at the
        current partition count survives (divisors of the original P,
        largest first; shrinking P requires a checkpoint — live shards
        cannot be re-partitioned in flight)."""
        control = self.control
        t = self.t
        window = control.snapshot_window_ms if control is not None else None
        snap = self.topo.snapshot(t, window_ms=iter_ms if window is None else window)
        alive = self._alive_fleet()
        if forced:
            P_candidates = [
                p for p in range(self._P0, 0, -1)
                if self._P0 % p == 0 and p <= self.P
            ]
        else:
            P_candidates = [self.P]
        cand = cand_P = job_p = None
        surviving = {dc for dc in alive if dc not in self.dead_dcs}
        for P_try in P_candidates:
            if not surviving:
                break
            job_try = self._job_for_P(P_try)
            job_s = dataclasses.replace(job_try, topology=snap)
            incumbent = self.epoch.plan.dc_order if P_try == self.P else None
            c = best_plan(
                algorithm1(
                    job_s, alive, P_try, C=self.C,
                    incumbent_order=incumbent,
                    exclude_dcs=sorted(self.dead_dcs) if self.dead_dcs else None,
                )
            )
            if math.isfinite(c.total_ms):
                cand, cand_P, job_p = c, P_try, job_try
                break
        if cand is None:
            if forced:
                raise ValueError(
                    f"forced failover ({reason}): no feasible placement "
                    f"survives on fleet {alive} at any P in {P_candidates}"
                )
            self.stats["replans_declined"] += 1
            return "declined"
        cand_spec = plan_spec(job_p, cand, self.live_topo)
        if (
            cand_P == self.P
            and cand_spec.stage_dc == self.epoch.spec.stage_dc
            and cand.D == self.epoch.plan.D
        ):
            # same deployment under current conditions: re-anchor the
            # drift reference so the detector stops firing on a change
            # the plan already tolerates best
            self.epoch.assumed = snap
            self.stats["replans_noop"] += 1
            return "noop"

        # price the recovery modes: live shipment (stage-to-stage, only
        # meaningful at unchanged P) vs checkpoint restore + replay
        dp_new = cand.D * self.C
        options: List[Tuple[str, MigrationEvent, float]] = []
        if cand_P == self.P:
            options.append((
                "ship",
                plan_migration(
                    self.epoch.spec.stage_dc,
                    cand_spec.stage_dc,
                    param_bytes=job_p.partition_param_bytes,
                    dp_replicas_old=self.epoch.dp_replicas,
                    dp_replicas_new=dp_new,
                    topo=self.topo,
                    at_ms=t,
                    model=self.mig_model,
                ),
                0.0,
            ))
        ck = None
        if self.checkpoint is not None:
            placement_alive = self.checkpoint.alive_placement(self.dead_dcs)
            if placement_alive:
                ck = self._last_durable
                options.append((
                    "restore",
                    plan_restore(
                        cand_spec.stage_dc,
                        placement_idx=[
                            self.live_topo.index_of(d) for d in placement_alive
                        ],
                        param_bytes=job_p.partition_param_bytes,
                        dp_replicas_old=self.epoch.dp_replicas,
                        dp_replicas_new=dp_new,
                        topo=self.topo,
                        at_ms=t,
                        model=self.mig_model,
                    ),
                    max(0.0, self.samples - ck[1]),
                ))
        if not options:
            if forced:
                raise ValueError(
                    f"forced failover ({reason}) must shrink P to {cand_P} "
                    "but no checkpoint policy is configured — live shards "
                    "cannot be re-partitioned in flight"
                )
            self.stats["replans_declined"] += 1
            return "declined"

        best = None
        for mode, mig, replay in options:
            cand_res = simulate(
                cand_spec,
                self.topo,
                policy=self.policy,
                n_pipelines=self.C,
                dp_replicas_for_allreduce=dp_new,
                start_ms=t + mig.duration_ms,
            )
            cand_per_sample = cand_res.iteration_ms / (
                dp_new * job_p.microbatches
            )
            # effective cost: the stall plus the wall time to re-earn
            # the forfeited samples at the candidate's own rate
            cost = mig.duration_ms + replay * cand_per_sample
            if best is None or cost < best[4]:
                best = (mode, mig, replay, cand_per_sample, cost)
        mode, mig, replay, cand_per_sample, cost = best
        inc_per_sample = iter_ms / self.epoch.samples_per_iteration
        remaining = self.samples_total - self.samples
        gain = remaining * (inc_per_sample - cand_per_sample)
        if not forced and gain <= cost + control.min_gain_ms:
            self.stats["replans_declined"] += 1
            return "declined"

        mig.projected_gain_ms = gain
        mig.remaining_samples = remaining
        mig.reason = reason
        self.migrations.append(mig)
        self.epoch.end_ms = t
        self.t = t + mig.duration_ms
        if mode == "restore":
            mig.replay_samples = replay
            mig.ckpt_ms, mig.ckpt_samples = ck
            self.samples = ck[1]
            # in-flight snapshot writes die with the old deployment; the
            # cadence restarts from the restore point
            self._pending_cks = []
            self._next_ck = self.t + self.checkpoint.interval_ms
        if cand_P != self.P:
            self.P = cand_P
            self.job = job_p
        self.epoch = self._open_epoch(
            self.epoch.index + 1, self.t, self.samples, cand, snap
        )
        self.epochs.append(self.epoch)
        if self.detector is not None:
            self.detector.reset()
        self._cache = {}
        self._crossing = _crossing_schedules(self.epoch.spec, self.topo)
        self._forced_handled = None
        return "migrated"

    def defer_epoch_start(self, new_t_ms: float) -> None:
        """Admission barrier hook for the fleet: extend the migration
        stall that just opened the current epoch so the epoch starts at
        ``new_t_ms`` — a job migrating *onto* channels other jobs hold
        in-flight windows on waits for those windows to drain before its
        first contended iteration.  Epoch/migration tiling is preserved
        (the wait is part of the stall; ``validate.check_horizon`` still
        holds) and the migration's transfers stay inside the window."""
        assert self.migrations and self.epoch.iterations == 0, (
            "defer_epoch_start only applies to a freshly migrated epoch"
        )
        assert abs(self.epoch.start_ms - self.t) < 1e-9
        if new_t_ms <= self.t:
            return
        self.migrations[-1].duration_ms += new_t_ms - self.t
        self.t = new_t_ms
        self.epoch.start_ms = new_t_ms

    def _trace_flush(self) -> None:
        """One-shot end-of-run emission of everything whose extent is
        only final at horizon end: migration stall spans (the fleet's
        admission barrier may have extended them via
        ``defer_epoch_start``), per-lane ``migration-stall`` GPU spans
        on the *new* epoch's lane grid, and outage windows (still-open
        windows clamp to the horizon end)."""
        if not self._tracing or self._trace_flushed:
            return
        self._trace_flushed = True
        tr = self.tracer
        lbl = self.trace_label
        pid = f"{lbl}/control"
        # migration i opened epoch i+1 — its stall stands on that
        # epoch's lane grid (n_pipelines × stages matches busy keys on
        # every engine path)
        for mig, ep in zip(self.migrations, self.epochs[1:]):
            t1 = mig.at_ms + mig.duration_ms
            tr.span(
                f"migration:{mig.mode}", obs.CAT_CONTROL, pid, "migrations",
                mig.at_ms, t1,
                reason=mig.reason, from_D=mig.from_D, to_D=mig.to_D,
                moves=len(mig.moves), wan_bytes=mig.wan_bytes,
                replay_samples=mig.replay_samples,
                projected_gain_ms=mig.projected_gain_ms,
                duration_ms=mig.duration_ms,
            )
            for p in range(ep.n_pipelines):
                for s in range(ep.spec.num_stages):
                    tr.span(
                        "migration-stall", obs.CAT_GPU, f"{lbl}/gpu",
                        f"p{p}/s{s}", mig.at_ms, t1, dc=ep.spec.stage_dc[s],
                    )
        for w in self.outages:
            t1 = self.t if math.isinf(w.t1_ms) else w.t1_ms
            tr.span(
                f"outage:{w.kind}", obs.CAT_CONTROL, pid, "failures",
                w.t0_ms, t1, **w.trace_args(self.live_topo),
            )

    def result(self) -> HorizonResult:
        self.epoch.end_ms = self.t
        self._trace_flush()
        return HorizonResult(
            total_ms=self.t,
            samples=self.samples,
            policy=self.policy,
            epochs=self.epochs,
            migrations=self.migrations,
            iteration_times=self.iteration_times,
            stats=self.stats,
            outages=self.outages,
        )


def simulate_horizon(
    job: JobModel,
    fleet: Dict[str, int],
    P: int,
    live_topo: TopologyMatrix,
    *,
    n_iterations: int,
    planned_topo: Optional[TopologyMatrix] = None,
    control: Optional[ControlConfig] = None,
    migration: Optional[MigrationModel] = None,
    C: Optional[int] = None,
    policy: str = "atlas",
    validate: bool = False,
    failures: Optional[FailureTrace] = None,
    checkpoint: Optional[CheckpointPolicy] = None,
    tracer=None,
    trace_label: str = "job",
) -> HorizonResult:
    """Co-simulate ``n_iterations`` (of the initial plan's global batch)
    against the live WAN, optionally with the reactive control plane.

    ``planned_topo`` is what Algorithm 1 believed at t=0 (default: the
    live topology — the planner knew the whole trace); the live/planned
    split is how an *unplanned* outage is modelled.  ``control=None``
    runs the static PR-3 behaviour — plan once, never react — so the
    same call is both arms of the reactive-vs-static comparison.  ``C``
    (pipelines per DP-cell) is pinned across re-plans: re-sizing a cell
    is a full re-shard, not a migration; D is re-picked freely.

    ``failures`` injects a seeded ``FailureTrace``: its bandwidth
    consequences are baked into the live topology here
    (``apply_to_topology`` — the planner still prices the *raw* WAN, so
    failures are always unplanned), and its apply/heal steps drive
    forced failovers and opportunistic elasticity re-plans inside the
    runner.  ``checkpoint`` (or ``migration.checkpoint``) makes those
    recoveries checkpoint-aware.

    This is the single-job driver of ``HorizonRunner``; the multi-job
    fleet (``repro.core.fleet.simulate_fleet``) interleaves several
    runners over one shared WAN and is differentially identical to this
    function when the fleet has exactly one job.
    """
    if failures is not None and len(failures):
        if planned_topo is None:
            planned_topo = live_topo
        live_topo = failures.apply_to_topology(live_topo)
    runner = HorizonRunner(
        job, fleet, P, live_topo,
        n_iterations=n_iterations,
        planned_topo=planned_topo,
        control=control,
        migration=migration,
        C=C,
        policy=policy,
        validate=validate,
        failures=failures,
        checkpoint=checkpoint,
        tracer=tracer,
        trace_label=trace_label,
    )
    while not runner.done:
        runner.advance()
    return runner.result()
