"""Reactive control plane — mid-training re-planning under live WAN drift.

Atlas (paper §4) plans a placement *once*, pricing every link at its
worst-segment bandwidth — but the paper's own Fig 7 premise is that WAN
bandwidth drifts over 24 h, and a static plan holds exactly as long as
the WAN resembles what the planner assumed.  This module closes the
loop: it co-simulates training over a long multi-iteration horizon
against the *live* WAN (``TopologyMatrix.bw_schedules``) and reacts when
delivery deviates from the plan:

  * ``DriftDetector`` — after each iteration, compares the bandwidth
    each monitored link actually delivered (``BandwidthSchedule
    .mean_bw_gbps`` over the iteration's wall-clock span) against what
    the incumbent plan assumed for that link.  It fires only on
    *sustained* deviation: ``hysteresis`` consecutive drifted iterations
    arm it, and a post-fire ``cooldown`` stops thrash — planned diurnal
    wiggle (live trace == planned trace) produces zero deviation and
    never fires.

  * re-planner — on a fire, snapshots the WAN as currently observed
    (``TopologyMatrix.snapshot``), re-runs Algorithm 1 on the snapshot
    (re-picking D; the branch-and-bound order search is warm-started
    from the incumbent order so ties resolve to "stay put"), and prices
    the **migration**: moving every relocated stage's weights plus
    optimizer shards over the live WAN (per directed pair the moves
    serialize on the channel and integrate across bandwidth segments;
    DP replica fan-out rides the intra-DC fabric).  The switch happens
    only when ``remaining_samples × per-sample gain > migration cost +
    margin`` — a re-plan that cannot amortize its own migration is
    declined.

  * ``simulate_horizon`` — the horizon co-simulator: every iteration is
    priced by the event engines at its absolute wall-clock offset
    (``simulate(..., start_ms=t)``), so a transfer in flight when a
    bandwidth segment flips keeps its sent bits and re-integrates the
    remainder at the new rate.  Within an epoch, an iteration whose
    full span sits inside constant-bandwidth segments (for every pair
    the placement crosses) reuses the previous simulation of the same
    rates — the horizon-level steady-state fast-forward.  The reuse is
    gated off across segment boundaries and across re-plan epoch
    boundaries (``fastforward.GATE_REPLAN_EPOCH``), so complexity is
    O((bandwidth segments + re-plans) · sim + iterations), not
    O(iterations · sim).

Progress is tracked in *samples* (one iteration of a D-cell plan
consumes ``D·C·M`` microbatches), so plans with different D remain
comparable and the horizon ends when the static plan's sample budget is
exhausted — reactive and static totals are end-to-end comparable,
migration stalls included.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import fastforward
from repro.core.dc_selection import JobModel, PlanEntry, algorithm1, best_plan
from repro.core.simulator import PipelineSpec, simulate
from repro.core.topology import TopologyMatrix


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Knobs of the reactive control plane (defaults are deliberately
    conservative: fire on a sustained ≥20% delivery miss, wait three
    iterations, and require the projected gain to cover the migration)."""

    drift_threshold: float = 0.2  # relative |delivered − assumed| that arms
    hysteresis: int = 3  # consecutive drifted iterations before a fire
    cooldown_iterations: int = 8  # min iterations between re-plan attempts
    min_gain_ms: float = 0.0  # extra margin the switch must clear
    snapshot_window_ms: Optional[float] = None  # None: the last iteration's span


@dataclasses.dataclass(frozen=True)
class MigrationModel:
    """What moving one pipeline stage costs.

    A stage relocation ships its weights plus the optimizer shards —
    ``opt_state_mult`` bytes of optimizer state per parameter byte
    (Adam's two moments at parameter precision by default) — over the
    live WAN via the existing transfer pricing.  Replica fan-out
    (``dp_replicas`` copies of a stage live in its DC, §4.2) streams
    over the intra-DC fabric after the WAN copy lands.
    """

    opt_state_mult: float = 2.0

    def stage_bytes(self, param_bytes: float) -> float:
        return param_bytes * (1.0 + self.opt_state_mult)


@dataclasses.dataclass
class MigrationEvent:
    """One executed re-plan: the stall window and what moved."""

    at_ms: float  # wall time training paused
    duration_ms: float  # stall: max over links of WAN serialization + fan-out
    bytes_per_stage: float
    moves: List[Tuple[int, int, int]]  # (stage, src_dc, dst_dc)
    transfers: List[Tuple[int, int, float, float]]  # (src, dst, start, end)
    projected_gain_ms: float
    remaining_samples: float
    from_D: int
    to_D: int

    @property
    def wan_bytes(self) -> float:
        return self.bytes_per_stage * len(self.moves)


@dataclasses.dataclass
class EpochRecord:
    """One span of the horizon governed by a single plan."""

    index: int
    start_ms: float
    start_sample: float
    plan: PlanEntry
    spec: PipelineSpec
    n_pipelines: int  # pipelines per DP-cell (the Atlas temporal-sharing D)
    dp_replicas: int  # total DP replicas (cells × pipelines per cell)
    assumed: TopologyMatrix  # the WAN the plan priced (drift reference)
    iterations: int = 0
    end_ms: float = math.nan

    @property
    def samples_per_iteration(self) -> float:
        return float(self.dp_replicas * self.spec.microbatches)


@dataclasses.dataclass
class HorizonResult:
    total_ms: float
    samples: float
    policy: str
    epochs: List[EpochRecord]
    migrations: List[MigrationEvent]
    iteration_times: List[float]
    stats: Dict

    @property
    def replans(self) -> int:
        return len(self.migrations)

    @property
    def migration_ms(self) -> float:
        return sum(m.duration_ms for m in self.migrations)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


class DriftDetector:
    """Sustained-deviation trigger with hysteresis.

    Feed it the worst per-link relative deviation of each completed
    iteration; it returns True once ``hysteresis`` consecutive
    observations exceeded ``drift_threshold`` (then resets, so the next
    fire needs a fresh streak).  One calm iteration clears the streak —
    a transient trace spike shorter than the hysteresis never fires.
    """

    def __init__(self, cfg: ControlConfig):
        self.cfg = cfg
        self.streak = 0
        self.fires = 0

    def observe(self, deviation: float) -> bool:
        if deviation > self.cfg.drift_threshold:
            self.streak += 1
        else:
            self.streak = 0
        if self.streak >= self.cfg.hysteresis:
            self.streak = 0
            self.fires += 1
            return True
        return False

    def reset(self) -> None:
        self.streak = 0


def link_deviation(
    live: TopologyMatrix, assumed, t0_ms: float, t1_ms: float
) -> float:
    """Worst relative |delivered − assumed| bandwidth across all WAN
    pairs over ``[t0_ms, t1_ms)``.  Delivery is the live schedule's
    window mean; the reference is what the incumbent plan's topology
    assumed for the same window (its own schedule's mean when the plan
    *knew* a trace — so a planned diurnal cycle deviates by exactly 0 —
    else its static link rate)."""
    worst = 0.0
    for a, b in live.wan_pairs():
        sched = live.bandwidth_schedule(a, b)
        obs = sched.mean_bw_gbps(t0_ms, t1_ms) if sched else live.link(a, b).bw_gbps
        asm_sched = assumed.bandwidth_schedule(a, b)
        asm = (
            asm_sched.mean_bw_gbps(t0_ms, t1_ms)
            if asm_sched
            else assumed.link(a, b).bw_gbps
        )
        worst = max(worst, abs(obs - asm) / asm)
    return worst


# ---------------------------------------------------------------------------
# plan -> spec, migration pricing
# ---------------------------------------------------------------------------


def plan_spec(job: JobModel, plan: PlanEntry, topo: TopologyMatrix) -> PipelineSpec:
    """The ``PipelineSpec`` a ``PlanEntry`` deploys: stages laid out in
    the plan's DC order, mapped to *topology* indices (the control plane
    requires a named topology — fleet keys are fixed WAN sites)."""
    assert topo.dc_names, "control plane needs a named topology"
    stage_dc: List[int] = []
    for dc in plan.dc_order:
        stage_dc.extend([topo.index_of(dc)] * plan.partitions.get(dc, 0))
    return PipelineSpec(
        num_stages=len(stage_dc),
        microbatches=job.microbatches,
        t_fwd_ms=job.t_fwd_ms,
        act_bytes=job.act_bytes,
        stage_dc=tuple(stage_dc),
        stage_param_bytes=job.partition_param_bytes,
        recompute=job.recompute,
        bwd_mult=job.bwd_mult,
    )


def plan_migration(
    old_stage_dc: Sequence[int],
    new_stage_dc: Sequence[int],
    *,
    param_bytes: float,
    dp_replicas_old: int,
    dp_replicas_new: int,
    topo: TopologyMatrix,
    at_ms: float,
    model: MigrationModel,
) -> MigrationEvent:
    """Price moving from one placement to another at wall time ``at_ms``.

    Every relocated stage ships ``stage_bytes`` (weights + optimizer
    shards) over its ``src → dst`` link; moves sharing a directed pair
    serialize on that channel, each priced by the bandwidth schedule in
    force at its own start (segments integrate — migrating *during* an
    outage is expensive, which is exactly the trade-off the re-planner
    weighs).  Distinct pairs run in parallel.  After the WAN copy, the
    destination DC fans the stage out to its ``dp_replicas_new``
    replicas over the intra-DC fabric; a pure D change (no relocation)
    pays only the fan-out for the extra replicas.  The stall is the
    slowest link's completion plus the slowest DC's fan-out — training
    is paused for the whole window (GPUs and links are occupied;
    ``validate.check_horizon`` asserts nothing overlaps it)."""
    stage_bytes = model.stage_bytes(param_bytes)
    moves = [
        (i, src, dst)
        for i, (src, dst) in enumerate(zip(old_stage_dc, new_stage_dc))
        if src != dst
    ]
    by_pair: Dict[Tuple[int, int], List[int]] = {}
    for i, src, dst in moves:
        by_pair.setdefault((src, dst), []).append(i)

    transfers: List[Tuple[int, int, float, float]] = []
    wan_done = 0.0
    for (src, dst), stages in sorted(by_pair.items()):
        link = topo.link(src, dst)
        sched = topo.bandwidth_schedule(src, dst)
        cur = at_ms
        for _ in stages:
            if sched is not None:
                occ = sched.transfer_ms(stage_bytes, cur)
            else:
                occ = stage_bytes * 8.0 / (link.bw_gbps * 1e9) * 1e3
            transfers.append((src, dst, cur, cur + occ))
            cur += occ
        wan_done = max(wan_done, (cur - at_ms) + link.latency_ms)

    intra_ms_one = stage_bytes * 8.0 / (topo.intra_bw_gbps * 1e9) * 1e3
    fan: Dict[int, float] = {}
    for _i, _src, dst in moves:
        fan[dst] = fan.get(dst, 0.0) + (dp_replicas_new - 1) * intra_ms_one
    if dp_replicas_new > dp_replicas_old:
        extra = dp_replicas_new - dp_replicas_old
        for i, (src, dst) in enumerate(zip(old_stage_dc, new_stage_dc)):
            if src == dst:  # unmoved stages still need the new replicas
                fan[dst] = fan.get(dst, 0.0) + extra * intra_ms_one
    fan_ms = max(fan.values(), default=0.0)

    return MigrationEvent(
        at_ms=at_ms,
        duration_ms=wan_done + fan_ms,
        bytes_per_stage=stage_bytes,
        moves=moves,
        transfers=transfers,
        projected_gain_ms=0.0,
        remaining_samples=0.0,
        from_D=dp_replicas_old,
        to_D=dp_replicas_new,
    )


# ---------------------------------------------------------------------------
# the horizon co-simulator
# ---------------------------------------------------------------------------


def _crossing_schedules(spec: PipelineSpec, topo: TopologyMatrix):
    """Bandwidth schedules governing any directed pair this placement's
    boundaries cross (deduped, deterministic order) — the set whose
    segment boundaries invalidate iteration reuse."""
    out = []
    seen = set()
    for s in range(spec.num_stages - 1):
        for a, b in ((spec.stage_dc[s], spec.stage_dc[s + 1]),
                     (spec.stage_dc[s + 1], spec.stage_dc[s])):
            if a == b:
                continue
            sched = topo.bandwidth_schedule(a, b)
            # dedup by schedule identity, not directed pair: the
            # reverse-pair fallback hands both directions one object
            if sched is None or sched.is_flat() or id(sched) in seen:
                continue
            seen.add(id(sched))
            out.append(sched)
    return out


class HorizonRunner:
    """Stepwise horizon co-simulator — one job, one iteration per call.

    ``simulate_horizon`` drives a runner to completion against the live
    topology; the multi-job fleet (``repro.core.fleet``) interleaves N
    runners in wall-clock order and injects a *contended* topology view
    (``set_topology``) whenever the channel allocator re-partitions the
    shared WAN — every engine underneath (event simulator, Atlas
    list-scheduler, the invariant checker) then prices this job's
    transfers at contended effective bandwidth, and the drift detector
    compares contended delivery against the plan's assumption, which is
    what lets one job's re-plan trigger another's (the cascade).

    ``advance()`` runs exactly one iteration plus the control-plane
    decision for it and returns an event tag:

      ``"done"``       the sample budget is exhausted (partial last
                       iteration included);
      ``"iter"``       a plain iteration (no detector, or no deviation);
      ``"drift"``      deviation above threshold, streak still arming;
      ``"calm"``       deviation below threshold (streak cleared);
      ``"cooldown"``   the detector fired inside the cooldown window;
      ``"suppressed"`` the detector fired but the caller disallowed
                       re-planning (the fleet's cascade guard);
      ``"declined"``   a re-plan was evaluated and rejected (infeasible
                       or the migration cannot amortize);
      ``"noop"``       the re-plan kept the deployment and re-anchored
                       the drift reference;
      ``"migrated"``   a migration executed and a new epoch opened.
    """

    def __init__(
        self,
        job: JobModel,
        fleet: Dict[str, int],
        P: int,
        live_topo: TopologyMatrix,
        *,
        n_iterations: int,
        planned_topo: Optional[TopologyMatrix] = None,
        control: Optional[ControlConfig] = None,
        migration: Optional[MigrationModel] = None,
        C: Optional[int] = None,
        policy: str = "atlas",
        validate: bool = False,
    ):
        assert live_topo.dc_names, "control plane needs a named topology"
        planned = planned_topo if planned_topo is not None else live_topo
        self.job = job
        self.fleet = fleet
        self.P = P
        self.live_topo = live_topo
        self.topo = live_topo  # current pricing view (fleet may contend it)
        self.control = control
        self.mig_model = migration if migration is not None else MigrationModel()
        self.policy = policy
        self.validate = validate

        job0 = dataclasses.replace(job, topology=planned)
        if C is None:
            C = max(1, round(job0.comm_compute_ratio))
        self.C = C
        plan0 = best_plan(algorithm1(job0, fleet, P, C=C))
        if not math.isfinite(plan0.total_ms):
            raise ValueError("initial plan infeasible for this fleet/P/C")

        self.epoch = self._open_epoch(0, 0.0, 0.0, plan0, planned)
        self.epochs: List[EpochRecord] = [self.epoch]
        self.migrations: List[MigrationEvent] = []
        self.iteration_times: List[float] = []
        self.detector = DriftDetector(control) if control is not None else None
        self.stats: Dict = {
            "iter_sims": 0,
            "iter_reused": 0,
            "drift_iterations": 0,
            "drift_fires": 0,
            "replans_declined": 0,
            "replans_noop": 0,
            "replans_suppressed": 0,
            "fast_forward_gates": {},
        }
        self.samples_total = float(n_iterations) * self.epoch.samples_per_iteration
        self.t = 0.0
        self.samples = 0.0
        self.k = 0  # completed full iterations (cooldown clock)
        self.last_replan_k = -(10 ** 9)
        self._cache: Dict[Tuple, object] = {}
        self.last_result = None  # SimResult of the latest _run_iteration
        # (cache hits reuse the representative result: its busy/bubble
        # intervals are relative to iteration start, so they re-anchor at
        # any wall-clock offset — the fleet's BubbleTea loop relies on
        # this to read *contended* bubbles per iteration window)
        self._crossing = _crossing_schedules(self.epoch.spec, self.topo)
        # an empty budget is already exhausted — advance() must never
        # simulate a phantom iteration for n_iterations=0
        self._done = self.samples_total <= 1e-9

    # -- plumbing ----------------------------------------------------------

    def _open_epoch(self, index, t, samples, plan, assumed) -> EpochRecord:
        spec = plan_spec(self.job, plan, self.live_topo)
        return EpochRecord(
            index=index,
            start_ms=t,
            start_sample=samples,
            plan=plan,
            spec=spec,
            n_pipelines=self.C,
            dp_replicas=plan.D * self.C,
            assumed=assumed,
        )

    @property
    def done(self) -> bool:
        return self._done

    def set_topology(self, topo: TopologyMatrix) -> None:
        """Swap the pricing view (the fleet's contended topology).  The
        iteration-reuse cache and the crossing-schedule set are tied to
        the old view and are rebuilt; passing the current view is a
        no-op so the single-job path keeps its cache across calls."""
        if topo is self.topo:
            return
        self.topo = topo
        self._cache = {}
        self._crossing = _crossing_schedules(self.epoch.spec, topo)

    def _run_iteration(self) -> float:
        t = self.t
        key = tuple(s.bw_at(t) for s in self._crossing)
        hit = self._cache.get(key)
        if hit is not None and all(
            s.constant_over(t, t + hit.iteration_ms) for s in self._crossing
        ):
            self.stats["iter_reused"] += 1
            self.last_result = hit
            return hit.iteration_ms
        # first iteration after a re-plan never extrapolates across the
        # migration (the epoch-boundary gate); otherwise the single-
        # iteration fast-forward engages whenever its own gates allow
        boundary = self.epoch.index > 0 and self.epoch.iterations == 0
        gate = fastforward.fast_forward_gate(
            self.epoch.spec, self.topo, epoch_boundary=boundary
        )
        res = simulate(
            self.epoch.spec,
            self.topo,
            policy=self.policy,
            n_pipelines=self.epoch.n_pipelines,
            dp_replicas_for_allreduce=self.epoch.dp_replicas,
            start_ms=t,
            fast_forward=False if gate is not None else None,
            validate=self.validate,
        )
        self.stats["iter_sims"] += 1
        if gate is not None:
            self.stats["fast_forward_gates"][gate] = (
                self.stats["fast_forward_gates"].get(gate, 0) + 1
            )
        if all(s.constant_over(t, t + res.iteration_ms) for s in self._crossing):
            self._cache[key] = res
        self.last_result = res
        return res.iteration_ms

    # -- one iteration + its control decision ------------------------------

    def advance(self, *, allow_replan: bool = True) -> str:
        assert not self._done, "horizon already exhausted"
        iter_ms = self._run_iteration()
        spi = self.epoch.samples_per_iteration
        if self.samples + spi >= self.samples_total - 1e-9:
            frac = (self.samples_total - self.samples) / spi
            self.t += iter_ms * frac
            self.samples = self.samples_total
            self.epoch.iterations += 1
            self.iteration_times.append(iter_ms)
            self._done = True
            return "done"
        self.t += iter_ms
        self.samples += spi
        self.k += 1
        self.epoch.iterations += 1
        self.iteration_times.append(iter_ms)
        if self.detector is None:
            return "iter"

        control = self.control
        dev = link_deviation(self.topo, self.epoch.assumed, self.t - iter_ms, self.t)
        drifted = dev > control.drift_threshold
        self.stats["drift_iterations"] += int(drifted)
        if not self.detector.observe(dev):
            return "drift" if drifted else "calm"
        self.stats["drift_fires"] += 1
        if self.k - self.last_replan_k < control.cooldown_iterations:
            return "cooldown"
        if not allow_replan:
            # the fleet's cascade guard: the fire is real but this round
            # of the cascade is over budget — treat like a declined
            # attempt (the cooldown clock resets, the budget pressure
            # cannot re-fire every iteration)
            self.last_replan_k = self.k
            self.stats["replans_suppressed"] += 1
            return "suppressed"
        self.last_replan_k = self.k

        t = self.t
        window = control.snapshot_window_ms
        snap = self.topo.snapshot(t, window_ms=iter_ms if window is None else window)
        job_s = dataclasses.replace(self.job, topology=snap)
        cand = best_plan(
            algorithm1(job_s, self.fleet, self.P, C=self.C,
                       incumbent_order=self.epoch.plan.dc_order)
        )
        if not math.isfinite(cand.total_ms):
            self.stats["replans_declined"] += 1
            return "declined"
        cand_spec = plan_spec(self.job, cand, self.live_topo)
        if cand_spec.stage_dc == self.epoch.spec.stage_dc and cand.D == self.epoch.plan.D:
            # same deployment under current conditions: re-anchor the
            # drift reference so the detector stops firing on a change
            # the plan already tolerates best
            self.epoch.assumed = snap
            self.stats["replans_noop"] += 1
            return "noop"

        mig = plan_migration(
            self.epoch.spec.stage_dc,
            cand_spec.stage_dc,
            param_bytes=self.job.partition_param_bytes,
            dp_replicas_old=self.epoch.dp_replicas,
            dp_replicas_new=cand.D * self.C,
            topo=self.topo,
            at_ms=t,
            model=self.mig_model,
        )
        cand_res = simulate(
            cand_spec,
            self.topo,
            policy=self.policy,
            n_pipelines=self.C,
            dp_replicas_for_allreduce=cand.D * self.C,
            start_ms=t + mig.duration_ms,
        )
        inc_per_sample = iter_ms / spi
        cand_per_sample = cand_res.iteration_ms / (cand.D * self.C * self.job.microbatches)
        remaining = self.samples_total - self.samples
        gain = remaining * (inc_per_sample - cand_per_sample)
        if gain <= mig.duration_ms + control.min_gain_ms:
            self.stats["replans_declined"] += 1
            return "declined"

        mig.projected_gain_ms = gain
        mig.remaining_samples = remaining
        self.migrations.append(mig)
        self.epoch.end_ms = t
        self.t = t + mig.duration_ms
        self.epoch = self._open_epoch(
            self.epoch.index + 1, self.t, self.samples, cand, snap
        )
        self.epochs.append(self.epoch)
        self.detector.reset()
        self._cache = {}
        self._crossing = _crossing_schedules(self.epoch.spec, self.topo)
        return "migrated"

    def defer_epoch_start(self, new_t_ms: float) -> None:
        """Admission barrier hook for the fleet: extend the migration
        stall that just opened the current epoch so the epoch starts at
        ``new_t_ms`` — a job migrating *onto* channels other jobs hold
        in-flight windows on waits for those windows to drain before its
        first contended iteration.  Epoch/migration tiling is preserved
        (the wait is part of the stall; ``validate.check_horizon`` still
        holds) and the migration's transfers stay inside the window."""
        assert self.migrations and self.epoch.iterations == 0, (
            "defer_epoch_start only applies to a freshly migrated epoch"
        )
        assert abs(self.epoch.start_ms - self.t) < 1e-9
        if new_t_ms <= self.t:
            return
        self.migrations[-1].duration_ms += new_t_ms - self.t
        self.t = new_t_ms
        self.epoch.start_ms = new_t_ms

    def result(self) -> HorizonResult:
        self.epoch.end_ms = self.t
        return HorizonResult(
            total_ms=self.t,
            samples=self.samples,
            policy=self.policy,
            epochs=self.epochs,
            migrations=self.migrations,
            iteration_times=self.iteration_times,
            stats=self.stats,
        )


def simulate_horizon(
    job: JobModel,
    fleet: Dict[str, int],
    P: int,
    live_topo: TopologyMatrix,
    *,
    n_iterations: int,
    planned_topo: Optional[TopologyMatrix] = None,
    control: Optional[ControlConfig] = None,
    migration: Optional[MigrationModel] = None,
    C: Optional[int] = None,
    policy: str = "atlas",
    validate: bool = False,
) -> HorizonResult:
    """Co-simulate ``n_iterations`` (of the initial plan's global batch)
    against the live WAN, optionally with the reactive control plane.

    ``planned_topo`` is what Algorithm 1 believed at t=0 (default: the
    live topology — the planner knew the whole trace); the live/planned
    split is how an *unplanned* outage is modelled.  ``control=None``
    runs the static PR-3 behaviour — plan once, never react — so the
    same call is both arms of the reactive-vs-static comparison.  ``C``
    (pipelines per DP-cell) is pinned across re-plans: re-sizing a cell
    is a full re-shard, not a migration; D is re-picked freely.

    This is the single-job driver of ``HorizonRunner``; the multi-job
    fleet (``repro.core.fleet.simulate_fleet``) interleaves several
    runners over one shared WAN and is differentially identical to this
    function when the fleet has exactly one job.
    """
    runner = HorizonRunner(
        job, fleet, P, live_topo,
        n_iterations=n_iterations,
        planned_topo=planned_topo,
        control=control,
        migration=migration,
        C=C,
        policy=policy,
        validate=validate,
    )
    while not runner.done:
        runner.advance()
    return runner.result()
