# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

from repro.core.topology import TopologyMatrix, preset as topology_preset  # noqa: F401
from repro.core.control import (  # noqa: F401
    ControlConfig,
    DriftDetector,
    HorizonResult,
    HorizonRunner,
    MigrationModel,
    simulate_horizon,
)
from repro.core.fleet import (  # noqa: F401
    FleetConfig,
    FleetJob,
    FleetResult,
    simulate_fleet,
)
