"""BubbleTea — prefill-as-a-service in training bubbles (paper §5).

Components:
  * ``PrefillLatencyModel`` — deterministic prefill-duration / TTFT model
    for an inference model served PP-sharded over training GPUs (Fig 14):
    compute + per-stage pipeline hops + the weight-swap penalty that makes
    high PP degrees *win* for large prefills (PP=p keeps model_bytes/p per
    GPU resident in the small BubbleTea memory budget; PP=1 must stream
    non-resident layers over PCIe once compute saturates).
  * ``BubbleTeaController`` — receives prefill requests from the inference
    controller, places them into *reserved* bubble windows of a training
    pipeline (same-rank GPUs across DP-cells, same DC — §5.1), never
    concurrent with training compute, and hands the KV cache to a decode
    GPU in the same DC (Splitwise-style).  Requests that do not fit any
    bubble are rejected back to the dedicated inference fleet.

The controller consumes bubbles produced by ``repro.core.simulator`` /
``repro.core.temporal`` — the same bubble-consolidation property Atlas
§4.3 advertises is what gives BubbleTea long contiguous windows.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

# hardware constants (A100 80GB testbed, paper §6)
GPU_TFLOPS = 312.0  # A100 bf16 dense
PREFILL_EFFICIENCY = 0.55  # achieved fraction of peak during prefill
PCIE_GBPS_BYTES = 64.0  # one-way PCIe gen5 (paper §5 fn. 4), GB/s
NVLINK_GBPS_BYTES = 100.0  # effective KV-transfer bandwidth intra-node
# the three constants below are calibrated so the TTFT model hits the
# paper's two Fig 14 anchors: PP=8 inflates TTFT by +29% at 512 tokens;
# PP=1 is +67% over PP=8 at 8K tokens (see EXPERIMENTS.md §Fig14)
BASE_OVERHEAD_MS = 29.0  # tokenization + queueing + launch
PIPE_HOP_MS = 3.2  # per-stage activation hop + kernel relaunch
SATURATION_TOKENS = 2048  # prompt length beyond which compute saturates
SWAP_OVERLAP = 0.34  # fraction of swap hidden under compute


@dataclasses.dataclass(frozen=True)
class InferenceModelSpec:
    name: str
    num_params: float  # e.g. 8e9 for Llama3-8B
    bytes_per_param: float = 2.0  # fp16
    kv_bytes_per_token: float = 131072.0  # 2·L·Hkv·dh·2B (llama3-8b GQA)
    mem_budget_gb: float = 2.0  # BubbleTea per-GPU weight budget (§5.1)

    @property
    def model_bytes(self) -> float:
        return self.num_params * self.bytes_per_param


@dataclasses.dataclass(frozen=True)
class PrefillLatencyModel:
    model: InferenceModelSpec
    gpu_tflops: float = GPU_TFLOPS

    def compute_ms(self, prompt_tokens: int) -> float:
        flops = 2.0 * self.model.num_params * prompt_tokens
        return flops / (self.gpu_tflops * 1e12 * PREFILL_EFFICIENCY) * 1e3

    def swap_ms(self, prompt_tokens: int, pp_degree: int) -> float:
        """Weight-streaming penalty (§6.6): with PP=p each GPU must hold
        model_bytes/p; bytes beyond the resident budget stream over PCIe
        once per compute wave and only partially overlap."""
        per_gpu = self.model.model_bytes / pp_degree
        budget = self.model.mem_budget_gb * 1e9
        non_resident_total = max(0.0, per_gpu - budget) * pp_degree
        if non_resident_total <= 0.0:
            return 0.0
        waves = max(1, -(-prompt_tokens // SATURATION_TOKENS))
        if prompt_tokens < SATURATION_TOKENS:
            return 0.0  # streaming fully hidden under unsaturated compute
        stream_ms = non_resident_total / (PCIE_GBPS_BYTES * 1e9) * 1e3
        return waves * stream_ms * (1.0 - SWAP_OVERLAP)

    def prefill_ms(self, prompt_tokens: int, pp_degree: int) -> float:
        """End-to-end prefill duration on `pp_degree` stages."""
        return (
            self.compute_ms(prompt_tokens)
            + (pp_degree - 1) * PIPE_HOP_MS
            + self.swap_ms(prompt_tokens, pp_degree)
        )

    def ttft_ms(self, prompt_tokens: int, pp_degree: int, queue_ms: float = 0.0) -> float:
        kv_ms = (
            prompt_tokens * self.model.kv_bytes_per_token
            / (NVLINK_GBPS_BYTES * 1e9) * 1e3
        )
        return BASE_OVERHEAD_MS + queue_ms + self.prefill_ms(prompt_tokens, pp_degree) + kv_ms


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrefillRequest:
    req_id: int
    arrival_ms: float
    prompt_tokens: int


@dataclasses.dataclass
class Placement:
    req_id: int
    pipeline: int
    start_ms: float
    duration_ms: float
    ttft_ms: float
    queue_ms: float


@dataclasses.dataclass
class _Window:
    start: float
    end: float


class BubbleTeaController:
    """Admission control + placement of prefills into training bubbles.

    ``pipelines``: list of per-inference-pipeline bubble interval lists.
    Each inference pipeline is formed by same-rank GPUs across DP-cells in
    one DC (paper §5.1); its usable windows are the *intersection* of its
    member GPUs' training bubbles, which the caller computes (for PP=1 the
    member is a single GPU and windows are its raw bubbles).

    Requests must arrive in nondecreasing ``arrival_ms`` order: windows
    that ended before the current arrival are pruned (a per-pipeline live
    cursor), so first-fit scans live windows only instead of degrading
    linearly in dead windows over a long trace.

    ``ttft_slo_ms`` (paper §5: prefills ride bubbles only if the TTFT SLO
    still holds) enables admission control: a request whose *earliest*
    feasible placement already blows the SLO — queue delay included — is
    rejected back to the dedicated inference fleet instead of being
    placed late.
    """

    def __init__(
        self,
        pipelines: Sequence[Sequence[Tuple[float, float]]],
        latency_model: PrefillLatencyModel,
        pp_degree: int = 1,
        guard_ms: float = 1.0,
        ttft_slo_ms: Optional[float] = None,
    ):
        self.windows: List[List[_Window]] = [
            sorted((_Window(a, b) for a, b in pipe), key=lambda w: w.start)
            for pipe in pipelines
        ]
        self.lat = latency_model
        self.pp = pp_degree
        self.guard = guard_ms  # paper §6.5: small residual gap so training
        # resumes without delay
        self.ttft_slo_ms = ttft_slo_ms
        self.placements: List[Placement] = []
        self.rejected: List[int] = []
        self.rejected_slo: List[int] = []
        self.search_time_us: List[float] = []
        # first window per pipeline that could still serve a request at
        # the latest arrival seen (windows are disjoint and start-sorted,
        # hence end-sorted — everything before the cursor is dead)
        self._live: List[int] = [0] * len(self.windows)
        self._last_arrival = -math.inf

    def reset_windows(
        self, bubbles_by_pipeline: Sequence[Sequence[Tuple[float, float]]]
    ) -> None:
        """Replace the bubble windows wholesale — the control-plane hook.

        After a re-plan epoch (``repro.core.control``) the training
        schedule, and therefore every bubble, is different: stale
        windows would let prefills land inside migration stalls or the
        new schedule's compute.  The caller recomputes the intersected
        bubbles from the new epoch's ``SimResult`` and swaps them in;
        live cursors restart at the new windows' heads.  Accounting
        (placements, rejections, the arrival-order clock) carries over —
        the controller is one continuous service across epochs."""
        self.windows = [
            sorted((_Window(a, b) for a, b in pipe), key=lambda w: w.start)
            for pipe in bubbles_by_pipeline
        ]
        self._live = [0] * len(self.windows)

    def submit(self, req: PrefillRequest) -> Optional[Placement]:
        """Place a prefill (first-fit over pipelines' live windows) or
        reject (capacity or TTFT SLO)."""
        assert req.arrival_ms >= self._last_arrival, (
            "requests must be submitted in arrival order"
        )
        self._last_arrival = req.arrival_ms
        t0 = time.perf_counter()
        need = self.lat.prefill_ms(req.prompt_tokens, self.pp) + self.guard
        best: Optional[Tuple[float, int, int]] = None  # (start, pipe, idx)
        for pi, wins in enumerate(self.windows):
            lo = self._live[pi]
            while lo < len(wins) and wins[lo].end <= req.arrival_ms + 1e-9:
                lo += 1  # dead: ended before this (and every later) arrival
            self._live[pi] = lo
            for wi in range(lo, len(wins)):
                w = wins[wi]
                start = max(w.start, req.arrival_ms)
                if w.end - start >= need:
                    if best is None or start < best[0]:
                        best = (start, pi, wi)
                    break  # windows sorted; first feasible is earliest here
        self.search_time_us.append((time.perf_counter() - t0) * 1e6)
        if best is None:
            self.rejected.append(req.req_id)
            return None
        start, pi, wi = best
        queue = start - req.arrival_ms
        ttft = self.lat.ttft_ms(req.prompt_tokens, self.pp, queue_ms=queue)
        if self.ttft_slo_ms is not None and ttft > self.ttft_slo_ms:
            # first-fit minimizes the start time, so every other feasible
            # placement has at least this queue delay: reject, don't place
            self.rejected.append(req.req_id)
            self.rejected_slo.append(req.req_id)
            return None
        w = self.windows[pi][wi]
        dur = need - self.guard
        # split the window
        new = []
        if start - w.start > 1e-9:
            new.append(_Window(w.start, start))
        if w.end - (start + need) > 1e-9:
            new.append(_Window(start + need, w.end))
        self.windows[pi][wi : wi + 1] = new
        p = Placement(req.req_id, pi, start, dur, ttft, queue)
        self.placements.append(p)
        return p

    # -- reporting ---------------------------------------------------------

    def acceptance_rate(self) -> float:
        n = len(self.placements) + len(self.rejected)
        return len(self.placements) / n if n else 0.0

    def slo_rejection_rate(self) -> float:
        n = len(self.placements) + len(self.rejected)
        return len(self.rejected_slo) / n if n else 0.0

    def prefill_busy_ms(self) -> float:
        """End-to-end prefill service time (window occupancy per pipeline)."""
        return sum(p.duration_ms for p in self.placements)

    def prefill_gpu_busy_ms(self) -> float:
        """Aggregate *GPU* busy time the placed prefills add, summed over
        the ``pp`` member stages — the Fig-13 utilization numerator."""
        return sum(
            prefill_stage_busy_ms(p.duration_ms, self.pp) * self.pp
            for p in self.placements
        )


def prefill_stage_busy_ms(duration_ms: float, pp_degree: int) -> float:
    """Busy time of *one* stage during a PP-sharded prefill.

    A PP=p prefill occupies the pipeline's window for ``duration_ms``,
    but each of the p stages computes only its own pipeline wave —
    roughly 1/p of the work plus its activation hop — and idles while
    the wave is elsewhere.  Counting the full duration per stage (the
    pre-fix accounting) multiplied the busy time p×, pushing the Fig-13
    utilization past what the bubbles can physically absorb."""
    if pp_degree <= 1:
        return duration_ms
    return min(duration_ms, duration_ms / pp_degree + PIPE_HOP_MS)


def utilization_with_prefills(
    sim_busy_ms: float,
    total_gpu_ms: float,
    controller: BubbleTeaController,
) -> float:
    """GPU utilization after BubbleTea fills bubbles (paper Fig 13).

    The prefill contribution is per-stage pipeline-wave busy time
    (``prefill_stage_busy_ms``) summed over the ``pp`` member stages —
    *not* ``duration × pp``: a PP-sharded prefill reserves every stage's
    window but keeps each stage busy only for its own wave."""
    if total_gpu_ms <= 0.0:
        return 0.0  # zero-length window (e.g. a horizon epoch closed
        # before its first iteration) — no time to be utilized in
    extra = controller.prefill_gpu_busy_ms()
    return min(1.0, (sim_busy_ms + extra) / total_gpu_ms)


def intersect_bubbles(
    bubble_lists: Sequence[Sequence[Tuple[float, float]]],
) -> List[Tuple[float, float]]:
    """Common idle windows across the GPUs forming one inference pipeline."""
    if not bubble_lists:
        return []
    cur = list(bubble_lists[0])
    for nxt in bubble_lists[1:]:
        out = []
        i = j = 0
        nxt = list(nxt)
        while i < len(cur) and j < len(nxt):
            a0, a1 = cur[i]
            b0, b1 = nxt[j]
            lo, hi = max(a0, b0), min(a1, b1)
            if hi > lo:
                out.append((lo, hi))
            if a1 < b1:
                i += 1
            else:
                j += 1
        cur = out
    return cur
