"""BubbleTea — prefill-as-a-service in training bubbles (paper §5).

Components:
  * ``PrefillLatencyModel`` — deterministic prefill-duration / TTFT model
    for an inference model served PP-sharded over training GPUs (Fig 14):
    compute + per-stage pipeline hops + the weight-swap penalty that makes
    high PP degrees *win* for large prefills (PP=p keeps model_bytes/p per
    GPU resident in the small BubbleTea memory budget; PP=1 must stream
    non-resident layers over PCIe once compute saturates).
  * ``ArrivalProcess`` / ``PromptMix`` — deterministic (seeded) production
    traffic: a diurnal-modulated Poisson stream, optionally Markov-
    modulated (on/off bursts, an MMPP-2), with a prompt-length mixture
    and an SLO-tier mixture.  One continuous arrival-ordered stream feeds
    ``BubbleTeaController.submit`` across re-plan epochs.
  * ``BubbleTeaController`` — receives prefill requests from the inference
    controller, places them into *reserved* bubble windows of a training
    pipeline (same-rank GPUs across DP-cells, same DC — §5.1), never
    concurrent with training compute, and hands the KV cache to a decode
    GPU (Splitwise-style).  Admission is SLO-*tier* aware: each request
    carries a tier whose TTFT budget gates its placement, and acceptance
    and TTFT percentiles are reported per tier.  Requests that do not fit
    any bubble are rejected back to the dedicated inference fleet.
  * KV-handoff pricing protocol (``KVQuote``) — when the decode DC is not
    the prefill DC the KV cache is real WAN traffic; a pricer object
    (``price``/``commit``) quotes the transfer so the controller can fold
    it into TTFT *before* admission.  ``LocalKVHandoff`` is the same-DC
    NVLink default; ``repro.core.fleet.KVFlows`` prices the transfer at
    contended (residual) bandwidth on the shared fleet WAN and records it
    in the reservation ledger.

The controller consumes bubbles produced by ``repro.core.simulator`` /
``repro.core.temporal`` — the same bubble-consolidation property Atlas
§4.3 advertises is what gives BubbleTea long contiguous windows.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro import obs, units

# hardware constants (A100 80GB testbed, paper §6)
GPU_TFLOPS = 312.0  # A100 bf16 dense
PREFILL_EFFICIENCY = 0.55  # achieved fraction of peak during prefill
PCIE_GBPS_BYTES = 64.0  # one-way PCIe gen5 (paper §5 fn. 4), GB/s
NVLINK_GBPS_BYTES = 100.0  # effective KV-transfer bandwidth intra-node
# the three constants below are calibrated so the TTFT model hits the
# paper's two Fig 14 anchors: PP=8 inflates TTFT by +29% at 512 tokens;
# PP=1 is +67% over PP=8 at 8K tokens (see EXPERIMENTS.md §Fig14)
BASE_OVERHEAD_MS = 29.0  # tokenization + queueing + launch
PIPE_HOP_MS = 3.2  # per-stage activation hop + kernel relaunch
SATURATION_TOKENS = 2048  # prompt length beyond which compute saturates
SWAP_OVERLAP = 0.34  # fraction of swap hidden under compute


@dataclasses.dataclass(frozen=True)
class InferenceModelSpec:
    name: str
    num_params: float  # e.g. 8e9 for Llama3-8B
    bytes_per_param: float = 2.0  # fp16
    kv_bytes_per_token: float = 131072.0  # 2·L·Hkv·dh·2B (llama3-8b GQA)
    mem_budget_gb: float = 2.0  # BubbleTea per-GPU weight budget (§5.1)

    @property
    def model_bytes(self) -> float:
        return self.num_params * self.bytes_per_param


@dataclasses.dataclass(frozen=True)
class PrefillLatencyModel:
    model: InferenceModelSpec
    gpu_tflops: float = GPU_TFLOPS

    def compute_ms(self, prompt_tokens: int) -> float:
        flops = 2.0 * self.model.num_params * prompt_tokens
        return flops / (self.gpu_tflops * 1e12 * PREFILL_EFFICIENCY) * 1e3

    def swap_ms(self, prompt_tokens: int, pp_degree: int) -> float:
        """Weight-streaming penalty (§6.6): with PP=p each GPU must hold
        model_bytes/p; bytes beyond the resident budget stream over PCIe
        once per compute wave and only partially overlap."""
        per_gpu = self.model.model_bytes / pp_degree
        budget = units.gb_to_bytes(self.model.mem_budget_gb)
        non_resident_total = max(0.0, per_gpu - budget) * pp_degree
        if non_resident_total <= 0.0:
            return 0.0
        waves = max(1, -(-prompt_tokens // SATURATION_TOKENS))
        if prompt_tokens < SATURATION_TOKENS:
            return 0.0  # streaming fully hidden under unsaturated compute
        stream_ms = units.serialization_ms_gbytes(non_resident_total, PCIE_GBPS_BYTES)
        return waves * stream_ms * (1.0 - SWAP_OVERLAP)

    def prefill_ms(self, prompt_tokens: int, pp_degree: int) -> float:
        """End-to-end prefill duration on `pp_degree` stages."""
        return (
            self.compute_ms(prompt_tokens)
            + (pp_degree - 1) * PIPE_HOP_MS
            + self.swap_ms(prompt_tokens, pp_degree)
        )

    def ttft_ms(self, prompt_tokens: int, pp_degree: int, queue_ms: float = 0.0) -> float:
        kv_ms = units.serialization_ms_gbytes(
            prompt_tokens * self.model.kv_bytes_per_token, NVLINK_GBPS_BYTES
        )
        return BASE_OVERHEAD_MS + queue_ms + self.prefill_ms(prompt_tokens, pp_degree) + kv_ms


# ---------------------------------------------------------------------------
# production traffic: seeded arrival processes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PromptMix:
    """Discrete prompt-length mixture (production traces are heavy on
    short prompts with a long tail of large contexts)."""

    lengths: Tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096, 8192)
    weights: Tuple[float, ...] = (0.25, 0.22, 0.18, 0.15, 0.10, 0.06, 0.04)

    def __post_init__(self):
        assert len(self.lengths) == len(self.weights) and self.lengths
        assert all(w >= 0 for w in self.weights) and sum(self.weights) > 0


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Deterministic (seeded) request-arrival generator.

    Base process is Poisson at ``rate_per_s``, modulated two ways:

      * diurnal — the rate swings sinusoidally by ``±diurnal_amplitude``
        over ``diurnal_period_ms`` (production traffic's day/night wave);
      * bursty — an on/off Markov modulation (an MMPP-2): exponential
        dwells of ``mean_off_ms`` at the base rate and ``mean_on_ms`` at
        ``burst_rate_mult ×`` the base rate.  Disabled unless
        ``burst_rate_mult > 1`` and both dwell means are positive.

    Generation uses thinning against the peak rate, driven by a single
    ``random.Random(seed)`` stream, so the trace is a pure function of
    the dataclass fields — two processes with equal fields emit
    identical arrival-ordered ``PrefillRequest`` lists.
    """

    rate_per_s: float
    horizon_ms: float
    seed: int = 0
    diurnal_amplitude: float = 0.0  # 0..1 fraction of the base rate
    diurnal_period_ms: float = 86_400_000.0
    burst_rate_mult: float = 1.0
    mean_on_ms: float = 0.0
    mean_off_ms: float = 0.0

    def __post_init__(self):
        assert self.rate_per_s > 0 and self.horizon_ms > 0
        assert 0.0 <= self.diurnal_amplitude <= 1.0
        assert self.burst_rate_mult >= 1.0

    @property
    def _bursty(self) -> bool:
        return (self.burst_rate_mult > 1.0
                and self.mean_on_ms > 0.0 and self.mean_off_ms > 0.0)

    def rate_at(self, t_ms: float, burst_on: bool = False) -> float:
        """Instantaneous rate in requests/ms."""
        lam = self.rate_per_s / 1e3
        lam *= 1.0 + self.diurnal_amplitude * math.sin(
            2.0 * math.pi * t_ms / self.diurnal_period_ms
        )
        if burst_on:
            lam *= self.burst_rate_mult
        return lam

    def generate(
        self,
        prompts: Optional[PromptMix] = None,
        tiers: Optional[Mapping[str, float]] = None,
        req_id0: int = 0,
    ) -> List["PrefillRequest"]:
        """Materialize the trace: arrival-ordered ``PrefillRequest``s with
        prompt lengths drawn from ``prompts`` and (optionally) SLO tiers
        drawn from the ``tiers`` share mapping (tier name → share)."""
        prompts = prompts or PromptMix()
        rng = random.Random(self.seed)
        peak = (self.rate_per_s / 1e3) * (1.0 + self.diurnal_amplitude)
        peak *= self.burst_rate_mult if self._bursty else 1.0
        tier_names: Optional[List[str]] = None
        tier_weights: Optional[List[float]] = None
        if tiers:
            tier_names = list(tiers.keys())
            tier_weights = [float(tiers[n]) for n in tier_names]
        out: List[PrefillRequest] = []
        on = False
        flip_at = rng.expovariate(1.0 / self.mean_off_ms) if self._bursty else math.inf
        t = 0.0
        rid = req_id0
        while True:
            t += rng.expovariate(peak)
            if t >= self.horizon_ms:
                break
            while t >= flip_at:  # advance the on/off modulating chain
                on = not on
                dwell = self.mean_on_ms if on else self.mean_off_ms
                flip_at += rng.expovariate(1.0 / dwell)
            if rng.random() * peak > self.rate_at(t, on):
                continue  # thinned
            tier = None
            if tier_names:
                tier = rng.choices(tier_names, weights=tier_weights)[0]
            out.append(PrefillRequest(
                req_id=rid,
                arrival_ms=t,
                prompt_tokens=rng.choices(prompts.lengths, weights=prompts.weights)[0],
                tier=tier,
            ))
            rid += 1
        return out


# ---------------------------------------------------------------------------
# KV-handoff pricing protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVQuote:
    """Priced KV-cache handoff for one prefill (prefill DC → decode DC).

    ``kv_ms`` is the admission-relevant term: time from KV-ready (prefill
    completion) to fully landed at the decode side, including any channel
    queueing.  ``payload`` is pricer-private state consumed by
    ``commit`` (e.g. the residual-rate segments to reserve)."""

    prompt_tokens: int
    src_dc: Optional[int]
    ready_ms: float
    start_ms: float  # when bytes start moving (>= ready_ms under queueing)
    done_ms: float
    kv_ms: float
    payload: object = None


class LocalKVHandoff:
    """Same-DC handoff over NVLink — the pre-fleet default pricing, as a
    pricer object so the controller has one code path."""

    def __init__(self, model: InferenceModelSpec):
        self.model = model

    def price(self, prompt_tokens: int, src_dc: Optional[int],
              ready_ms: float) -> KVQuote:
        kv_ms = units.serialization_ms_gbytes(
            prompt_tokens * self.model.kv_bytes_per_token, NVLINK_GBPS_BYTES
        )
        return KVQuote(prompt_tokens, src_dc, ready_ms, ready_ms,
                       ready_ms + kv_ms, kv_ms)

    def commit(self, quote: KVQuote) -> None:
        pass  # nothing reserved off-node


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrefillRequest:
    req_id: int
    arrival_ms: float
    prompt_tokens: int
    tier: Optional[str] = None  # SLO class; None → controller default SLO


@dataclasses.dataclass
class Placement:
    req_id: int
    pipeline: int
    start_ms: float
    duration_ms: float
    ttft_ms: float
    queue_ms: float
    tier: Optional[str] = None
    kv_ms: float = 0.0
    src_dc: Optional[int] = None


@dataclasses.dataclass
class _Window:
    start: float
    end: float


class BubbleTeaController:
    """Admission control + placement of prefills into training bubbles.

    ``pipelines``: list of per-inference-pipeline bubble interval lists.
    Each inference pipeline is formed by same-rank GPUs across DP-cells in
    one DC (paper §5.1); its usable windows are the *intersection* of its
    member GPUs' training bubbles, which the caller computes (for PP=1 the
    member is a single GPU and windows are its raw bubbles).

    Requests must arrive in nondecreasing ``arrival_ms`` order: windows
    that ended before the current arrival are pruned (a per-pipeline live
    cursor), so first-fit scans live windows only instead of degrading
    linearly in dead windows over a long trace.

    ``ttft_slo_ms`` (paper §5: prefills ride bubbles only if the TTFT SLO
    still holds) enables admission control: a request whose *earliest*
    feasible placement already blows the SLO — queue delay included — is
    rejected back to the dedicated inference fleet instead of being
    placed late.  ``tiers`` generalizes this to per-request SLO classes:
    a mapping tier-name → TTFT budget (ms); a request's ``tier`` selects
    its budget (falling back to ``ttft_slo_ms`` for untiered requests),
    and acceptance/TTFT percentiles are reported per tier.

    ``kv`` + ``pipeline_dc`` wire in WAN-priced KV handoff: ``kv`` is a
    pricer with ``price(prompt_tokens, src_dc, ready_ms) → KVQuote`` and
    ``commit(quote)`` (see ``KVQuote``; ``repro.core.fleet.KVFlows`` is
    the contended-WAN implementation), and ``pipeline_dc[pi]`` names the
    DC hosting pipeline ``pi`` (§5.1: every member GPU of an inference
    pipeline sits in one DC).  The quoted ``kv_ms`` replaces the NVLink
    term in TTFT *before* the SLO gate, so a request whose KV cache
    would crawl over a contended channel is rejected up front; admission
    then walks feasible placements in start order and takes the earliest
    one whose tier SLO holds (with heterogeneous KV cost, a later local
    placement may pass where the earliest cross-WAN one cannot).
    """

    def __init__(
        self,
        pipelines: Sequence[Sequence[Tuple[float, float]]],
        latency_model: PrefillLatencyModel,
        pp_degree: int = 1,
        guard_ms: float = 1.0,
        ttft_slo_ms: Optional[float] = None,
        tiers: Optional[Mapping[str, float]] = None,
        pipeline_dc: Optional[Sequence[int]] = None,
        kv: Optional[object] = None,
        clock: Optional[Callable[[], float]] = None,
        tracer: Optional[object] = None,
    ):
        self.lat = latency_model
        # obs tracing (``repro.obs``): placements become spans on the
        # ``prefill`` lane group, admission rejections and WAN KV
        # handoffs become instants — all in sim time
        self.tracer = tracer
        self._tracing = tracer is not None and getattr(tracer, "enabled", False)
        self.pp = pp_degree
        self.guard = guard_ms  # paper §6.5: small residual gap so training
        # resumes without delay
        self.ttft_slo_ms = ttft_slo_ms
        self.tiers = dict(tiers) if tiers else None
        self.kv = kv
        self.windows: List[List[_Window]] = []
        self.pipeline_dc: Optional[List[int]] = None
        self.placements: List[Placement] = []
        self.rejected: List[int] = []
        self.rejected_slo: List[int] = []
        # admission-search profiling is opt-in: ``repro.core`` traces are
        # pure functions of their seeds, so the wall clock only enters
        # when a caller injects one (e.g. ``clock=time.perf_counter``)
        self._clock = clock
        self.search_time_us: List[float] = []
        # per-tier accounting: tier → [offered, placed, slo-rejects, ttfts]
        self._tier_stats: Dict[str, Dict[str, object]] = {}
        self._last_arrival = -math.inf
        self._install(pipelines, pipeline_dc)

    def _install(
        self,
        pipelines: Sequence[Sequence[Tuple[float, float]]],
        pipeline_dc: Optional[Sequence[int]],
    ) -> None:
        # fragments shorter than guard_ms can never host a placement
        # (need = prefill_ms + guard > guard always) — drop them here so
        # first-fit never rescans them (see submit's split, same rule)
        self.windows = [
            sorted((_Window(a, b) for a, b in pipe if b - a > self.guard),
                   key=lambda w: w.start)
            for pipe in pipelines
        ]
        if pipeline_dc is not None:
            assert len(pipeline_dc) == len(self.windows)
            self.pipeline_dc = list(pipeline_dc)
        else:
            self.pipeline_dc = None
        # first window per pipeline that could still serve a request at
        # the latest arrival seen (windows are disjoint and start-sorted,
        # hence end-sorted — everything before the cursor is dead)
        self._live: List[int] = [0] * len(self.windows)

    def reset_windows(
        self,
        bubbles_by_pipeline: Sequence[Sequence[Tuple[float, float]]],
        pipeline_dc: Optional[Sequence[int]] = None,
    ) -> None:
        """Replace the bubble windows wholesale — the control-plane hook.

        After a re-plan epoch (``repro.core.control``) the training
        schedule, and therefore every bubble, is different: stale
        windows would let prefills land inside migration stalls or the
        new schedule's compute.  The caller recomputes the intersected
        bubbles from the new epoch's ``SimResult`` and swaps them in
        (with ``pipeline_dc`` when the placement moved pipelines across
        DCs); live cursors restart at the new windows' heads.
        Accounting (placements, rejections, the arrival-order clock)
        carries over — the controller is one continuous service across
        epochs."""
        self._install(bubbles_by_pipeline, pipeline_dc)

    def _slo_for(self, req: PrefillRequest) -> Optional[float]:
        if req.tier is not None and self.tiers is not None:
            return self.tiers.get(req.tier, self.ttft_slo_ms)
        return self.ttft_slo_ms

    def _tier_of(self, req: PrefillRequest) -> str:
        return req.tier if req.tier is not None else "default"

    def _account(self, req: PrefillRequest, placed: bool, slo_reject: bool,
                 ttft: Optional[float]) -> None:
        s = self._tier_stats.setdefault(
            self._tier_of(req),
            {"offered": 0, "placed": 0, "rejected_slo": 0, "ttfts": []},
        )
        s["offered"] += 1
        if placed:
            s["placed"] += 1
            s["ttfts"].append(ttft)
        elif slo_reject:
            s["rejected_slo"] += 1

    def submit(self, req: PrefillRequest) -> Optional[Placement]:
        """Place a prefill (first-fit over pipelines' live windows) or
        reject (capacity or TTFT SLO)."""
        assert req.arrival_ms >= self._last_arrival, (
            "requests must be submitted in arrival order"
        )
        self._last_arrival = req.arrival_ms
        t0 = self._clock() if self._clock is not None else None
        need = self.lat.prefill_ms(req.prompt_tokens, self.pp) + self.guard
        # earliest feasible placement per pipeline (windows sorted: the
        # first window that fits gives that pipeline's earliest start)
        cands: List[Tuple[float, int, int]] = []  # (start, pipe, idx)
        for pi, wins in enumerate(self.windows):
            lo = self._live[pi]
            while lo < len(wins) and wins[lo].end <= req.arrival_ms + 1e-9:
                lo += 1  # dead: ended before this (and every later) arrival
            self._live[pi] = lo
            for wi in range(lo, len(wins)):
                w = wins[wi]
                start = max(w.start, req.arrival_ms)
                if w.end - start >= need:
                    cands.append((start, pi, wi))
                    break  # windows sorted; first feasible is earliest here
        if t0 is not None:
            self.search_time_us.append((self._clock() - t0) * 1e6)
        if not cands:
            self.rejected.append(req.req_id)
            self._account(req, False, False, None)
            if self._tracing:
                self.tracer.instant(
                    "reject_capacity", obs.CAT_PREFILL, "prefill",
                    "admission", req.arrival_ms,
                    req_id=req.req_id, tier=self._tier_of(req),
                )
            return None
        slo = self._slo_for(req)
        chosen: Optional[Tuple[float, int, int, float, float, Optional[KVQuote]]] = None
        for start, pi, wi in sorted(cands):
            queue = start - req.arrival_ms
            quote: Optional[KVQuote] = None
            if self.kv is not None:
                src = (self.pipeline_dc[pi]
                       if self.pipeline_dc is not None else None)
                ready = start + (need - self.guard)
                quote = self.kv.price(req.prompt_tokens, src, ready)
                ttft = (BASE_OVERHEAD_MS + queue
                        + self.lat.prefill_ms(req.prompt_tokens, self.pp)
                        + quote.kv_ms)
            else:
                ttft = self.lat.ttft_ms(req.prompt_tokens, self.pp,
                                        queue_ms=queue)
            # an infinite quote (permanently saturated KV channel) is an
            # SLO-class rejection even for untiered requests
            if math.isfinite(ttft) and (slo is None or ttft <= slo):
                chosen = (start, pi, wi, queue, ttft, quote)
                break
            # earliest start already blows the SLO through queueing alone
            # only when later starts must too — but KV cost varies by
            # pipeline DC, so keep scanning in start order
        if chosen is None:
            self.rejected.append(req.req_id)
            self.rejected_slo.append(req.req_id)
            self._account(req, False, True, None)
            if self._tracing:
                self.tracer.instant(
                    "reject_slo", obs.CAT_PREFILL, "prefill",
                    "admission", req.arrival_ms,
                    req_id=req.req_id, tier=self._tier_of(req),
                )
            return None
        start, pi, wi, queue, ttft, quote = chosen
        if quote is not None:
            self.kv.commit(quote)
        w = self.windows[pi][wi]
        dur = need - self.guard
        # split the window; fragments under guard_ms can never host a
        # future placement (need > guard always) — drop them instead of
        # leaving them for first-fit to rescan forever
        new = []
        if start - w.start > self.guard:
            new.append(_Window(w.start, start))
        if w.end - (start + need) > self.guard:
            new.append(_Window(start + need, w.end))
        self.windows[pi][wi : wi + 1] = new
        p = Placement(req.req_id, pi, start, dur, ttft, queue,
                      tier=req.tier, kv_ms=quote.kv_ms if quote else 0.0,
                      src_dc=quote.src_dc if quote else None)
        self.placements.append(p)
        self._account(req, True, False, ttft)
        if self._tracing:
            self.tracer.span(
                "prefill", obs.CAT_PREFILL, "prefill", f"pipe{pi}",
                start, start + dur,
                req_id=req.req_id, tier=self._tier_of(req),
                ttft_ms=ttft, queue_ms=queue, kv_ms=p.kv_ms, src_dc=p.src_dc,
            )
            if quote is not None and quote.payload is not None:
                self.tracer.instant(
                    "kv_handoff", obs.CAT_PREFILL, "prefill",
                    "kv", start + dur,
                    req_id=req.req_id, tier=self._tier_of(req),
                    src_dc=quote.src_dc, kv_ms=quote.kv_ms,
                )
        return p

    # -- reporting ---------------------------------------------------------

    def acceptance_rate(self) -> float:
        n = len(self.placements) + len(self.rejected)
        return len(self.placements) / n if n else 0.0

    def slo_rejection_rate(self) -> float:
        n = len(self.placements) + len(self.rejected)
        return len(self.rejected_slo) / n if n else 0.0

    def tier_report(self) -> Dict[str, Dict[str, float]]:
        """Per-tier acceptance and TTFT percentiles (untiered requests
        report under ``"default"``)."""
        out: Dict[str, Dict[str, float]] = {}
        for tier, s in sorted(self._tier_stats.items()):
            ttfts = sorted(s["ttfts"])
            rep = {
                "offered": s["offered"],
                "placed": s["placed"],
                "rejected_slo": s["rejected_slo"],
                "acceptance": s["placed"] / s["offered"] if s["offered"] else 0.0,
            }
            for pc in (50, 95, 99):
                # unit-suffixed key (PR-8 grammar): these are millisecond
                # percentiles, the schema registry enforces the name
                rep[f"ttft_p{pc}_ms"] = _pctl(ttfts, pc / 100.0)
            out[tier] = rep
        return out

    def prefill_busy_ms(self) -> float:
        """End-to-end prefill service time (window occupancy per pipeline)."""
        return sum(p.duration_ms for p in self.placements)

    def prefill_gpu_busy_ms(self) -> float:
        """Aggregate *GPU* busy time the placed prefills add, summed over
        the ``pp`` member stages — the Fig-13 utilization numerator."""
        return sum(
            prefill_stage_busy_ms(p.duration_ms, self.pp) * self.pp
            for p in self.placements
        )


def _pctl(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ascending list (0.0 when empty)."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[i]


def prefill_stage_busy_ms(duration_ms: float, pp_degree: int) -> float:
    """Busy time of *one* stage during a PP-sharded prefill.

    A PP=p prefill occupies the pipeline's window for ``duration_ms``,
    but each of the p stages computes only its own pipeline wave —
    roughly 1/p of the work plus its activation hop — and idles while
    the wave is elsewhere.  Counting the full duration per stage (the
    pre-fix accounting) multiplied the busy time p×, pushing the Fig-13
    utilization past what the bubbles can physically absorb."""
    if pp_degree <= 1:
        return duration_ms
    return min(duration_ms, duration_ms / pp_degree + PIPE_HOP_MS)


def utilization_with_prefills(
    sim_busy_ms: float,
    total_gpu_ms: float,
    controller: BubbleTeaController,
) -> float:
    """GPU utilization after BubbleTea fills bubbles (paper Fig 13).

    The prefill contribution is per-stage pipeline-wave busy time
    (``prefill_stage_busy_ms``) summed over the ``pp`` member stages —
    *not* ``duration × pp``: a PP-sharded prefill reserves every stage's
    window but keeps each stage busy only for its own wave."""
    if total_gpu_ms <= 0.0:
        return 0.0  # zero-length window (e.g. a horizon epoch closed
        # before its first iteration) — no time to be utilized in
    extra = controller.prefill_gpu_busy_ms()
    return min(1.0, (sim_busy_ms + extra) / total_gpu_ms)


def intersect_bubbles(
    bubble_lists: Sequence[Sequence[Tuple[float, float]]],
) -> List[Tuple[float, float]]:
    """Common idle windows across the GPUs forming one inference pipeline."""
    if not bubble_lists:
        return []
    cur = list(bubble_lists[0])
    for nxt in bubble_lists[1:]:
        out = []
        i = j = 0
        nxt = list(nxt)
        while i < len(cur) and j < len(nxt):
            a0, a1 = cur[i]
            b0, b1 = nxt[j]
            lo, hi = max(a0, b0), min(a1, b1)
            if hi > lo:
                out.append((lo, hi))
            if a1 < b1:
                i += 1
            else:
                j += 1
        cur = out
    return cur
