"""Multi-job fleet sharing one WAN — contention-priced channels and
cross-job re-plan cascades.

Everything before this module simulated one training job owning every
WAN link.  The paper's premise — workload-aware sharing of *scarce*
inter-DC bandwidth — only bites when several jobs contend for the same
directed channels: job A's migration or re-plan changes the bandwidth
job B observes, so B's drift detector may fire in response.  This
module co-simulates N jobs (each its own ``JobModel``, GPU fleet slice,
placement and optional ``ControlConfig``) over one shared
``TopologyMatrix``:

  * **Channel allocator** — per *directed* DC pair, each job's demand is
    its per-iteration channel bits over its planned iteration time, as a
    rate against the pair's guaranteed (worst-segment) capacity.
    *Temporal sharing first*: when the demands fit the channel together,
    transfers can serialize into each other's idle windows (the same
    §4.2 principle Atlas applies within a job) and every job keeps full
    rate.  Only when the channel is oversubscribed do transfers have to
    overlap, and the allocator falls back to a *weighted max-min fair
    share* — each job's schedule view is scaled to its granted fraction
    (``TopologyMatrix.with_rate_multipliers``), so every engine
    underneath (event simulator, Atlas list-scheduler,
    ``validate.check_schedule``, the horizon runner) prices transfers at
    contended effective bandwidth with no engine changes.
    ``sharing="fair"`` keeps the naive strawman — contenders always
    split the channel by weight even when serialization would have fit —
    as the bench's comparison arm.

  * **Reservation ledger + windowed residual** — every iteration
    records the average rate granted on each pair it crosses
    (``ChannelReservation``).  Grants are *residual-aware*: a window may
    never reserve more than what the open holds of other jobs leave
    free.  Fleet windows are created in nondecreasing start order (the
    scheduler always advances the job with the smallest wall clock), so
    by induction the ledger satisfies the fleet invariant *pointwise*:
    aggregate reserved rate per directed channel never exceeds the
    schedule's capacity at any instant (``validate.check_fleet``).  In
    steady state every open hold sits at or below its fair-share
    target, so the residual never bites and grants equal targets; it
    exists for generation transitions (a job migrating or finishing
    mid-window of another).

  * **Migration admission barrier** — a job migrating *onto* pairs
    where other jobs still have in-flight windows would find only the
    leftover residual there.  Instead its migration stall is extended
    until those holds drain (``HorizonRunner.defer_epoch_start`` —
    epoch/migration tiling is preserved), after which its fair-share
    target is guaranteed available.  Migration stall windows themselves
    are outside the steady-state ledger; their per-pair serialization
    and live-schedule pricing are asserted per job by
    ``validate.check_horizon``.

  * **Cascade + convergence guard** — contention enters each job's
    drift detector through the contended topology view (delivered mean
    bandwidth is the scaled schedule's), so a re-plan by one job can
    push another over its drift threshold and trigger a re-plan chain.
    The fleet bounds each chain: at most ``max_cascade_replans``
    migrations per *cascade epoch*; further fires are suppressed
    (``HorizonRunner.advance(allow_replan=False)``) until every active
    job has completed an iteration without migrating, which closes the
    epoch and resets the budget.  Jobs are processed in deterministic
    wall-clock order (ties broken by job list order), so cascades are
    reproducible.

A single-job fleet degenerates exactly: the lone demander on every
channel keeps ``mult == 1``, ``with_rate_multipliers`` returns the live
topology by identity, and the run is differentially identical to
``control.simulate_horizon`` (tested in ``tests/test_fleet.py``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.control import (
    ControlConfig,
    HorizonResult,
    HorizonRunner,
    MigrationModel,
)
from repro.core.dc_selection import JobModel
from repro.core.simulator import iteration_wan_bits, simulate
from repro.core.topology import Pair, TopologyMatrix

SHARINGS = ("temporal", "fair")
# pricing floor for a residual-squeezed window, as a fraction of the
# channel's capacity (see fleet.simulate_fleet's grant logic)
MIN_GRANT_FRAC = 0.01


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """One training job of the fleet: its workload model, its slice of
    the GPU fleet (per-DC counts), partition count and control knobs.
    ``weight`` is the job's fair-share weight on oversubscribed
    channels (capacity splits proportionally to weight)."""

    name: str
    job: JobModel
    gpus: Dict[str, int]
    P: int
    n_iterations: int
    C: Optional[int] = None
    policy: str = "atlas"
    weight: float = 1.0
    planned_topo: Optional[TopologyMatrix] = None
    control: Optional[ControlConfig] = None

    def __post_init__(self):
        assert self.weight > 0.0, "fair-share weight must be positive"
        assert self.n_iterations >= 1, self.n_iterations


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs.

    ``sharing="temporal"`` is the contention-aware policy (serialize
    first, fair-share only under oversubscription); ``"fair"`` is the
    always-fair-share strawman the bench compares against.
    ``max_cascade_replans`` is the convergence guard: migrations allowed
    per cascade epoch before further drift fires are suppressed."""

    sharing: str = "temporal"
    max_cascade_replans: int = 4
    migration: MigrationModel = dataclasses.field(default_factory=MigrationModel)

    def __post_init__(self):
        assert self.sharing in SHARINGS, self.sharing
        assert self.max_cascade_replans >= 1


@dataclasses.dataclass
class ChannelReservation:
    """Average rate one job holds on one directed channel over one
    iteration window — the unit of the fleet capacity invariant."""

    job: str
    pair: Pair
    t0_ms: float
    t1_ms: float
    rate_gbps: float  # allocated average rate over the window
    mult: float  # rate multiplier the job's schedule view was scaled by


@dataclasses.dataclass
class FleetResult:
    jobs: Dict[str, HorizonResult]
    reservations: List[ChannelReservation]
    total_ms: float  # wall time the last job finished
    stats: Dict

    @property
    def replans(self) -> int:
        return sum(hr.replans for hr in self.jobs.values())


# ---------------------------------------------------------------------------
# demand + fair-share targets
# ---------------------------------------------------------------------------


def pair_demand_rates(spec, n_pipelines: int, iteration_ms: float) -> Dict[Pair, float]:
    """Average rate (Gbit/s) one job needs on each directed WAN pair:
    its per-iteration channel bits (``simulator.iteration_wan_bits`` —
    the same count every engine reports in ``stats["wan_bits"]``) over
    its iteration time.  Bits/ms = 1e6 · Gbit/s."""
    assert iteration_ms > 0
    bits = iteration_wan_bits(spec, n_pipelines)
    return {p: b / iteration_ms / 1e6 for p, b in bits.items()}


def _weighted_max_min(entries: Sequence[Tuple[str, float, float]]) -> Dict[str, float]:
    """Weighted max-min fair shares of one unit of capacity.

    ``entries`` are ``(key, demand_fraction, weight)``.  Water-fill:
    jobs whose demand sits below their weighted share are satisfied
    exactly and their slack is redistributed; the rest split the
    remaining capacity by weight.  Deterministic in input order."""
    alloc: Dict[str, float] = {}
    active = list(entries)
    remaining = 1.0
    while active:
        wsum = sum(w for _k, _d, w in active)
        sat = [(k, d, w) for k, d, w in active if d <= remaining * w / wsum + 1e-15]
        if not sat:
            for k, _d, w in active:
                alloc[k] = remaining * w / wsum
            return alloc
        for k, d, _w in sat:
            alloc[k] = d
            remaining -= d
        done = {k for k, _d, _w in sat}
        active = [e for e in active if e[0] not in done]
    return alloc


def channel_targets(
    demands: Mapping[str, Mapping[Pair, float]],
    weights: Mapping[str, float],
    topo: TopologyMatrix,
    *,
    sharing: str = "temporal",
    order: Optional[Sequence[str]] = None,
) -> Dict[str, Dict[Pair, Tuple[float, float, Optional[float]]]]:
    """Steady-state allocation targets for every demanded channel.

    Per job and directed pair, returns ``(capped_need, target,
    fixed_mult)``: the demand rate clamped at the pair's guaranteed
    (worst-segment) capacity, the average rate the job is entitled to
    reserve, and — in the naive ``"fair"`` mode — the rate multiplier
    its transfers are pinned to regardless of demand (``None`` in
    temporal mode, where the multiplier follows the granted rate).

    *Temporal sharing first*: a lone demander, or demanders whose
    capped needs fit the channel together, keep ``target ==
    capped_need`` (their transfer windows serialize; nobody slows
    down).  An oversubscribed channel splits by weighted max-min.  By
    construction the targets on one pair sum to at most its
    worst-segment capacity, which is what makes the fleet invariant
    hold pointwise even while the live schedule fluctuates above that
    floor."""
    assert sharing in SHARINGS, sharing
    names = [n for n in (order if order is not None else demands) if n in demands]
    out: Dict[str, Dict[Pair, Tuple[float, float, Optional[float]]]] = {
        n: {} for n in names
    }
    pairs = sorted({p for n in names for p in demands[n]})
    for pair in pairs:
        cap = topo.effective_bw_gbps(*pair)
        entries = [
            (n, min(1.0, demands[n][pair] / cap), weights.get(n, 1.0))
            for n in names
            if pair in demands[n]
        ]
        fits = sum(d for _n, d, _w in entries) <= 1.0 + 1e-12
        if len(entries) == 1 or (sharing == "temporal" and fits):
            for n, d, _w in entries:
                out[n][pair] = (d * cap, d * cap, None)
            continue
        if sharing == "fair":
            # the strawman: overlapping flows always split the channel
            # by weight — transfers run at the share rate even when
            # serialization would have fit everyone at full speed
            wsum = sum(w for _n, _d, w in entries)
            for n, d, w in entries:
                share = w / wsum
                out[n][pair] = (d * cap, min(d, share) * cap, share)
            continue
        shares = _weighted_max_min(entries)
        for n, d, _w in entries:
            out[n][pair] = (d * cap, min(d, shares[n]) * cap, None)
    return out


# ---------------------------------------------------------------------------
# the fleet co-simulator
# ---------------------------------------------------------------------------


def simulate_fleet(
    jobs: Sequence[FleetJob],
    live_topo: TopologyMatrix,
    *,
    config: Optional[FleetConfig] = None,
    validate: bool = False,
) -> FleetResult:
    """Co-simulate every job of the fleet over the shared live WAN.

    Jobs advance one iteration at a time in wall-clock order (earliest
    current time first, list order on ties).  Before each iteration the
    job's grant on every pair it crosses is ``min(target, residual)`` —
    its fair-share target, clipped by whatever the other jobs' open
    windows leave free — and its runner is handed the matching contended
    topology view.  Targets are recomputed whenever the demand set
    changes (a migration re-placed a job, or a job finished and released
    its channels).  Drift fires that would exceed the cascade budget are
    suppressed until the cascade epoch closes (see module docstring).
    """
    cfg = config if config is not None else FleetConfig()
    names = [j.name for j in jobs]
    assert len(set(names)) == len(names), "fleet job names must be unique"
    runners: Dict[str, HorizonRunner] = {
        j.name: HorizonRunner(
            j.job,
            j.gpus,
            j.P,
            live_topo,
            n_iterations=j.n_iterations,
            planned_topo=j.planned_topo,
            control=j.control,
            migration=cfg.migration,
            C=j.C,
            policy=j.policy,
            validate=validate,
        )
        for j in jobs
    }
    weights = {j.name: j.weight for j in jobs}
    reservations: List[ChannelReservation] = []
    # per-pair index of *open* holds: closed windows are pruned once the
    # fleet's minimum wall clock passes them (every future window starts
    # at or after that clock, so a dead hold can never matter again) —
    # the full ledger for check_fleet lives in `reservations`
    pair_res: Dict[Pair, Deque[ChannelReservation]] = {}
    stats: Dict = {
        "sharing": cfg.sharing,
        "generations": 0,
        "cascade_replans_max": cfg.max_cascade_replans,
        "cascade_epochs": 0,
        "cascade_suppressed": 0,
        "admission_wait_ms": 0.0,
        "floor_grants": 0,
        "demand_probe_sims": 0,
        "per_job": {
            n: {"throttled_iterations": 0, "throttled_ms": 0.0} for n in names
        },
    }

    # per job, chronological demand segments (start, end, rates): the
    # job's channel demand is active only over the wall-time span that
    # generates it — job A's post-migration demand must not throttle a
    # window of job B that starts before A's migration even begins (A
    # can lag the fleet in wall time).  A migration's new demand claims
    # from the migration *start* (anticipatory: stall included), so no
    # window opened during the stall can re-occupy the migrant's share
    INF = float("inf")
    segments: Dict[str, List[Tuple[float, float, Dict[Pair, float]]]] = {
        n: [] for n in names
    }
    caps: Dict[Pair, float] = {}

    def uncontended_iter_ms(r: HorizonRunner) -> float:
        """One probe simulation of the runner's current epoch against
        the *live* (uncontended) WAN at its current wall offset — the
        full-rate iteration time its channel demand is measured over.
        Contention-independent, so the allocation cannot oscillate with
        its own throttling; one probe per job per epoch."""
        stats["demand_probe_sims"] += 1
        return simulate(
            r.epoch.spec,
            live_topo,
            policy=r.policy,
            n_pipelines=r.epoch.n_pipelines,
            dp_replicas_for_allreduce=r.epoch.dp_replicas,
            start_ms=r.t,
        ).iteration_ms

    def open_segment(name: str, start_ms: Optional[float] = None) -> None:
        """Open the job's current-epoch demand segment at ``start_ms``
        (default: the epoch start).  A migrating job passes its
        migration *start*: the claim is anticipatory — windows other
        jobs open during the stall already count the migrant as a
        demander on its new pairs and leave its fair share free."""
        r = runners[name]
        stats["generations"] += 1
        rates = pair_demand_rates(
            r.epoch.spec, r.epoch.n_pipelines, uncontended_iter_ms(r)
        )
        at = r.epoch.start_ms if start_ms is None else start_ms
        segments[name].append((at, INF, rates))
        for pair in rates:
            if pair not in caps:
                caps[pair] = live_topo.effective_bw_gbps(*pair)

    def close_segment(name: str, t: float) -> None:
        if segments[name]:
            s0, _s1, rates = segments[name][-1]
            segments[name][-1] = (s0, t, rates)

    def demand_at(t: float) -> Dict[str, Dict[Pair, float]]:
        """The demand rates of every job whose epoch is active at ``t``."""
        out: Dict[str, Dict[Pair, float]] = {}
        for n in names:
            for s0, s1, rates in reversed(segments[n]):
                if s0 <= t + 1e-9 and t < s1 - 1e-9:
                    out[n] = rates
                    break
        return out

    def residual(name: str, pair: Pair, t: float) -> float:
        """Capacity the other jobs' open holds leave free on ``pair``
        from ``t`` on.  Per other job, the largest rate among its
        reservations still open at ``t`` bounds its pointwise hold.
        ``t`` is the fleet's minimum wall clock (grants run for the
        earliest job), so heads that ended by ``t`` are dead for every
        future window and are dropped — the scan stays O(open holds),
        not O(horizon)."""
        chain = pair_res.get(pair)
        if chain is None:
            return caps[pair]
        while chain and chain[0].t1_ms <= t + 1e-9:
            chain.popleft()
        held: Dict[str, float] = {}
        for res in chain:
            if res.job != name and res.t1_ms > t + 1e-9:
                held[res.job] = max(held.get(res.job, 0.0), res.rate_gbps)
        return caps[pair] - sum(held.values())

    def grants(name: str, t: float) -> Tuple[Dict[Pair, float], Dict[Pair, float]]:
        """(mults, reserved rates) for one window of ``name`` at ``t``:
        fair-share targets over the demanders active at ``t``, clipped
        per pair by what other jobs' open holds leave free."""
        targets = channel_targets(
            demand_at(t), weights, live_topo, sharing=cfg.sharing, order=names
        )
        mults: Dict[Pair, float] = {}
        reserved: Dict[Pair, float] = {}
        for pair, (capped, target, fixed_mult) in targets.get(name, {}).items():
            allowed = min(target, max(residual(name, pair, t), 0.0))
            reserved[pair] = allowed
            if fixed_mult is not None and allowed >= target - 1e-12:
                # naive fair share, steady state: the rate is pinned to
                # the weight share regardless of demand (average usage
                # is then exactly `target`, which the ledger reserved)
                mults[pair] = fixed_mult
            elif allowed >= capped - 1e-12:
                mults[pair] = 1.0  # temporal sharing: full-rate transfers
            else:
                # residual-squeezed window (either mode): the transfers
                # themselves are slowed to the granted average so the
                # ledger never understates what the engines priced.
                # The anticipatory demand segments + admission barrier
                # keep `allowed >= target` in every constructed case;
                # the floor (1% of capacity, counted in stats) bounds
                # the stretch of the one theoretical corner — a job
                # lagging behind the migrant's claim while straddling
                # its barrier — instead of letting a ~zero residual
                # price a window at effectively no bandwidth
                if allowed < MIN_GRANT_FRAC * caps[pair]:
                    stats["floor_grants"] += 1
                mults[pair] = max(allowed / caps[pair], MIN_GRANT_FRAC)
        return mults, reserved

    for n in names:
        open_segment(n)

    topos: Dict[str, TopologyMatrix] = {}
    topo_keys: Dict[str, Tuple] = {}
    cascade_replans = 0
    quiesced: Set[str] = set()
    while True:
        active = [n for n in names if not runners[n].done]
        if not active:
            break
        name = min(active, key=lambda n: (runners[n].t, names.index(n)))
        r = runners[name]
        mults, reserved = grants(name, r.t)
        key = tuple(sorted(mults.items()))
        if topo_keys.get(name) != key:
            # identity-preserving: an unchanged grant keeps the runner's
            # topology object, its crossing set and its reuse cache
            topos[name] = live_topo.with_rate_multipliers(mults)
            topo_keys[name] = key
        r.set_topology(topos[name])
        t0 = r.t
        throttled = any(m < 1.0 for m in mults.values())
        ev = r.advance(allow_replan=cascade_replans < cfg.max_cascade_replans)
        iter_ms = r.iteration_times[-1]
        t_end = r.t if ev == "done" else t0 + iter_ms
        if t_end > t0:
            for pair in sorted(reserved):
                rate = reserved[pair]
                chain = pair_res.setdefault(pair, deque())
                prev = chain[-1] if chain else None
                if (
                    prev is not None
                    and prev.job == name
                    and prev.rate_gbps == rate
                    and abs(prev.t1_ms - t0) < 1e-9
                ):
                    prev.t1_ms = t_end  # coalesce back-to-back windows
                else:
                    res = ChannelReservation(
                        name, pair, t0, t_end, rate, mults.get(pair, 1.0)
                    )
                    reservations.append(res)
                    chain.append(res)
        if throttled:
            pj = stats["per_job"][name]
            pj["throttled_iterations"] += 1
            pj["throttled_ms"] += t_end - t0

        if ev == "migrated":
            cascade_replans += 1
            quiesced = set()
            mig_start = r.migrations[-1].at_ms
            close_segment(name, mig_start)
            # admission barrier: entering pairs where other jobs still
            # have open windows, wait for those holds to drain — the
            # extended stall keeps the entrant's fair-share target
            # available at its first contended iteration
            new_pairs = pair_demand_rates(r.epoch.spec, r.epoch.n_pipelines, 1.0)
            t_bar = r.t
            for pair in new_pairs:
                for res in pair_res.get(pair, ()):
                    if res.job != name and res.t1_ms > t_bar:
                        t_bar = res.t1_ms
            if t_bar > r.t:
                stats["admission_wait_ms"] += t_bar - r.t
                r.defer_epoch_start(t_bar)
            # the new demand claims from the migration *start* — no
            # unclaimed gap for windows other jobs open during the stall
            open_segment(name, start_ms=mig_start)
            continue
        if ev == "suppressed":
            stats["cascade_suppressed"] += 1
        if ev == "done":
            close_segment(name, r.t)  # the job released its channels
        quiesced.add(name)
        still_active = {n for n in names if not runners[n].done}
        if cascade_replans and still_active <= quiesced:
            # every active job completed an iteration without migrating:
            # the cascade epoch closes, the re-plan budget resets
            cascade_replans = 0
            quiesced = set()
            stats["cascade_epochs"] += 1

    results = {n: runners[n].result() for n in names}
    stats["replans_total"] = sum(hr.replans for hr in results.values())
    for n in names:
        stats["per_job"][n].update(
            total_ms=results[n].total_ms,
            samples=results[n].samples,
            replans=results[n].replans,
            migration_ms=results[n].migration_ms,
            replans_suppressed=results[n].stats.get("replans_suppressed", 0),
        )
    out = FleetResult(
        jobs=results,
        reservations=reservations,
        total_ms=max((hr.total_ms for hr in results.values()), default=0.0),
        stats=stats,
    )
    if validate:
        from repro.core import validate as _validate

        _validate.check_fleet(out, live_topo)
    return out
