"""Multi-job fleet sharing one WAN — contention-priced channels and
cross-job re-plan cascades.

Everything before this module simulated one training job owning every
WAN link.  The paper's premise — workload-aware sharing of *scarce*
inter-DC bandwidth — only bites when several jobs contend for the same
directed channels: job A's migration or re-plan changes the bandwidth
job B observes, so B's drift detector may fire in response.  This
module co-simulates N jobs (each its own ``JobModel``, GPU fleet slice,
placement and optional ``ControlConfig``) over one shared
``TopologyMatrix``:

  * **Channel allocator** — per *directed* DC pair, each job's demand is
    its per-iteration channel bits over its planned iteration time, as a
    rate against the pair's guaranteed (worst-segment) capacity.
    *Temporal sharing first*: when the demands fit the channel together,
    transfers can serialize into each other's idle windows (the same
    §4.2 principle Atlas applies within a job) and every job keeps full
    rate.  Only when the channel is oversubscribed do transfers have to
    overlap, and the allocator falls back to a *weighted max-min fair
    share* — each job's schedule view is scaled to its granted fraction
    (``TopologyMatrix.with_rate_multipliers``), so every engine
    underneath (event simulator, Atlas list-scheduler,
    ``validate.check_schedule``, the horizon runner) prices transfers at
    contended effective bandwidth with no engine changes.
    ``sharing="fair"`` keeps the naive strawman — contenders always
    split the channel by weight even when serialization would have fit —
    as the bench's comparison arm.

  * **Reservation ledger + windowed residual** — every iteration
    records the average rate granted on each pair it crosses
    (``ChannelReservation``).  Grants are *residual-aware*: a window may
    never reserve more than what the open holds of other jobs leave
    free.  Fleet windows are created in nondecreasing start order (the
    scheduler always advances the job with the smallest wall clock), so
    by induction the ledger satisfies the fleet invariant *pointwise*:
    aggregate reserved rate per directed channel never exceeds the
    schedule's capacity at any instant (``validate.check_fleet``).  In
    steady state every open hold sits at or below its fair-share
    target, so the residual never bites and grants equal targets; it
    exists for generation transitions (a job migrating or finishing
    mid-window of another).

  * **Migration admission barrier** — a job migrating *onto* pairs
    where other jobs still have in-flight windows would find only the
    leftover residual there.  Instead its migration stall is extended
    until those holds drain (``HorizonRunner.defer_epoch_start`` —
    epoch/migration tiling is preserved), after which its fair-share
    target is guaranteed available.  Migration stall windows themselves
    are outside the steady-state ledger; their per-pair serialization
    and live-schedule pricing are asserted per job by
    ``validate.check_horizon``.

  * **Cascade + convergence guard** — contention enters each job's
    drift detector through the contended topology view (delivered mean
    bandwidth is the scaled schedule's), so a re-plan by one job can
    push another over its drift threshold and trigger a re-plan chain.
    The fleet bounds each chain: at most ``max_cascade_replans``
    migrations per *cascade epoch*; further fires are suppressed
    (``HorizonRunner.advance(allow_replan=False)``) until every active
    job has completed an iteration without migrating, which closes the
    epoch and resets the budget.  Jobs are processed in deterministic
    wall-clock order (ties broken by job list order), so cascades are
    reproducible.

A single-job fleet degenerates exactly: the lone demander on every
channel keeps ``mult == 1``, ``with_rate_multipliers`` returns the live
topology by identity, and the run is differentially identical to
``control.simulate_horizon`` (tested in ``tests/test_fleet.py``).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro import obs, units
from repro.core.bubbletea import (
    NVLINK_GBPS_BYTES,
    BubbleTeaController,
    InferenceModelSpec,
    KVQuote,
    PrefillLatencyModel,
    PrefillRequest,
    intersect_bubbles,
    utilization_with_prefills,
)
from repro.core.control import (
    ControlConfig,
    HorizonResult,
    HorizonRunner,
    MigrationModel,
)
from repro.core.dc_selection import JobModel
from repro.core.failures import CheckpointPolicy, FailureTrace
from repro.core.simulator import iteration_wan_bits, simulate
from repro.core.topology import Pair, TopologyMatrix

SHARINGS = ("temporal", "fair")
# pricing floor for a residual-squeezed window, as a fraction of the
# channel's capacity (see fleet.simulate_fleet's grant logic)
MIN_GRANT_FRAC = 0.01
# ledger pseudo-job name for BubbleTea KV-handoff reservations: KV
# transfers are a scavenger class priced at the *residual* rate, but the
# bytes are real — recording them under this name makes later training
# grants' residual() subtract them like any other job's holds, which is
# what keeps check_fleet's pointwise capacity invariant true with
# prefill traffic on the wire
KV_JOB = "~prefill"


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """One training job of the fleet: its workload model, its slice of
    the GPU fleet (per-DC counts), partition count and control knobs.
    ``weight`` is the job's fair-share weight on oversubscribed
    channels (capacity splits proportionally to weight)."""

    name: str
    job: JobModel
    gpus: Dict[str, int]
    P: int
    n_iterations: int
    C: Optional[int] = None
    policy: str = "atlas"
    weight: float = 1.0
    planned_topo: Optional[TopologyMatrix] = None
    control: Optional[ControlConfig] = None
    # per-job checkpoint policy: makes this job's forced failovers and
    # re-plans checkpoint-aware (restore + replay priced against live
    # shipment); None falls back to the fleet MigrationModel's policy
    checkpoint: Optional[CheckpointPolicy] = None

    def __post_init__(self):
        assert self.weight > 0.0, "fair-share weight must be positive"
        assert self.n_iterations >= 1, self.n_iterations


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs.

    ``sharing="temporal"`` is the contention-aware policy (serialize
    first, fair-share only under oversubscription); ``"fair"`` is the
    always-fair-share strawman the bench compares against.
    ``max_cascade_replans`` is the convergence guard: migrations allowed
    per cascade epoch before further drift fires are suppressed."""

    sharing: str = "temporal"
    max_cascade_replans: int = 4
    migration: MigrationModel = dataclasses.field(default_factory=MigrationModel)

    def __post_init__(self):
        assert self.sharing in SHARINGS, self.sharing
        assert self.max_cascade_replans >= 1


@dataclasses.dataclass
class ChannelReservation:
    """Average rate one job holds on one directed channel over one
    iteration window — the unit of the fleet capacity invariant."""

    job: str
    pair: Pair
    t0_ms: float
    t1_ms: float
    rate_gbps: float  # allocated average rate over the window
    mult: float  # rate multiplier the job's schedule view was scaled by


@dataclasses.dataclass(frozen=True)
class PrefillService:
    """BubbleTea riding one fleet job: production prefill traffic served
    out of ``host_job``'s training bubbles (paper §5 at fleet scale).

    ``arrivals`` is one continuous arrival-ordered ``PrefillRequest``
    stream (see ``bubbletea.ArrivalProcess``) fed across every horizon
    epoch; ``decode_dc`` names the DC whose dedicated decode GPUs
    receive the KV cache — prefills in other DCs pay for the handoff as
    real WAN traffic on the directed channel (``KVFlows``).  ``tiers``
    maps SLO-class name → TTFT budget (ms) for tier-aware admission;
    ``pp_degree`` must be 1 (each training GPU is its own inference
    pipeline) or the host's ``n_pipelines`` (same-rank GPUs across DP
    cells form one pipeline per stage, §5.1)."""

    host_job: str
    arrivals: Sequence[PrefillRequest]
    model: InferenceModelSpec
    decode_dc: str
    tiers: Optional[Mapping[str, float]] = None
    ttft_slo_ms: Optional[float] = None
    pp_degree: int = 1
    guard_ms: float = 1.0


@dataclasses.dataclass
class FleetResult:
    jobs: Dict[str, HorizonResult]
    reservations: List[ChannelReservation]
    total_ms: float  # wall time the last job finished
    stats: Dict
    prefill: Optional[BubbleTeaController] = None

    @property
    def replans(self) -> int:
        return sum(hr.replans for hr in self.jobs.values())


# ---------------------------------------------------------------------------
# demand + fair-share targets
# ---------------------------------------------------------------------------


def pair_demand_rates(spec, n_pipelines: int, iteration_ms: float) -> Dict[Pair, float]:
    """Average rate (Gbit/s) one job needs on each directed WAN pair:
    its per-iteration channel bits (``simulator.iteration_wan_bits`` —
    the same count every engine reports in ``stats["wan_bits"]``) over
    its iteration time.  Bits/ms = 1e6 · Gbit/s."""
    assert iteration_ms > 0
    bits = iteration_wan_bits(spec, n_pipelines)
    return {p: units.bits_rate_gbps(b, iteration_ms) for p, b in bits.items()}


def _weighted_max_min(entries: Sequence[Tuple[str, float, float]]) -> Dict[str, float]:
    """Weighted max-min fair shares of one unit of capacity.

    ``entries`` are ``(key, demand_fraction, weight)``.  Water-fill:
    jobs whose demand sits below their weighted share are satisfied
    exactly and their slack is redistributed; the rest split the
    remaining capacity by weight.  Deterministic in input order."""
    alloc: Dict[str, float] = {}
    active = list(entries)
    remaining = 1.0
    while active:
        wsum = sum(w for _k, _d, w in active)
        sat = [(k, d, w) for k, d, w in active if d <= remaining * w / wsum + 1e-15]
        if not sat:
            for k, _d, w in active:
                alloc[k] = remaining * w / wsum
            return alloc
        for k, d, _w in sat:
            alloc[k] = d
            remaining -= d
        done = {k for k, _d, _w in sat}
        active = [e for e in active if e[0] not in done]
    return alloc


def channel_targets(
    demands: Mapping[str, Mapping[Pair, float]],
    weights: Mapping[str, float],
    topo: TopologyMatrix,
    *,
    sharing: str = "temporal",
    order: Optional[Sequence[str]] = None,
) -> Dict[str, Dict[Pair, Tuple[float, float, Optional[float]]]]:
    """Steady-state allocation targets for every demanded channel.

    Per job and directed pair, returns ``(capped_need, target,
    fixed_mult)``: the demand rate clamped at the pair's guaranteed
    (worst-segment) capacity, the average rate the job is entitled to
    reserve, and — in the naive ``"fair"`` mode — the rate multiplier
    its transfers are pinned to regardless of demand (``None`` in
    temporal mode, where the multiplier follows the granted rate).

    *Temporal sharing first*: a lone demander, or demanders whose
    capped needs fit the channel together, keep ``target ==
    capped_need`` (their transfer windows serialize; nobody slows
    down).  An oversubscribed channel splits by weighted max-min.  By
    construction the targets on one pair sum to at most its
    worst-segment capacity, which is what makes the fleet invariant
    hold pointwise even while the live schedule fluctuates above that
    floor."""
    assert sharing in SHARINGS, sharing
    names = [n for n in (order if order is not None else demands) if n in demands]
    out: Dict[str, Dict[Pair, Tuple[float, float, Optional[float]]]] = {
        n: {} for n in names
    }
    pairs = sorted({p for n in names for p in demands[n]})
    for pair in pairs:
        cap = topo.effective_bw_gbps(*pair)
        entries = [
            (n, min(1.0, demands[n][pair] / cap), weights.get(n, 1.0))
            for n in names
            if pair in demands[n]
        ]
        fits = sum(d for _n, d, _w in entries) <= 1.0 + 1e-12
        if len(entries) == 1 or (sharing == "temporal" and fits):
            for n, d, _w in entries:
                out[n][pair] = (d * cap, d * cap, None)
            continue
        if sharing == "fair":
            # the strawman: overlapping flows always split the channel
            # by weight — transfers run at the share rate even when
            # serialization would have fit everyone at full speed
            wsum = sum(w for _n, _d, w in entries)
            for n, d, w in entries:
                share = w / wsum
                out[n][pair] = (d * cap, min(d, share) * cap, share)
            continue
        shares = _weighted_max_min(entries)
        for n, d, _w in entries:
            out[n][pair] = (d * cap, min(d, shares[n]) * cap, None)
    return out


# ---------------------------------------------------------------------------
# WAN-priced KV handoff
# ---------------------------------------------------------------------------


class KVFlows:
    """Prices BubbleTea KV-cache handoffs on the shared fleet WAN.

    Implements the ``bubbletea`` pricer protocol (``price``/``commit``).
    A prefill whose pipeline DC equals the decode DC hands off over
    NVLink; otherwise the KV bytes are demand on the directed
    ``(src, decode)`` channel, and the transfer is a *scavenger class*:

      * transfers on one channel serialize behind a per-pair cursor
        (KV has no fair-share entitlement — it consumes leftovers);
      * each transfer moves at the pointwise **residual** rate — the
        pair's worst-segment capacity minus every ledger hold open at
        that instant *and* minus the declared steady-state training
        demand on the pair (``demand_rate``) — integrated piecewise
        until the bytes drain, so a training-busy channel stretches the
        quote and the controller's SLO gate rejects the request up
        front.  Subtracting declared demand (not just materialized
        holds) is what keeps KV strictly scavenger-class: a transfer
        running ahead of the training clock must not book the capacity
        the next training window is entitled to, or that window's grant
        would collapse to the pricing floor;
      * on commit, one ``ChannelReservation`` per constant-rate segment
        is recorded under ``KV_JOB``.  Later training grants clip
        against these holds through the same ``residual()`` as against
        each other, and each KV segment's rate is by construction
        exactly the capacity the earlier holds left free — so the
        fleet's pointwise capacity invariant (``validate.check_fleet``)
        survives prefill traffic by the same creation-order induction
        that covers training windows.

    Pricing must see every hold overlapping the transfer, including ones
    the allocator's open-hold index already pruned, so the class keeps
    its own per-pair history fed from the append-only global ledger.
    Dead entries are compacted away only when provably immutable: KV
    segments are final, but a training hold that is the current tail of
    its pair chain may still be extended in place by the allocator's
    window coalescing, so the tail always survives compaction.
    """

    def __init__(
        self,
        live_topo: TopologyMatrix,
        model: InferenceModelSpec,
        decode_dc: int,
        caps: Dict[Pair, float],
        pair_res: Dict[Pair, Deque[ChannelReservation]],
        reservations: List[ChannelReservation],
        demand_rate=None,  # (pair, t) -> summed training demand Gbit/s
        demand_bounds=None,  # () -> iterable of demand-segment edges (ms)
    ):
        self.topo = live_topo
        self.model = model
        self.decode_dc = decode_dc
        self.caps = caps  # shared with the allocator
        self.pair_res = pair_res
        self.reservations = reservations  # shared append-only ledger
        self.demand_rate = demand_rate
        self.demand_bounds = demand_bounds
        self._seen = 0  # absorbed prefix of `reservations`
        self._hist: Dict[Pair, List[ChannelReservation]] = {}
        self._cursor: Dict[Pair, float] = {}
        self.n_wan = 0
        self.n_local = 0
        self.wan_bits = 0.0
        self.local_bits = 0.0
        self.kv_reservations = 0

    def _cap(self, pair: Pair) -> float:
        if pair not in self.caps:
            self.caps[pair] = self.topo.effective_bw_gbps(*pair)
        return self.caps[pair]

    def _absorb(self) -> None:
        while self._seen < len(self.reservations):
            r = self.reservations[self._seen]
            self._seen += 1
            self._hist.setdefault(r.pair, []).append(r)

    def _walk(
        self, pair: Pair, start: float, bits: float
    ) -> Tuple[List[Tuple[float, float, float]], float]:
        """Integrate ``bits`` from ``start`` at the pointwise residual
        rate; returns the constant-rate segments and the finish time."""
        cap = self._cap(pair)
        hist = self._hist.get(pair, [])
        if len(hist) > 64:
            chain = self.pair_res.get(pair)
            tail = chain[-1] if chain else None
            hist = [
                r for r in hist
                if r.t1_ms > start - 1e-9 or (r.job != KV_JOB and r is tail)
            ]
            self._hist[pair] = hist
        holds = [
            (r.t0_ms, r.t1_ms, r.rate_gbps)
            for r in hist
            if r.t1_ms > start + 1e-9 and r.rate_gbps > 0.0
        ]
        edges = {b for h in holds for b in h[:2] if b > start + 1e-9}
        if self.demand_bounds is not None:
            edges |= {b for b in self.demand_bounds() if b > start + 1e-9}
        bounds = sorted(edges)
        segs: List[Tuple[float, float, float]] = []
        t = start
        remaining = bits
        bi = 0
        while remaining > 1e-6:
            while bi < len(bounds) and bounds[bi] <= t + 1e-9:
                bi += 1
            nxt = bounds[bi] if bi < len(bounds) else float("inf")
            held = sum(r for (a, b, r) in holds if a <= t + 1e-9 < b)
            if self.demand_rate is not None:
                held = max(held, min(cap, self.demand_rate(pair, t)))
            rate = max(cap - held, 0.0)
            if rate <= cap * 1e-9:
                if bi >= len(bounds):
                    # permanently saturated (open-ended demand fills the
                    # channel): the transfer never drains — return an
                    # infinite finish so admission rejects the request
                    return segs, float("inf")
                t = nxt
                continue
            need_ms = units.bits_serialization_ms(remaining, rate)
            if t + need_ms <= nxt:
                segs.append((t, t + need_ms, rate))
                t += need_ms
                remaining = 0.0
            else:
                segs.append((t, nxt, rate))
                remaining -= units.window_bits(nxt - t, rate)
                t = nxt
        return segs, t

    # -- pricer protocol ---------------------------------------------------

    def price(self, prompt_tokens: int, src_dc: Optional[int],
              ready_ms: float) -> KVQuote:
        bits = units.bytes_to_bits(prompt_tokens * self.model.kv_bytes_per_token)
        if src_dc is None or src_dc == self.decode_dc:
            kv_ms = units.serialization_ms_gbytes(
                prompt_tokens * self.model.kv_bytes_per_token, NVLINK_GBPS_BYTES
            )
            return KVQuote(prompt_tokens, src_dc, ready_ms, ready_ms,
                           ready_ms + kv_ms, kv_ms)
        self._absorb()
        pair = (src_dc, self.decode_dc)
        start = max(ready_ms, self._cursor.get(pair, 0.0))
        segs, end = self._walk(pair, start, bits)
        if not math.isfinite(end):
            return KVQuote(prompt_tokens, src_dc, ready_ms, start,
                           float("inf"), float("inf"))
        done = end + self.topo.link(*pair).latency_ms
        return KVQuote(prompt_tokens, src_dc, ready_ms, start, done,
                       done - ready_ms, payload=(pair, segs))

    def commit(self, quote: KVQuote) -> None:
        bits = units.bytes_to_bits(quote.prompt_tokens * self.model.kv_bytes_per_token)
        if quote.payload is None:
            self.n_local += 1
            self.local_bits += bits
            return
        pair, segs = quote.payload
        self._cursor[pair] = segs[-1][1]
        cap = self._cap(pair)
        chain = self.pair_res.setdefault(pair, deque())
        for a, b, rate in segs:
            res = ChannelReservation(KV_JOB, pair, a, b, rate, rate / cap)
            self.reservations.append(res)
            chain.append(res)
            self.kv_reservations += 1
        self.n_wan += 1
        self.wan_bits += bits


# ---------------------------------------------------------------------------
# the fleet co-simulator
# ---------------------------------------------------------------------------


def simulate_fleet(
    jobs: Sequence[FleetJob],
    live_topo: TopologyMatrix,
    *,
    config: Optional[FleetConfig] = None,
    validate: bool = False,
    prefill: Optional[PrefillService] = None,
    failures: Optional[FailureTrace] = None,
    tracer=None,
) -> FleetResult:
    """Co-simulate every job of the fleet over the shared live WAN.

    Jobs advance one iteration at a time in wall-clock order (earliest
    current time first, list order on ties).  Before each iteration the
    job's grant on every pair it crosses is ``min(target, residual)`` —
    its fair-share target, clipped by whatever the other jobs' open
    windows leave free — and its runner is handed the matching contended
    topology view.  Targets are recomputed whenever the demand set
    changes (a migration re-placed a job, or a job finished and released
    its channels).  Drift fires that would exceed the cascade budget are
    suppressed until the cascade epoch closes (see module docstring).

    ``prefill`` closes the BubbleTea loop at fleet scale: the host job's
    per-iteration **contended** ``SimResult`` bubbles (a throttled job
    has longer iterations and therefore more bubble supply) become the
    controller's windows, production arrivals are fed in wall-clock
    order, and cross-DC KV handoffs are priced and reserved on the
    shared WAN (``KVFlows``).  A host window ``[t0, t1)`` is processed
    only once the fleet's minimum wall clock has passed ``t1``, so every
    training hold overlapping the window — from any job — is already in
    the ledger when the KV transfers through it are priced.

    ``failures`` injects one fleet-wide ``FailureTrace``: its bandwidth
    consequences are baked into the shared live WAN once (every job —
    reacting or not — prices the same degraded physics), its apply/heal
    steps drive forced failovers inside every runner, and each forced
    migration re-enters the normal cascade plumbing (segment close,
    admission barrier, cascade budget) like a drift migration would.
    Planners still price the raw WAN — failures are always unplanned.

    ``tracer`` (see ``repro.obs``) is shared across every runner: each
    job's iteration/migration/outage spans land under its own
    ``{name}/gpu`` / ``{name}/wan`` / ``{name}/control`` lane groups,
    allocator grant/throttle instants under ``fleet/alloc``, and — at
    horizon end — one span per ledger ``ChannelReservation`` (training
    grants *and* ``~prefill`` KV handoffs) under ``fleet/wan``.
    """
    cfg = config if config is not None else FleetConfig()
    tracing = tracer is not None and getattr(tracer, "enabled", False)
    names = [j.name for j in jobs]
    assert len(set(names)) == len(names), "fleet job names must be unique"
    assert KV_JOB not in names, f"{KV_JOB!r} is reserved for KV handoff"
    planned_default = None
    if failures is not None and len(failures):
        planned_default = live_topo  # the raw WAN the planners believed
        live_topo = failures.apply_to_topology(live_topo)
    runners: Dict[str, HorizonRunner] = {
        j.name: HorizonRunner(
            j.job,
            j.gpus,
            j.P,
            live_topo,
            n_iterations=j.n_iterations,
            planned_topo=(
                j.planned_topo if j.planned_topo is not None else planned_default
            ),
            control=j.control,
            migration=cfg.migration,
            C=j.C,
            policy=j.policy,
            validate=validate,
            failures=failures,
            checkpoint=j.checkpoint,
            tracer=tracer,
            trace_label=j.name,
        )
        for j in jobs
    }
    weights = {j.name: j.weight for j in jobs}
    reservations: List[ChannelReservation] = []
    # per-pair index of *open* holds: closed windows are pruned once the
    # fleet's minimum wall clock passes them (every future window starts
    # at or after that clock, so a dead hold can never matter again) —
    # the full ledger for check_fleet lives in `reservations`
    pair_res: Dict[Pair, Deque[ChannelReservation]] = {}
    stats: Dict = {
        "sharing": cfg.sharing,
        "generations": 0,
        "cascade_replans_max": cfg.max_cascade_replans,
        "cascade_epochs": 0,
        "cascade_suppressed": 0,
        "admission_wait_ms": 0.0,
        "floor_grants": 0,
        "demand_probe_sims": 0,
        "per_job": {
            n: {"throttled_iterations": 0, "throttled_ms": 0.0} for n in names
        },
    }

    # per job, chronological demand segments (start, end, rates): the
    # job's channel demand is active only over the wall-time span that
    # generates it — job A's post-migration demand must not throttle a
    # window of job B that starts before A's migration even begins (A
    # can lag the fleet in wall time).  A migration's new demand claims
    # from the migration *start* (anticipatory: stall included), so no
    # window opened during the stall can re-occupy the migrant's share
    INF = float("inf")
    segments: Dict[str, List[Tuple[float, float, Dict[Pair, float]]]] = {
        n: [] for n in names
    }
    caps: Dict[Pair, float] = {}

    def uncontended_iter_ms(r: HorizonRunner) -> float:
        """One probe simulation of the runner's current epoch against
        the *live* (uncontended) WAN at its current wall offset — the
        full-rate iteration time its channel demand is measured over.
        Contention-independent, so the allocation cannot oscillate with
        its own throttling; one probe per job per epoch."""
        stats["demand_probe_sims"] += 1
        return simulate(
            r.epoch.spec,
            live_topo,
            policy=r.policy,
            n_pipelines=r.epoch.n_pipelines,
            dp_replicas_for_allreduce=r.epoch.dp_replicas,
            start_ms=r.t,
        ).iteration_ms

    def open_segment(name: str, start_ms: Optional[float] = None) -> None:
        """Open the job's current-epoch demand segment at ``start_ms``
        (default: the epoch start).  A migrating job passes its
        migration *start*: the claim is anticipatory — windows other
        jobs open during the stall already count the migrant as a
        demander on its new pairs and leave its fair share free."""
        r = runners[name]
        stats["generations"] += 1
        rates = pair_demand_rates(
            r.epoch.spec, r.epoch.n_pipelines, uncontended_iter_ms(r)
        )
        at = r.epoch.start_ms if start_ms is None else start_ms
        segments[name].append((at, INF, rates))
        for pair in rates:
            if pair not in caps:
                caps[pair] = live_topo.effective_bw_gbps(*pair)

    def close_segment(name: str, t: float) -> None:
        if segments[name]:
            s0, _s1, rates = segments[name][-1]
            segments[name][-1] = (s0, t, rates)

    def demand_at(t: float) -> Dict[str, Dict[Pair, float]]:
        """The demand rates of every job whose epoch is active at ``t``."""
        out: Dict[str, Dict[Pair, float]] = {}
        for n in names:
            for s0, s1, rates in reversed(segments[n]):
                if s0 <= t + 1e-9 and t < s1 - 1e-9:
                    out[n] = rates
                    break
        return out

    def residual(name: str, pair: Pair, t: float) -> float:
        """Capacity the other jobs' open holds leave free on ``pair``
        from ``t`` on.  Per other job, the largest rate among its
        reservations still open at ``t`` bounds its pointwise hold.
        ``t`` is the fleet's minimum wall clock (grants run for the
        earliest job), so heads that ended by ``t`` are dead for every
        future window and are dropped — the scan stays O(open holds),
        not O(horizon)."""
        chain = pair_res.get(pair)
        if chain is None:
            return caps[pair]
        while chain and chain[0].t1_ms <= t + 1e-9:
            chain.popleft()
        held: Dict[str, float] = {}
        for res in chain:
            if res.job != name and res.t1_ms > t + 1e-9:
                held[res.job] = max(held.get(res.job, 0.0), res.rate_gbps)
        return caps[pair] - sum(held.values())

    def grants(name: str, t: float) -> Tuple[Dict[Pair, float], Dict[Pair, float]]:
        """(mults, reserved rates) for one window of ``name`` at ``t``:
        fair-share targets over the demanders active at ``t``, clipped
        per pair by what other jobs' open holds leave free."""
        targets = channel_targets(
            demand_at(t), weights, live_topo, sharing=cfg.sharing, order=names
        )
        mults: Dict[Pair, float] = {}
        reserved: Dict[Pair, float] = {}
        for pair, (capped, target, fixed_mult) in targets.get(name, {}).items():
            allowed = min(target, max(residual(name, pair, t), 0.0))
            reserved[pair] = allowed
            if fixed_mult is not None and allowed >= target - 1e-12:
                # naive fair share, steady state: the rate is pinned to
                # the weight share regardless of demand (average usage
                # is then exactly `target`, which the ledger reserved)
                mults[pair] = fixed_mult
            elif allowed >= capped - 1e-12:
                mults[pair] = 1.0  # temporal sharing: full-rate transfers
            else:
                # residual-squeezed window (either mode): the transfers
                # themselves are slowed to the granted average so the
                # ledger never understates what the engines priced.
                # The anticipatory demand segments + admission barrier
                # keep `allowed >= target` in every constructed case;
                # the floor (1% of capacity, counted in stats) bounds
                # the stretch of the one theoretical corner — a job
                # lagging behind the migrant's claim while straddling
                # its barrier — instead of letting a ~zero residual
                # price a window at effectively no bandwidth
                if allowed < MIN_GRANT_FRAC * caps[pair]:
                    stats["floor_grants"] += 1
                mults[pair] = max(allowed / caps[pair], MIN_GRANT_FRAC)
        return mults, reserved

    for n in names:
        open_segment(n)

    # -- BubbleTea prefill service (closed loop) ---------------------------
    ctrl: Optional[BubbleTeaController] = None
    kvflows: Optional[KVFlows] = None
    arrivals: List[PrefillRequest] = []
    svc_windows: Deque[Tuple[float, float, object, object]] = deque()
    svc_state = {"next": 0, "busy_gpu_ms": 0.0, "span_gpu_ms": 0.0}
    if prefill is not None:
        assert prefill.host_job in runners, prefill.host_job
        arrivals = list(prefill.arrivals)

        def _kv_demand_rate(pair: Pair, t: float) -> float:
            total = 0.0
            for rates in demand_at(t).values():
                r = rates.get(pair, 0.0)
                if r > 0.0:
                    total += min(r, caps.get(pair, r))
            return total

        def _kv_demand_bounds():
            out = set()
            for segs_ in segments.values():
                for s0, s1, _rates in segs_:
                    out.add(s0)
                    if s1 != INF:
                        out.add(s1)
            return out

        kvflows = KVFlows(
            live_topo,
            prefill.model,
            live_topo.index_of(prefill.decode_dc),
            caps,
            pair_res,
            reservations,
            demand_rate=_kv_demand_rate,
            demand_bounds=_kv_demand_bounds,
        )
        ctrl = BubbleTeaController(
            [],
            PrefillLatencyModel(prefill.model),
            pp_degree=prefill.pp_degree,
            guard_ms=prefill.guard_ms,
            ttft_slo_ms=prefill.ttft_slo_ms,
            tiers=prefill.tiers,
            kv=kvflows,
            tracer=tracer,
        )

    def process_window(t0: float, t1: float, res, spec) -> None:
        """One matured host iteration window: swap in its contended
        bubbles (absolute wall-clock, clipped to the window — the last
        window of a horizon is fractional) and feed the arrivals that
        land inside it."""
        pp = ctrl.pp
        if pp == 1:
            keys = sorted(res.busy)
            rel = [res.bubbles[g] for g in keys]
            dcs = [spec.stage_dc[g[1]] for g in keys]
        else:
            assert pp == res.n_pipelines, (
                "pp_degree must be 1 (each GPU its own pipeline) or the "
                "host's n_pipelines (same-rank GPUs across DP cells, §5.1)"
            )
            rel = [
                intersect_bubbles(
                    [res.bubbles[(p, s)] for p in range(res.n_pipelines)]
                )
                for s in range(spec.num_stages)
            ]
            dcs = list(spec.stage_dc)
        span = t1 - t0
        pipes = []
        for windows in rel:
            absw = []
            for a, b in windows:
                b = min(b, span)
                if b - a > 1e-9:
                    absw.append((t0 + a, t0 + b))
            pipes.append(absw)
        ctrl.reset_windows(pipes, pipeline_dc=dcs)
        while (svc_state["next"] < len(arrivals)
               and arrivals[svc_state["next"]].arrival_ms < t1 - 1e-9):
            ctrl.submit(arrivals[svc_state["next"]])
            svc_state["next"] += 1
        n_gpus = len(res.busy)
        svc_state["busy_gpu_ms"] += res.utilization * span * n_gpus
        svc_state["span_gpu_ms"] += span * n_gpus

    topos: Dict[str, TopologyMatrix] = {}
    topo_keys: Dict[str, Tuple] = {}
    cascade_replans = 0
    quiesced: Set[str] = set()
    while True:
        active = [n for n in names if not runners[n].done]
        if not active:
            break
        name = min(active, key=lambda n: (runners[n].t, names.index(n)))
        r = runners[name]
        mults, reserved = grants(name, r.t)
        key = tuple(sorted(mults.items()))
        if topo_keys.get(name) != key:
            # identity-preserving: an unchanged grant keeps the runner's
            # topology object, its crossing set and its reuse cache
            topos[name] = live_topo.with_rate_multipliers(mults)
            topo_keys[name] = key
        r.set_topology(topos[name])
        t0 = r.t
        throttled = any(m < 1.0 for m in mults.values())
        ev = r.advance(allow_replan=cascade_replans < cfg.max_cascade_replans)
        iter_ms = r.iteration_times[-1]
        t_end = r.t if ev == "done" else t0 + iter_ms
        if t_end > t0:
            for pair in sorted(reserved):
                rate = reserved[pair]
                chain = pair_res.setdefault(pair, deque())
                prev = chain[-1] if chain else None
                if (
                    prev is not None
                    and prev.job == name
                    and prev.rate_gbps == rate
                    and abs(prev.t1_ms - t0) < 1e-9
                ):
                    prev.t1_ms = t_end  # coalesce back-to-back windows
                else:
                    res = ChannelReservation(
                        name, pair, t0, t_end, rate, mults.get(pair, 1.0)
                    )
                    reservations.append(res)
                    chain.append(res)
        if throttled:
            pj = stats["per_job"][name]
            pj["throttled_iterations"] += 1
            pj["throttled_ms"] += t_end - t0
        if tracing and reserved and t_end > t0:
            tracer.instant(
                "throttle" if throttled else "grant",
                obs.CAT_FLEET, "fleet/alloc", name, t0,
                pairs=len(reserved),
                min_mult=min(mults.values()) if mults else 1.0,
            )
        if (prefill is not None and name == prefill.host_job
                and t_end > t0 and r.last_result is not None):
            # queue the window; it is processed only once the fleet's
            # minimum clock passes t_end, when every overlapping
            # training hold is in the ledger (see process_window)
            svc_windows.append((t0, t_end, r.last_result, r.epoch.spec))
        if prefill is not None and svc_windows:
            tmin = min(
                (runners[n].t for n in names if not runners[n].done),
                default=INF,
            )
            while svc_windows and svc_windows[0][1] <= tmin + 1e-9:
                process_window(*svc_windows.popleft())

        if ev == "migrated":
            cascade_replans += 1
            quiesced = set()
            mig_start = r.migrations[-1].at_ms
            close_segment(name, mig_start)
            # admission barrier: entering pairs where other jobs still
            # have open windows, wait for those holds to drain — the
            # extended stall keeps the entrant's fair-share target
            # available at its first contended iteration
            new_pairs = pair_demand_rates(r.epoch.spec, r.epoch.n_pipelines, 1.0)
            t_bar = r.t
            for pair in new_pairs:
                for res in pair_res.get(pair, ()):
                    if res.job != name and res.t1_ms > t_bar:
                        t_bar = res.t1_ms
            if t_bar > r.t:
                stats["admission_wait_ms"] += t_bar - r.t
                r.defer_epoch_start(t_bar)
            # the new demand claims from the migration *start* — no
            # unclaimed gap for windows other jobs open during the stall
            open_segment(name, start_ms=mig_start)
            continue
        if ev == "suppressed":
            stats["cascade_suppressed"] += 1
        if ev == "done":
            close_segment(name, r.t)  # the job released its channels
        quiesced.add(name)
        still_active = {n for n in names if not runners[n].done}
        if cascade_replans and still_active <= quiesced:
            # every active job completed an iteration without migrating:
            # the cascade epoch closes, the re-plan budget resets
            cascade_replans = 0
            quiesced = set()
            stats["cascade_epochs"] += 1

    results = {n: runners[n].result() for n in names}
    stats["replans_total"] = sum(hr.replans for hr in results.values())
    for n in names:
        stats["per_job"][n].update(
            total_ms=results[n].total_ms,
            samples=results[n].samples,
            replans=results[n].replans,
            migration_ms=results[n].migration_ms,
            replans_suppressed=results[n].stats.get("replans_suppressed", 0),
        )
    if prefill is not None:
        while svc_windows:  # every job is done; all windows are mature
            process_window(*svc_windows.popleft())
        busy, span = svc_state["busy_gpu_ms"], svc_state["span_gpu_ms"]
        stats["prefill"] = {
            "requests_offered": svc_state["next"],
            "requests_total": len(arrivals),
            "placed": len(ctrl.placements),
            "rejected": len(ctrl.rejected),
            "rejected_slo": len(ctrl.rejected_slo),
            "acceptance": ctrl.acceptance_rate(),
            "per_tier": ctrl.tier_report(),
            "prefill_gpu_busy_ms": ctrl.prefill_gpu_busy_ms(),
            "kv_wan_transfers": kvflows.n_wan,
            "kv_local_transfers": kvflows.n_local,
            "kv_wan_bits": kvflows.wan_bits,
            "kv_reservations": kvflows.kv_reservations,
            "host_gpu_ms": span,
            "utilization_train": busy / span if span > 0 else 0.0,
            "utilization_with_prefills": utilization_with_prefills(
                busy, span, ctrl
            ),
        }
    if tracing:
        # the ledger is final only now: migrations extend holds via
        # coalescing and KV segments append out of wall-clock order
        dcn = live_topo.dc_names
        for hold in reservations:
            tracer.span(
                hold.job, obs.CAT_FLEET, "fleet/wan",
                obs.pair_lane(hold.pair, dcn),
                hold.t0_ms, hold.t1_ms,
                rate_gbps=hold.rate_gbps, mult=hold.mult,
            )
    out = FleetResult(
        jobs=results,
        reservations=reservations,
        total_ms=max((hr.total_ms for hr in results.values()), default=0.0),
        stats=stats,
        prefill=ctrl,
    )
    if validate:
        from repro.core import validate as _validate

        _validate.check_fleet(out, live_topo)
    return out
