"""Seeded failure & elasticity engine — DC loss, spot preemption, joins.

The reactive control plane (``repro.core.control``) assumes every DC
survives the horizon: drift can re-route a placement, but nothing can
*force* one — a dead DC still hosts stages, a preempted spot slice still
counts toward capacity, and a freshly joined DC is invisible until the
next drift fire happens to re-plan.  Real geo-distributed fleets lose
DCs, get slices reclaimed, and gain capacity mid-run (ATOM's join/leave
elasticity; "99 Problems But FLOPS Ain't One" on WAN-scale failure
planning).  This module supplies the missing event model:

  * ``FailureEvent`` — one timestamped event: ``dc_outage`` (optionally
    healing after ``recover_ms``), ``slice_preemption`` (a DC's GPU
    slice shrinks), ``dc_join`` (capacity arrives), ``link_failure``
    (one WAN pair degrades, optionally healing).

  * ``FailureTrace`` — an ordered, optionally seed-generated sequence of
    events.  ``apply_to_topology`` bakes the bandwidth consequences into
    a ``TopologyMatrix`` (every directed pair touching a dead DC — or
    the failed pair itself — drops to ``residual_frac`` of its nominal
    rate for the outage window), so the *same physics* degrade a static
    run, a ship-live-weights recovery, and a checkpoint-aware one.
    ``timeline()`` yields the apply/heal steps the ``HorizonRunner``
    consumes to mutate its surviving fleet and force re-plans.

  * ``CheckpointPolicy`` — periodic async checkpoints written to
    ``placement`` DCs at ``write_bw_gbps``; feeds checkpoint *recency*
    (how many samples a restore forfeits) and *placement* (which DC a
    restore pulls from) into ``control.plan_restore`` so recovery can
    price restore-plus-replay against live weight shipment.

  * ``OutageWindow`` — the audit record of one outage's span, consumed
    by ``validate.check_horizon``/``check_fleet`` to assert nothing ran
    on (or reserved a channel into) a dead DC while it was down.

Bandwidth during an outage is *residual*, not zero: a reclaimed or
partitioned DC can usually still be reached over a trickle path (spot
grace periods, partial partitions), which is exactly what makes
"ship the live weights out anyway" finite-but-expensive — the trade
checkpoint-aware recovery is designed to win.  ``BandwidthSchedule``
also requires strictly positive rates, so a true hard-zero is
approximated by a small ``residual_frac``.

No jax imports here: the failure engine must run in the numpy-only
perf-smoke environment.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro import units
from repro.core import wan
from repro.core.topology import TopologyMatrix

KINDS = ("dc_outage", "slice_preemption", "dc_join", "link_failure")


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One timestamped failure/elasticity event.

    ``dc_outage``       ``dc`` goes dark at ``at_ms``; every WAN pair
                        touching it delivers ``residual_frac`` of its
                        nominal rate until ``at_ms + recover_ms`` (or
                        forever when ``recover_ms`` is None), and the
                        DC's GPUs leave the schedulable fleet.
    ``slice_preemption``  ``gpus`` GPUs of ``dc``'s slice are reclaimed
                        (per affected job — spot slices are per-tenant).
                        Bandwidth is untouched.
    ``dc_join``         ``dc`` offers ``gpus`` additional GPUs from
                        ``at_ms`` on — an opportunity, never a forced
                        re-plan.
    ``link_failure``    both directions of WAN pair ``pair`` drop to
                        ``residual_frac`` until recovery.
    """

    at_ms: float
    kind: str
    dc: Optional[str] = None
    gpus: int = 0
    pair: Optional[Tuple[str, str]] = None
    recover_ms: Optional[float] = None
    residual_frac: float = 0.05

    def __post_init__(self):
        assert self.kind in KINDS, f"unknown failure kind {self.kind!r}"
        assert self.at_ms >= 0.0, self.at_ms
        assert 0.0 < self.residual_frac < 1.0, self.residual_frac
        if self.kind == "link_failure":
            assert self.pair is not None and len(self.pair) == 2, self.pair
        else:
            assert self.dc is not None, f"{self.kind} needs a dc"
        if self.kind in ("slice_preemption", "dc_join"):
            assert self.gpus > 0, f"{self.kind} needs gpus > 0"
        if self.recover_ms is not None:
            assert self.recover_ms > 0.0, self.recover_ms

    @property
    def recovery_ms(self) -> Optional[float]:
        """Absolute heal time, or None when the failure is permanent."""
        if self.recover_ms is None:
            return None
        return self.at_ms + self.recover_ms

    def degrades_bandwidth(self) -> bool:
        return self.kind in ("dc_outage", "link_failure")


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Periodic async checkpointing for checkpoint-aware recovery.

    Every ``interval_ms`` of wall time the job snapshots its full state
    (weights + optimizer shards) and streams it to the ``placement``
    DCs at ``write_bw_gbps`` — the write is *asynchronous* (training
    does not stall for it), but a snapshot only becomes restorable once
    the write lands, ``write_ms`` after its stamp.  A restore pulls
    from the nearest *alive* placement DC and forfeits every sample
    since the newest durable snapshot (the replay debt
    ``control.plan_restore`` prices against live weight shipment).
    """

    interval_ms: float
    placement: Tuple[str, ...]
    write_bw_gbps: float = 1.0

    def __post_init__(self):
        assert self.interval_ms > 0.0, self.interval_ms
        assert self.placement, "checkpoint policy needs at least one placement DC"
        assert self.write_bw_gbps > 0.0, self.write_bw_gbps

    def write_ms(self, nbytes: float) -> float:
        """Async-write landing latency of one ``nbytes`` snapshot."""
        return units.serialization_ms(nbytes, self.write_bw_gbps)

    def alive_placement(self, dead_dcs) -> Tuple[str, ...]:
        return tuple(dc for dc in self.placement if dc not in dead_dcs)


@dataclasses.dataclass
class OutageWindow:
    """Audit record of one outage span — the negative-checkable fact
    ``validate.check_horizon``/``check_fleet`` test GPU busy time and
    channel reservations against.  ``t1_ms`` stays ``inf`` while the
    outage is unresolved at horizon end.  Windows open at the wall time
    the runner *handled* the event (iteration granularity): the
    iteration in flight when the failure lands completes, and only the
    span after the forced failover is claimed dead."""

    kind: str
    t0_ms: float
    t1_ms: float = math.inf
    dc: Optional[str] = None
    pair: Optional[Tuple[str, str]] = None

    def trace_args(self, topo: Optional[TopologyMatrix] = None) -> Dict:
        """Span args for the tracing layer: the named dc/pair plus their
        topology indices (when resolvable), so a trace validator can
        match outage windows against GPU-span ``dc`` indices without a
        name table."""
        out: Dict = {}
        if self.dc is not None:
            out["dc"] = self.dc
            if topo is not None and topo.dc_names:
                out["dc_index"] = topo.index_of(self.dc)
        if self.pair is not None:
            out["pair"] = list(self.pair)
            if topo is not None and topo.dc_names:
                out["pair_index"] = [topo.index_of(d) for d in self.pair]
        return out


@dataclasses.dataclass(frozen=True)
class FailureTrace:
    """An ordered, replayable sequence of failure/elasticity events.

    Events are sorted by ``at_ms`` on construction; ``timeline()``
    interleaves each event's apply step with its heal step (when it
    recovers), so a runner consumes one monotone stream.  The same
    trace (same ``seed`` through ``generate``) always replays the same
    cascade — determinism is a tested property.
    """

    events: Tuple[FailureEvent, ...]
    seed: Optional[int] = None

    def __post_init__(self):
        evs = tuple(sorted(self.events, key=lambda e: e.at_ms))
        object.__setattr__(self, "events", evs)

    def __len__(self) -> int:
        return len(self.events)

    def timeline(self) -> List[Tuple[float, str, FailureEvent]]:
        """Monotone ``(t_ms, phase, event)`` steps, ``phase`` in
        ``("apply", "heal")``; heals only exist for recovering
        ``dc_outage``/``link_failure`` events.  Ties order applies
        before heals, then by event order."""
        steps: List[Tuple[float, int, int, str, FailureEvent]] = []
        for i, ev in enumerate(self.events):
            steps.append((ev.at_ms, 0, i, "apply", ev))
            if ev.degrades_bandwidth() and ev.recover_ms is not None:
                steps.append((ev.recovery_ms, 1, i, "heal", ev))
            elif ev.kind == "slice_preemption" and ev.recover_ms is not None:
                steps.append((ev.recovery_ms, 1, i, "heal", ev))
        steps.sort(key=lambda s: (s[0], s[1], s[2]))
        return [(t, phase, ev) for t, _p, _i, phase, ev in steps]

    @classmethod
    def generate(
        cls,
        dcs: Sequence[str],
        *,
        seed: int,
        horizon_ms: float,
        n_events: int = 3,
        kinds: Sequence[str] = ("dc_outage", "slice_preemption", "dc_join"),
        mean_recover_frac: float = 0.3,
        max_slice_gpus: int = 4,
        residual_frac: float = 0.05,
    ) -> "FailureTrace":
        """A seeded random trace over ``dcs`` — same seed, same trace,
        same cascade.  Events land uniformly in the middle 80% of the
        horizon; outages recover after an exponential holding time of
        mean ``mean_recover_frac · horizon_ms`` (clamped away from
        zero) so some traces heal in-horizon and some don't."""
        rng = random.Random(seed)
        events: List[FailureEvent] = []
        names = list(dcs)
        for _ in range(n_events):
            kind = rng.choice(list(kinds))
            at = rng.uniform(0.1, 0.9) * horizon_ms
            if kind == "link_failure":
                a, b = rng.sample(names, 2)
                events.append(FailureEvent(
                    at_ms=at, kind=kind, pair=(a, b),
                    recover_ms=max(1.0, rng.expovariate(
                        1.0 / (mean_recover_frac * horizon_ms))),
                    residual_frac=residual_frac,
                ))
                continue
            dc = rng.choice(names)
            if kind == "dc_outage":
                rec = None
                if rng.random() < 0.7:
                    rec = max(1.0, rng.expovariate(
                        1.0 / (mean_recover_frac * horizon_ms)))
                events.append(FailureEvent(
                    at_ms=at, kind=kind, dc=dc, recover_ms=rec,
                    residual_frac=residual_frac,
                ))
            else:  # slice_preemption / dc_join
                events.append(FailureEvent(
                    at_ms=at, kind=kind, dc=dc,
                    gpus=rng.randint(1, max_slice_gpus),
                ))
        return cls(events=tuple(events), seed=seed)

    # -- bandwidth consequences -------------------------------------------

    def degraded_windows(
        self, topo: TopologyMatrix
    ) -> Dict[Tuple[int, int], List[Tuple[float, float, float]]]:
        """Per directed pair, the ``(t0, t1, frac)`` degradation windows
        this trace imposes (``t1`` may be ``inf``)."""
        assert topo.dc_names, "failure traces need a named topology"
        out: Dict[Tuple[int, int], List[Tuple[float, float, float]]] = {}
        for ev in self.events:
            if not ev.degrades_bandwidth():
                continue
            t1 = math.inf if ev.recover_ms is None else ev.recovery_ms
            if ev.kind == "dc_outage":
                idx = topo.index_of(ev.dc)
                pairs = [(a, b) for a, b in topo.wan_pairs() if idx in (a, b)]
            else:
                ia, ib = topo.index_of(ev.pair[0]), topo.index_of(ev.pair[1])
                pairs = [(ia, ib), (ib, ia)]
            for p in pairs:
                out.setdefault(p, []).append((ev.at_ms, t1, ev.residual_frac))
        return out

    def apply_to_topology(self, topo: TopologyMatrix) -> TopologyMatrix:
        """The live WAN with this trace's outages baked in: every
        affected directed pair carries a ``BandwidthSchedule`` whose
        rate drops to ``residual_frac ×`` nominal inside each outage
        window (overlapping windows compound to the worst fraction).
        Pairs the trace never touches keep their original links and
        schedules.  Existing schedules on affected pairs must be
        aperiodic (a periodic diurnal trace has no single composition
        grid); both directions of every touched pair are materialized
        so the reverse-pair fallback cannot alias a degraded direction
        onto a healthy one."""
        windows = self.degraded_windows(topo)
        if not windows:
            return topo
        # materialize both directions of touched pairs (fallback aliasing)
        touched = set(windows)
        for a, b in sorted(touched):
            touched.add((b, a))
        scheds = dict(topo.bw_schedules)
        for a, b in sorted(touched):
            base = topo.bandwidth_schedule(a, b)
            wins = windows.get((a, b), [])
            if base is not None:
                assert base.period_ms is None, (
                    "cannot compose failure windows onto a periodic schedule; "
                    "flatten it first (BandwidthSchedule.from_samples)"
                )
                bounds = set(base.times_ms)
                base_bw = base.bw_at
            else:
                bw0 = topo.link(a, b).bw_gbps
                bounds = {0.0}
                base_bw = lambda _t, _bw=bw0: _bw  # noqa: E731
            for t0, t1, _f in wins:
                bounds.add(t0)
                if math.isfinite(t1):
                    bounds.add(t1)
            times = sorted(bounds)
            rates = []
            for t in times:
                frac = 1.0
                for t0, t1, f in wins:
                    if t0 <= t < t1:
                        frac = min(frac, f)
                rates.append(base_bw(t) * frac)
            # coalesce equal-rate neighbours
            ct, cr = [times[0]], [rates[0]]
            for t, r in zip(times[1:], rates[1:]):
                if r != cr[-1]:
                    ct.append(t)
                    cr.append(r)
            scheds[(a, b)] = wan.BandwidthSchedule(tuple(ct), tuple(cr))
        return topo.with_bandwidth_schedules(scheds)

    # -- fleet consequences ------------------------------------------------

    def dead_dcs_at(self, t_ms: float) -> FrozenSet[str]:
        """DCs inside a ``dc_outage`` window at ``t_ms`` (event-time
        granularity — the runner's own windows open at handled time)."""
        dead = set()
        for ev in self.events:
            if ev.kind != "dc_outage" or ev.at_ms > t_ms:
                continue
            if ev.recover_ms is None or t_ms < ev.recovery_ms:
                dead.add(ev.dc)
        return frozenset(dead)
