"""Atlas temporal-bandwidth-sharing scheduler — the paper's §4.4 heuristic.

Unlike the reactive baselines (Varuna/GPipe react to arrivals), Atlas
*precomputes* the full iteration schedule for a DP-cell before training
starts.  This module is that scheduler: a serial list-scheduler over
(pipeline, stage, microbatch, phase) tasks and their WAN transfers,
implementing the paper's four rules:

  (1) the D DP pipelines of a cell share one WAN channel per stage
      boundary and direction at D× node-pair bandwidth, one transfer at a
      time (LocalDPRank staggering emerges from serialization order);
  (2) memory-cap filtering: a forward is only scheduled when the stage's
      in-flight count (forwards minus completed backwards) is below the
      cap — Atlas never exceeds peak memory, unlike Varuna;
  (3) compute is scheduled only if its output transfer can start the
      moment compute ends (no buffered activations clogging the channel):
      the task's start is delayed so that compute-end == channel-free;
  (4) when both forward and backward are ready at a stage, backward wins
      (it unlocks downstream stages).

Scheduling core: the original implementation re-scanned every available
task per pick (O(n·|avail|) — minutes at GPT-3 scale).  This one keeps
the candidates in a *lazy* priority heap keyed by the same rank
``(feasible_start, bwd-first, micro, rank)``.  Every component of a
task's feasible start is nondecreasing over time (GPU frees, channel
frees and the scheduled-task counters only move forward), so a popped
entry is either still the true minimum (schedule it), stale (re-push
with its recomputed rank), or cap-blocked (park it until the next
backward on that stage is scheduled).  The emitted schedule is
*identical* to the full-scan reference (``repro.core.reference``) —
ranks are unique per task, so no tie depends on scan order — at
O(n log n) instead of O(n²); ``tests/test_engine_equiv.py`` asserts the
equivalence.

The returned Schedule carries per-GPU busy intervals and transfer windows;
``repro.core.simulator.simulate(policy="atlas")`` wraps it into the same
SimResult shape as the reactive baselines.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro import units
from repro.core import wan


@dataclasses.dataclass
class Task:
    pipeline: int
    stage: int
    micro: int
    kind: str  # 'fwd' | 'bwd' (bwd includes recompute time)
    start: float = -1.0
    end: float = -1.0


@dataclasses.dataclass
class Transfer:
    pipeline: int
    boundary: int  # between stage b and b+1
    direction: str  # 'act' | 'grad'
    micro: int
    start: float
    end: float  # channel occupancy end
    arrive: float  # end + propagation latency


@dataclasses.dataclass
class Schedule:
    tasks: List[Task]
    transfers: List[Transfer]
    makespan: float
    num_stages: int
    num_pipelines: int

    def wan_bits(self, spec) -> Dict[Tuple[int, int], float]:
        """Bits the schedule's transfers put on each *directed* WAN DC
        pair — measured from the emitted transfers, the differential
        reference for the analytic per-iteration demand the fleet
        allocator uses (``simulator`` stats ``wan_bits``)."""
        out: Dict[Tuple[int, int], float] = {}
        for tr in self.transfers:
            b = tr.boundary
            dc_a, dc_b = spec.stage_dc[b], spec.stage_dc[b + 1]
            if dc_a == dc_b:
                continue
            src, dst = (dc_a, dc_b) if tr.direction == "act" else (dc_b, dc_a)
            out[(src, dst)] = out.get((src, dst), 0.0) + units.bytes_to_bits(
                spec.act_bytes
            )
        return out


def is_wan_boundary(spec, topo, b: int) -> bool:
    return spec.stage_dc[b] != spec.stage_dc[b + 1]


def atlas_schedule(
    spec,  # repro.core.simulator.PipelineSpec
    topo,  # simulator.GeoTopology | topology.TopologyMatrix
    n_pipelines: int,
    *,
    inflight_cap: Optional[int] = None,
    start_ms: float = 0.0,
    tracer=None,
) -> Schedule:
    """Precompute one iteration's schedule.  ``start_ms`` anchors the
    iteration at an absolute wall-clock offset: time-varying transfers
    are priced against the bandwidth segments in force at
    ``start_ms + (local start)`` — a transfer straddling a segment
    boundary keeps its sent bits and re-integrates the remainder at the
    new rate.  Task/transfer times stay iteration-local.

    ``tracer`` (``repro.obs.Tracer``, recording) emits the raw schedule
    as sim-time spans — one GPU span per task on ``atlas/gpu`` lanes,
    one channel span per WAN transfer on ``atlas/wan`` lanes, anchored
    at ``start_ms``.  Callers going through ``simulate(policy="atlas")``
    should pass the tracer there instead: the wrapped result adds the
    bubble/allreduce accounting and the second-witness expectation."""
    P, M, D = spec.num_stages, spec.microbatches, n_pipelines
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * t_f
    cap = inflight_cap if inflight_cap is not None else P

    def boundary_times(b: int, direction: str = "act") -> Tuple:
        """(occupancy, delivery delay, schedule, rate multiplier) for
        boundary b.

        Direction matters on asymmetric topologies: activations ride the
        b -> b+1 link, gradients the reverse b+1 -> b link (matching the
        event simulator's transfer times).  The intra-DC scatter/gather
        hops stream with the WAN send: they delay delivery but never
        hold the shared WAN channel.

        On a static pair the occupancy is the returned constant; a pair
        with a ``wan.BandwidthSchedule`` is priced per transfer at its
        actual start time (``_occupancy``), the cell's temporal sharing
        entering as a D× rate multiplier.  The returned constant is then
        the *worst-segment* occupancy — used only for the DP-injection
        stagger slot, where a conservative (largest) slot keeps the
        transfer demands interleaved through the slowest segment."""
        dc_a, dc_b = spec.stage_dc[b], spec.stage_dc[b + 1]
        link = topo.link(dc_a, dc_b) if direction == "act" else topo.link(dc_b, dc_a)
        sched = None
        get = getattr(topo, "bandwidth_schedule", None)
        if get is not None:
            sched = get(dc_a, dc_b) if direction == "act" else get(dc_b, dc_a)
        bw = link.bw_gbps if sched is None else sched.min_bw_gbps()
        if sched is not None and sched.is_flat():
            sched = None  # constant rate (= min_bw): keep the fast path
        ser = units.serialization_ms(spec.act_bytes, bw)
        if dc_a == dc_b:
            return ser, link.latency_ms, None, 1
        hop = units.serialization_ms(
            spec.act_bytes * (D - 1) / D, topo.intra_bw_gbps
        )
        return ser / D, link.latency_ms + 2.0 * hop, sched, D

    is_wan = [spec.stage_dc[b] != spec.stage_dc[b + 1] for b in range(P - 1)]
    btimes = {
        (b, d): boundary_times(b, d) for b in range(P - 1) for d in ("act", "grad")
    }

    def _occupancy(b: int, direction: str, start: float) -> float:
        """Channel occupancy of one transfer on boundary b beginning at
        ``start`` — integrates across bandwidth-schedule segments when
        the pair is time-varying, else the memoized constant."""
        ser, _delay, sched, mult = btimes[(b, direction)]
        if sched is None:
            return ser
        return sched.transfer_ms(spec.act_bytes, start_ms + start, rate_mult=mult)

    gpu_free = {(p, s): 0.0 for p in range(D) for s in range(P)}
    chan_free: Dict[Tuple[int, str], float] = {}
    # LocalDPRank stagger (§4.4 rule 1): offset each pipeline's injection
    # by one cell-transfer slot so transfer demands interleave instead of
    # bursting the shared channel (Fig 6(b): DP-2 starts at 1, DP-1 at 5).
    wan_sers = [
        btimes[(b, d)][0]
        for b in range(P - 1)
        if is_wan_boundary(spec, topo, b)
        for d in ("act", "grad")
    ]
    slot = max(wan_sers) if wan_sers else 0.0
    # dependency-readiness of tasks: time activation/grad is available
    avail: Dict[Tuple[str, int, int, int], float] = {}
    for p in range(D):
        for m in range(M):
            avail[("fwd", p, 0, m)] = p * slot
    fwd_sched = {(p, s): 0 for p in range(D) for s in range(P)}
    bwd_sched = {(p, s): 0 for p in range(D) for s in range(P)}

    tasks: List[Task] = []
    transfers: List[Transfer] = []
    n_total = D * P * M * 2
    done = 0

    def task_dur(kind: str, s: int) -> float:
        if kind == "fwd":
            return t_f
        rec = t_f if (spec.recompute and s != P - 1) else 0.0
        return t_b + rec

    def rank_of(key) -> Optional[Tuple]:
        """(feasible start, bwd-first, micro, rank) or None if cap-blocked.

        Rule 3 folds in here: the start is delayed so compute-end meets
        channel-free on the output boundary."""
        kind, p, s, m = key
        if kind == "fwd" and fwd_sched[(p, s)] - bwd_sched[(p, s)] >= cap:
            return None
        t0 = avail[key]
        gf = gpu_free[(p, s)]
        if gf > t0:
            t0 = gf
        has_out = (kind == "fwd" and s < P - 1) or (kind == "bwd" and s > 0)
        if has_out:
            out_b = s if kind == "fwd" else s - 1
            if is_wan[out_b]:
                direction = "act" if kind == "fwd" else "grad"
                cf = chan_free.get((out_b, direction), 0.0) - task_dur(kind, s)
                if cf > t0:
                    t0 = cf
        return (t0, 0 if kind == "bwd" else 1, m, p)

    heap: List[Tuple[Tuple, Tuple]] = []
    # cap-blocked forwards per (p, s), a min-heap of microbatch indices:
    # within one (pipeline, stage) forwards arrive and schedule in micro
    # order, so when a backward frees an in-flight slot only the
    # smallest-m parked forward can be the next candidate
    parked: Dict[Tuple[int, int], List[int]] = {}

    def add(key):
        r = rank_of(key)
        if r is None:
            kind, p, s, m = key
            heapq.heappush(parked.setdefault((p, s), []), m)
        else:
            heap.append((r, key))

    for key in avail:
        add(key)
    heapq.heapify(heap)

    def emit_transfer(p, b, direction, m, ready):
        delay = btimes[(b, direction)][1]
        if is_wan[b]:
            start = max(ready, chan_free.get((b, direction), 0.0))
            occ = _occupancy(b, direction, start)
            chan_free[(b, direction)] = start + occ
        else:
            start = ready  # intra-DC links are effectively uncontended
            occ = _occupancy(b, direction, start)
        arrive = start + occ + delay
        transfers.append(Transfer(p, b, direction, m, start, start + occ, arrive))
        dst = b + 1 if direction == "act" else b
        kind = "fwd" if direction == "act" else "bwd"
        key = (kind, p, dst, m)
        avail[key] = arrive
        r = rank_of(key)
        if r is None:
            heapq.heappush(parked.setdefault((p, dst), []), m)
        else:
            heapq.heappush(heap, (r, key))

    while done < n_total:
        assert heap, "deadlock in atlas schedule (cap too small?)"
        r, key = heapq.heappop(heap)
        if key not in avail:
            continue  # stale duplicate of an already-scheduled task
        r2 = rank_of(key)
        if r2 is None:  # became cap-blocked since it was pushed
            kind, p, s, m = key
            heapq.heappush(parked.setdefault((p, s), []), m)
            continue
        if heap and r2 > heap[0][0]:
            heapq.heappush(heap, (r2, key))  # stale rank: requeue and retry
            continue
        kind, p, s, m = key
        t0 = r2[0]
        del avail[key]
        dur = task_dur(kind, s)
        end = t0 + dur
        gpu_free[(p, s)] = end
        tasks.append(Task(p, s, m, kind, t0, end))
        if kind == "fwd":
            fwd_sched[(p, s)] += 1
            if s < P - 1:
                emit_transfer(p, s, "act", m, end)
            else:
                bkey = ("bwd", p, s, m)
                avail[bkey] = end
                br = rank_of(bkey)
                assert br is not None
                heapq.heappush(heap, (br, bkey))
        else:
            bwd_sched[(p, s)] += 1
            # rule 2: a scheduled backward frees exactly one in-flight
            # slot — admit the smallest-m parked forward for it
            pq = parked.get((p, s))
            if pq:
                pm = heapq.heappop(pq)
                pkey = ("fwd", p, s, pm)
                pr = rank_of(pkey)
                assert pr is not None  # the slot just freed
                heapq.heappush(heap, (pr, pkey))
            if s > 0:
                emit_transfer(p, s - 1, "grad", m, end)
        done += 1

    makespan = max(t.end for t in tasks)
    if transfers:
        makespan = max(makespan, max(tr.arrive for tr in transfers))
    sched = Schedule(tasks, transfers, makespan, P, D)
    if tracer is not None and getattr(tracer, "enabled", False):
        from repro import obs

        obs.trace_schedule(
            tracer, sched, spec, t0_ms=start_ms,
            dc_names=getattr(topo, "dc_names", None),
        )
    return sched
