"""Atlas temporal-bandwidth-sharing scheduler — the paper's §4.4 heuristic.

Unlike the reactive baselines (Varuna/GPipe react to arrivals), Atlas
*precomputes* the full iteration schedule for a DP-cell before training
starts.  This module is that scheduler: a serial list-scheduler over
(pipeline, stage, microbatch, phase) tasks and their WAN transfers,
implementing the paper's four rules:

  (1) the D DP pipelines of a cell share one WAN channel per stage
      boundary and direction at D× node-pair bandwidth, one transfer at a
      time (LocalDPRank staggering emerges from serialization order);
  (2) memory-cap filtering: a forward is only scheduled when the stage's
      in-flight count (forwards minus completed backwards) is below the
      cap — Atlas never exceeds peak memory, unlike Varuna;
  (3) compute is scheduled only if its output transfer can start the
      moment compute ends (no buffered activations clogging the channel):
      the task's start is delayed so that compute-end == channel-free;
  (4) when both forward and backward are ready at a stage, backward wins
      (it unlocks downstream stages).

The returned Schedule carries per-GPU busy intervals and transfer windows;
``repro.core.simulator.simulate(policy="atlas")`` wraps it into the same
SimResult shape as the reactive baselines.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core import wan


@dataclasses.dataclass
class Task:
    pipeline: int
    stage: int
    micro: int
    kind: str  # 'fwd' | 'bwd' (bwd includes recompute time)
    start: float = -1.0
    end: float = -1.0


@dataclasses.dataclass
class Transfer:
    pipeline: int
    boundary: int  # between stage b and b+1
    direction: str  # 'act' | 'grad'
    micro: int
    start: float
    end: float  # channel occupancy end
    arrive: float  # end + propagation latency


@dataclasses.dataclass
class Schedule:
    tasks: List[Task]
    transfers: List[Transfer]
    makespan: float
    num_stages: int
    num_pipelines: int


def is_wan_boundary(spec, topo, b: int) -> bool:
    return spec.stage_dc[b] != spec.stage_dc[b + 1]


def atlas_schedule(
    spec,  # repro.core.simulator.PipelineSpec
    topo,  # simulator.GeoTopology | topology.TopologyMatrix
    n_pipelines: int,
    *,
    inflight_cap: Optional[int] = None,
) -> Schedule:
    P, M, D = spec.num_stages, spec.microbatches, n_pipelines
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * t_f
    cap = inflight_cap if inflight_cap is not None else P

    def boundary_times(b: int, direction: str = "act") -> Tuple[float, float]:
        """(channel occupancy, delivery delay) for boundary b.

        Direction matters on asymmetric topologies: activations ride the
        b -> b+1 link, gradients the reverse b+1 -> b link (matching the
        event simulator's transfer_times).  The intra-DC scatter/gather
        hops stream with the WAN send: they delay delivery but never
        hold the shared WAN channel."""
        dc_a, dc_b = spec.stage_dc[b], spec.stage_dc[b + 1]
        link = topo.link(dc_a, dc_b) if direction == "act" else topo.link(dc_b, dc_a)
        ser = (spec.act_bytes * 8.0) / (link.bw_gbps * 1e9) * 1e3
        if dc_a == dc_b:
            return ser, link.latency_ms
        hop = (spec.act_bytes * (D - 1) / D * 8.0) / (topo.intra_bw_gbps * 1e9) * 1e3
        return ser / D, link.latency_ms + 2.0 * hop

    is_wan = [spec.stage_dc[b] != spec.stage_dc[b + 1] for b in range(P - 1)]

    gpu_free = {(p, s): 0.0 for p in range(D) for s in range(P)}
    chan_free: Dict[Tuple[int, str], float] = {}
    # LocalDPRank stagger (§4.4 rule 1): offset each pipeline's injection
    # by one cell-transfer slot so transfer demands interleave instead of
    # bursting the shared channel (Fig 6(b): DP-2 starts at 1, DP-1 at 5).
    wan_sers = [
        boundary_times(b, d)[0]
        for b in range(P - 1)
        if is_wan_boundary(spec, topo, b)
        for d in ("act", "grad")
    ]
    slot = max(wan_sers) if wan_sers else 0.0
    # dependency-readiness of tasks: time activation/grad is available
    avail: Dict[Tuple[str, int, int, int], float] = {}
    for p in range(D):
        for m in range(M):
            avail[("fwd", p, 0, m)] = p * slot
    fwd_sched = {(p, s): 0 for p in range(D) for s in range(P)}
    bwd_sched = {(p, s): 0 for p in range(D) for s in range(P)}

    tasks: List[Task] = []
    transfers: List[Transfer] = []
    n_total = D * P * M * 2
    done = 0

    def task_dur(kind: str, s: int) -> float:
        if kind == "fwd":
            return t_f
        rec = t_f if (spec.recompute and s != P - 1) else 0.0
        return t_b + rec

    def feasible_start(kind: str, p: int, s: int, m: int) -> Optional[float]:
        key = (kind, p, s, m)
        if key not in avail:
            return None
        if kind == "fwd" and fwd_sched[(p, s)] - bwd_sched[(p, s)] >= cap:
            return None
        t0 = max(avail[key], gpu_free[(p, s)])
        dur = task_dur(kind, s)
        # rule 3: output transfer must start at compute end
        out_b = s if kind == "fwd" else s - 1
        has_out = (kind == "fwd" and s < P - 1) or (kind == "bwd" and s > 0)
        if has_out and is_wan[out_b]:
            direction = "act" if kind == "fwd" else "grad"
            cf = chan_free.get((out_b, direction), 0.0)
            t0 = max(t0, cf - dur)
        return t0

    while done < n_total:
        # choose among ready tasks the earliest feasible start;
        # ties: backward first (rule 4), then micro, then rank
        best = None
        for key in list(avail.keys()):
            kind, p, s, m = key
            t0 = feasible_start(kind, p, s, m)
            if t0 is None:
                continue
            rank = (t0, 0 if kind == "bwd" else 1, m, p)
            if best is None or rank < best[0]:
                best = (rank, key, t0)
        assert best is not None, "deadlock in atlas schedule (cap too small?)"
        _, (kind, p, s, m), t0 = best
        del avail[(kind, p, s, m)]
        dur = task_dur(kind, s)
        end = t0 + dur
        gpu_free[(p, s)] = end
        tasks.append(Task(p, s, m, kind, t0, end))
        if kind == "fwd":
            fwd_sched[(p, s)] += 1
            if s < P - 1:
                _emit_transfer(
                    transfers, chan_free, boundary_times, avail,
                    p, s, "act", m, end, is_wan,
                )
            else:
                avail[("bwd", p, s, m)] = end
        else:
            bwd_sched[(p, s)] += 1
            if s > 0:
                _emit_transfer(
                    transfers, chan_free, boundary_times, avail,
                    p, s - 1, "grad", m, end, is_wan,
                )
        done += 1

    makespan = max(t.end for t in tasks)
    if transfers:
        makespan = max(makespan, max(tr.arrive for tr in transfers))
    return Schedule(tasks, transfers, makespan, P, D)


def _emit_transfer(transfers, chan_free, boundary_times, avail, p, b, direction, m, ready, is_wan):
    ser, delay = boundary_times(b, direction)
    if is_wan[b]:
        start = max(ready, chan_free.get((b, direction), 0.0))
        chan_free[(b, direction)] = start + ser
    else:
        start = ready  # intra-DC links are effectively uncontended
    arrive = start + ser + delay
    transfers.append(Transfer(p, b, direction, m, start, start + ser, arrive))
    dst = b + 1 if direction == "act" else b
    kind = "fwd" if direction == "act" else "bwd"
    avail[(kind, p, dst, m)] = arrive
