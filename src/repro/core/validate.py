"""Schedule-invariant checker — differential testing for the schedulers.

Any schedule the repo produces (a reactive ``SimResult`` from
``repro.core.simulator.simulate`` or a precomputed Atlas ``Schedule`` from
``repro.core.temporal``) must obey the physics of the machine it models:

  * a GPU never executes two tasks at once;
  * every (pipeline, stage) runs exactly M forwards and M backwards, with
    the documented durations (backward = bwd_mult·t_fwd, + recompute);
  * backward-after-forward causality per microbatch, and stage-order
    causality along the pipeline (an activation cannot be consumed before
    it was produced; a gradient cannot flow upstream before the
    downstream backward finished);
  * the in-flight memory cap holds (forwards never run more than ``cap``
    ahead of backwards on a stage);
  * WAN transfers serialize per (boundary, direction) channel and occupy
    it for at least the bytes/bandwidth serialization time (temporal
    sharing: 1/D of it) — priced against the ``wan.BandwidthSchedule``
    in force at the transfer's start when the pair is time-varying;
  * utilization ∈ [0, 1] and the reported bubbles exactly tile the
    complement of busy time within the pipeline span (the trailing DP
    all-reduce is busy communication, never a bubble);
  * the precomputed Atlas schedule and the event-driven simulator agree
    on iteration time.

Violations raise ``InvariantViolation`` (an ``AssertionError``, so these
work directly as pytest helpers).  ``simulate(..., validate=True)`` runs
the checker as an opt-in runtime assertion mode.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Dict, List, Optional, Tuple

from repro import units
from repro.core import wan

EPS = 1e-6


class InvariantViolation(AssertionError):
    """A schedule broke a physical invariant."""


def _fail(msg: str, *ctx) -> None:
    raise InvariantViolation(msg + (f" :: {ctx}" if ctx else ""))


# ---------------------------------------------------------------------------
# SimResult checks (any policy)
# ---------------------------------------------------------------------------


def _default_cap(spec, policy: Optional[str]) -> Optional[int]:
    if spec.inflight_cap is not None:
        return spec.inflight_cap
    if policy == "gpipe":
        return spec.microbatches
    if policy in ("megatron", "varuna", "atlas"):
        return spec.num_stages
    return None


def check_sim_result(
    res,
    spec,
    *,
    policy: Optional[str] = None,
    inflight_cap: Optional[int] = None,
) -> None:
    """Assert the physical invariants on a ``simulator.SimResult``."""
    P, M = spec.num_stages, spec.microbatches
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * t_f
    total = res.iteration_ms
    cap = inflight_cap if inflight_cap is not None else _default_cap(spec, policy)

    if not (-EPS <= res.utilization <= 1.0 + EPS):
        _fail("utilization outside [0, 1]", res.utilization)
    if total < -EPS:
        _fail("negative iteration time", total)
    if set(res.busy) != {(p, s) for p in range(res.n_pipelines) for s in range(P)}:
        _fail("busy map does not cover pipelines x stages")

    busy_sum = 0.0
    for g, ivs in res.busy.items():
        ivs = sorted(ivs, key=lambda iv: iv.start)
        by_kind: Dict[str, List] = {"fwd": [], "bwd": []}
        prev_end = 0.0
        for iv in ivs:
            if iv.start < -EPS or iv.end > total + EPS:
                _fail("interval outside [0, iteration]", g, iv)
            if iv.end <= iv.start + EPS:
                _fail("empty/negative interval", g, iv)
            if iv.start < prev_end - EPS:
                _fail("GPU executes two tasks at once", g, iv, prev_end)
            prev_end = iv.end
            busy_sum += iv.end - iv.start
            if iv.kind not in by_kind:
                _fail("unknown task kind", g, iv)
            by_kind[iv.kind].append(iv)
            dur = iv.end - iv.start
            if iv.kind == "fwd":
                if abs(dur - t_f) > EPS:
                    _fail("forward duration != t_fwd", g, iv, t_f)
            else:
                if not (abs(dur - t_b) < EPS or abs(dur - (t_b + t_f)) < EPS):
                    _fail("backward duration != t_bwd (+recompute)", g, iv, t_b)
        if len(by_kind["fwd"]) != M or len(by_kind["bwd"]) != M:
            _fail("stage did not run M forwards + M backwards", g,
                  len(by_kind["fwd"]), len(by_kind["bwd"]))
        micros_f = sorted(iv.micro for iv in by_kind["fwd"])
        micros_b = sorted(iv.micro for iv in by_kind["bwd"])
        if micros_f != list(range(M)) or micros_b != list(range(M)):
            _fail("microbatch indices not a permutation of 0..M-1", g)

        # backward-after-forward per microbatch
        f_end = {iv.micro: iv.end for iv in by_kind["fwd"]}
        for iv in by_kind["bwd"]:
            if iv.start < f_end[iv.micro] - EPS:
                _fail("backward before its forward", g, iv)

        # memory cap: completed forwards minus completed backwards at any
        # forward's start must leave room for it (sorted ends + bisect —
        # the naive quadratic scan dominated validation at paper-scale M)
        if cap is not None:
            f_ends = sorted(o.end for o in by_kind["fwd"])
            b_ends = sorted(o.end for o in by_kind["bwd"])
            for iv in by_kind["fwd"]:
                in_flight = bisect_right(f_ends, iv.start + EPS) \
                    - bisect_right(b_ends, iv.start + EPS)
                if in_flight >= cap:
                    _fail("in-flight cap exceeded", g, iv, in_flight, cap)

    # stage-order causality (transfers only delay, never advance)
    for p in range(res.n_pipelines):
        for s in range(P - 1):
            fa = {iv.micro: iv for iv in res.busy[(p, s)] if iv.kind == "fwd"}
            fb = {iv.micro: iv for iv in res.busy[(p, s + 1)] if iv.kind == "fwd"}
            ba = {iv.micro: iv for iv in res.busy[(p, s)] if iv.kind == "bwd"}
            bb = {iv.micro: iv for iv in res.busy[(p, s + 1)] if iv.kind == "bwd"}
            for m in range(M):
                if fb[m].start < fa[m].end - EPS:
                    _fail("activation consumed before produced", p, s, m)
                if ba[m].start < bb[m].end - EPS:
                    _fail("gradient consumed before produced", p, s, m)

    # bubbles tile the complement of busy within the pipeline span
    # [0, pp_end]: the trailing DP all-reduce is busy communication, so
    # no reported bubble may overlap it
    pp_end = total - res.allreduce_ms
    for g, ivs in res.busy.items():
        gaps = []
        cur = 0.0
        for iv in sorted(ivs, key=lambda iv: iv.start):
            if iv.start > cur + 1e-9:
                gaps.append((cur, iv.start))
            cur = max(cur, iv.end)
        if cur < pp_end - 1e-9:
            gaps.append((cur, pp_end))
        rec = res.bubbles.get(g)
        # exact tiling against gaps capped at pp_end also guarantees no
        # recorded bubble overlaps the all-reduce span
        if rec is None or len(rec) != len(gaps) or any(
            abs(a - c) > 1e-6 or abs(b - d) > 1e-6
            for (a, b), (c, d) in zip(gaps, rec)
        ):
            _fail("bubbles do not tile the complement of busy", g)

    n_gpus = len(res.busy)
    if total > 0:
        want_util = busy_sum / (total * n_gpus)
        if abs(want_util - res.utilization) > 1e-6:
            _fail("utilization inconsistent with busy intervals",
                  res.utilization, want_util)


# ---------------------------------------------------------------------------
# Atlas Schedule checks (transfers + channels)
# ---------------------------------------------------------------------------


def check_schedule(
    sched, spec, topo, *, inflight_cap: Optional[int] = None, start_ms: float = 0.0
) -> None:
    """Assert the §4.4 invariants on a precomputed ``temporal.Schedule``.

    ``start_ms`` anchors the schedule at an absolute wall-clock offset
    (matching ``temporal.atlas_schedule(..., start_ms=...)``): transfer
    occupancies are priced against the bandwidth segments in force at
    ``start_ms + tr.start``, so a per-epoch plan inside a re-planning
    horizon is checked against the WAN it actually ran on."""
    P, M = spec.num_stages, spec.microbatches
    D = sched.num_pipelines
    t_f = spec.t_fwd_ms
    t_b = spec.bwd_mult * t_f

    tasks_by_gpu: Dict[Tuple[int, int], List] = {}
    task_index: Dict[Tuple[str, int, int, int], object] = {}
    for t in sched.tasks:
        if not (0 <= t.stage < P and 0 <= t.pipeline < D and 0 <= t.micro < M):
            _fail("task outside spec ranges", t)
        tasks_by_gpu.setdefault((t.pipeline, t.stage), []).append(t)
        task_index[(t.kind, t.pipeline, t.stage, t.micro)] = t

    for g, ts in tasks_by_gpu.items():
        ts.sort(key=lambda t: t.start)
        prev = 0.0
        for t in ts:
            if t.start < prev - EPS:
                _fail("GPU executes two tasks at once (schedule)", g, t)
            prev = t.end
            dur = t.end - t.start
            want = t_f if t.kind == "fwd" else (
                t_b + (t_f if (spec.recompute and t.stage != P - 1) else 0.0)
            )
            if abs(dur - want) > EPS:
                _fail("task duration mismatch", g, t, want)
        nf = sum(1 for t in ts if t.kind == "fwd")
        nb = sum(1 for t in ts if t.kind == "bwd")
        if nf != M or nb != M:
            _fail("stage did not run M forwards + M backwards (schedule)", g, nf, nb)

    cap = inflight_cap if inflight_cap is not None else (
        spec.inflight_cap if spec.inflight_cap is not None else P
    )
    for g, ts in tasks_by_gpu.items():
        f_starts = sorted(t.start for t in ts if t.kind == "fwd")
        b_ends = sorted(t.end for t in ts if t.kind == "bwd")
        for t in ts:
            if t.kind != "fwd":
                continue
            in_flight = bisect_right(f_starts, t.start + EPS) \
                - bisect_right(b_ends, t.start + EPS)
            if in_flight > cap:
                _fail("in-flight cap exceeded (schedule)", g, t, in_flight, cap)

    # transfers: channel serialization, bandwidth, and dependency edges
    get_sched = getattr(topo, "bandwidth_schedule", None)
    chan: Dict[Tuple[int, str], List] = {}
    for tr in sched.transfers:
        b = tr.boundary
        dc_a, dc_b = spec.stage_dc[b], spec.stage_dc[b + 1]
        # activations ride b -> b+1, gradients the reverse link (matters
        # on asymmetric topologies)
        src, dst = (dc_a, dc_b) if tr.direction == "act" else (dc_b, dc_a)
        link = topo.link(src, dst)
        is_wan_b = dc_a != dc_b
        # minimum physical occupancy, priced against the bandwidth
        # schedule in force over [tr.start, tr.end) when the pair is
        # time-varying (temporal sharing: the cell transfers at D×)
        bw_sched = get_sched(src, dst) if get_sched is not None else None
        if bw_sched is not None:
            ser = bw_sched.transfer_ms(
                spec.act_bytes, start_ms + tr.start, rate_mult=D if is_wan_b else 1
            )
        else:
            ser_one = units.serialization_ms(spec.act_bytes, link.bw_gbps)
            ser = ser_one / D if is_wan_b else ser_one
        occupancy = tr.end - tr.start
        if occupancy < ser - EPS:
            _fail("transfer faster than link bandwidth allows", tr, ser)
        if tr.arrive < tr.end + link.latency_ms - EPS:
            _fail("transfer arrives before propagation latency", tr, link)
        src_kind, src_stage = ("fwd", b) if tr.direction == "act" else ("bwd", b + 1)
        dst_kind, dst_stage = ("fwd", b + 1) if tr.direction == "act" else ("bwd", b)
        src = task_index.get((src_kind, tr.pipeline, src_stage, tr.micro))
        dst = task_index.get((dst_kind, tr.pipeline, dst_stage, tr.micro))
        if src is None or dst is None:
            _fail("transfer without producer/consumer task", tr)
        if tr.start < src.end - EPS:
            _fail("transfer starts before its producer finished", tr, src)
        if dst.start < tr.arrive - EPS:
            _fail("consumer starts before transfer arrived", tr, dst)
        if is_wan_b:
            chan.setdefault((b, tr.direction), []).append(tr)

    for key, trs in chan.items():
        trs.sort(key=lambda tr: tr.start)
        prev = trs[0]
        for tr in trs[1:]:
            if tr.start < prev.end - EPS:
                _fail("two transfers share a WAN channel at once", key, prev, tr)
            prev = tr

    last = max([t.end for t in sched.tasks] + [tr.arrive for tr in sched.transfers])
    if abs(last - sched.makespan) > EPS:
        _fail("makespan inconsistent with tasks/transfers", last, sched.makespan)


# ---------------------------------------------------------------------------
# differential: precomputed Atlas schedule vs event-driven simulation
# ---------------------------------------------------------------------------


def check_atlas_consistency(
    spec, topo, n_pipelines: int = 1, dp_replicas: int = 1, start_ms: float = 0.0
) -> None:
    """The precomputed §4.4 schedule and the event-driven simulator must
    report the same iteration time (the simulator's atlas policy wraps the
    schedule; this guards the wrapper AND re-validates both artifacts)."""
    from repro.core import simulator, temporal

    sched = temporal.atlas_schedule(
        spec, topo, n_pipelines, inflight_cap=spec.inflight_cap, start_ms=start_ms
    )
    check_schedule(sched, spec, topo, start_ms=start_ms)
    res = simulator.simulate(
        spec, topo, policy="atlas", n_pipelines=n_pipelines,
        dp_replicas_for_allreduce=dp_replicas, start_ms=start_ms,
    )
    check_sim_result(res, spec, policy="atlas")
    ar = wan.allreduce_ms(
        spec.stage_param_bytes, dp_replicas, topo.intra_bw_gbps
    )
    if abs((sched.makespan + ar) - res.iteration_ms) > EPS:
        _fail("precomputed schedule and simulator disagree on iteration time",
              sched.makespan + ar, res.iteration_ms)


def check_horizon(hr, live_topo, *, check_epoch_schedules: bool = True) -> None:
    """Assert the control-plane invariants on a ``control.HorizonResult``.

      * epochs and migration windows tile ``[0, total_ms]`` exactly —
        training never overlaps a migration (the stall occupies the
        GPUs), and every migration sits between the epoch it closed and
        the epoch it opened;
      * each per-epoch plan passes ``check_schedule`` *independently*,
        anchored at its own wall-clock offset (transfers priced against
        the live bandwidth segments in force during that epoch);
      * migration transfers serialize per directed WAN pair, stay inside
        their stall window, and occupy the channel for at least the
        physical (schedule-integrated) serialization of the moved bytes;
      * failure/elasticity (``hr.outages`` non-empty): no epoch with GPU
        busy time places a stage in a dead DC inside its outage window,
        and sample accounting is consistent with checkpoint recency —
        a ship-mode migration carries zero replay debt and preserves
        sample continuity exactly; a restore-mode one resumes at its
        checkpoint's sample count with ``replay_samples`` equal to the
        progress it forfeited.
    """
    import math

    migs = list(hr.migrations)
    if len(hr.epochs) != len(migs) + 1:
        _fail("epoch/migration counts inconsistent", len(hr.epochs), len(migs))
    prev_end = 0.0
    for i, ep in enumerate(hr.epochs):
        if abs(ep.start_ms - prev_end) > EPS:
            _fail("epoch does not start where the previous span ended",
                  i, ep.start_ms, prev_end)
        if math.isnan(ep.end_ms) or ep.end_ms < ep.start_ms - EPS:
            _fail("epoch end missing or before its start", i, ep.end_ms)
        if i < len(migs):
            m = migs[i]
            if abs(m.at_ms - ep.end_ms) > EPS:
                _fail("migration does not begin when its epoch ends",
                      i, m.at_ms, ep.end_ms)
            prev_end = m.at_ms + m.duration_ms
        else:
            prev_end = ep.end_ms
    if abs(prev_end - hr.total_ms) > EPS:
        _fail("epoch/migration spans do not tile the horizon",
              prev_end, hr.total_ms)

    if check_epoch_schedules and hr.policy == "atlas":
        from repro.core import temporal

        for ep in hr.epochs:
            sched = temporal.atlas_schedule(
                ep.spec, live_topo, ep.n_pipelines,
                inflight_cap=ep.spec.inflight_cap, start_ms=ep.start_ms,
            )
            check_schedule(sched, ep.spec, live_topo, start_ms=ep.start_ms)

    get_sched = getattr(live_topo, "bandwidth_schedule", None)
    for m in migs:
        window_end = m.at_ms + m.duration_ms
        by_pair: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
        for src, dst, s, e in m.transfers:
            if s < m.at_ms - EPS or e > window_end + EPS:
                _fail("migration transfer outside its stall window", m.at_ms, (s, e))
            link = live_topo.link(src, dst)
            bw_sched = get_sched(src, dst) if get_sched is not None else None
            if bw_sched is not None:
                ser = bw_sched.transfer_ms(m.bytes_per_stage, s)
            else:
                ser = units.serialization_ms(m.bytes_per_stage, link.bw_gbps)
            if (e - s) < ser - EPS:
                _fail("migration transfer faster than the live link allows",
                      (src, dst), (s, e), ser)
            by_pair.setdefault((src, dst), []).append((s, e))
        for pair, ws in by_pair.items():
            ws.sort()
            for (s0, e0), (s1, e1) in zip(ws, ws[1:]):
                if s1 < e0 - EPS:
                    _fail("two migration transfers share a WAN channel at once",
                          pair, (s0, e0), (s1, e1))

    # --- failure & elasticity invariants (inert without outages) ---------
    for w in getattr(hr, "outages", None) or []:
        if w.kind != "dc_outage":
            continue
        idx = live_topo.index_of(w.dc)
        t1 = min(w.t1_ms, hr.total_ms)
        for ep in hr.epochs:
            if ep.iterations <= 0:
                continue
            end = ep.end_ms if not math.isnan(ep.end_ms) else hr.total_ms
            if end <= w.t0_ms + EPS or ep.start_ms >= t1 - EPS:
                continue
            if idx in ep.spec.stage_dc:
                _fail("GPU busy time inside a dead DC's outage window",
                      w.dc, (w.t0_ms, t1), ep.index, ep.spec.stage_dc)

    for i, m in enumerate(migs):
        if m.replay_samples < -EPS:
            _fail("negative replay debt", i, m.replay_samples)
        ep, nxt = hr.epochs[i], hr.epochs[i + 1]
        progress = ep.start_sample + ep.iterations * ep.samples_per_iteration
        if getattr(m, "mode", "ship") == "restore":
            if math.isnan(m.ckpt_samples):
                _fail("restore-mode migration missing its checkpoint stamp", i)
            if abs(nxt.start_sample - m.ckpt_samples) > 1e-6:
                _fail("restored epoch does not resume at its checkpoint's "
                      "sample count", i, nxt.start_sample, m.ckpt_samples)
            if abs(m.replay_samples - (progress - m.ckpt_samples)) > 1e-6:
                _fail("replay debt inconsistent with checkpoint recency",
                      i, m.replay_samples, progress, m.ckpt_samples)
        else:
            if m.replay_samples != 0.0:
                _fail("ship-mode migration claims replay debt", i,
                      m.replay_samples)
            if abs(nxt.start_sample - progress) > 1e-6:
                _fail("sample accounting broken across a migration",
                      i, nxt.start_sample, progress)


def check_fleet(fr, live_topo, *, check_jobs: bool = True) -> None:
    """Assert the multi-job fleet invariants on a ``fleet.FleetResult``.

      * per job: epochs and migration windows tile its horizon exactly
        (``check_horizon`` without per-epoch schedule re-derivation —
        fleet epochs ran on *contended* topology views that change with
        the allocation generation, so re-pricing them against the live
        matrix would be checking different physics);
      * the fleet capacity invariant: on every directed channel, the
        aggregate rate the allocator reserved never exceeds the
        schedule's capacity at any instant.  Reservations are
        piecewise-constant, so the check walks the elementary intervals
        of their union and compares the rate sum against the channel's
        *lowest* rate in force anywhere in the interval
        (``wan.BandwidthSchedule.min_bw_over``) — a pointwise bound,
        not an integral one;
      * per (job, channel): reservation windows never overlap.  Training
        windows are recorded sequentially per job (coalesced when
        contiguous) and KV-handoff transfers (the ``~prefill`` pseudo-
        job of ``fleet.KVFlows``) serialize behind a per-channel cursor,
        so an overlap means double-booking — e.g. a KV transfer priced
        before its predecessor's segments were committed.
    """
    if check_jobs:
        for hr in fr.jobs.values():
            check_horizon(hr, live_topo, check_epoch_schedules=False)

    # failure invariant: none of a job's channel reservations may touch a
    # dead DC (or ride a failed pair) inside that job's outage windows —
    # the straddling iteration ends exactly where the window opens, and
    # every post-failover placement must have routed off the dead
    # resources.  Windows are per-job (handled-time granularity), so one
    # job's outage never indicts another job's healthy reservation; the
    # KV pseudo-job carries no outage record and is exempt.
    for jname, hr in sorted(fr.jobs.items()):
        for w in getattr(hr, "outages", None) or []:
            t1 = min(w.t1_ms, hr.total_ms)
            if w.kind == "dc_outage":
                idx = live_topo.index_of(w.dc)
                affected = lambda p: idx in p  # noqa: E731
            else:  # link_failure
                dead = {live_topo.index_of(w.pair[0]),
                        live_topo.index_of(w.pair[1])}
                affected = lambda p: set(p) == dead  # noqa: E731
            for r in fr.reservations:
                if r.job != jname or r.rate_gbps <= EPS:
                    continue
                if not affected(tuple(r.pair)):
                    continue
                if r.t0_ms < t1 - EPS and r.t1_ms > w.t0_ms + EPS:
                    _fail("channel reservation touches dead resources "
                          "during an outage window", jname, w.kind,
                          w.dc or w.pair, (w.t0_ms, t1), r)

    by_pair: Dict[Tuple[int, int], List] = {}
    by_job_pair: Dict[Tuple[str, Tuple[int, int]], List] = {}
    for r in fr.reservations:
        if r.t1_ms < r.t0_ms - EPS:
            _fail("reservation window inverted", r)
        if r.rate_gbps < -EPS:
            _fail("negative reservation rate", r)
        by_pair.setdefault(tuple(r.pair), []).append(r)
        by_job_pair.setdefault((r.job, tuple(r.pair)), []).append(r)

    for (job, pair), rs in sorted(by_job_pair.items()):
        ws = sorted((r.t0_ms, r.t1_ms) for r in rs)
        for (s0, e0), (s1, e1) in zip(ws, ws[1:]):
            if s1 < e0 - EPS:
                _fail(
                    "one job's reservations overlap on a channel",
                    job, pair, (s0, e0), (s1, e1),
                )

    get_sched = getattr(live_topo, "bandwidth_schedule", None)
    for pair, rs in sorted(by_pair.items()):
        link = live_topo.link(*pair)
        sched = get_sched(*pair) if get_sched is not None else None
        # sweep over the sorted window endpoints (+rate at t0, −rate at
        # t1): one O(R log R) pass maintains the pointwise rate sum —
        # re-scanning all reservations per elementary interval would be
        # O(R²) on a hot channel
        events = sorted(
            [(r.t0_ms, r.rate_gbps) for r in rs]
            + [(r.t1_ms, -r.rate_gbps) for r in rs]
        )
        total = 0.0
        for i, (x0, delta) in enumerate(events):
            total += delta
            x1 = events[i + 1][0] if i + 1 < len(events) else x0
            if x1 - x0 <= EPS or total <= EPS:
                continue
            cap = (
                sched.min_bw_over(x0, x1) if sched is not None else link.bw_gbps
            )
            if total > cap * (1.0 + 1e-9) + EPS:
                _fail(
                    "aggregate channel reservations exceed capacity",
                    pair, (x0, x1), total, cap,
                )


def check_policy(spec, topo, policy: str, n_pipelines: int = 1):
    """Simulate one policy with validation on; returns the SimResult."""
    from repro.core import simulator

    res = simulator.simulate(spec, topo, policy=policy, n_pipelines=n_pipelines)
    check_sim_result(res, spec, policy=policy)
    return res


# ---------------------------------------------------------------------------
# differential: two SimResults must be interval-identical
# ---------------------------------------------------------------------------


def check_equivalent(res_a, res_b, *, eps: float = EPS) -> None:
    """Assert two ``SimResult``s describe the *same* schedule: identical
    interval sets per GPU (start, end, kind, micro), iteration time,
    utilization and bubbles.  The engine-equivalence net: optimized
    engine vs ``repro.core.reference``, and steady-state fast-forward vs
    full event replay."""
    if res_a.n_pipelines != res_b.n_pipelines:
        _fail("pipeline counts differ", res_a.n_pipelines, res_b.n_pipelines)
    if set(res_a.busy) != set(res_b.busy):
        _fail("busy maps cover different GPUs")
    if abs(res_a.iteration_ms - res_b.iteration_ms) > eps:
        _fail("iteration times differ", res_a.iteration_ms, res_b.iteration_ms)
    if abs(res_a.allreduce_ms - res_b.allreduce_ms) > eps:
        _fail("all-reduce times differ", res_a.allreduce_ms, res_b.allreduce_ms)
    if abs(res_a.utilization - res_b.utilization) > 1e-9:
        _fail("utilizations differ", res_a.utilization, res_b.utilization)
    key = lambda iv: (iv.start, iv.kind, iv.micro)  # noqa: E731
    for g in res_a.busy:
        ivs_a = sorted(res_a.busy[g], key=key)
        ivs_b = sorted(res_b.busy[g], key=key)
        if len(ivs_a) != len(ivs_b):
            _fail("interval counts differ", g, len(ivs_a), len(ivs_b))
        for a, b in zip(ivs_a, ivs_b):
            if (
                abs(a.start - b.start) > eps
                or abs(a.end - b.end) > eps
                or a.kind != b.kind
                or a.micro != b.micro
            ):
                _fail("intervals differ", g, a, b)
        gaps_a, gaps_b = res_a.bubbles[g], res_b.bubbles[g]
        if len(gaps_a) != len(gaps_b) or any(
            abs(x0 - y0) > eps or abs(x1 - y1) > eps
            for (x0, x1), (y0, y1) in zip(gaps_a, gaps_b)
        ):
            _fail("bubbles differ", g)


def check_trace(tracer) -> int:
    """Second-witness trace check as an engine invariant: re-derive
    utilization / bubble / allreduce / wan_bits totals from the spans a
    :class:`repro.obs.RecordingTracer` collected and compare against the
    expectations the engines registered at emission time.  Wraps
    ``obs.crosscheck`` so trace mismatches surface as the same
    ``InvariantViolation`` family every other checker raises.  Returns
    the number of iteration windows verified."""
    from repro import obs

    try:
        return obs.verify_trace(tracer)
    except obs.TraceMismatch as e:
        _fail(f"trace crosscheck failed: {e}")


def check_fast_forward(spec, topo, policy: str, n_pipelines: int = 1):
    """Cross-check the steady-state fast-forward against full event
    replay: both paths must produce interval-identical results (and both
    must pass the physical invariants).  Returns (fast result, whether
    the fast-forward actually engaged)."""
    from repro.core import simulator

    full = simulator.simulate(
        spec, topo, policy=policy, n_pipelines=n_pipelines, fast_forward=False
    )
    fast = simulator.simulate(
        spec, topo, policy=policy, n_pipelines=n_pipelines, fast_forward=True
    )
    check_sim_result(full, spec, policy=policy)
    check_sim_result(fast, spec, policy=policy)
    check_equivalent(full, fast)
    return fast, bool(fast.stats and fast.stats.get("fast_forward"))
