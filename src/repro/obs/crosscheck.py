"""Second-witness cross-check: the trace must re-derive the engine's
own accounting.

``verify_trace`` takes a :class:`~repro.obs.tracer.RecordingTracer`
whose expectations were registered at emission time (each one is the
engine's first-witness totals for one iteration window) and recomputes,
from the emitted spans alone:

* GPU utilization — busy span time over ``window * n_lanes``, the same
  quotient ``simulator._finalize`` forms;
* bubble totals — the sum of ``bubble`` span durations;
* per-lane allreduce durations;
* per-directed-pair WAN bits — the sum of ``transfer`` span ``bits``
  args, which count ``bytes_to_bits(act_bytes) * replicas`` per
  recorded transfer.  The expectation side is the engines' *analytic*
  ``stats["wan_bits"]`` (``simulator.iteration_wan_bits``), so the two
  witnesses really are independent: one counts what moved on the wire,
  the other derives what must move from the model.

Comparisons use ``math.isclose`` at ``rel_tol=1e-9`` — the only
admissible slack is float summation order (the witness accumulates in
sorted-lane order, ``_finalize`` in dict order), orders of magnitude
below any real corruption.  This intentionally mirrors
``validate.check_sim_result``'s bubble-tiling/utilization accounting
(``validate.EPS``-style tolerances on derived quantities, exact
identity on counts).
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.obs.tracer import BUSY_KINDS, Expectation, SpanEvent

#: tolerance for re-derived totals: float summation order only
REL_TOL = 1e-9
ABS_TOL = 1e-6


class TraceMismatch(AssertionError):
    """The spans do not re-derive the engine's accounting."""


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=REL_TOL, abs_tol=ABS_TOL)


def _in_window(sp: SpanEvent, t0_ms: float, t1_ms: float) -> bool:
    return sp.t0_ms >= t0_ms - ABS_TOL and sp.t1_ms <= t1_ms + ABS_TOL


def _check_window(exp: Expectation, spans: List[SpanEvent]) -> None:
    gpu_pid = f"{exp.label}/gpu"
    sel = [
        sp for sp in spans
        if sp.pid == gpu_pid and _in_window(sp, exp.t0_ms, exp.t1_ms)
    ]
    window_ms = exp.t1_ms - exp.t0_ms
    busy_sum = 0.0
    bubble_sum = 0.0
    lanes = set()
    for sp in sel:
        lanes.add(sp.tid)
        if sp.name in BUSY_KINDS:
            busy_sum += sp.duration_ms
        elif sp.name == "bubble":
            bubble_sum += sp.duration_ms
        elif sp.name == "allreduce":
            if not _close(sp.duration_ms, exp.allreduce_ms):
                raise TraceMismatch(
                    f"{exp.label} @ {exp.t0_ms}: allreduce span "
                    f"{sp.duration_ms} != {exp.allreduce_ms}"
                )
    if len(lanes) != exp.n_lanes:
        raise TraceMismatch(
            f"{exp.label} @ {exp.t0_ms}: {len(lanes)} GPU lanes traced, "
            f"engine accounted {exp.n_lanes}"
        )
    util = (
        busy_sum / (window_ms * exp.n_lanes)
        if window_ms > 0 and exp.n_lanes
        else 0.0
    )
    if not _close(util, exp.utilization):
        raise TraceMismatch(
            f"{exp.label} @ {exp.t0_ms}: span-derived utilization {util} "
            f"!= engine utilization {exp.utilization}"
        )
    if not _close(bubble_sum, exp.bubble_ms):
        raise TraceMismatch(
            f"{exp.label} @ {exp.t0_ms}: span-derived bubble total "
            f"{bubble_sum} != engine bubble total {exp.bubble_ms}"
        )
    if exp.wan_bits is None:
        return
    chan_pid = f"{exp.label}/wan"
    derived: Dict[Tuple[int, int], float] = {}
    for sp in spans:
        if sp.pid != chan_pid or not _in_window(sp, exp.t0_ms, exp.t1_ms):
            continue
        pair = tuple(sp.arg("pair"))
        derived[pair] = derived.get(pair, 0.0) + sp.arg("bits", 0.0)
    expected = dict(exp.wan_bits)
    for pair in sorted(set(derived) | set(expected)):
        got = derived.get(pair, 0.0)
        want = expected.get(pair, 0.0)
        if not _close(got, want):
            raise TraceMismatch(
                f"{exp.label} @ {exp.t0_ms}: channel {pair} moved {got} "
                f"bits in spans, engine accounted {want}"
            )


def verify_trace(tracer) -> int:
    """Check every registered expectation against the recorded spans;
    returns the number of windows verified.  Raises
    :class:`TraceMismatch` on the first disagreement."""
    spans = list(tracer.spans)
    for exp in tracer.expectations:
        _check_window(exp, spans)
    return len(tracer.expectations)
