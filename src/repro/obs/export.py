"""Byte-deterministic Chrome trace-event JSON export.

The output loads in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``: one process per ``pid`` lane group (a job's GPU
grid, the shared WAN, the prefill service), one thread per lane, with
span (``"X"``), instant (``"i"``), counter (``"C"``) and metadata
(``"M"``) events.  Timestamps are microseconds in the trace format, so
sim-time milliseconds are scaled by 1e3 at the boundary and rounded to
nanosecond resolution to keep the file stable and small.

Determinism contract (regression-tested byte-for-byte across process
restarts and ``PYTHONHASHSEED`` values):

* numeric pid/tid ids are assigned by *sorting* the string lane names,
  never by first-seen or hash order;
* events are emitted in a total sort order (timestamp, lane, phase,
  name, payload);
* the JSON is dumped with sorted keys and fixed separators.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

#: Chrome trace-event timestamps are microseconds; sim time is ms.
_US_PER_MS = 1e3


def _us(t_ms: float) -> float:
    return round(t_ms * _US_PER_MS, 3)


def _jsonable(value):
    if isinstance(value, tuple):
        return [_jsonable(v) for v in value]
    if isinstance(value, list):
        return [_jsonable(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _jsonable(v) for k, v in sorted(value.items(), key=lambda kv: str(kv[0]))}
    return value


def _args_dict(args) -> Dict[str, object]:
    return {k: _jsonable(v) for k, v in args}


def chrome_trace(tracer, *, label: Optional[str] = None) -> Dict:
    """Render a :class:`~repro.obs.tracer.RecordingTracer` as a Chrome
    trace-event dict (``{"traceEvents": [...], ...}``)."""
    pids = sorted(
        {ev.pid for ev in tracer.spans}
        | {ev.pid for ev in tracer.instants}
        | {ev.pid for ev in tracer.counters}
    )
    pid_id = {name: i + 1 for i, name in enumerate(pids)}
    tids_by_pid: Dict[str, List[str]] = {}
    for name in pids:
        lanes = sorted(
            {ev.tid for ev in tracer.spans if ev.pid == name}
            | {ev.tid for ev in tracer.instants if ev.pid == name}
        )
        tids_by_pid[name] = lanes
    tid_id = {
        (pname, t): j + 1
        for pname in pids
        for j, t in enumerate(tids_by_pid[pname])
    }

    events: List[Dict] = []
    for pname in pids:
        events.append({
            "ph": "M", "name": "process_name", "pid": pid_id[pname],
            "tid": 0, "args": {"name": pname},
        })
        events.append({
            "ph": "M", "name": "process_sort_index", "pid": pid_id[pname],
            "tid": 0, "args": {"sort_index": pid_id[pname]},
        })
        for t in tids_by_pid[pname]:
            events.append({
                "ph": "M", "name": "thread_name", "pid": pid_id[pname],
                "tid": tid_id[(pname, t)], "args": {"name": t},
            })
            events.append({
                "ph": "M", "name": "thread_sort_index", "pid": pid_id[pname],
                "tid": tid_id[(pname, t)],
                "args": {"sort_index": tid_id[(pname, t)]},
            })

    body: List[Dict] = []
    for sp in tracer.spans:
        body.append({
            "ph": "X", "name": sp.name, "cat": sp.cat,
            "pid": pid_id[sp.pid], "tid": tid_id[(sp.pid, sp.tid)],
            "ts": _us(sp.t0_ms), "dur": _us(sp.t1_ms - sp.t0_ms),
            "args": _args_dict(sp.args),
        })
    for ins in tracer.instants:
        body.append({
            "ph": "i", "s": "t", "name": ins.name, "cat": ins.cat,
            "pid": pid_id[ins.pid], "tid": tid_id[(ins.pid, ins.tid)],
            "ts": _us(ins.t_ms), "args": _args_dict(ins.args),
        })
    for cnt in tracer.counters:
        body.append({
            "ph": "C", "name": cnt.name, "pid": pid_id[cnt.pid], "tid": 0,
            "ts": _us(cnt.t_ms), "args": {"value": cnt.value},
        })
    body.sort(
        key=lambda ev: (
            ev["ts"], ev["pid"], ev["tid"], ev["ph"], ev["name"],
            json.dumps(ev, sort_keys=True),
        )
    )
    trace = {
        "displayTimeUnit": "ms",
        "traceEvents": events + body,
    }
    if label is not None:
        trace["otherData"] = {"label": label}
    return trace


def dump_chrome_trace(tracer, *, label: Optional[str] = None) -> str:
    """Byte-deterministic JSON string for :func:`chrome_trace`."""
    trace = chrome_trace(tracer, label=label)
    return json.dumps(trace, sort_keys=True, separators=(",", ":")) + "\n"


def write_chrome_trace(tracer, path: str, *, label: Optional[str] = None) -> str:
    """Write the trace to ``path``; returns the path for chaining."""
    payload = dump_chrome_trace(tracer, label=label)
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(payload)
    return path


def read_chrome_trace(path: str):
    """Load an exported trace back into a ``RecordingTracer``.

    The inverse of :func:`write_chrome_trace` up to expectation records
    (first-witness totals are engine state, not part of the file — the
    second-witness crosscheck runs on live tracers, while the CLI's
    structural validation and the metrics report run on loaded ones).
    Unknown / foreign trace-event phases are ignored, so the loader also
    tolerates hand-edited files."""
    from repro.obs.tracer import RecordingTracer

    with open(path, "r", encoding="utf-8") as fh:
        trace = json.load(fh)
    pid_name: Dict[int, str] = {}
    tid_name: Dict[tuple, str] = {}
    events = trace.get("traceEvents", [])
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            pid_name[ev["pid"]] = ev["args"]["name"]
        elif ev.get("name") == "thread_name":
            tid_name[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    tr = RecordingTracer()
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("X", "i", "C"):
            continue
        pid = pid_name.get(ev["pid"], str(ev["pid"]))
        if ph == "C":
            tr.counter(ev["name"], pid, ev["ts"] / _US_PER_MS,
                       ev.get("args", {}).get("value", 0.0))
            continue
        tid = tid_name.get((ev["pid"], ev["tid"]), str(ev["tid"]))
        args = ev.get("args", {})
        if ph == "X":
            t0 = ev["ts"] / _US_PER_MS
            tr.span(ev["name"], ev.get("cat", ""), pid, tid,
                    t0, t0 + ev.get("dur", 0.0) / _US_PER_MS, **args)
        else:
            tr.instant(ev["name"], ev.get("cat", ""), pid, tid,
                       ev["ts"] / _US_PER_MS, **args)
    return tr
