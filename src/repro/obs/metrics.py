"""Run-metrics registry: counters, gauges, histograms and a diffable
snapshot.

A :class:`MetricsRegistry` is a plain accumulator; the interesting
entry point is :func:`metrics_from_tracer`, which distills the standard
run metrics out of a recorded trace — iteration times, bubble
fractions, channel traffic, TTFT, migration and replay cost — so the
``python -m repro.obs report`` CLI (and tests) can summarize any run
the same way regardless of which engine produced it.

Snapshots are frozen and deterministic (sorted keys, sorted histogram
samples), so two snapshots of the same run compare equal and
``MetricsSnapshot.diff`` gives a stable, reviewable delta between two
runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from repro.obs.tracer import BUSY_KINDS, CAT_GPU, CAT_PREFILL


def _pctl(sorted_vals: Tuple[float, ...], q: float) -> float:
    """Nearest-rank percentile over an already-sorted sample."""
    if not sorted_vals:
        return math.nan
    idx = max(0, min(len(sorted_vals) - 1, math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[idx]


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """Frozen view of a registry; fields are sorted ``(name, ...)``."""

    counters: Tuple[Tuple[str, float], ...]
    gauges: Tuple[Tuple[str, float], ...]
    histograms: Tuple[Tuple[str, Tuple[float, ...]], ...]

    def as_dict(self) -> Dict:
        out: Dict = {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {},
        }
        for name, vals in self.histograms:
            out["histograms"][name] = {
                "count": len(vals),
                "min": vals[0] if vals else math.nan,
                "max": vals[-1] if vals else math.nan,
                "mean": sum(vals) / len(vals) if vals else math.nan,
                "p50": _pctl(vals, 0.50),
                "p95": _pctl(vals, 0.95),
                "p99": _pctl(vals, 0.99),
            }
        return out

    def diff(self, other: "MetricsSnapshot") -> Dict:
        """What changed from ``other`` to ``self``: counter deltas,
        gauge (old, new) pairs, histogram count deltas.  Unchanged
        entries are omitted, so ``snap.diff(snap) == {}``."""
        mine_c, theirs_c = dict(self.counters), dict(other.counters)
        mine_g, theirs_g = dict(self.gauges), dict(other.gauges)
        mine_h = {k: v for k, v in self.histograms}
        theirs_h = {k: v for k, v in other.histograms}
        out: Dict = {}
        for name in sorted(set(mine_c) | set(theirs_c)):
            delta = mine_c.get(name, 0.0) - theirs_c.get(name, 0.0)
            if delta != 0.0:
                out.setdefault("counters", {})[name] = delta
        for name in sorted(set(mine_g) | set(theirs_g)):
            old = theirs_g.get(name, math.nan)
            new = mine_g.get(name, math.nan)
            same = (old == new) or (math.isnan(old) and math.isnan(new))
            if not same:
                out.setdefault("gauges", {})[name] = (old, new)
        for name in sorted(set(mine_h) | set(theirs_h)):
            delta = len(mine_h.get(name, ())) - len(theirs_h.get(name, ()))
            if delta != 0:
                out.setdefault("histograms", {})[name] = delta
        return out


class MetricsRegistry:
    """Counters accumulate, gauges hold the latest value, histograms
    collect samples.  ``snapshot()`` freezes the current state."""

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}

    def count(self, name: str, inc: float = 1.0) -> None:
        self._counters[name] = self._counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float) -> None:
        self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        self._hists.setdefault(name, []).append(value)

    def snapshot(self) -> MetricsSnapshot:
        return MetricsSnapshot(
            counters=tuple(sorted(self._counters.items())),
            gauges=tuple(sorted(self._gauges.items())),
            histograms=tuple(
                (name, tuple(sorted(vals)))
                for name, vals in sorted(self._hists.items())
            ),
        )


def metrics_from_tracer(tracer) -> MetricsRegistry:
    """Standard run metrics derived from a recorded trace.

    Per GPU lane group (``<label>/gpu``): busy / bubble / allreduce /
    migration-stall milliseconds and a ``bubble_frac`` gauge.  Per
    channel lane group: transfer counts and bits.  Prefill spans feed a
    ``ttft_ms`` histogram; per-iteration counter samples feed
    ``iteration_ms`` / ``utilization`` histograms; migration spans feed
    ``migration_ms`` and ``replay_samples`` counters.
    """
    reg = MetricsRegistry()
    for sp in tracer.spans:
        if sp.cat == CAT_GPU:
            if sp.name in BUSY_KINDS:
                reg.count(f"{sp.pid}/busy_ms", sp.duration_ms)
            elif sp.name == "bubble":
                reg.count(f"{sp.pid}/bubble_ms", sp.duration_ms)
            elif sp.name == "allreduce":
                reg.count(f"{sp.pid}/allreduce_ms", sp.duration_ms)
            elif sp.name == "migration-stall":
                reg.count(f"{sp.pid}/migration_stall_ms", sp.duration_ms)
        elif sp.cat == CAT_PREFILL:
            ttft = sp.arg("ttft_ms")
            if ttft is not None:
                reg.observe("ttft_ms", ttft)
        elif sp.name == "transfer":
            reg.count(f"{sp.pid}/transfers", 1.0)
            reg.count(f"{sp.pid}/wan_bits", sp.arg("bits", 0.0))
        elif sp.name.startswith("migration:"):
            reg.count("migration_ms", sp.duration_ms)
            reg.count("replay_samples", sp.arg("replay_samples", 0.0))
    for cnt in tracer.counters:
        if cnt.name in ("iteration_ms", "utilization"):
            reg.observe(cnt.name, cnt.value)
    pids = sorted({sp.pid for sp in tracer.spans if sp.cat == CAT_GPU})
    snap_counters = dict(reg.snapshot().counters)
    for pid in pids:
        busy = snap_counters.get(f"{pid}/busy_ms", 0.0)
        bubble = snap_counters.get(f"{pid}/bubble_ms", 0.0)
        denom = busy + bubble
        reg.gauge(f"{pid}/bubble_frac", bubble / denom if denom > 0 else 0.0)
    return reg
