"""Unified observability layer: structured sim tracing, Perfetto export,
and a run-metrics report.

Every engine in ``repro.core`` accepts an optional :class:`Tracer`.  The
default (``None`` or :class:`NullTracer`) is near-zero overhead — the
engines guard every emission behind ``tracer.enabled`` — while a
:class:`RecordingTracer` collects typed span/instant/counter events
stamped in **sim time** (milliseconds on the simulated wall clock, never
the host clock), so a recorded trace is a pure function of the run's
inputs and seeds.

Layers on top:

* ``repro.obs.export`` — byte-deterministic Chrome trace-event JSON
  (load in Perfetto / ``chrome://tracing``): GPU lanes, WAN channel
  lanes, prefill lanes, control-plane instants.
* ``repro.obs.crosscheck`` — the *second witness*: busy/bubble/
  utilization/wan_bits re-derived from the emitted spans must agree
  with the engine's own ``SimResult`` accounting, turning the trace
  into a falsifiable invariant rather than a log stream.
* ``repro.obs.metrics`` — counters/gauges/histograms distilled from a
  trace, with a diffable :class:`MetricsSnapshot`.
* ``repro.obs.schema`` — the registry of every ``SimResult.stats`` key
  the engines emit, with units-suffix-conformant names.
* ``python -m repro.obs report|validate`` — CLI over exported traces.

This package deliberately imports nothing from ``repro.core`` at module
level, so the engines can import it without cycles.
"""
from repro.obs.tracer import (
    BUSY_KINDS,
    CAT_CHANNEL,
    CAT_CONTROL,
    CAT_FLEET,
    CAT_GPU,
    CAT_PREFILL,
    CounterEvent,
    Expectation,
    InstantEvent,
    NullTracer,
    RecordingTracer,
    SpanEvent,
    Tracer,
)
from repro.obs.emit import pair_lane, trace_schedule, trace_sim_result
from repro.obs.crosscheck import TraceMismatch, verify_trace
from repro.obs.export import (
    chrome_trace,
    dump_chrome_trace,
    read_chrome_trace,
    write_chrome_trace,
)
from repro.obs.metrics import MetricsRegistry, MetricsSnapshot, metrics_from_tracer
from repro.obs.schema import (
    REGISTRY,
    StatKey,
    conformance_errors,
    unregistered_keys,
)

__all__ = [
    "BUSY_KINDS",
    "CAT_CHANNEL",
    "CAT_CONTROL",
    "CAT_FLEET",
    "CAT_GPU",
    "CAT_PREFILL",
    "CounterEvent",
    "Expectation",
    "InstantEvent",
    "MetricsRegistry",
    "MetricsSnapshot",
    "NullTracer",
    "REGISTRY",
    "RecordingTracer",
    "SpanEvent",
    "StatKey",
    "TraceMismatch",
    "Tracer",
    "chrome_trace",
    "conformance_errors",
    "dump_chrome_trace",
    "metrics_from_tracer",
    "pair_lane",
    "read_chrome_trace",
    "trace_schedule",
    "trace_sim_result",
    "unregistered_keys",
    "verify_trace",
    "write_chrome_trace",
]
