"""Typed structured events and the ``Tracer`` protocol.

All timestamps are **sim time** — milliseconds on the simulated wall
clock the engines advance — never the host clock.  A recorded trace is
therefore a pure function of the run's inputs and seeds: two runs of
the same scenario produce byte-identical exports (regression-tested
across ``PYTHONHASHSEED`` values).

Three event shapes, mirroring the Chrome trace-event model the exporter
targets:

* :class:`SpanEvent` — a closed interval on one lane (a GPU doing
  ``fwd`` work, a WAN channel occupied by a transfer, a prefill running
  in a bubble, a migration stall).
* :class:`InstantEvent` — a point event (drift fire, re-plan decision,
  admission rejection, checkpoint stamp).
* :class:`CounterEvent` — a sampled scalar (per-iteration utilization).

Lanes are addressed by ``(pid, tid)`` string pairs — ``pid`` is the
process-level group (``"jobA/gpu"``, ``"fleet/wan"``), ``tid`` the lane
inside it (``"p0/s1"``, ``"a->b"``).  The exporter assigns numeric ids
deterministically by sorting these names.

``Tracer`` is duck-typed: engines only call ``span``/``instant``/
``counter``/``expect`` and read ``enabled``.  :class:`NullTracer` keeps
``enabled`` False so engines skip even argument construction;
:class:`RecordingTracer` appends frozen events to plain lists.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

#: Interval kinds that count as productive GPU work in the second
#: witness (``repro.obs.crosscheck``) — must mirror the ``Interval``
#: kinds the engines emit plus BubbleTea's ``prefill``.
BUSY_KINDS = ("fwd", "rec", "bwd", "prefill")

CAT_GPU = "gpu"  # per-(pipeline, stage) GPU lanes
CAT_CHANNEL = "channel"  # directed WAN channel lanes (per-transfer spans)
CAT_PREFILL = "prefill"  # BubbleTea admission / placement lanes
CAT_CONTROL = "control"  # control-plane instants + migration/outage spans
CAT_FLEET = "fleet"  # allocator reservation / grant / throttle lanes

#: frozen ``(key, value)`` representation of event args — sorted by key
#: at construction so event identity is independent of kwargs order.
Args = Tuple[Tuple[str, object], ...]


def _freeze(args: dict) -> Args:
    return tuple(sorted(args.items(), key=lambda kv: kv[0]))


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One closed interval ``[t0_ms, t1_ms]`` on lane ``(pid, tid)``."""

    name: str
    cat: str
    pid: str
    tid: str
    t0_ms: float
    t1_ms: float
    args: Args = ()

    def arg(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default

    @property
    def duration_ms(self) -> float:
        return self.t1_ms - self.t0_ms


@dataclasses.dataclass(frozen=True)
class InstantEvent:
    """One point event at ``t_ms`` on lane ``(pid, tid)``."""

    name: str
    cat: str
    pid: str
    tid: str
    t_ms: float
    args: Args = ()

    def arg(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default


@dataclasses.dataclass(frozen=True)
class CounterEvent:
    """One sampled scalar at ``t_ms`` on counter track ``(pid, name)``."""

    name: str
    pid: str
    t_ms: float
    value: float


@dataclasses.dataclass(frozen=True)
class Expectation:
    """First-witness totals registered at emission time.

    Whenever an engine emits the spans of one iteration window it also
    registers what its *own* accounting said the window contains
    (``SimResult.utilization``, ``allreduce_ms``, bubble totals,
    ``stats["wan_bits"]``).  ``crosscheck.verify_trace`` re-derives the
    same totals from the emitted spans alone and compares — a corrupted
    or double-counted span set fails the check.

    ``wan_bits`` is ``None`` when the window carries no transfer log
    (e.g. a result emitted without transfer recording); the channel leg
    of the check is then skipped for that window.
    """

    label: str  # lane prefix: gpu spans on f"{label}/gpu", channels on f"{label}/wan"
    t0_ms: float
    t1_ms: float
    n_lanes: int
    utilization: float
    allreduce_ms: float
    bubble_ms: float
    wan_bits: Optional[Tuple[Tuple[Tuple[int, int], float], ...]] = None


class Tracer:
    """Duck-typed tracing protocol; the base class is a no-op.

    Engines must guard emission with ``tracer is not None and
    tracer.enabled`` so the disabled path never builds event
    arguments.
    """

    enabled: bool = False

    def span(self, name: str, cat: str, pid: str, tid: str,
             t0_ms: float, t1_ms: float, **args) -> None:
        pass

    def instant(self, name: str, cat: str, pid: str, tid: str,
                t_ms: float, **args) -> None:
        pass

    def counter(self, name: str, pid: str, t_ms: float, value: float) -> None:
        pass

    def expect(self, expectation: Expectation) -> None:
        pass


class NullTracer(Tracer):
    """Explicit no-op tracer — behaviourally identical to passing
    ``tracer=None`` (the overhead budget is benchmarked in
    ``benchmarks/sim_bench.py``'s ``trace_overhead`` cell)."""

    __slots__ = ()


class RecordingTracer(Tracer):
    """Collects every event in emission order, in sim time."""

    enabled = True

    def __init__(self) -> None:
        self.spans: List[SpanEvent] = []
        self.instants: List[InstantEvent] = []
        self.counters: List[CounterEvent] = []
        self.expectations: List[Expectation] = []

    def span(self, name: str, cat: str, pid: str, tid: str,
             t0_ms: float, t1_ms: float, **args) -> None:
        self.spans.append(
            SpanEvent(name, cat, pid, tid, t0_ms, t1_ms, _freeze(args))
        )

    def instant(self, name: str, cat: str, pid: str, tid: str,
                t_ms: float, **args) -> None:
        self.instants.append(
            InstantEvent(name, cat, pid, tid, t_ms, _freeze(args))
        )

    def counter(self, name: str, pid: str, t_ms: float, value: float) -> None:
        self.counters.append(CounterEvent(name, pid, t_ms, value))

    def expect(self, expectation: Expectation) -> None:
        self.expectations.append(expectation)

    @property
    def n_events(self) -> int:
        return len(self.spans) + len(self.instants) + len(self.counters)

    def clear(self) -> None:
        self.spans.clear()
        self.instants.clear()
        self.counters.clear()
        self.expectations.clear()
