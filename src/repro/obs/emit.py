"""Span emission helpers shared by every engine.

The engines do not hand-roll event construction: ``simulate`` (and the
horizon runner, per iteration) call :func:`trace_sim_result` on a
finished ``SimResult``; ``atlas_schedule`` calls
:func:`trace_schedule` on a raw ``temporal.Schedule``.  Centralising
emission keeps lane naming, span kinds and the first-witness
:class:`~repro.obs.tracer.Expectation` registration identical across
the event-heap engine, the Atlas list-scheduler and the replicated
baseline path.

Everything here is duck-typed against ``repro.core`` objects
(``SimResult.busy`` intervals, ``temporal.Transfer`` records) so this
module never imports the engines.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro import units
from repro.obs.tracer import (
    CAT_CHANNEL,
    CAT_GPU,
    Expectation,
    Tracer,
)


def pair_lane(pair: Tuple[int, int], dc_names: Optional[Sequence[str]] = None) -> str:
    """Deterministic lane name for one directed DC pair."""
    a, b = pair
    if dc_names:  # TopologyMatrix defaults to an empty dc_names tuple
        return f"{dc_names[a]}->{dc_names[b]}"
    return f"dc{a}->dc{b}"


def _transfer_pair(tr, stage_dc) -> Tuple[int, int]:
    """Directed DC pair one ``temporal.Transfer`` rides: activations go
    down the stage chain, gradients back up."""
    a, b = stage_dc[tr.boundary], stage_dc[tr.boundary + 1]
    return (a, b) if tr.direction == "act" else (b, a)


def _emit_transfers(
    tracer, transfers, spec, *, label: str, t0_ms: float,
    replicas: int, dc_names=None,
) -> None:
    pid = f"{label}/wan"
    bits_each = units.bytes_to_bits(spec.act_bytes)
    for tr in transfers:
        pair = _transfer_pair(tr, spec.stage_dc)
        if pair[0] == pair[1]:
            continue  # intra-DC hop: not WAN traffic
        dur = tr.end - tr.start
        rate = units.bits_rate_gbps(bits_each, dur) if dur > 0 else 0.0
        tracer.span(
            "transfer",
            CAT_CHANNEL,
            pid,
            pair_lane(pair, dc_names),
            t0_ms + tr.start,
            t0_ms + tr.end,
            pair=list(pair),
            direction=tr.direction,
            pipeline=tr.pipeline,
            micro=tr.micro,
            arrive_ms=t0_ms + tr.arrive,
            bits=bits_each * replicas,
            rate_gbps=rate,
            replicas=replicas,
        )


def trace_sim_result(
    tracer: Tracer,
    res,
    spec,
    *,
    label: str = "sim",
    t0_ms: float = 0.0,
    dc_names: Optional[Sequence[str]] = None,
) -> Optional[Expectation]:
    """Emit one iteration window of a ``SimResult`` and register its
    first-witness expectation.

    GPU lanes get one span per busy interval (named by its kind), one
    per bubble gap and one trailing ``allreduce`` span; the channel
    lanes get one span per WAN transfer when the result carries a
    transfer log (``res.transfers``).  The result's intervals are
    iteration-relative, so the same (possibly cache-reused) result can
    be re-anchored at any ``t0_ms`` — exactly how the horizon runner
    replays reused iterations.
    """
    if tracer is None or not tracer.enabled:
        return None
    total = res.iteration_ms
    pp_end = total - res.allreduce_ms
    gpu_pid = f"{label}/gpu"
    bubble_ms = 0.0
    for key in sorted(res.busy):
        p, s = key
        tid = f"p{p}/s{s}"
        dc = spec.stage_dc[s]
        for iv in res.busy[key]:
            tracer.span(
                iv.kind, CAT_GPU, gpu_pid, tid,
                t0_ms + iv.start, t0_ms + iv.end,
                micro=iv.micro, dc=dc,
            )
        for a, b in res.bubbles.get(key, ()):
            tracer.span(
                "bubble", CAT_GPU, gpu_pid, tid, t0_ms + a, t0_ms + b, dc=dc
            )
            bubble_ms += b - a
        if res.allreduce_ms > 0.0:
            tracer.span(
                "allreduce", CAT_GPU, gpu_pid, tid,
                t0_ms + pp_end, t0_ms + total, dc=dc,
            )
    stats = res.stats or {}
    transfers = getattr(res, "transfers", None)
    wan_expect = None
    if transfers is not None:
        replicas = int(stats.get("replicated_pipelines", 1))
        _emit_transfers(
            tracer, transfers, spec,
            label=label, t0_ms=t0_ms, replicas=replicas, dc_names=dc_names,
        )
        wan = stats.get("wan_bits")
        if wan is not None:
            wan_expect = tuple(sorted((tuple(p), b) for p, b in wan.items()))
    exp = Expectation(
        label=label,
        t0_ms=t0_ms,
        t1_ms=t0_ms + total,
        n_lanes=len(res.busy),
        utilization=res.utilization,
        allreduce_ms=res.allreduce_ms,
        bubble_ms=bubble_ms,
        wan_bits=wan_expect,
    )
    tracer.expect(exp)
    return exp


def trace_schedule(
    tracer: Tracer,
    sched,
    spec,
    *,
    label: str = "atlas",
    t0_ms: float = 0.0,
    dc_names: Optional[Sequence[str]] = None,
) -> None:
    """Emit a raw ``temporal.Schedule``: one GPU span per task, one
    channel span per WAN transfer.  Used by ``atlas_schedule`` callers
    who want the schedule's timeline without running ``simulate``;
    spans carry no bubble/allreduce accounting, so no expectation is
    registered."""
    if tracer is None or not tracer.enabled:
        return
    gpu_pid = f"{label}/gpu"
    for task in sched.tasks:
        tracer.span(
            task.kind, CAT_GPU, gpu_pid, f"p{task.pipeline}/s{task.stage}",
            t0_ms + task.start, t0_ms + task.end,
            micro=task.micro, dc=spec.stage_dc[task.stage],
        )
    _emit_transfers(
        tracer, sched.transfers, spec,
        label=label, t0_ms=t0_ms, replicas=1, dc_names=dc_names,
    )
