"""CLI over exported traces: ``python -m repro.obs <report|validate> trace.json``.

``report`` distills the standard run metrics (busy/bubble/allreduce
time, bubble fractions, channel traffic, TTFT/iteration histograms,
migration cost) out of an exported Chrome trace and prints them as
deterministic JSON — the same summary regardless of which engine
produced the trace.

``validate`` structurally checks an exported trace file:

* every event carries its required fields for its phase and references
  a metadata-named process/thread;
* span bounds are monotone (``dur >= 0``) and finite;
* no productive GPU span sits inside a dead-DC outage window (windows
  are reconstructed from the ``outage:dc_outage`` spans the control
  plane emits; the span's ``dc`` arg is matched against the outage's
  ``dc_index``) — the trace-level form of ``validate.check_horizon``'s
  nothing-ran-on-a-dead-DC invariant.

Exit status 0 on success, 1 with one line per violation on failure.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import List

from repro.obs.export import read_chrome_trace
from repro.obs.metrics import metrics_from_tracer
from repro.obs.tracer import BUSY_KINDS, CAT_GPU

_REQUIRED = {
    "X": ("name", "cat", "pid", "tid", "ts", "dur"),
    "i": ("name", "pid", "tid", "ts"),
    "C": ("name", "pid", "ts", "args"),
    "M": ("name", "pid", "args"),
}


def validate_trace_file(path: str) -> List[str]:
    """Structural violations in an exported trace (empty when valid)."""
    errors: List[str] = []
    with open(path, "r", encoding="utf-8") as fh:
        trace = json.load(fh)
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: no traceEvents array"]
    named_pids = set()
    named_tids = set()
    for ev in events:
        if ev.get("ph") == "M":
            if ev.get("name") == "process_name":
                named_pids.add(ev.get("pid"))
            elif ev.get("name") == "thread_name":
                named_tids.add((ev.get("pid"), ev.get("tid")))
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in _REQUIRED:
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        missing = [f for f in _REQUIRED[ph] if f not in ev]
        if missing:
            errors.append(f"event {i} (ph={ph}): missing fields {missing}")
            continue
        if ph in ("X", "i", "C") and not math.isfinite(ev["ts"]):
            errors.append(f"event {i}: non-finite ts")
        if ph == "X":
            if not math.isfinite(ev["dur"]) or ev["dur"] < 0.0:
                errors.append(
                    f"event {i} ({ev['name']}): non-monotone span "
                    f"(dur={ev['dur']})"
                )
            if (ev["pid"], ev["tid"]) not in named_tids:
                errors.append(
                    f"event {i} ({ev['name']}): unnamed lane "
                    f"pid={ev['pid']} tid={ev['tid']}"
                )
        if ph in ("X", "i", "C") and ev["pid"] not in named_pids:
            errors.append(f"event {i} ({ev['name']}): unnamed pid {ev['pid']}")

    # dead-DC invariant: reconstruct outage windows, then reject any
    # productive GPU span on the dead DC fully inside one
    tr = read_chrome_trace(path)
    outages = [
        (sp.t0_ms, sp.t1_ms, sp.arg("dc_index"))
        for sp in tr.spans
        if sp.name == "outage:dc_outage" and sp.arg("dc_index") is not None
    ]
    if outages:
        eps = 1e-6
        for sp in tr.spans:
            if sp.cat != CAT_GPU or sp.name not in BUSY_KINDS:
                continue
            dc = sp.arg("dc")
            for t0, t1, dead in outages:
                if dc == dead and sp.t0_ms >= t0 - eps and sp.t1_ms <= t1 + eps:
                    errors.append(
                        f"{sp.name} span [{sp.t0_ms}, {sp.t1_ms}] on "
                        f"{sp.pid}/{sp.tid} runs on dead dc {dead} inside "
                        f"outage [{t0}, {t1}]"
                    )
    return errors


def report(path: str) -> str:
    """Deterministic JSON metrics report for an exported trace."""
    snap = metrics_from_tracer(read_chrome_trace(path)).snapshot()
    return json.dumps(snap.as_dict(), sort_keys=True, indent=2) + "\n"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect and validate exported simulation traces.",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_rep = sub.add_parser("report", help="print run metrics as JSON")
    p_rep.add_argument("trace", help="exported Chrome trace-event JSON file")
    p_val = sub.add_parser("validate", help="structurally validate a trace")
    p_val.add_argument("trace", help="exported Chrome trace-event JSON file")
    args = parser.parse_args(argv)
    if args.cmd == "report":
        sys.stdout.write(report(args.trace))
        return 0
    errors = validate_trace_file(args.trace)
    if errors:
        for e in errors:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1
    print(f"OK: {args.trace} passes structural validation")
    return 0


if __name__ == "__main__":
    sys.exit(main())
