"""Registry of every engine-emitted stats key, with units.

``SimResult.stats``, ``HorizonResult.stats`` and ``FleetResult.stats``
are the public accounting surface of the simulator; their key names
follow the units-suffix grammar enforced by ``repro.analysis``
(quantities carry their unit as a ``_ms`` / ``_bits`` / ``_gbps`` /
``_samples`` suffix, counts and fractions carry none).  This module
makes that contract explicit and testable:

* :data:`REGISTRY` — one :class:`StatKey` per known key path, per
  domain (``sim`` / ``horizon`` / ``fleet``).  Dotted paths address
  nesting; a ``*`` segment matches any map key (per-job, per-tier).
* :func:`conformance_errors` — the registry audits *itself*: a key
  registered with unit ``ms`` must end in ``_ms``, a count must *not*
  end in any unit suffix.
* :func:`unregistered_keys` — audits a live stats dict: every key an
  engine actually emitted must be registered (the test suite runs every
  engine and asserts this is empty, so adding a stats key without
  registering its unit fails CI).

The registry describes *names*, not values — value invariants live in
``repro.core.validate``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Tuple

#: units that must appear as a ``_<unit>`` suffix on the key's last
#: path segment (or be the entire segment, e.g. ``samples``)
SUFFIX_UNITS = ("ms", "bits", "bytes", "gbps", "samples", "hours")

#: units carrying no suffix requirement — but the name must not *end*
#: in one of the suffix units either (a count named ``foo_ms`` lies)
BARE_UNITS = ("count", "frac", "bool", "str", "enum", "dict", "tuple")


@dataclasses.dataclass(frozen=True)
class StatKey:
    """One registered stats key: its dotted path, unit and meaning."""

    path: str
    unit: str
    description: str

    def __post_init__(self):
        assert self.unit in SUFFIX_UNITS + BARE_UNITS, self.unit


def _k(path: str, unit: str, description: str) -> Tuple[str, StatKey]:
    return path, StatKey(path, unit, description)


#: ``simulate`` — one iteration of one job (``SimResult.stats``)
SIM_STATS: Dict[str, StatKey] = dict([
    _k("engine", "str", "which engine ran (events / atlas / …)"),
    _k("events", "count", "event-heap pops (engine work measure)"),
    _k("fast_forward", "bool", "whether steady-state extrapolation ran"),
    _k("fast_forward_gate", "str", "why fast-forward was gated off"),
    _k("period", "count", "microbatch period K the extrapolation locked"),
    _k("probe_attempts", "count", "fast-forward probe simulations"),
    _k("probe_microbatches", "tuple", "(m1, m2) probe truncation sizes"),
    _k("extrapolated_microbatches", "count", "microbatches synthesized"),
    _k("replicated_pipelines", "count", "replica factor of the baseline path"),
    _k("wan_bits", "dict", "per directed DC pair: bits per iteration"),
])

#: ``HorizonRunner`` / ``simulate_horizon`` (``HorizonResult.stats``)
HORIZON_STATS: Dict[str, StatKey] = dict([
    _k("iter_sims", "count", "iterations priced by a fresh simulation"),
    _k("iter_reused", "count", "iterations reusing a cached simulation"),
    _k("drift_iterations", "count", "iterations with deviation above threshold"),
    _k("drift_fires", "count", "detector fires (hysteresis satisfied)"),
    _k("replans_declined", "count", "re-plans rejected (infeasible / no gain)"),
    _k("replans_noop", "count", "re-plans that kept the deployment"),
    _k("replans_suppressed", "count", "fires suppressed by the cascade guard"),
    _k("replans_forced", "count", "forced failovers (outage / preemption)"),
    _k("fast_forward_gates", "dict", "per gate reason: iterations gated"),
])

#: ``simulate_fleet`` (``FleetResult.stats``)
FLEET_STATS: Dict[str, StatKey] = dict([
    _k("sharing", "enum", "channel sharing mode (temporal / fair)"),
    _k("generations", "count", "demand-segment openings (epoch starts)"),
    _k("cascade_replans_max", "count", "cascade budget (config echo)"),
    _k("cascade_epochs", "count", "cascade epochs closed"),
    _k("cascade_suppressed", "count", "drift fires suppressed fleet-wide"),
    _k("admission_wait_ms", "ms", "total migration admission-barrier wait"),
    _k("floor_grants", "count", "windows priced at the grant floor"),
    _k("demand_probe_sims", "count", "uncontended demand-probe simulations"),
    _k("replans_total", "count", "migrations across all jobs"),
    _k("per_job.*.throttled_iterations", "count", "windows below full rate"),
    _k("per_job.*.throttled_ms", "ms", "wall time spent throttled"),
    _k("per_job.*.total_ms", "ms", "job wall time to sample budget"),
    _k("per_job.*.samples", "samples", "samples the job completed"),
    _k("per_job.*.replans", "count", "migrations this job executed"),
    _k("per_job.*.migration_ms", "ms", "total migration stall"),
    _k("per_job.*.replans_suppressed", "count", "suppressed fires (this job)"),
    _k("prefill.requests_offered", "count", "arrivals inside the horizon"),
    _k("prefill.requests_total", "count", "arrivals in the full trace"),
    _k("prefill.placed", "count", "prefills placed into bubbles"),
    _k("prefill.rejected", "count", "prefills rejected (any reason)"),
    _k("prefill.rejected_slo", "count", "prefills rejected on TTFT SLO"),
    _k("prefill.acceptance", "frac", "placed / offered"),
    _k("prefill.per_tier.*.offered", "count", "tier arrivals offered"),
    _k("prefill.per_tier.*.placed", "count", "tier arrivals placed"),
    _k("prefill.per_tier.*.rejected_slo", "count", "tier SLO rejections"),
    _k("prefill.per_tier.*.acceptance", "frac", "tier placed / offered"),
    _k("prefill.per_tier.*.ttft_p50_ms", "ms", "tier TTFT median"),
    _k("prefill.per_tier.*.ttft_p95_ms", "ms", "tier TTFT p95"),
    _k("prefill.per_tier.*.ttft_p99_ms", "ms", "tier TTFT p99"),
    _k("prefill.prefill_gpu_busy_ms", "ms", "GPU busy time prefills added"),
    _k("prefill.kv_wan_transfers", "count", "KV handoffs over the WAN"),
    _k("prefill.kv_local_transfers", "count", "KV handoffs over NVLink"),
    _k("prefill.kv_wan_bits", "bits", "KV bits shipped over the WAN"),
    _k("prefill.kv_reservations", "count", "KV ledger segments recorded"),
    _k("prefill.host_gpu_ms", "ms", "host GPU-time denominator"),
    _k("prefill.utilization_train", "frac", "training-only utilization"),
    _k("prefill.utilization_with_prefills", "frac", "Fig-13 utilization"),
])

REGISTRY: Dict[str, Dict[str, StatKey]] = {
    "sim": SIM_STATS,
    "horizon": HORIZON_STATS,
    "fleet": FLEET_STATS,
}


def _segment_conforms(segment: str, unit: str) -> bool:
    if unit in SUFFIX_UNITS:
        return segment == unit or segment.endswith(f"_{unit}")
    if unit == "dict":
        # a map may carry its *value* unit as suffix (wan_bits: pair->bits)
        return True
    # other bare units must not carry a misleading quantity suffix
    return not any(
        segment == u or segment.endswith(f"_{u}") for u in SUFFIX_UNITS
    )


def conformance_errors() -> List[str]:
    """Units-suffix violations *inside the registry itself* (empty when
    every registered name matches its declared unit)."""
    errors = []
    for domain, reg in sorted(REGISTRY.items()):
        for path, key in sorted(reg.items()):
            seg = path.rsplit(".", 1)[-1]
            if not _segment_conforms(seg, key.unit):
                errors.append(
                    f"{domain}:{path}: name does not conform to unit "
                    f"{key.unit!r}"
                )
    return errors


def unregistered_keys(stats: Mapping, domain: str) -> List[str]:
    """Key paths present in a live ``stats`` dict but absent from the
    ``domain`` registry.  A path matches its exact registration or a
    ``*``-wildcarded one (map keys); registered ``dict``-unit keys are
    opaque leaves (their keys are data — pair tuples, gate names — not
    schema)."""
    reg = REGISTRY[domain]
    missing: List[str] = []

    def lookup(path: str):
        if path in reg:
            return reg[path]
        parts = path.split(".")
        for i in range(len(parts)):
            wc = parts[:i] + ["*"] + parts[i + 1:]
            cand = ".".join(wc)
            if cand in reg:
                return reg[cand]
        return None

    def walk(node, prefix: str) -> None:
        for k, v in node.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            key = lookup(path)
            if key is None:
                if isinstance(v, Mapping):
                    walk(v, path)  # maybe only the children are registered
                    continue
                missing.append(path)
                continue
            if key.unit != "dict" and isinstance(v, Mapping):
                walk(v, path)

    walk(stats, "")
    # a dict whose children all failed reports each child; dedupe any
    # parent that is itself unregistered and non-mapping
    return sorted(set(missing))
