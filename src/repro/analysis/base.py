"""Shared infrastructure for the ``repro.analysis`` lint passes.

Each pass consumes parsed :class:`Module` objects and yields
:class:`Finding`s.  Findings can be silenced two ways:

* an inline ``# lint: ok[rule]`` comment on the offending line (several
  rules comma-separated; a pass prefix like ``units`` silences every
  ``units/*`` rule on that line), or
* a baseline file (``analysis_baseline.json``) listing known findings —
  shipped empty: the tree is expected to lint clean.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ok\[([^\]]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rule`` is ``<pass>/<check>`` (e.g. ``units/scale-mismatch``)."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def fingerprint(self) -> Tuple[str, str, int]:
        return (self.rule, self.path, self.line)


@dataclasses.dataclass
class Module:
    """A parsed source file plus everything passes need to scope rules."""

    path: str  # as given (repo-relative when invoked from the repo root)
    source: str
    tree: ast.Module
    suppressions: Dict[int, Set[str]]  # line -> suppressed rule names/prefixes

    @property
    def is_core(self) -> bool:
        norm = self.path.replace(os.sep, "/")
        return "repro/core/" in norm

    @property
    def is_tests(self) -> bool:
        norm = self.path.replace(os.sep, "/")
        return norm.startswith("tests/") or "/tests/" in norm

    @property
    def is_units_module(self) -> bool:
        """The sanctioned conversion site (``repro/units.py``)."""
        norm = self.path.replace(os.sep, "/")
        return norm.endswith("repro/units.py")

    @property
    def is_analysis_module(self) -> bool:
        norm = self.path.replace(os.sep, "/")
        return "repro/analysis/" in norm

    def suppressed(self, finding: Finding) -> bool:
        rules = self.suppressions.get(finding.line)
        if not rules:
            return False
        for r in rules:
            if finding.rule == r or finding.rule.startswith(r + "/"):
                return True
        return False


def _parse_suppressions(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    # tokenize so string literals containing "# lint: ok[...]" don't count
    try:
        import io

        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                m = _SUPPRESS_RE.search(tok.string)
                if m:
                    rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                    out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        for i, text in enumerate(source.splitlines(), 1):
            m = _SUPPRESS_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out.setdefault(i, set()).update(rules)
    return out


def parse_module(path: str, source: Optional[str] = None) -> Module:
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    tree = ast.parse(source, filename=path)
    return Module(path, source, tree, _parse_suppressions(source))


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def load_modules(paths: Sequence[str]) -> List[Module]:
    return [parse_module(p) for p in iter_python_files(paths)]


# --- call/function signature registry (for call-argument unit binding) ----

#: name -> parameter-name tuple (leading self/cls stripped).  Only
#: functions whose every definition across the analyzed tree agrees on
#: the parameter list are bindable — ambiguous names map to None.
SignatureRegistry = Dict[str, Optional[Tuple[str, ...]]]


def build_signature_registry(modules: Sequence[Module]) -> SignatureRegistry:
    reg: SignatureRegistry = {}
    for mod in modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            a = node.args
            if a.vararg or a.kwarg or a.posonlyargs:
                params: Optional[Tuple[str, ...]] = None
            else:
                names = [arg.arg for arg in a.args]
                if names and names[0] in ("self", "cls"):
                    names = names[1:]
                params = tuple(names) + tuple(arg.arg for arg in a.kwonlyargs)
            if node.name in reg and reg[node.name] != params:
                reg[node.name] = None  # ambiguous across defs
            else:
                reg[node.name] = params
    return reg


# --- baseline -------------------------------------------------------------


def load_baseline(path: str) -> Set[Tuple[str, str, int]]:
    with open(path, encoding="utf-8") as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    out: Set[Tuple[str, str, int]] = set()
    for e in entries:
        out.add((e["rule"], e["path"], int(e["line"])))
    return out


def run_passes(modules: Sequence[Module]) -> List[Finding]:
    """Run every pass over ``modules``; inline suppressions applied."""
    from repro.analysis import api_pass, concurrency_pass, determinism_pass, units_pass

    registry = build_signature_registry(modules)
    findings: List[Finding] = []
    by_path = {m.path: m for m in modules}
    for pass_mod in (units_pass, determinism_pass, concurrency_pass, api_pass):
        findings.extend(pass_mod.run(modules, registry))
    kept = [f for f in findings if not by_path[f.path].suppressed(f)]
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def all_rules() -> Dict[str, str]:
    """rule id -> one-line description, aggregated from every pass."""
    from repro.analysis import api_pass, concurrency_pass, determinism_pass, units_pass

    out: Dict[str, str] = {}
    for pass_mod in (units_pass, determinism_pass, concurrency_pass, api_pass):
        out.update(pass_mod.RULES)
    return out
