"""Shared infrastructure for the ``repro.analysis`` lint passes.

Each pass consumes parsed :class:`Module` objects and yields
:class:`Finding`s.  Findings can be silenced two ways:

* an inline ``# lint: ok[rule]`` comment on the offending line (several
  rules comma-separated; a pass prefix like ``units`` silences every
  ``units/*`` rule on that line), or
* a baseline file (``analysis_baseline.json``) listing known findings —
  shipped empty: the tree is expected to lint clean.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ok\[([^\]]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rule`` is ``<pass>/<check>`` (e.g. ``units/scale-mismatch``)."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def fingerprint(self) -> Tuple[str, str, int]:
        return (self.rule, self.path, self.line)


class ModuleIndex:
    """Facts every pass needs, collected in ONE recursive walk of the
    tree (several passes used to re-walk the whole module each)."""

    __slots__ = ("functions", "called_names", "from_imports", "import_roots")

    def __init__(self, tree: ast.Module) -> None:
        self.functions: List[ast.AST] = []  # every (nested) function def
        #: id(fn) -> names called directly in fn's own body (innermost
        #: attribution: nested defs keep their own call sets)
        self.called_names: Dict[int, Set[str]] = {}
        self.from_imports: Dict[str, str] = {}  # local name -> "module.orig"
        self.import_roots: Set[str] = set()  # top-level imported module names
        self._walk(tree, None)

    def _walk(self, node: ast.AST, fn_calls: Optional[Set[str]]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.append(child)
                calls: Set[str] = set()
                self.called_names[id(child)] = calls
                self._walk(child, calls)
                continue
            if isinstance(child, ast.Call) and fn_calls is not None:
                f = child.func
                if isinstance(f, ast.Name):
                    fn_calls.add(f.id)
                elif isinstance(f, ast.Attribute):
                    fn_calls.add(f.attr)
            elif isinstance(child, ast.Import):
                self.import_roots.update(
                    a.name.split(".")[0] for a in child.names
                )
            elif isinstance(child, ast.ImportFrom):
                if child.module:
                    self.import_roots.add(child.module.split(".")[0])
                    for a in child.names:
                        local = a.asname or a.name
                        self.from_imports[local] = f"{child.module}.{a.name}"
            self._walk(child, fn_calls)


@dataclasses.dataclass
class Module:
    """A parsed source file plus everything passes need to scope rules."""

    path: str  # as given (repo-relative when invoked from the repo root)
    source: str
    tree: ast.Module
    suppressions: Dict[int, Set[str]]  # line -> suppressed rule names/prefixes
    _index: Optional[ModuleIndex] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _cfg_cache: Dict[int, object] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    @property
    def index(self) -> ModuleIndex:
        if self._index is None:
            self._index = ModuleIndex(self.tree)
        return self._index

    def cfg(self, body):
        """Shared per-body CFG, memoized so the dataflow passes build
        each function's graph once (keyed by the body list's identity —
        the tree outlives the Module, so ids are stable)."""
        from repro.analysis.cfg import build_cfg

        key = id(body)
        g = self._cfg_cache.get(key)
        if g is None:
            g = self._cfg_cache[key] = build_cfg(body)
        return g

    @property
    def is_core(self) -> bool:
        norm = self.path.replace(os.sep, "/")
        return "repro/core/" in norm

    @property
    def is_tests(self) -> bool:
        norm = self.path.replace(os.sep, "/")
        return norm.startswith("tests/") or "/tests/" in norm

    @property
    def is_units_module(self) -> bool:
        """The sanctioned conversion site (``repro/units.py``)."""
        norm = self.path.replace(os.sep, "/")
        return norm.endswith("repro/units.py")

    @property
    def is_analysis_module(self) -> bool:
        norm = self.path.replace(os.sep, "/")
        return "repro/analysis/" in norm

    def suppressed(self, finding: Finding) -> bool:
        rules = self.suppressions.get(finding.line)
        if not rules:
            return False
        for r in rules:
            if finding.rule == r or finding.rule.startswith(r + "/"):
                return True
        return False


def _parse_suppressions(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    if "lint:" not in source:
        return out  # tokenizing is ~half of parse cost; skip when clean
    # tokenize so suppression-shaped text inside string literals (test
    # fixtures!) doesn't count — only real comments do
    try:
        import io

        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                m = _SUPPRESS_RE.search(tok.string)
                if m:
                    rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                    out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        for i, text in enumerate(source.splitlines(), 1):
            m = _SUPPRESS_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out.setdefault(i, set()).update(rules)
    return out


def parse_module(path: str, source: Optional[str] = None) -> Module:
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    tree = ast.parse(source, filename=path)
    return Module(path, source, tree, _parse_suppressions(source))


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def load_modules(paths: Sequence[str]) -> List[Module]:
    return [parse_module(p) for p in iter_python_files(paths)]


# --- call/function signature registry (for call-argument unit binding) ----

#: name -> parameter-name tuple (leading self/cls stripped).  Only
#: functions whose every definition across the analyzed tree agrees on
#: the parameter list are bindable — ambiguous names map to None.
SignatureRegistry = Dict[str, Optional[Tuple[str, ...]]]


def build_signature_registry(modules: Sequence[Module]) -> SignatureRegistry:
    reg: SignatureRegistry = {}
    for mod in modules:
        for node in mod.index.functions:
            a = node.args
            if a.vararg or a.kwarg or a.posonlyargs:
                params: Optional[Tuple[str, ...]] = None
            else:
                names = [arg.arg for arg in a.args]
                if names and names[0] in ("self", "cls"):
                    names = names[1:]
                params = tuple(names) + tuple(arg.arg for arg in a.kwonlyargs)
            if node.name in reg and reg[node.name] != params:
                reg[node.name] = None  # ambiguous across defs
            else:
                reg[node.name] = params
    return reg


def _merge_signatures(
    reg: SignatureRegistry, file_sigs: Dict[str, Optional[List[str]]]
) -> None:
    for name, params in file_sigs.items():
        tup = tuple(params) if params is not None else None
        if name in reg and reg[name] != tup:
            reg[name] = None
        else:
            reg[name] = tup


def build_signature_registry_cached(
    modules: Sequence[Module], cache_path: str
) -> SignatureRegistry:
    """Whole-tree registry with a per-file cache keyed by source hash.

    The registry is a pure function of each file's function signatures,
    so per-file results are cached under the file's content hash and the
    whole-tree merge is recomputed from the (cheap) per-file maps.  The
    cache lives outside version control (see .gitignore) so CI's
    ``--fix`` no-diff gate never sees it.  A corrupt or stale cache is
    ignored, never trusted.
    """
    import hashlib

    try:
        with open(cache_path, encoding="utf-8") as f:
            cache = json.load(f)
        if not isinstance(cache, dict):
            cache = {}
    except (OSError, ValueError):
        cache = {}

    fresh: Dict[str, Dict] = {}
    reg: SignatureRegistry = {}
    dirty = False
    for mod in modules:
        digest = hashlib.sha256(mod.source.encode("utf-8")).hexdigest()
        entry = cache.get(mod.path)
        if entry is not None and entry.get("hash") == digest:
            file_sigs = entry["signatures"]
        else:
            per_file = build_signature_registry([mod])
            file_sigs = {
                name: (list(params) if params is not None else None)
                for name, params in per_file.items()
            }
            dirty = True
        fresh[mod.path] = {"hash": digest, "signatures": file_sigs}
        _merge_signatures(reg, file_sigs)
    if dirty or set(cache) != set(fresh):
        try:
            with open(cache_path, "w", encoding="utf-8") as f:
                json.dump(fresh, f)
        except OSError:
            pass  # caching is best-effort; the registry is already built
    return reg


# --- baseline -------------------------------------------------------------


def load_baseline(path: str) -> Set[Tuple[str, str, int]]:
    with open(path, encoding="utf-8") as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    out: Set[Tuple[str, str, int]] = set()
    for e in entries:
        out.add((e["rule"], e["path"], int(e["line"])))
    return out


def _pass_modules():
    from repro.analysis import (
        api_pass,
        concurrency_pass,
        determinism_pass,
        res_pass,
        schema_pass,
        taint_pass,
        units_pass,
    )

    return (
        units_pass,
        determinism_pass,
        concurrency_pass,
        api_pass,
        taint_pass,
        res_pass,
        schema_pass,
    )


#: meta-rules emitted by the driver itself (suppression hygiene); they
#: are not themselves suppressible — fix the comment instead
META_RULES = {
    "lint/unused-suppression": "`# lint: ok[...]` comment that silences "
    "nothing on its line (the finding was fixed, or the rule never fired "
    "here) — delete it",
    "lint/unknown-rule": "`# lint: ok[...]` names a rule or pass that "
    "does not exist",
}


def _suppression_findings(
    modules: Sequence[Module], raw: Sequence[Finding]
) -> List[Finding]:
    """Suppression-rot audit: a ``# lint: ok[...]`` that matches no
    finding on its line is dead weight, and one naming a nonexistent
    rule never worked at all."""
    rules = all_rules()
    prefixes = {r.split("/", 1)[0] for r in rules}
    by_line: Dict[Tuple[str, int], List[Finding]] = {}
    for f in raw:
        by_line.setdefault((f.path, f.line), []).append(f)
    out: List[Finding] = []
    for mod in modules:
        for line, tokens in sorted(mod.suppressions.items()):
            hits = by_line.get((mod.path, line), [])
            for tok in sorted(tokens):
                if tok not in rules and tok not in prefixes:
                    out.append(
                        Finding(
                            "lint/unknown-rule", mod.path, line, 0,
                            f"suppression names unknown rule {tok!r} "
                            "(see --list-rules)",
                        )
                    )
                    continue
                used = any(
                    f.rule == tok or f.rule.startswith(tok + "/") for f in hits
                )
                if not used:
                    out.append(
                        Finding(
                            "lint/unused-suppression", mod.path, line, 0,
                            f"suppression for {tok!r} matches no finding "
                            "on this line; delete it",
                        )
                    )
    return out


def run_passes(
    modules: Sequence[Module], registry: Optional[SignatureRegistry] = None
) -> List[Finding]:
    """Run every pass over ``modules``; inline suppressions applied and
    audited (dead or misspelled suppressions are themselves findings)."""
    if registry is None:
        registry = build_signature_registry(modules)
    findings: List[Finding] = []
    by_path = {m.path: m for m in modules}
    for pass_mod in _pass_modules():
        findings.extend(pass_mod.run(modules, registry))
    kept = [f for f in findings if not by_path[f.path].suppressed(f)]
    kept.extend(_suppression_findings(modules, findings))
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def all_rules() -> Dict[str, str]:
    """rule id -> one-line description, aggregated from every pass."""
    out: Dict[str, str] = {}
    for pass_mod in _pass_modules():
        out.update(pass_mod.RULES)
    out.update(META_RULES)
    return out
