"""Taint pass: wall-clock and unseeded-RNG values never reach sim outputs.

PR-9's observability layer made "simulated milliseconds only" a
*convention*: tracer event timestamps, ``SimResult.stats`` values and
exported payloads must be functions of the event clock, never of the
host's wall clock (or of the global RNG, which is just wall time with
extra steps).  The determinism pass bans wall-clock *reads* inside
``repro.core``; this pass checks the *flow*: a wall-derived value
produced anywhere (a launch script, a serving shim, a helper) must not
reach a sim-time sink, no matter how many assignments or call
boundaries it crosses on the way.

Sources (``taint/wall-time``):

* ``time.time()`` / ``perf_counter()`` / ``monotonic()`` / ... and
  their ``from time import ...`` aliases,
* ``datetime.now()`` / ``utcnow()`` / ``today()``,
* global-RNG ``random.*`` calls and unseeded ``random.Random()``.

Sinks:

* tracer event constructors (``SpanEvent``/``InstantEvent``/
  ``CounterEvent``) and ``.span()``/``.instant()``/``.counter()``
  method calls on tracer-named receivers,
* writes into ``stats``-named dicts (subscript assignment and
  ``.update()``/``.setdefault()``),
* export payloads: ``json.dump``/``json.dumps`` arguments.

Taint is tracked per local variable with the CFG dataflow engine
(:mod:`repro.analysis.dataflow`): the abstract value is the set of
taint tokens — ``<wall>`` plus the function's own parameter names — and
joins are set union.  Interprocedural flow uses whole-tree summaries
iterated to a fixpoint: for every function we compute (a) which tokens
reach its return value and (b) which of its parameters reach a sink in
its body (directly or through further calls).  A call site then maps
argument taint through the callee summary via the shared signature
registry, so ``record(helper(time.time()))`` is flagged even when the
source, the hop and the sink live in three different functions.

Scope: ``src/repro`` minus ``repro/launch`` (operator-facing scripts
report real wall time by design) and minus tests/fixtures.  The
``repro/obs`` exporters sit inside the sink set, not the scope cut:
they may *format* sim-time payloads but never inject wall time.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.analysis import dataflow
from repro.analysis.base import Finding, Module, SignatureRegistry
from repro.analysis.cfg import FOR, STMT, TEST, WITH, Element, build_cfg
from repro.analysis.determinism_pass import (
    _GLOBAL_RNG_FUNCS,
    _WALL_CLOCK_DATETIME_ATTRS,
    _WALL_CLOCK_TIME_ATTRS,
    _dotted,
)

RULES = {
    "taint/wall-time": "wall-clock/global-RNG-derived value flows into a "
    "sim-time sink (tracer event, stats dict, export payload)",
}

#: the taint token for a wall-clock/RNG source
WALL = "<wall>"

Taint = FrozenSet[str]
EMPTY: Taint = frozenset()
_WALL_TAINT: Taint = frozenset((WALL,))

#: tracer event dataclass constructors — all timestamp/value arguments
#: are sim-time by contract
_EVENT_CTORS = {"SpanEvent", "InstantEvent", "CounterEvent"}
#: tracer emit methods, checked when the receiver chain mentions a tracer
_TRACER_METHODS = {"span", "instant", "counter", "expect"}
#: export entry points whose payload must be sim-time-pure
_EXPORT_FUNCS = {"dump", "dumps", "write_chrome_trace"}


def _is_wall_source(node: ast.Call, from_imports: Dict[str, str]) -> bool:
    dotted = _dotted(node.func)
    if dotted is None:
        return False
    parts = dotted.split(".")
    if len(parts) >= 2 and parts[-2] == "time" and parts[-1] in _WALL_CLOCK_TIME_ATTRS:
        return True
    if parts[-1] in _WALL_CLOCK_DATETIME_ATTRS and "datetime" in parts[:-1]:
        return True
    if len(parts) == 2 and parts[0] == "random" and parts[1] in _GLOBAL_RNG_FUNCS:
        return True
    if dotted == "random.Random" and not node.args and not node.keywords:
        return True
    if len(parts) == 1 and parts[0] in from_imports:
        mod, _, name = from_imports[parts[0]].rpartition(".")
        if mod == "time" and name in _WALL_CLOCK_TIME_ATTRS:
            return True
        if mod == "random" and name in _GLOBAL_RNG_FUNCS:
            return True
    return False


def _receiver_is_tracer(node: ast.expr) -> bool:
    """Does the attribute chain mention a tracer (``self.tracer.span``,
    ``trace.instant``)?"""
    while isinstance(node, ast.Attribute):
        if "trace" in node.attr.lower():
            return True
        node = node.value
    return isinstance(node, ast.Name) and "trace" in node.id.lower()


def _is_stats_target(node: ast.expr) -> bool:
    """``stats[...]`` / ``self.stats[...]`` / ``result.stats[...]`` —
    possibly nested (``stats["a"]["b"]``)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr == "stats"
    return isinstance(node, ast.Name) and node.id == "stats"


class Summary:
    """Interprocedural facts for one function name."""

    __slots__ = ("ret", "sink_params")

    def __init__(self) -> None:
        self.ret: Taint = EMPTY  # tokens reaching the return value
        self.sink_params: Set[str] = set()  # params reaching a sink


class _TaintAnalysis(dataflow.ForwardAnalysis):
    TOP = EMPTY  # taint is a may-analysis; the union lattice is finite

    def __init__(self, checker: "_FunctionTaint", init_env: Dict[str, object]):
        self.checker = checker
        self.init_env = init_env

    def initial(self):
        return dict(self.init_env)

    def transfer_element(self, state, elem: Element, report: bool):
        self.checker._report = report
        self.checker._transfer(state, elem)
        return state

    def join_value(self, a, b):
        return (a or EMPTY) | (b or EMPTY)

    def join(self, a, b):
        # hot path: most variables are untainted on both sides, so the
        # generic per-key join_value round-trip is pure overhead
        out = dict(a)
        for k, v in b.items():
            cur = out.get(k, EMPTY)
            out[k] = v if not cur else (cur if not v or v == cur else cur | v)
        return out

    def missing_value(self, name: str):
        return EMPTY

    def widen(self, old, new):
        return new  # finite lattice: union converges without widening


class _FunctionTaint:
    """Taint dataflow over one code body (function or module scope)."""

    def __init__(
        self,
        mod: Module,
        registry: SignatureRegistry,
        summaries: Dict[str, Summary],
        from_imports: Dict[str, str],
        fname: str,
        findings: Optional[List[Finding]],
    ) -> None:
        self.mod = mod
        self.registry = registry
        self.summaries = summaries
        self.from_imports = from_imports
        self.fname = fname
        self.findings = findings  # None during the summary phase
        self._report = False
        self.ret_taint: Taint = EMPTY
        self.sink_params: Set[str] = set()
        self.would_emit = False  # a wall token reached a sink this run

    # --- driving ----------------------------------------------------------

    def run(
        self,
        body: Sequence[ast.stmt],
        params: Sequence[str],
        g=None,
        entry_states=None,
    ):
        env: Dict[str, object] = {
            p: frozenset((p,)) for p in params if p not in ("self", "cls")
        }
        if g is None:
            g = build_cfg(body)
        analysis = _TaintAnalysis(self, env)
        if entry_states is None:
            entry_states = dataflow.solve(g, analysis)
        # the sweep always runs: during the summary phase (findings is
        # None) it is what accumulates ret_taint/sink_params for bodies
        # whose solve() took the straight-line shortcut; emissions stay
        # gated on findings
        dataflow.report_sweep(g, analysis, entry_states)
        return entry_states

    def emit(self, node: ast.AST, what: str) -> None:
        self.would_emit = True
        if self.findings is None or not self._report:
            return
        self.findings.append(
            Finding(
                "taint/wall-time",
                self.mod.path,
                node.lineno,
                node.col_offset,
                f"wall-clock/RNG-derived value reaches {what} "
                "(sim outputs must be functions of the event clock)",
            )
        )

    # --- transfer ---------------------------------------------------------

    def _transfer(self, env: Dict[str, object], elem: Element) -> None:
        node = elem.node
        if elem.kind == TEST:
            if self._report:  # tests bind nothing (no walrus in-tree)
                self.taint_of(node, env)
        elif elem.kind == FOR:
            t = self.taint_of(node.iter, env)
            self._bind(node.target, t, env)
        elif elem.kind == WITH:
            for item in node.items:
                t = self.taint_of(item.context_expr, env)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, t, env)
        else:
            self._stmt(node, env)

    def _stmt(self, stmt: ast.stmt, env: Dict[str, object]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested defs are separate code bodies (run() per def)
        if not self._report and not isinstance(
            stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.ExceptHandler)
        ):
            # solve phase: non-binding statements cannot change the state;
            # sinks and return/summary accumulation happen in the report
            # sweep, which always runs over the fixpoint states
            return
        if isinstance(stmt, ast.Assign):
            t = self.taint_of(stmt.value, env)
            for tgt in stmt.targets:
                self._check_store(tgt, stmt.value, t, env)
                self._bind(tgt, t, env)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                t = self.taint_of(stmt.value, env)
                self._check_store(stmt.target, stmt.value, t, env)
                self._bind(stmt.target, t, env)
        elif isinstance(stmt, ast.AugAssign):
            t = self.taint_of(stmt.value, env)
            if isinstance(stmt.target, ast.Name):
                cur = env.get(stmt.target.id, EMPTY) or EMPTY
                env[stmt.target.id] = cur | t
            else:
                self._check_store(stmt.target, stmt.value, t, env)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self.ret_taint |= self.taint_of(stmt.value, env)
        elif isinstance(stmt, ast.Expr):
            self.taint_of(stmt.value, env)
        elif isinstance(stmt, (ast.Assert, ast.Raise, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.taint_of(child, env)
        elif isinstance(stmt, ast.ExceptHandler):
            if stmt.name is not None:
                env[stmt.name] = EMPTY

    def _bind(self, tgt: ast.expr, t: Taint, env: Dict[str, object]) -> None:
        if isinstance(tgt, ast.Name):
            env[tgt.id] = t
        elif isinstance(tgt, ast.Starred):
            self._bind(tgt.value, t, env)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._bind(e, t, env)
        # attribute/subscript stores: untracked (attributes are opaque)

    def _check_store(
        self, tgt: ast.expr, value: ast.expr, t: Taint, env: Dict[str, object]
    ) -> None:
        """A subscript store into a stats dict is a sink."""
        if isinstance(tgt, ast.Subscript) and _is_stats_target(tgt):
            self._sink(value, t, "a stats dict entry")

    def _sink(self, node: ast.AST, t: Taint, what: str) -> None:
        if WALL in t:
            self.emit(node, what)
        for tok in t:
            if tok != WALL:
                self.sink_params.add(tok)

    # --- expression taint -------------------------------------------------

    def taint_of(self, node: ast.expr, env: Dict[str, object]) -> Taint:
        if isinstance(node, ast.Constant):
            return EMPTY
        if isinstance(node, ast.Name):
            v = env.get(node.id, EMPTY)
            return v if isinstance(v, frozenset) else EMPTY
        if isinstance(node, ast.Call):
            return self._call(node, env)
        if isinstance(node, (ast.Attribute, ast.Subscript)):
            # element/attribute of a tainted object is tainted
            out = self.taint_of(node.value, env)
            if isinstance(node, ast.Subscript):
                out |= self.taint_of(node.slice, env)
            return out
        if isinstance(node, (ast.Lambda,)):
            return EMPTY
        out = EMPTY
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                out |= self.taint_of(child, env)
            elif isinstance(child, ast.comprehension):
                out |= self.taint_of(child.iter, env)
        return out

    def _call(self, node: ast.Call, env: Dict[str, object]) -> Taint:
        if _is_wall_source(node, self.from_imports):
            return _WALL_TAINT

        arg_taints = [self.taint_of(a, env) for a in node.args]
        kw_taints = [
            (kw.arg, self.taint_of(kw.value, env)) for kw in node.keywords
        ]
        all_args: Taint = EMPTY
        for t in arg_taints:
            all_args |= t
        for _, t in kw_taints:
            all_args |= t

        fname: Optional[str] = None
        if isinstance(node.func, ast.Name):
            fname = node.func.id
        elif isinstance(node.func, ast.Attribute):
            fname = node.func.attr
            all_args |= self.taint_of(node.func.value, env)

        # --- sinks --------------------------------------------------------
        if fname in _EVENT_CTORS:
            for a, t in zip(node.args, arg_taints):
                self._sink(a, t, f"a {fname} field")
            for kw, (_, t) in zip(node.keywords, kw_taints):
                self._sink(kw.value, t, f"a {fname} field")
        elif (
            fname in _TRACER_METHODS
            and isinstance(node.func, ast.Attribute)
            and _receiver_is_tracer(node.func.value)
        ):
            for a, t in zip(node.args, arg_taints):
                self._sink(a, t, f"tracer .{fname}()")
            for kw, (_, t) in zip(node.keywords, kw_taints):
                self._sink(kw.value, t, f"tracer .{fname}()")
        elif fname in _EXPORT_FUNCS:
            is_json = not isinstance(node.func, ast.Attribute) or (
                isinstance(node.func.value, ast.Name)
                and node.func.value.id == "json"
            )
            if fname == "write_chrome_trace" or is_json:
                for a, t in zip(node.args, arg_taints):
                    self._sink(a, t, f"export payload ({fname})")
                for kw, (_, t) in zip(node.keywords, kw_taints):
                    if kw.arg is None or kw.arg in ("obj", "fp", "events"):
                        self._sink(kw.value, t, f"export payload ({fname})")
        elif (
            fname in ("update", "setdefault")
            and isinstance(node.func, ast.Attribute)
            and _is_stats_target(node.func.value)
        ):
            for a, t in zip(node.args, arg_taints):
                self._sink(a, t, "a stats dict entry")
            for kw, (_, t) in zip(node.keywords, kw_taints):
                self._sink(kw.value, t, "a stats dict entry")

        # --- interprocedural flow through the summary ---------------------
        summary = self.summaries.get(fname) if fname else None
        if summary is None:
            # unknown callee: conservatively pass argument taint through
            return all_args
        out: Taint = summary.ret & _WALL_TAINT
        params = self.registry.get(fname) if fname else None
        bound = self._bind_args(node, params, arg_taints, kw_taints)
        for p, t in bound.items():
            if p in summary.ret:
                out |= t
            if p in summary.sink_params:
                self._sink(node, t, f"a sink inside {fname}()")
        if params is None and (summary.ret - _WALL_TAINT or summary.sink_params):
            # callee uses its params but the signature is ambiguous:
            # treat every argument as potentially flowing through
            if summary.ret - _WALL_TAINT:
                out |= all_args
            if summary.sink_params:
                self._sink(node, all_args, f"a sink inside {fname}()")
        return out

    @staticmethod
    def _bind_args(
        node: ast.Call,
        params: Optional[Tuple[str, ...]],
        arg_taints: List[Taint],
        kw_taints: List[Tuple[Optional[str], Taint]],
    ) -> Dict[str, Taint]:
        if not params:
            return {}
        bound: Dict[str, Taint] = {}
        for i, t in enumerate(arg_taints):
            if i < len(params):
                bound[params[i]] = bound.get(params[i], EMPTY) | t
        for name, t in kw_taints:
            if name in params:
                bound[name] = bound.get(name, EMPTY) | t
        return bound


def _param_names(node) -> List[str]:
    a = node.args
    return [
        arg.arg
        for arg in list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    ]


def _in_scope(mod: Module) -> bool:
    norm = mod.path.replace("\\", "/")
    if mod.is_tests or mod.is_analysis_module:
        return False
    if "repro/launch/" in norm:
        return False  # operator scripts report real wall time by design
    return "repro/" in norm or norm.startswith("src/")


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    from collections import deque

    in_scope = [m for m in modules if _in_scope(m)]
    if not in_scope:
        return []
    imports = {m.path: m.index.from_imports for m in in_scope}

    funcs = []  # (mod, fn, params)
    for mod in in_scope:
        for fn in mod.index.functions:
            funcs.append((mod, fn, _param_names(fn)))
    summaries: Dict[str, Summary] = {}
    for _, fn, _ in funcs:
        summaries.setdefault(fn.name, Summary())

    # Phase A: whole-tree summaries to a fixpoint, worklist-driven — a
    # function re-runs only when a callee's summary grew.  Summaries
    # only grow over a finite token set, so this terminates.
    cfgs = {i: mod.cfg(fn.body) for i, (mod, fn, _) in enumerate(funcs)}
    callers: Dict[str, List[int]] = {}
    for i, (mod, fn, _) in enumerate(funcs):
        for name in mod.index.called_names[id(fn)]:
            callers.setdefault(name, []).append(i)
    work = deque(range(len(funcs)))
    queued = set(work)
    states: Dict[int, Dict] = {}
    would_emit: Dict[int, bool] = {}
    while work:
        i = work.popleft()
        queued.discard(i)
        mod, fn, params = funcs[i]
        ft = _FunctionTaint(
            mod, registry, summaries, imports[mod.path], fn.name, None
        )
        states[i] = ft.run(fn.body, params, cfgs[i])
        would_emit[i] = ft.would_emit
        s = summaries[fn.name]
        new_ret = s.ret | ft.ret_taint
        new_sinks = s.sink_params | ft.sink_params
        if new_ret != s.ret or new_sinks != s.sink_params:
            s.ret = new_ret
            s.sink_params = set(new_sinks)
            for j in callers.get(fn.name, ()):
                if j not in queued:
                    work.append(j)
                    queued.add(j)

    # Phase B: per-function + module-scope check sweep.  A function's
    # last Phase-A run already used the final summaries (it re-enqueues
    # whenever a callee grows), so its fixpoint entry states are final —
    # reuse them instead of solving again.
    findings: List[Finding] = []
    for i, (mod, fn, params) in enumerate(funcs):
        if not would_emit.get(i):
            # the function's final Phase-A run (same entry states, same
            # summaries) saw no wall token reach a sink — the report
            # sweep would emit nothing, so skip it
            continue
        ft = _FunctionTaint(
            mod, registry, summaries, imports[mod.path], fn.name, findings
        )
        ft.run(fn.body, params, cfgs[i], states.get(i))
    for mod in in_scope:
        top = _FunctionTaint(
            mod, registry, summaries, imports[mod.path], "<module>", findings
        )
        top.run(mod.tree.body, [], mod.cfg(mod.tree.body))
    return findings
