"""Resource-safety pass: acquisitions must survive exceptional paths.

The PR-7 checkpointer shipped a worker thread whose queue sentinel was
posted *outside* ``finally`` — one exception between ``start()`` and
``join()`` and the interpreter hung on a non-daemon thread.  That bug
class is purely structural: a resource is acquired, and the release is
reachable only on the fall-through path.  These checks flag the
structure, before runtime and regardless of whether a test happens to
take the exceptional path:

``res/file-no-close``
    A file handle (``open``/``os.fdopen``/``tempfile.*``) bound to a
    local variable outside a ``with`` and not closed in a ``finally``.
    Any statement between the open and the ``.close()`` can raise, so a
    bare close is a leak on the exceptional path.  Handles that *escape*
    — returned, yielded, stored on an attribute or into a container,
    passed to another call — are someone else's lifetime and exempt.

``res/lock-no-release``
    ``.acquire()`` on a lock-named receiver with no matching
    ``.release()`` in a ``finally`` block.  ``with lock:`` is the
    sanctioned form.

``res/thread-leak-on-raise``
    A non-daemon ``threading.Thread`` bound to a local, started, and
    either never joined or joined only on the fall-through side of an
    explicit ``raise``.  Attribute-stored threads (``self._thread``)
    have object-lifetime management and are exempt, as are threads that
    escape into containers/calls.

Scoped to ``src/repro`` excluding tests; the lock/thread rules further
require the module to import ``threading`` (same gate as the
concurrency pass).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.base import Finding, Module, SignatureRegistry

RULES = {
    "res/file-no-close": "file handle opened outside `with` and not closed "
    "in a finally (leaks on the exceptional path)",
    "res/lock-no-release": "lock .acquire() without .release() in a finally "
    "(use `with lock:`)",
    "res/thread-leak-on-raise": "thread started but not joined on every "
    "path (join in a finally, or store the thread on the object)",
}

_OPEN_FUNCS = {"open"}
_OPEN_ATTRS = {
    ("os", "fdopen"),
    ("tempfile", "NamedTemporaryFile"),
    ("tempfile", "TemporaryFile"),
    ("tempfile", "SpooledTemporaryFile"),
    ("io", "open"),
    ("gzip", "open"),
    ("bz2", "open"),
    ("lzma", "open"),
}


def _is_open_call(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Name):
        return f.id in _OPEN_FUNCS
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        return (f.value.id, f.attr) in _OPEN_ATTRS
    return False


def _is_thread_ctor(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Name) and f.id == "Thread":
        return True
    return (
        isinstance(f, ast.Attribute)
        and f.attr == "Thread"
        and isinstance(f.value, ast.Name)
        and f.value.id == "threading"
    )


def _is_daemon_thread(node: ast.Call) -> bool:
    for kw in node.keywords:
        if kw.arg == "daemon" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


#: quick source prescan — a module whose text contains none of these
#: cannot trigger any res/* rule, so skip its AST entirely
_PRESCAN_TOKENS = ("open(", ".acquire(", "Thread(", "TemporaryFile(", "fdopen(")


class _MethodCalls(ast.NodeVisitor):
    """All ``<name>.<method>()`` statements on local-name receivers,
    plus escape facts per local name."""

    def __init__(self) -> None:
        self.calls: List[ast.Call] = []  # name.method(...) calls
        self.escaped: Set[str] = set()
        self.finally_depth = 0
        self.in_finally: List[ast.Call] = []  # calls lexically inside a finalbody
        self._raises: List[ast.Raise] = []

    def visit_Try(self, node: ast.Try) -> None:
        for part in (node.body, node.handlers, node.orelse):
            for child in part:
                self.visit(child)
        self.finally_depth += 1
        for child in node.finalbody:
            self.visit(child)
        self.finally_depth -= 1

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute) and isinstance(
            node.func.value, ast.Name
        ):
            self.calls.append(node)
            if self.finally_depth:
                self.in_finally.append(node)
        # a local passed as an argument escapes this function's control
        for a in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(a, ast.Name):
                self.escaped.add(a.id)
        self.generic_visit(node)

    def visit_Return(self, node: ast.Return) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name):
                self.escaped.add(sub.id)
        self.generic_visit(node)

    def visit_Yield(self, node: ast.Yield) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name):
                self.escaped.add(sub.id)

    def visit_Raise(self, node: ast.Raise) -> None:
        self._raises.append(node)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # x stored into an attribute/subscript/tuple escapes
        value_names = {
            s.id for s in ast.walk(node.value) if isinstance(s, ast.Name)
        }
        for t in node.targets:
            if not isinstance(t, ast.Name):
                self.escaped.update(value_names)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass  # nested defs are separate scopes

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass


def _local_binds(body: Sequence[ast.stmt], pred) -> List:
    """(name, value_call, assign_node) for each local ``x = <pred-call>``
    in this scope, skipping nested function/class bodies."""
    out = []

    class V(ast.NodeVisitor):
        def visit_Assign(self, node: ast.Assign) -> None:
            if (
                len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and pred(node.value)
            ):
                out.append((node.targets[0].id, node.value, node))
            self.generic_visit(node)

        def visit_FunctionDef(self, node):
            pass

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Lambda(self, node):
            pass

    v = V()
    for stmt in body:
        v.visit(stmt)
    return out


class _ScopeChecker:
    def __init__(self, mod: Module, threaded: bool):
        self.mod = mod
        self.threaded = threaded
        self.findings: List[Finding] = []

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(rule, self.mod.path, node.lineno, node.col_offset, message)
        )

    def check_scope(self, body: Sequence[ast.stmt]) -> None:
        mc = _MethodCalls()
        for stmt in body:
            mc.visit(stmt)
        self._check_files(body, mc)
        if self.threaded:
            self._check_locks(mc)
            self._check_threads(body, mc)

    # --- files ------------------------------------------------------------

    def _check_files(self, body: Sequence[ast.stmt], mc: _MethodCalls) -> None:
        for name, call, assign in _local_binds(body, _is_open_call):
            if name in mc.escaped:
                continue
            closed_in_finally = any(
                c.func.attr == "close" and c.func.value.id == name
                for c in mc.in_finally
            )
            if closed_in_finally:
                continue
            self.emit(
                "res/file-no-close",
                assign,
                f"{name} = open(...) outside `with`; a raise before "
                f"{name}.close() leaks the handle — use `with` or "
                "close in a finally",
            )

    # --- locks ------------------------------------------------------------

    def _check_locks(self, mc: _MethodCalls) -> None:
        released_in_finally = {
            c.func.value.id for c in mc.in_finally if c.func.attr == "release"
        }
        for c in mc.calls:
            if c.func.attr != "acquire":
                continue
            recv = c.func.value.id
            if recv in released_in_finally:
                continue
            self.emit(
                "res/lock-no-release",
                c,
                f"{recv}.acquire() without {recv}.release() in a finally; "
                f"use `with {recv}:`",
            )

    # --- threads ----------------------------------------------------------

    def _check_threads(self, body: Sequence[ast.stmt], mc: _MethodCalls) -> None:
        for name, ctor, assign in _local_binds(body, _is_thread_ctor):
            if name in mc.escaped or _is_daemon_thread(ctor):
                continue
            started = [
                c for c in mc.calls
                if c.func.attr == "start" and c.func.value.id == name
            ]
            if not started:
                continue
            joins = [
                c for c in mc.calls
                if c.func.attr == "join" and c.func.value.id == name
            ]
            if not joins:
                self.emit(
                    "res/thread-leak-on-raise",
                    assign,
                    f"thread {name} is started but never joined in this "
                    "scope; join it (in a finally) or store it on the object",
                )
                continue
            join_in_finally = any(c in mc.in_finally for c in joins)
            if join_in_finally:
                continue
            start_line = min(c.lineno for c in started)
            join_line = max(c.lineno for c in joins)
            risky = [
                r for r in mc._raises if start_line < r.lineno < join_line
            ]
            if risky:
                self.emit(
                    "res/thread-leak-on-raise",
                    risky[0],
                    f"raise between {name}.start() and {name}.join() "
                    f"skips the join; move the join into a finally",
                )


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        if mod.is_tests or mod.is_analysis_module:
            continue
        norm = mod.path.replace("\\", "/")
        if "repro/" not in norm and not norm.startswith("src/"):
            continue
        if not any(tok in mod.source for tok in _PRESCAN_TOKENS):
            continue
        threaded = "threading" in mod.index.import_roots
        checker = _ScopeChecker(mod, threaded)
        # one scope per function plus the module top level; `with open()
        # as f` binds no Assign node, so managed handles never enter
        checker.check_scope(mod.tree.body)
        for node in mod.index.functions:
            checker.check_scope(node.body)
        findings.extend(checker.findings)
    return findings
