"""SARIF 2.1.0 export (``--sarif out.sarif``).

One run, one driver (``repro.analysis``), one result per finding, in
the subset of SARIF that GitHub code scanning ingests: ``ruleId`` +
``ruleIndex`` into the driver's rule table, a ``physicalLocation`` with
``%SRCROOT%``-relative URI, and a stable ``partialFingerprints`` entry
matching the baseline fingerprint (rule, path, line) so annotations
survive unrelated diffs.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.analysis.base import Finding, all_rules

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = "https://json.schemastore.org/sarif-2.1.0.json"


def sarif_payload(findings: Sequence[Finding]) -> Dict:
    rules = all_rules()
    rule_ids = sorted(rules)
    index = {r: i for i, r in enumerate(rule_ids)}
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": "repro.analysis",
                        "informationUri": "https://example.invalid/repro",
                        "rules": [
                            {
                                "id": r,
                                "shortDescription": {"text": rules[r]},
                                "defaultConfiguration": {"level": "error"},
                            }
                            for r in rule_ids
                        ],
                    }
                },
                "results": [_result(f, index) for f in findings],
            }
        ],
    }


def _result(f: Finding, index: Dict[str, int]) -> Dict:
    return {
        "ruleId": f.rule,
        "ruleIndex": index.get(f.rule, -1),
        "level": "error",
        "message": {"text": f.message},
        "locations": [
            {
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": f.path.replace("\\", "/"),
                        "uriBaseId": "%SRCROOT%",
                    },
                    "region": {
                        "startLine": f.line,
                        # SARIF columns are 1-based; ast cols are 0-based
                        "startColumn": f.col + 1,
                    },
                }
            }
        ],
        "partialFingerprints": {
            "reproAnalysisFingerprint/v1": f"{f.rule}:{f.path}:{f.line}",
        },
    }
