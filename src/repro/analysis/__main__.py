"""CLI: ``python -m repro.analysis [--baseline FILE] [paths...]``.

Exit status 0 when every finding is baselined (or none exist), 1 when
new findings are present, 2 on usage errors.  Default paths are
``src`` and ``tests`` relative to the current directory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis import all_rules, analyze_paths, load_baseline


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="units/determinism/concurrency/API lint over the repo",
    )
    ap.add_argument("paths", nargs="*", help="files or directories (default: src tests)")
    ap.add_argument(
        "--baseline",
        metavar="FILE",
        help="JSON list of known findings to ignore (shipped empty)",
    )
    ap.add_argument("--json", action="store_true", help="emit findings as JSON")
    ap.add_argument(
        "--list-rules", action="store_true", help="print every rule id and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(all_rules().items()):
            print(f"{rule:28s} {desc}")
        return 0

    paths = args.paths or [p for p in ("src", "tests") if os.path.isdir(p)]
    if not paths:
        print("error: no paths given and no src/ or tests/ here", file=sys.stderr)
        return 2

    findings = analyze_paths(paths)
    if args.baseline:
        try:
            known = load_baseline(args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"error: cannot read baseline {args.baseline}: {e}", file=sys.stderr)
            return 2
        findings = [f for f in findings if f.fingerprint() not in known]

    if args.json:
        print(
            json.dumps(
                [
                    {
                        "rule": f.rule,
                        "path": f.path,
                        "line": f.line,
                        "col": f.col,
                        "message": f.message,
                    }
                    for f in findings
                ],
                indent=2,
            )
        )
    else:
        for f in findings:
            print(f.render())
        n = len(findings)
        print(f"repro.analysis: {n} finding{'s' if n != 1 else ''}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
