"""CLI: ``python -m repro.analysis [options] [paths...]``.

Exit status 0 when every finding is baselined (or none exist), 1 when
new findings are present, 2 on usage errors.  Default paths are
``src`` and ``tests`` relative to the current directory.

``--fix`` rewrites the mechanical findings in place (``sorted()`` wrap
for ``det/set-iteration``, ``None``-sentinel for
``api/mutable-default``) and re-lints; ``--sarif FILE`` writes the
(post-baseline) findings as SARIF 2.1.0 for code-scanning ingestion.
The whole-tree signature registry is cached per file-content hash in
``.repro_analysis_cache.json`` (untracked; delete freely).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis import all_rules, load_baseline
from repro.analysis.base import (
    build_signature_registry_cached,
    load_modules,
    run_passes,
)
from repro.analysis.fix import apply_fixes
from repro.analysis.sarif import sarif_payload

CACHE_PATH = ".repro_analysis_cache.json"


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="units/determinism/concurrency/API/taint/resource/schema "
        "lint over the repo",
    )
    ap.add_argument("paths", nargs="*", help="files or directories (default: src tests)")
    ap.add_argument(
        "--baseline",
        metavar="FILE",
        help="JSON list of known findings to ignore (shipped empty)",
    )
    ap.add_argument("--json", action="store_true", help="emit findings as JSON")
    ap.add_argument(
        "--sarif",
        metavar="FILE",
        help="write findings as SARIF 2.1.0 (GitHub code-scanning format)",
    )
    ap.add_argument(
        "--fix",
        action="store_true",
        help="rewrite mechanical findings in place "
        "(det/set-iteration, api/mutable-default), then re-lint",
    )
    ap.add_argument(
        "--no-cache",
        action="store_true",
        help="skip the signature-registry content-hash cache",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print every rule id and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(all_rules().items()):
            print(f"{rule:32s} {desc}")
        return 0

    paths = args.paths or [p for p in ("src", "tests") if os.path.isdir(p)]
    if not paths:
        print("error: no paths given and no src/ or tests/ here", file=sys.stderr)
        return 2

    known = set()
    if args.baseline:
        try:
            known = load_baseline(args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"error: cannot read baseline {args.baseline}: {e}", file=sys.stderr)
            return 2

    def analyze():
        modules = load_modules(paths)
        if args.no_cache:
            registry = None  # run_passes builds it uncached
        else:
            registry = build_signature_registry_cached(modules, CACHE_PATH)
        found = run_passes(modules, registry)
        return modules, [f for f in found if f.fingerprint() not in known]

    modules, findings = analyze()

    if args.fix:
        rewrites = apply_fixes(modules, findings)
        for path, new_source in rewrites.items():
            with open(path, "w", encoding="utf-8") as fh:
                fh.write(new_source)
            print(f"fixed: {path}", file=sys.stderr)
        if rewrites:
            modules, findings = analyze()  # re-lint the rewritten tree

    if args.sarif:
        with open(args.sarif, "w", encoding="utf-8") as fh:
            json.dump(sarif_payload(findings), fh, indent=2)

    if args.json:
        print(
            json.dumps(
                [
                    {
                        "rule": f.rule,
                        "path": f.path,
                        "line": f.line,
                        "col": f.col,
                        "message": f.message,
                    }
                    for f in findings
                ],
                indent=2,
            )
        )
    else:
        for f in findings:
            print(f.render())
        n = len(findings)
        print(f"repro.analysis: {n} finding{'s' if n != 1 else ''}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
