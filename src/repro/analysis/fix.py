"""Autofixes for the mechanical rules (``--fix``).

Two rules have a single canonical remediation and get one:

* ``det/set-iteration`` — wrap the iterated set expression in
  ``sorted(...)``.  ``sorted`` is the sanctioned order; the wrap is
  behavior-defining, not behavior-preserving, which is exactly the
  point.
* ``api/mutable-default`` — replace the mutable default with ``None``
  and materialize it at call time behind an ``if param is None:`` guard
  inserted at the top of the function body (after the docstring).

Fixes are driven by the *filtered* finding list — suppressed or
baselined findings are never rewritten — and edits are applied
bottom-up so earlier spans stay valid.  Running ``--fix`` twice is a
no-op by construction: a wrapped iteration is no longer set-valued to
the determinism pass, and a ``None`` default is no longer mutable.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.base import Finding, Module

FIXABLE_RULES = ("det/set-iteration", "api/mutable-default")

# one edit: replace [start, end) (line/col, 1-based lines) with text
Edit = Tuple[int, int, int, int, str]


def _segment(lines: List[str], n: ast.expr) -> str:
    if n.lineno == n.end_lineno:
        return lines[n.lineno - 1][n.col_offset:n.end_col_offset]
    parts = [lines[n.lineno - 1][n.col_offset:]]
    parts.extend(lines[i] for i in range(n.lineno, n.end_lineno - 1))
    parts.append(lines[n.end_lineno - 1][:n.end_col_offset])
    return "\n".join(parts)


def _iter_exprs(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter
        elif isinstance(node, ast.comprehension):
            yield node.iter


def _defaults_with_params(fn) -> List[Tuple[str, ast.expr]]:
    a = fn.args
    out: List[Tuple[str, ast.expr]] = []
    pos = list(a.posonlyargs) + list(a.args)
    for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        out.append((arg.arg, default))
    for arg, default in zip(a.kwonlyargs, a.kw_defaults):
        if default is not None:
            out.append((arg.arg, default))
    return out


def _body_insert_point(fn, lines: List[str]) -> Tuple[int, str]:
    """(1-based line to insert before, indent string) for a guard at the
    top of ``fn``'s body, skipping the docstring."""
    body = fn.body
    first = body[0]
    if (
        isinstance(first, ast.Expr)
        and isinstance(first.value, ast.Constant)
        and isinstance(first.value.value, str)
        and len(body) > 1
    ):
        first = body[1]
    indent = lines[first.lineno - 1][: first.col_offset]
    return first.lineno, indent


def fix_module(mod: Module, findings: Sequence[Finding]) -> str:
    """New source for ``mod`` with every fixable finding remediated."""
    lines = mod.source.splitlines()
    edits: List[Edit] = []

    set_iter_sites = {
        (f.line, f.col) for f in findings if f.rule == "det/set-iteration"
    }
    for it in _iter_exprs(mod.tree):
        if (it.lineno, it.col_offset) in set_iter_sites:
            edits.append(
                (
                    it.lineno, it.col_offset, it.end_lineno, it.end_col_offset,
                    f"sorted({_segment(lines, it)})",
                )
            )

    default_sites = {
        (f.line, f.col) for f in findings if f.rule == "api/mutable-default"
    }
    if default_sites:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            guards: List[str] = []
            for param, default in _defaults_with_params(node):
                if (default.lineno, default.col_offset) not in default_sites:
                    continue
                literal = _segment(lines, default)
                edits.append(
                    (
                        default.lineno, default.col_offset,
                        default.end_lineno, default.end_col_offset,
                        "None",
                    )
                )
                guards.append((param, literal))
            if guards:
                at, indent = _body_insert_point(node, lines)
                text = "".join(
                    f"{indent}if {param} is None:\n"
                    f"{indent}    {param} = {literal}\n"
                    for param, literal in guards
                )
                edits.append((at, 0, at, 0, text))

    return _apply(lines, edits)


def _apply(lines: List[str], edits: List[Edit]) -> str:
    text = "\n".join(lines) + "\n"
    # to flat offsets
    starts: List[int] = []
    off = 0
    for ln in lines:
        starts.append(off)
        off += len(ln) + 1

    def flat(line: int, col: int) -> int:
        return starts[line - 1] + col

    spans = sorted(
        ((flat(a, b), flat(c, d), rep) for a, b, c, d, rep in edits),
        key=lambda e: (e[0], e[1]),
        reverse=True,
    )
    last_start = None
    for s, e, rep in spans:
        if last_start is not None and e > last_start:
            continue  # overlapping edit (shouldn't happen); keep the later one
        text = text[:s] + rep + text[e:]
        last_start = s
    return text


def apply_fixes(
    modules: Sequence[Module], findings: Sequence[Finding]
) -> Dict[str, str]:
    """path -> new source, for every module with at least one fixable
    finding.  Pure: the caller writes files (and re-lints if it wants
    proof of convergence)."""
    by_path: Dict[str, List[Finding]] = {}
    for f in findings:
        if f.rule in FIXABLE_RULES:
            by_path.setdefault(f.path, []).append(f)
    out: Dict[str, str] = {}
    mods = {m.path: m for m in modules}
    for path, fs in sorted(by_path.items()):
        mod = mods.get(path)
        if mod is None:
            continue
        new = fix_module(mod, fs)
        if new != mod.source:
            out[path] = new
    return out
