"""``repro.analysis`` — static enforcement of the simulator's invariants.

Seven passes over ``src/`` and ``tests/`` (run as
``python -m repro.analysis``), the stateful ones built on a shared
per-function CFG (:mod:`repro.analysis.cfg`) and forward dataflow
solver (:mod:`repro.analysis.dataflow`) so facts survive branches,
loops and call boundaries:

* **units** (``units/*``) — flow-sensitive dimensional analysis over
  identifier suffixes; conversions must go through ``repro.units``.
* **determinism** (``det/*``) — ``repro.core`` is wall-clock-free,
  seeded-RNG-only, and never iterates sets in hash order.
* **concurrency** (``conc/*``) — queue/thread discipline in threaded
  modules.
* **api** (``api/*``) — engine calls in tests validate, no exact float
  equality on computed ``_ms`` arithmetic, no mutable defaults.
* **taint** (``taint/*``) — wall-clock/RNG values never flow
  (interprocedurally) into tracer events, stats dicts or exports.
* **resource safety** (``res/*``) — files/locks/threads released on
  the exceptional path, not just the fall-through one.
* **schema** (``schema/*``) — literal stats keys are registered in
  ``repro.obs.schema`` before an engine can emit them.

Silence one finding with ``# lint: ok[rule]`` on its line — audited:
a suppression that silences nothing (``lint/unused-suppression``) or
names a nonexistent rule (``lint/unknown-rule``) is itself a finding.
``--fix`` applies the mechanical remediations; ``--sarif`` exports for
code-scanning annotations.  The baseline file
(``analysis_baseline.json``) is shipped empty and CI fails on any new
finding.
"""
from repro.analysis.base import (  # noqa: F401
    Finding,
    Module,
    all_rules,
    load_baseline,
    load_modules,
    parse_module,
    run_passes,
)


def analyze_paths(paths):
    """Parse every ``.py`` under ``paths`` and run all passes."""
    return run_passes(load_modules(paths))
