"""``repro.analysis`` — static enforcement of the simulator's invariants.

Four AST passes over ``src/`` and ``tests/`` (run as
``python -m repro.analysis``):

* **units** (``units/*``) — dimensional analysis over identifier
  suffixes; conversions must go through ``repro.units``.
* **determinism** (``det/*``) — ``repro.core`` is wall-clock-free,
  seeded-RNG-only, and never iterates sets in hash order.
* **concurrency** (``conc/*``) — queue/thread discipline in threaded
  modules.
* **api** (``api/*``) — engine calls in tests validate, no exact float
  equality on computed ``_ms`` arithmetic, no mutable defaults.

Silence one finding with ``# lint: ok[rule]`` on its line; the
baseline file (``analysis_baseline.json``) is shipped empty and CI
fails on any new finding.
"""
from repro.analysis.base import (  # noqa: F401
    Finding,
    Module,
    all_rules,
    load_baseline,
    load_modules,
    parse_module,
    run_passes,
)


def analyze_paths(paths):
    """Parse every ``.py`` under ``paths`` and run all passes."""
    return run_passes(load_modules(paths))
