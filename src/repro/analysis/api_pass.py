"""API-misuse pass: engine- and test-surface contracts.

* ``api/validate-missing`` — tests that drive an engine
  (``simulate`` / ``simulate_fleet`` / ``simulate_horizon``) without
  ``validate=True`` skip the invariant checker and assert on outputs a
  corrupted schedule could also produce.  Scoped to ``tests/``; calls
  on the frozen reference engine (``ref.simulate`` /
  ``reference.simulate``) are exempt — it predates the ``validate``
  kwarg and is itself the differential oracle.

* ``api/float-eq-ms`` — ``==``/``!=`` between a *computed* ``_ms``
  expression and anything else: float arithmetic on wall-clock values
  is not exact, use ``pytest.approx`` / ``math.isclose``.  Comparing
  two stored ``_ms`` values verbatim (``r1.total_ms == r2.total_ms``)
  is a differential/determinism identity and allowed, as are literal
  sentinels (``t_ms == 0.0``) and ``pytest.approx`` comparisons.

* ``api/mutable-default`` — ``def f(x=[], y={}, z=set())`` shares one
  object across calls; the classic aliasing bug.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from repro.analysis.base import Finding, Module, SignatureRegistry

RULES = {
    "api/validate-missing": "engine call in tests without validate=True",
    "api/float-eq-ms": "float ==/!= on computed _ms values "
    "(use pytest.approx/math.isclose)",
    "api/mutable-default": "mutable default argument",
}

_ENGINE_FUNCS = {"simulate", "simulate_fleet", "simulate_horizon"}
_REFERENCE_RECEIVERS = {"ref", "reference"}


def _func_name(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _receiver_name(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        return node.value.id
    return None


def _contains_ms_identifier(node: ast.expr) -> bool:
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        if name is not None and (name.lower().endswith("_ms") or name.lower() == "ms"):
            return True
    return False


def _is_arithmetic(node: ast.expr) -> bool:
    return isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod)
    )


def _is_approx_call(node: ast.expr) -> bool:
    return (
        isinstance(node, ast.Call)
        and _func_name(node.func) in ("approx", "isclose")
    )


def _is_literal(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant):
        return True
    return isinstance(node, ast.UnaryOp) and isinstance(node.operand, ast.Constant)


def _is_mutable_literal(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("list", "dict", "set", "bytearray", "defaultdict", "deque")
    return False


class _Checker(ast.NodeVisitor):
    def __init__(self, mod: Module):
        self.mod = mod
        self.findings: List[Finding] = []

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(rule, self.mod.path, node.lineno, node.col_offset, message)
        )

    def visit_Call(self, node: ast.Call) -> None:
        name = _func_name(node.func)
        if (
            self.mod.is_tests
            and name in _ENGINE_FUNCS
            and _receiver_name(node.func) not in _REFERENCE_RECEIVERS
            and not any(kw.arg == "validate" for kw in node.keywords)
        ):
            self.emit(
                "api/validate-missing",
                node,
                f"{name}() in a test without validate=True "
                "(the invariant checker is off)",
            )
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        for i, op in enumerate(node.ops):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            left, right = operands[i], operands[i + 1]
            if _is_approx_call(left) or _is_approx_call(right):
                continue
            if _is_literal(left) or _is_literal(right):
                continue  # sentinel checks (t_ms == 0.0) are intentional
            computed = (_is_arithmetic(left) and _contains_ms_identifier(left)) or (
                _is_arithmetic(right) and _contains_ms_identifier(right)
            )
            if computed:
                self.emit(
                    "api/float-eq-ms",
                    node,
                    "exact ==/!= on computed _ms arithmetic; "
                    "use pytest.approx or math.isclose",
                )
        self.generic_visit(node)

    def _check_defaults(self, node) -> None:
        a = node.args
        for default in list(a.defaults) + [d for d in a.kw_defaults if d is not None]:
            if _is_mutable_literal(default):
                self.emit(
                    "api/mutable-default",
                    default,
                    f"mutable default argument in {node.name}() "
                    "(shared across calls; default to None)",
                )
        self.generic_visit(node)

    visit_FunctionDef = _check_defaults
    visit_AsyncFunctionDef = _check_defaults


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        checker = _Checker(mod)
        checker.visit(mod.tree)
        findings.extend(checker.findings)
    return findings
