"""Generic forward dataflow solver over :mod:`repro.analysis.cfg`.

A pass supplies a :class:`ForwardAnalysis` — an initial state, a
per-element transfer function and a join — and gets back the fixpoint
entry state of every block.  States are plain ``dict``\\ s (variable →
abstract value); the solver treats them opaquely apart from equality.

Termination: the worklist iterates until no entry state changes.  Joins
must be monotone (the solver *accumulates* — a block's new entry state
is ``join(old, incoming)``, never a recomputation from scratch), and a
widening hook bounds loops whose abstract values keep refining: after
``WIDEN_AFTER`` visits of the same block, any key still changing is
forced to the analysis' top value.  With the passes' finite-height
value lattices widening is a safety net, not the common path.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional

from repro.analysis.cfg import CFG, Block, Element

State = Dict[str, object]

#: visits of one block before the solver starts widening its entry state
WIDEN_AFTER = 16
#: hard per-block visit bound (defense in depth; unreachable in practice)
VISIT_LIMIT = 64


class ForwardAnalysis:
    """Interface a dataflow pass implements.  ``transfer_element`` must
    return a *new or mutated copy* — the solver hands it a private
    copy — and must be deterministic."""

    #: the analysis' ⊤ (forced by widening); None is a safe default for
    #: passes whose UNKNOWN is None
    TOP: object = None

    def initial(self) -> State:
        return {}

    def copy(self, state: State) -> State:
        return dict(state)

    def transfer_element(self, state: State, elem: Element, report: bool) -> State:
        raise NotImplementedError

    def join_value(self, a: object, b: object) -> object:
        raise NotImplementedError

    def missing_value(self, name: str) -> object:
        """Value of a variable absent from one side of a join (e.g. the
        name's declared unit, or the analysis' bottom)."""
        return self.TOP

    def join(self, a: State, b: State) -> State:
        out: State = {}
        for k in a.keys() | b.keys():
            av = a[k] if k in a else self.missing_value(k)
            bv = b[k] if k in b else self.missing_value(k)
            out[k] = self.join_value(av, bv)
        return out

    def widen(self, old: State, new: State) -> State:
        """Force every key that is still changing to TOP."""
        out = dict(new)
        for k, v in out.items():
            if old.get(k, self.missing_value(k)) != v:
                out[k] = self.TOP
        return out


def transfer_block(
    analysis: ForwardAnalysis, state: State, block: Block, report: bool
) -> State:
    for elem in block.elements:
        state = analysis.transfer_element(state, elem, report)
    return state


def _reverse_postorder(cfg: CFG) -> Optional[List[int]]:
    """Blocks reachable from the entry in reverse post-order, or None
    when the reachable subgraph has a cycle (a loop back edge)."""
    color: Dict[int, int] = {cfg.entry: 1}  # 1 = on stack, 2 = done
    stack: List[list] = [[cfg.entry, iter(cfg.block(cfg.entry).succs)]]
    post: List[int] = []
    while stack:
        frame = stack[-1]
        pushed = False
        for s in frame[1]:
            c = color.get(s)
            if c == 1:
                return None  # back edge
            if c is None:
                color[s] = 1
                stack.append([s, iter(cfg.block(s).succs)])
                pushed = True
                break
        if not pushed:
            color[frame[0]] = 2
            post.append(frame[0])
            stack.pop()
    post.reverse()
    return post


def solve(cfg: CFG, analysis: ForwardAnalysis) -> Dict[int, State]:
    """Fixpoint entry state per block id.  Blocks unreachable from the
    entry keep the initial state (the report sweep still checks them)."""
    if len(cfg.blocks) == 2:
        # entry + exit only: a straight-line body with no joins — the
        # fixpoint is the initial state, no transfer evaluation needed
        # (the report sweep will run the transfers exactly once)
        return {cfg.entry: analysis.initial()}
    rpo = _reverse_postorder(cfg)
    if rpo is not None:
        # acyclic: one pass in topological order IS the fixpoint — every
        # predecessor's out-state is final before its successors join it
        reachable = set(rpo)
        entry_states = {cfg.entry: analysis.initial()}
        outs: Dict[int, State] = {}
        for bid in rpo:
            if bid == cfg.entry:
                state = entry_states[cfg.entry]
            else:
                state = None
                for p in cfg.block(bid).preds:
                    if p not in reachable:
                        continue  # dead pred: the worklist never ran it
                    state = (
                        analysis.copy(outs[p]) if state is None
                        else analysis.join(state, outs[p])
                    )
                entry_states[bid] = state
            outs[bid] = transfer_block(
                analysis, analysis.copy(state), cfg.block(bid), report=False
            )
        return entry_states
    entry_states = {cfg.entry: analysis.initial()}
    visits: Dict[int, int] = {}
    work = deque([cfg.entry])
    queued = {cfg.entry}
    while work:
        bid = work.popleft()
        queued.discard(bid)
        n = visits.get(bid, 0) + 1
        visits[bid] = n
        if n > VISIT_LIMIT:
            continue
        block = cfg.block(bid)
        out = transfer_block(
            analysis, analysis.copy(entry_states[bid]), block, report=False
        )
        for succ in block.succs:
            old = entry_states.get(succ)
            if old is None:
                merged = analysis.copy(out)
            else:
                merged = analysis.join(old, out)
                if visits.get(succ, 0) >= WIDEN_AFTER:
                    merged = analysis.widen(old, merged)
            if old is None or merged != old:
                entry_states[succ] = merged
                if succ not in queued:
                    work.append(succ)
                    queued.add(succ)
    return entry_states


def report_sweep(
    cfg: CFG,
    analysis: ForwardAnalysis,
    entry_states: Dict[int, State],
    on_block: Optional[Callable[[Block, State], None]] = None,
) -> None:
    """One emission pass: every block visited exactly once with its
    fixpoint entry state (initial state when unreachable), transfer run
    with ``report=True`` so checks fire exactly once per site."""
    for block in cfg.blocks:
        state = entry_states.get(block.id)
        state = analysis.initial() if state is None else analysis.copy(state)
        if on_block is not None:
            on_block(block, state)
        transfer_block(analysis, state, block, report=True)
