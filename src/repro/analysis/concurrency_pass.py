"""Concurrency pass: thread/queue discipline in threaded modules.

Applies to any analyzed module that imports ``threading`` or ``queue``
(today: ``repro/ckpt/checkpoint.py``).  Three checks, each the static
form of a bug this repo has already shipped or reviewed:

* ``conc/queue-empty-poll`` — ``Queue.empty()`` is a snapshot, not a
  synchronization primitive: the PR-7 checkpointer race polled
  ``empty()`` and returned while the worker was still serializing the
  dequeued item.  Completion must go through ``join()``/``task_done()``
  or an explicit sentinel/event.

* ``conc/unlocked-shared-write`` — an attribute written both by a
  worker-thread function (a ``threading.Thread(target=...)``) and by
  other methods of the same class, with neither write under a
  ``with <lock>:`` block, is a data race.  ``__init__`` writes are
  exempt (setup happens before the thread starts).

* ``conc/thread-no-join`` — a module that starts a thread but never
  joins anything leaks the worker: there is no shutdown path, so
  errors surface never and interpreters hang or lose writes at exit.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.base import Finding, Module, SignatureRegistry

RULES = {
    "conc/queue-empty-poll": "Queue.empty() used as a completion signal "
    "(use join()/task_done() or a sentinel)",
    "conc/unlocked-shared-write": "attribute written by both worker thread "
    "and other methods without a lock",
    "conc/thread-no-join": "thread started but never joined "
    "(no shutdown/sentinel path)",
}


def _imports_threading(mod: Module) -> bool:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            if any(a.name in ("threading", "queue") for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module in ("threading", "queue"):
                return True
    return False


def _attr_chain(node: ast.expr) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_queue_ctor(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = _attr_chain(node.func)
    return chain is not None and chain.split(".")[-1] in (
        "Queue",
        "LifoQueue",
        "PriorityQueue",
        "SimpleQueue",
    )


def _is_lock_ctor(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = _attr_chain(node.func)
    return chain is not None and chain.split(".")[-1] in (
        "Lock",
        "RLock",
        "Condition",
        "Semaphore",
        "BoundedSemaphore",
    )


class _ModuleFacts(ast.NodeVisitor):
    """Collect queue-typed names, lock-typed names, thread targets and
    whether any ``.join(`` appears."""

    def __init__(self) -> None:
        self.queue_names: Set[str] = set()  # "q", "self._q" chains
        self.lock_names: Set[str] = set()
        self.thread_targets: Set[str] = set()  # function names passed as target=
        self.thread_ctors: List[ast.Call] = []
        self.has_join = False
        self.starts_thread = False

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            chain = _attr_chain(t)
            if chain is None:
                continue
            if _is_queue_ctor(node.value):
                self.queue_names.add(chain)
            if _is_lock_ctor(node.value):
                self.lock_names.add(chain)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        chain = _attr_chain(node.target)
        if chain is not None and node.value is not None:
            if _is_queue_ctor(node.value):
                self.queue_names.add(chain)
            if _is_lock_ctor(node.value):
                self.lock_names.add(chain)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if chain is not None:
            last = chain.split(".")[-1]
            if last == "join":
                self.has_join = True
            if last == "start":
                self.starts_thread = self.starts_thread or True
            if last == "Thread":
                self.thread_ctors.append(node)
                for kw in node.keywords:
                    if kw.arg == "target":
                        target_chain = _attr_chain(kw.value)
                        if target_chain is not None:
                            self.thread_targets.add(target_chain.split(".")[-1])
        self.generic_visit(node)


class _AttrWrites(ast.NodeVisitor):
    """self.<attr> writes inside one function, split by lock protection."""

    def __init__(self, lock_names: Set[str]) -> None:
        self.lock_names = lock_names
        self.writes: Dict[str, List[ast.AST]] = {}
        self._lock_depth = 0

    def visit_With(self, node: ast.With) -> None:
        locked = any(
            _attr_chain(item.context_expr) in self.lock_names
            or (
                isinstance(item.context_expr, ast.Call)
                and _attr_chain(item.context_expr.func) in self.lock_names
            )
            for item in node.items
        )
        if locked:
            self._lock_depth += 1
        self.generic_visit(node)
        if locked:
            self._lock_depth -= 1

    def _record(self, target: ast.expr) -> None:
        if self._lock_depth > 0:
            return
        if (
            isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"
        ):
            self.writes.setdefault(target.attr, []).append(target)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record(node.target)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._record(node.target)
        self.generic_visit(node)


def _check_class(
    cls: ast.ClassDef, facts: _ModuleFacts, mod: Module, findings: List[Finding]
) -> None:
    methods = {
        n.name: n
        for n in cls.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    workers = [m for name, m in methods.items() if name in facts.thread_targets]
    if not workers:
        return
    worker_names = {m.name for m in workers}
    worker_writes: Dict[str, List[ast.AST]] = {}
    other_writes: Set[str] = set()
    for name, m in methods.items():
        aw = _AttrWrites(facts.lock_names)
        aw.visit(m)
        if name in worker_names:
            for attr, sites in aw.writes.items():
                worker_writes.setdefault(attr, []).extend(sites)
        elif name != "__init__":  # setup precedes thread start
            other_writes.update(aw.writes)
    for attr, sites in sorted(worker_writes.items()):
        if attr in other_writes:
            for site in sites:
                findings.append(
                    Finding(
                        "conc/unlocked-shared-write",
                        mod.path,
                        site.lineno,
                        site.col_offset,
                        f"self.{attr} written by worker thread and other "
                        "methods without lock/queue mediation",
                    )
                )


class _EmptyPoll(ast.NodeVisitor):
    def __init__(self, mod: Module, queue_names: Set[str], findings: List[Finding]):
        self.mod = mod
        self.queue_names = queue_names
        self.findings = findings

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute) and node.func.attr == "empty":
            chain = _attr_chain(node.func.value)
            tail = chain.split(".")[-1] if chain else ""
            if (
                chain in self.queue_names
                or tail in ("q", "_q")
                or tail.endswith("queue")
            ):
                self.findings.append(
                    Finding(
                        "conc/queue-empty-poll",
                        self.mod.path,
                        node.lineno,
                        node.col_offset,
                        f"{chain or '<queue>'}.empty() is a racy snapshot; "
                        "use join()/task_done() or a sentinel",
                    )
                )
        self.generic_visit(node)


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        if mod.is_tests or not _imports_threading(mod):
            continue
        facts = _ModuleFacts()
        facts.visit(mod.tree)
        _EmptyPoll(mod, facts.queue_names, findings).visit(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                _check_class(node, facts, mod, findings)
        if facts.thread_ctors and facts.starts_thread and not facts.has_join:
            ctor = facts.thread_ctors[0]
            findings.append(
                Finding(
                    "conc/thread-no-join",
                    mod.path,
                    ctor.lineno,
                    ctor.col_offset,
                    "thread started but module has no join()/shutdown path",
                )
            )
    return findings
