"""Determinism pass: ``repro.core`` traces are pure functions of a seed.

Two families of checks, both scoped to ``src/repro/core/``:

* **Nondeterministic sources** — wall-clock reads (``time.time``,
  ``time.perf_counter``, ``datetime.now``, ...) and unseeded RNG
  (``random.Random()`` with no seed, module-level ``random.*``
  functions that hit the shared global RNG, ``np.random.*`` legacy
  API).  ``random.Random(seed)`` is the sanctioned idiom.

* **Hash-order iteration** — iterating a ``set``/``frozenset`` in a
  planner makes its output depend on ``PYTHONHASHSEED`` for string
  elements.  Any direct iteration (``for``, comprehensions) over a
  set-valued expression must go through ``sorted(...)``; ``list()`` /
  ``tuple()`` / ``iter()`` / ``reversed()`` merely materialize the
  hash order and do not sanction it.  Membership tests, ``len``,
  ``min``/``max``/``sum``/``any``/``all`` are order-insensitive and
  exempt.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.base import Finding, Module, SignatureRegistry

RULES = {
    "det/wall-clock": "wall-clock read inside repro.core "
    "(inject a clock instead)",
    "det/unseeded-rng": "unseeded or global RNG inside repro.core "
    "(use random.Random(seed))",
    "det/set-iteration": "iteration over a set in hash order inside "
    "repro.core (wrap in sorted(...))",
}

_WALL_CLOCK_TIME_ATTRS = {
    "time",
    "time_ns",
    "perf_counter",
    "perf_counter_ns",
    "monotonic",
    "monotonic_ns",
    "process_time",
    "process_time_ns",
}
_WALL_CLOCK_DATETIME_ATTRS = {"now", "utcnow", "today"}
#: module-level random.* functions that mutate/read the global RNG
_GLOBAL_RNG_FUNCS = {
    "random",
    "randint",
    "randrange",
    "uniform",
    "choice",
    "choices",
    "shuffle",
    "sample",
    "gauss",
    "normalvariate",
    "expovariate",
    "betavariate",
    "seed",
    "getrandbits",
    "triangular",
}
#: functions whose consumption of an iterable is order-insensitive
_ORDER_FREE_SINKS = {"sorted", "len", "min", "max", "sum", "any", "all", "set", "frozenset"}
#: wrappers that preserve (do not sanction) the underlying hash order
_ORDER_PRESERVING = {"list", "tuple", "iter", "reversed"}


def _dotted(node: ast.expr) -> Optional[str]:
    """'a.b.c' for a pure attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _SetTracker(ast.NodeVisitor):
    """Per-function inference of which local names hold sets."""

    def __init__(self) -> None:
        self.set_names: Set[str] = set()

    def visit_Assign(self, node: ast.Assign) -> None:
        if _is_set_expr(node.value, self.set_names):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.set_names.add(t.id)
        else:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.set_names.discard(t.id)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        ann = ast.unparse(node.annotation) if node.annotation is not None else ""
        if isinstance(node.target, ast.Name):
            if ann.split("[")[0] in ("set", "Set", "frozenset", "FrozenSet", "typing.Set"):
                self.set_names.add(node.target.id)
            elif node.value is not None and _is_set_expr(node.value, self.set_names):
                self.set_names.add(node.target.id)
        self.generic_visit(node)


def _is_set_expr(node: ast.expr, set_names: Set[str]) -> bool:
    """Is this expression set-valued (hash-ordered when iterated)?"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Name):
        return node.id in set_names
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name):
            if node.func.id in ("set", "frozenset"):
                return True
            if node.func.id in _ORDER_PRESERVING and node.args:
                return _is_set_expr(node.args[0], set_names)
        if isinstance(node.func, ast.Attribute):
            # s.union(...), s.copy(), ... on a set-typed receiver
            if node.func.attr in (
                "union", "intersection", "difference", "symmetric_difference", "copy"
            ):
                return _is_set_expr(node.func.value, set_names)
        return False
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
    ):
        return _is_set_expr(node.left, set_names) or _is_set_expr(node.right, set_names)
    return False


class _Checker(ast.NodeVisitor):
    def __init__(self, mod: Module):
        self.mod = mod
        self.findings: List[Finding] = []
        self._from_imports: Dict[str, str] = {}  # local name -> "module.orig"
        self._set_scopes: List[Set[str]] = [set()]

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(rule, self.mod.path, node.lineno, node.col_offset, message)
        )

    # --- imports ----------------------------------------------------------

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        for alias in node.names:
            local = alias.asname or alias.name
            self._from_imports[local] = f"{node.module}.{alias.name}"
            if node.module == "time" and alias.name in _WALL_CLOCK_TIME_ATTRS:
                self.emit(
                    "det/wall-clock", node,
                    f"imports wall clock time.{alias.name} into repro.core",
                )
            if node.module == "random" and alias.name in _GLOBAL_RNG_FUNCS:
                self.emit(
                    "det/unseeded-rng", node,
                    f"imports global-RNG random.{alias.name} into repro.core",
                )
        self.generic_visit(node)

    # --- calls ------------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted is not None:
            self._check_call(node, dotted)
        self.generic_visit(node)

    def _check_call(self, node: ast.Call, dotted: str) -> None:
        parts = dotted.split(".")
        # wall clocks
        if len(parts) == 2 and parts[0] == "time" and parts[1] in _WALL_CLOCK_TIME_ATTRS:
            self.emit("det/wall-clock", node, f"wall-clock read {dotted}()")
        if parts[-1] in _WALL_CLOCK_DATETIME_ATTRS and "datetime" in parts[:-1]:
            self.emit("det/wall-clock", node, f"wall-clock read {dotted}()")
        if len(parts) == 1 and parts[0] in self._from_imports:
            orig = self._from_imports[parts[0]]
            mod, _, name = orig.rpartition(".")
            if mod == "time" and name in _WALL_CLOCK_TIME_ATTRS:
                self.emit("det/wall-clock", node, f"wall-clock read {orig}()")
            if mod == "random" and name in _GLOBAL_RNG_FUNCS:
                self.emit("det/unseeded-rng", node, f"global RNG {orig}()")
        # RNG
        if dotted == "random.Random" and not node.args and not node.keywords:
            self.emit(
                "det/unseeded-rng", node,
                "random.Random() without a seed",
            )
        if len(parts) == 2 and parts[0] == "random" and parts[1] in _GLOBAL_RNG_FUNCS:
            self.emit("det/unseeded-rng", node, f"global RNG {dotted}()")
        if len(parts) >= 3 and parts[0] in ("np", "numpy") and parts[1] == "random":
            if parts[2] == "default_rng":
                if not node.args and not node.keywords:
                    self.emit(
                        "det/unseeded-rng", node,
                        "np.random.default_rng() without a seed",
                    )
            else:
                self.emit(
                    "det/unseeded-rng", node,
                    f"legacy global numpy RNG {dotted}()",
                )

    # --- set iteration ----------------------------------------------------

    def _enter_function(self, node) -> None:
        tracker = _SetTracker()
        tracker.visit(node)
        self._set_scopes.append(tracker.set_names)
        self.generic_visit(node)
        self._set_scopes.pop()

    visit_FunctionDef = _enter_function
    visit_AsyncFunctionDef = _enter_function

    def _set_names(self) -> Set[str]:
        return self._set_scopes[-1]

    def _check_iter(self, node: ast.expr) -> None:
        if _is_set_expr(node, self._set_names()):
            self.emit(
                "det/set-iteration", node,
                "iterates a set in hash order; wrap in sorted(...)",
            )

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        if not mod.is_core:
            continue
        checker = _Checker(mod)
        checker.visit(mod.tree)
        findings.extend(checker.findings)
    return findings
