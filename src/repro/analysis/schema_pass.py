"""Schema pass: stats keys are registered before they are emitted.

PR-9 added :mod:`repro.obs.schema` — every key an engine writes into
``SimResult.stats`` / ``HorizonResult.stats`` / ``FleetResult.stats``
must be registered with a unit — and enforces it with a *runtime* audit
(``unregistered_keys`` over a live stats dict, asserted empty by the
test suite).  That audit only sees keys on code paths the tests
exercise; the ``ttft_p99`` bare-unit key shipped exactly that way.

This pass moves the first line of defense to lint time: every *string
literal* used as a key in a stats-dict write is checked against the
union of path segments registered in :data:`repro.obs.schema.REGISTRY`.
Checked write forms:

* ``stats["key"] = ...`` / ``stats["a"]["b"] += ...`` (every literal
  segment in the subscript chain),
* ``stats = {"key": ...}`` / ``self.stats = {...}`` / ``stats["k"] =
  {...}`` — dict-literal keys, recursively (nested dicts and the value
  dicts of dict comprehensions),
* ``stats.update(key=..., ...)`` / ``stats.update({"key": ...})`` /
  ``stats.setdefault("key", ...)``,
* ``SomeResult(..., stats={...})`` keyword payloads.

The check is *segment*-based, not path-based: a static pass cannot
reconstruct the dotted path through loops and helper calls, so a
literal key is accepted if it appears as any non-wildcard segment of
any registered path in any domain.  That is deliberately one-sided —
it can miss a registered name used at the wrong nesting level (the
runtime audit still catches those) but it can never false-positive on
a correctly registered name.  Variable keys (``stats[name]``) are map
keys matched by ``*`` registrations and are skipped.

Scope: ``repro/core/`` and ``repro/obs/`` (the engines and exporters),
excluding tests.  Only receivers literally named ``stats`` (bare or
attribute) are checked — scratch dicts like ``svc_state`` or
``_tier_stats`` are internal accounting, not the public surface.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from repro.analysis.base import Finding, Module, SignatureRegistry

RULES = {
    "schema/unregistered-stats-key": "string-literal stats key not registered "
    "in repro.obs.schema.REGISTRY (register it with a unit first)",
}


def registered_segments() -> Optional[Set[str]]:
    """Union of non-wildcard path segments across every domain registry,
    or None when the schema module is unavailable (standalone lint of a
    single file outside the repo)."""
    try:
        from repro.obs.schema import REGISTRY
    except Exception:
        return None
    segs: Set[str] = set()
    for reg in REGISTRY.values():
        for path in reg:
            segs.update(s for s in path.split(".") if s != "*")
    return segs


def _is_stats_chain(node: ast.expr) -> bool:
    """``stats`` / ``self.stats`` / ``result.stats``, possibly under
    further subscripts (``stats["a"]["b"]``)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr == "stats"
    return isinstance(node, ast.Name) and node.id == "stats"


def _literal_key(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


class _Checker(ast.NodeVisitor):
    def __init__(self, mod: Module, segments: Set[str]):
        self.mod = mod
        self.segments = segments
        self.findings: List[Finding] = []

    def _check_key(self, node: ast.expr) -> None:
        key = _literal_key(node)
        if key is not None and key not in self.segments:
            self.findings.append(
                Finding(
                    "schema/unregistered-stats-key",
                    self.mod.path,
                    node.lineno,
                    node.col_offset,
                    f"stats key {key!r} is not registered in "
                    "repro.obs.schema.REGISTRY",
                )
            )

    def _check_subscript_chain(self, node: ast.expr) -> None:
        while isinstance(node, ast.Subscript):
            self._check_key(node.slice)
            node = node.value

    def _check_dict_value(self, node: ast.expr) -> None:
        """Literal keys of a dict expression flowing into stats,
        recursively through nested dict literals and comprehensions."""
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values):
                if k is not None:
                    self._check_key(k)
                self._check_dict_value(v)
        elif isinstance(node, ast.DictComp):
            # {name: {...} for name in jobs}: the outer keys are map
            # data (wildcard-registered); the value shape is schema
            self._check_dict_value(node.value)
        elif isinstance(node, ast.IfExp):
            self._check_dict_value(node.body)
            self._check_dict_value(node.orelse)

    def _is_stats_name(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Attribute):
            return node.attr == "stats"
        return isinstance(node, ast.Name) and node.id == "stats"

    # --- write forms ------------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript) and _is_stats_chain(tgt):
                self._check_subscript_chain(tgt)
                self._check_dict_value(node.value)
            elif self._is_stats_name(tgt):
                self._check_dict_value(node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            if isinstance(node.target, ast.Subscript) and _is_stats_chain(node.target):
                self._check_subscript_chain(node.target)
                self._check_dict_value(node.value)
            elif self._is_stats_name(node.target):
                self._check_dict_value(node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.target, ast.Subscript) and _is_stats_chain(node.target):
            self._check_subscript_chain(node.target)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and _is_stats_chain(f.value):
            if f.attr == "update":
                for kw in node.keywords:
                    if kw.arg is not None:
                        # kwarg names are the keys; reuse the finding
                        # location of the value expression
                        if kw.arg not in self.segments:
                            self._check_key(
                                ast.copy_location(ast.Constant(kw.arg), kw.value)
                            )
                    else:
                        self._check_dict_value(kw.value)
                for a in node.args:
                    self._check_dict_value(a)
            elif f.attr == "setdefault" and node.args:
                self._check_key(node.args[0])
                if len(node.args) > 1:
                    self._check_dict_value(node.args[1])
        # result constructors: SimResult(..., stats={...})
        for kw in node.keywords:
            if kw.arg == "stats":
                self._check_dict_value(kw.value)
        self.generic_visit(node)


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    segments = registered_segments()
    if segments is None:
        return []
    findings: List[Finding] = []
    for mod in modules:
        if mod.is_tests or mod.is_analysis_module:
            continue
        norm = mod.path.replace("\\", "/")
        if "repro/core/" not in norm and "repro/obs/" not in norm:
            continue
        checker = _Checker(mod, segments)
        checker.visit(mod.tree)
        findings.extend(checker.findings)
    return findings
