"""Per-function control-flow graphs for the dataflow passes.

PR-8's per-statement walkers processed ``if``/``else`` bodies
*sequentially* against one mutable environment: the last branch's
bindings leaked into the fall-through state, loop bodies were seen
exactly once (no back edge), and facts established before a branch were
silently overwritten by facts that only hold inside it.  The dataflow
passes need the real shape: a graph of basic blocks whose edges carry
abstract states, joined at merge points and iterated to a fixpoint
around loops (:mod:`repro.analysis.dataflow`).

The CFG is statement-granular and deliberately small:

* A :class:`Block` holds a list of :class:`Element`\\ s — simple
  statements plus synthetic elements for the *evaluated parts* of
  compound statements (an ``if``/``while`` test, a ``for`` iterable and
  its target binding, a ``with`` context expression).
* ``if``/``else`` fork and re-join; ``while``/``for`` get a loop-header
  block with a back edge from the body end (and from ``continue``);
  ``break`` jumps to the loop exit; ``return``/``raise`` edge to the
  single exit block.
* ``try`` is approximated conservatively: every block of the protected
  body (and the state *before* the try) edges to every handler entry —
  an exception may fire before any given statement completes, so the
  handler must join all of them.  ``finally`` runs after the body,
  ``orelse`` and every handler.
* ``match`` forks per case and re-joins (plus a no-case-matched edge).

Unreachable code (statements after a terminator) still gets blocks so
the report sweep can check it; those blocks simply have no predecessors
and start from the initial state.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional, Sequence, Tuple

#: element kinds — what the transfer function is asked to interpret
STMT = "stmt"  # a simple statement, interpreted whole
TEST = "test"  # the test expression of an if/while (evaluate only)
FOR = "for"  # a for-statement header: evaluate iter, bind target
WITH = "with"  # a with-statement header: evaluate items, bind as-names


@dataclasses.dataclass
class Element:
    kind: str
    node: ast.AST


@dataclasses.dataclass
class Block:
    id: int
    elements: List[Element] = dataclasses.field(default_factory=list)
    succs: List[int] = dataclasses.field(default_factory=list)
    preds: List[int] = dataclasses.field(default_factory=list)
    is_loop_header: bool = False


@dataclasses.dataclass
class CFG:
    blocks: List[Block]
    entry: int
    exit: int

    def block(self, bid: int) -> Block:
        return self.blocks[bid]


class _Builder:
    def __init__(self) -> None:
        self.blocks: List[Block] = []
        #: (header_id, after_id) per enclosing loop, innermost last
        self.loop_stack: List[Tuple[int, int]] = []

    def new_block(self, *, loop_header: bool = False) -> int:
        b = Block(id=len(self.blocks), is_loop_header=loop_header)
        self.blocks.append(b)
        return b.id

    def edge(self, a: int, b: int) -> None:
        if b not in self.blocks[a].succs:
            self.blocks[a].succs.append(b)
            self.blocks[b].preds.append(a)

    def build(self, body: Sequence[ast.stmt]) -> CFG:
        entry = self.new_block()
        exit_id = self.new_block()
        self.exit = exit_id
        end = self.body(body, entry)
        if end is not None:
            self.edge(end, exit_id)
        return CFG(self.blocks, entry, exit_id)

    # ------------------------------------------------------------------

    def body(self, stmts: Sequence[ast.stmt], cur: Optional[int]) -> Optional[int]:
        """Append ``stmts`` starting at block ``cur``; return the open
        block at the end, or None if every path terminated."""
        for stmt in stmts:
            if cur is None:
                # unreachable code: give it a block anyway so the report
                # sweep still checks it
                cur = self.new_block()
            cur = self.stmt(stmt, cur)
        return cur

    def stmt(self, stmt: ast.stmt, cur: int) -> Optional[int]:
        blocks = self.blocks
        if isinstance(stmt, (ast.Return, ast.Raise)):
            blocks[cur].elements.append(Element(STMT, stmt))
            self.edge(cur, self.exit)
            return None
        if isinstance(stmt, ast.Break):
            if self.loop_stack:
                self.edge(cur, self.loop_stack[-1][1])
            return None
        if isinstance(stmt, ast.Continue):
            if self.loop_stack:
                self.edge(cur, self.loop_stack[-1][0])
            return None
        if isinstance(stmt, ast.If):
            blocks[cur].elements.append(Element(TEST, stmt.test))
            after = self.new_block()
            then_entry = self.new_block()
            self.edge(cur, then_entry)
            then_end = self.body(stmt.body, then_entry)
            if then_end is not None:
                self.edge(then_end, after)
            if stmt.orelse:
                else_entry = self.new_block()
                self.edge(cur, else_entry)
                else_end = self.body(stmt.orelse, else_entry)
                if else_end is not None:
                    self.edge(else_end, after)
            else:
                self.edge(cur, after)
            return after if blocks[after].preds else None
        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            header = self.new_block(loop_header=True)
            self.edge(cur, header)
            if isinstance(stmt, ast.While):
                blocks[header].elements.append(Element(TEST, stmt.test))
            else:
                blocks[header].elements.append(Element(FOR, stmt))
            after = self.new_block()
            body_entry = self.new_block()
            self.edge(header, body_entry)
            self.edge(header, after)
            self.loop_stack.append((header, after))
            body_end = self.body(stmt.body, body_entry)
            self.loop_stack.pop()
            if body_end is not None:
                self.edge(body_end, header)
            if stmt.orelse:
                return self.body(stmt.orelse, after)
            return after
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            blocks[cur].elements.append(Element(WITH, stmt))
            return self.body(stmt.body, cur)
        if isinstance(stmt, ast.Try):
            body_entry = self.new_block()
            self.edge(cur, body_entry)
            mark = len(blocks)
            body_end = self.body(stmt.body, body_entry)
            body_blocks = [body_entry] + [b.id for b in blocks[mark:]]
            handler_ends: List[int] = []
            handler_entries: List[int] = []
            for h in stmt.handlers:
                h_entry = self.new_block()
                handler_entries.append(h_entry)
                if h.name is not None:
                    # bind the exception name: synthesize a no-value stmt
                    blocks[h_entry].elements.append(Element(STMT, h))
                h_end = self.body(h.body, h_entry)
                if h_end is not None:
                    handler_ends.append(h_end)
            # an exception can fire before any statement of the body
            # completes: handlers join the pre-try state and every
            # body-block out-state
            for h_entry in handler_entries:
                self.edge(cur, h_entry)
                for bb in body_blocks:
                    self.edge(bb, h_entry)
            if stmt.orelse and body_end is not None:
                body_end = self.body(stmt.orelse, body_end)
            norm_ends = [e for e in [body_end] + handler_ends if e is not None]
            if stmt.finalbody:
                final_entry = self.new_block()
                for e in norm_ends:
                    self.edge(e, final_entry)
                if not norm_ends:
                    # every path raised/returned; finally still runs
                    self.edge(cur, final_entry)
                return self.body(stmt.finalbody, final_entry)
            if not norm_ends:
                return None
            after = self.new_block()
            for e in norm_ends:
                self.edge(e, after)
            return after
        if isinstance(stmt, ast.Match):
            blocks[cur].elements.append(Element(TEST, stmt.subject))
            after = self.new_block()
            self.edge(cur, after)  # no case matched
            for case in stmt.cases:
                c_entry = self.new_block()
                self.edge(cur, c_entry)
                c_end = self.body(case.body, c_entry)
                if c_end is not None:
                    self.edge(c_end, after)
            return after
        # simple statement (incl. nested FunctionDef/ClassDef, which the
        # passes recurse into independently)
        blocks[cur].elements.append(Element(STMT, stmt))
        return cur


def build_cfg(body: Sequence[ast.stmt]) -> CFG:
    """CFG over a statement list (a function body or a module body)."""
    return _Builder().build(body)
