"""Units pass: flow-sensitive dimensional analysis over identifier suffixes.

Every quantity in the repo carries its unit in its name (``_ms``,
``_bytes``, ``_gbps``, ...).  This pass turns that convention into a
checkable type system: each known suffix maps to a *dimension vector*
over (time, data, samples) plus a *scale* relative to the canonical
units — milliseconds, bits, samples.  A value ``x`` in unit ``u``
represents ``x * scale(u)`` canonical units, so

* multiplication adds dimensions and multiplies scales,
* division subtracts dimensions and divides scales,
* multiplying by a conversion constant ``c`` (8, 1e3, 1e6, 1e9, ...)
  divides the scale by ``c`` (the value grew by ``c``; the quantity
  didn't),
* addition/subtraction/comparison requires equal dimensions *and*
  equal scales.

Under this algebra the sanctioned conversions come out exactly right —
``nbytes * 8.0 / (bw_gbps * 1e9) * 1e3`` has dimension *time* at scale
1 (milliseconds) — and the classic WAN-model bugs come out wrong:
``x_bits = y_bytes`` is a data/data scale mismatch of 8 (missing ×8),
``cap_bits = seg_ms * bw_gbps`` is off by 1e6 (Gbit/s is 1e6 bits per
ms).  Unknown names poison an expression to *unknown* and suppress all
checks — the pass only speaks when every operand is known.

Since ISSUE 10 the pass runs on the per-function CFG
(:mod:`repro.analysis.cfg`) under the forward dataflow solver
(:mod:`repro.analysis.dataflow`) instead of a single top-down sweep, so
unit facts are *flow-sensitive*:

* **if/else joins** — a variable assigned different units on different
  branches carries the *set* of alternatives (:class:`UnitAlt`) past
  the join; a later use that conflicts with any alternative is a bug on
  that path (PR-8's sweep kept only the last branch's binding).
* **loops** — bodies are iterated to a fixpoint, so a unit carried
  around the back edge (reassigned at the bottom of the loop, used at
  the top) is visible on the second abstract iteration.
* **tuple unpacking** — ``a_ms, b = f()`` binds ``a_ms`` to its
  declared unit (PR-8 bound it to *unknown*, shadowing the suffix).
* **augmented assignment** — ``x *= 8.0`` folds the conversion constant
  into the scale like ``x = x * 8.0`` always did (PR-8 treated the
  multiplier as dimensionless and kept the stale scale).

Checks:

``units/mixed-units``     cross-dimension ``+``/``-``/``%``/comparison
                          (also min/max arguments).
``units/scale-mismatch``  same dimension, wrong factor — in arithmetic,
                          assignments to suffixed names, returns from
                          suffixed functions, and call-argument binding
                          against suffixed parameters.
``units/inline-conversion``  conversion constants (8, 1e6, 1e9) applied
                          to dimensioned operands inside ``repro.core``
                          anywhere but ``repro/units.py`` — conversions
                          must go through the sanctioned helpers.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import dataflow
from repro.analysis.base import Finding, Module, SignatureRegistry
from repro.analysis.cfg import FOR, STMT, TEST, WITH, Element, build_cfg

RULES = {
    "units/mixed-units": "addition/comparison across different dimensions",
    "units/scale-mismatch": "same dimension combined at different scales "
    "(ms vs s, bytes vs bits, Gbps without the 1e6)",
    "units/inline-conversion": "conversion arithmetic outside repro/units.py "
    "(use the sanctioned helpers)",
}

# dimension vector: (time, data, samples)
Dim = Tuple[int, int, int]
_T: Dim = (1, 0, 0)
_D: Dim = (0, 1, 0)
_S: Dim = (0, 0, 1)
_RATE: Dim = (-1, 1, 0)  # data per time
_NONE: Dim = (0, 0, 0)


@dataclasses.dataclass(frozen=True)
class Unit:
    dims: Dim
    scale: float  # canonical units (ms / bits / samples) per 1 of this unit


DIMLESS = Unit(_NONE, 1.0)


@dataclasses.dataclass(frozen=True)
class UnitAlt:
    """Path-dependent value: one of ``members`` depending on which CFG
    path reached this point.  Produced by joins, consumed by checks
    (any conflicting member is a bug on that member's path)."""

    members: frozenset  # of Unit

    def __post_init__(self):
        assert len(self.members) > 1


class _Neutral:
    """A zero literal (or empty accumulator): unifies with any unit."""


NEUTRAL = _Neutral()
UNKNOWN = None

#: alternatives tracked per variable before a join degrades to UNKNOWN
ALT_CAP = 4

#: suffix token -> unit.  Canonical: time=ms, data=bit, samples=sample.
SUFFIX_UNITS: Dict[str, Unit] = {
    "ms": Unit(_T, 1.0),
    "s": Unit(_T, 1e3),
    "us": Unit(_T, 1e-3),
    "hours": Unit(_T, 3.6e6),
    "bytes": Unit(_D, 8.0),
    "nbytes": Unit(_D, 8.0),
    "bits": Unit(_D, 1.0),
    "gb": Unit(_D, 8e9),
    "gbps": Unit(_RATE, 1e6),  # 1 Gbit/s = 1e6 bits/ms
    "samples": Unit(_S, 1.0),
    "frac": DIMLESS,
    "mult": DIMLESS,
}
#: compound suffixes, matched before the last-token rule.  GB/s-rated
#: local links (NVLink/PCIe) move 8e6 bits per ms per unit.
COMPOUND_SUFFIX_UNITS: Dict[str, Unit] = {
    "gbps_bytes": Unit(_RATE, 8e6),
}

#: constants whose appearance in a product is a unit conversion, not a
#: count (scale bookkeeping folds them; anything else is a pure number).
CONVERSION_CONSTANTS = (8.0, 1e3, 1e6, 1e9, 1e12, 3.6e6)
#: the subset whose use next to a dimensioned operand means "inline
#: unit conversion" for the units/inline-conversion rule.
INLINE_CONVERSION_CONSTANTS = (8.0, 1e6, 1e9)

_UNIT_NAMES = {
    (_T, 1.0): "ms",
    (_T, 1e3): "s",
    (_T, 1e-3): "us",
    (_T, 3.6e6): "hours",
    (_D, 1.0): "bits",
    (_D, 8.0): "bytes",
    (_D, 8e9): "GB",
    (_RATE, 1e6): "Gbit/s",
    (_RATE, 8e6): "GB/s",
    (_S, 1.0): "samples",
    (_NONE, 1.0): "dimensionless",
}


def describe(u: object) -> str:
    if isinstance(u, UnitAlt):
        return "|".join(sorted(describe(m) for m in u.members))
    assert isinstance(u, Unit)
    for (dims, scale), name in _UNIT_NAMES.items():
        if u.dims == dims and math.isclose(u.scale, scale, rel_tol=1e-9):
            return name
    return f"dims(time,data,samples)={u.dims} scale={u.scale:g}"


@functools.lru_cache(maxsize=4096)
def unit_of_name(name: str) -> Optional[Unit]:
    """Unit implied by an identifier, or UNKNOWN."""
    low = name.lower()
    if "_per_" in low:
        return UNKNOWN  # rates-by-convention (rate_per_s, kv_bytes_per_token)
    for suf, u in COMPOUND_SUFFIX_UNITS.items():
        if low == suf or low.endswith("_" + suf):
            return u
    if "_" in low:
        token = low.rsplit("_", 1)[-1]
        if token in SUFFIX_UNITS:
            return SUFFIX_UNITS[token]
    elif low in SUFFIX_UNITS and len(low) > 1:
        # whole-name matches only for unambiguous multi-char names
        # ("ms", "nbytes", ...); a bare ``s`` is a loop variable or a
        # schedule, not seconds
        return SUFFIX_UNITS[low]
    # count-like names are dimensionless multipliers
    if low.startswith(("n_", "num_")) or low.endswith("_count"):
        return DIMLESS
    if len(name) == 1 and name.isupper():
        return DIMLESS  # D, P, M, ... — loop/shape counts by convention
    return UNKNOWN


def _const_value(node: ast.AST) -> Optional[float]:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)) \
            and not isinstance(node.value, bool):
        return float(node.value)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _const_value(node.operand)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        # fold constant-only arithmetic (``1.0 / 8.0``, ``6144 * 8192 * 2``)
        lv, rv = _const_value(node.left), _const_value(node.right)
        if lv is not None and rv is not None:
            try:
                if isinstance(node.op, ast.Add):
                    return lv + rv
                if isinstance(node.op, ast.Sub):
                    return lv - rv
                if isinstance(node.op, ast.Mult):
                    return lv * rv
                if isinstance(node.op, ast.Div):
                    return lv / rv
                if isinstance(node.op, ast.FloorDiv):
                    return float(lv // rv)
                if isinstance(node.op, ast.Pow):
                    return float(lv ** rv)
            except (ZeroDivisionError, OverflowError, ValueError):
                return None
    return None


def _is_conversion_const(v: float, table=CONVERSION_CONSTANTS) -> bool:
    return any(math.isclose(abs(v), c, rel_tol=1e-12) for c in table)


def _members(v: object) -> frozenset:
    if isinstance(v, UnitAlt):
        return v.members
    assert isinstance(v, Unit)
    return frozenset((v,))


def _units_close(a: Unit, b: Unit) -> bool:
    return a.dims == b.dims and math.isclose(a.scale, b.scale, rel_tol=1e-9)


def join_units(a: object, b: object) -> object:
    """Lattice join of two abstract values at a CFG merge point."""
    if a is UNKNOWN or b is UNKNOWN:
        return UNKNOWN
    if a is NEUTRAL:
        return b
    if b is NEUTRAL:
        return a
    if a == b:
        return a
    merged: List[Unit] = []
    for m in sorted(_members(a) | _members(b), key=lambda u: (u.dims, u.scale)):
        if not any(_units_close(m, kept) for kept in merged):
            merged.append(m)
    if len(merged) == 1:
        return merged[0]
    if len(merged) > ALT_CAP:
        return UNKNOWN
    return UnitAlt(frozenset(merged))


class _UnitsAnalysis(dataflow.ForwardAnalysis):
    """Adapter: the dataflow solver drives one :class:`FileChecker`
    over one code body (module, function or class)."""

    TOP = UNKNOWN

    def __init__(self, checker: "FileChecker", init_env: Dict[str, object]):
        self.checker = checker
        self.init_env = init_env

    def initial(self) -> Dict[str, object]:
        return dict(self.init_env)

    def transfer_element(self, state, elem: Element, report: bool):
        self.checker._report = report
        self.checker._transfer(state, elem)
        return state

    def join_value(self, a, b):
        return join_units(a, b)

    def missing_value(self, name: str):
        return unit_of_name(name)


class FileChecker:
    def __init__(self, mod: Module, registry: SignatureRegistry):
        self.mod = mod
        self.registry = registry
        self.findings: List[Finding] = []
        self._report = False
        self._ret_unit: object = UNKNOWN
        self._ret_name: str = ""

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        if not self._report:
            return
        self.findings.append(
            Finding(rule, self.mod.path, node.lineno, node.col_offset, message)
        )

    def check(self) -> List[Finding]:
        self._check_code(self.mod.tree.body, {}, UNKNOWN, "")
        return self.findings

    # --- code bodies (one CFG + fixpoint each) ----------------------------

    def _check_code(
        self,
        body: Sequence[ast.stmt],
        init_env: Dict[str, object],
        ret_unit: object,
        ret_name: str,
    ) -> None:
        outer = (self._report, self._ret_unit, self._ret_name)
        self._ret_unit, self._ret_name = ret_unit, ret_name
        g = self.mod.cfg(body)  # shared with the taint pass
        analysis = _UnitsAnalysis(self, init_env)
        entry_states = dataflow.solve(g, analysis)
        dataflow.report_sweep(g, analysis, entry_states)
        self._report, self._ret_unit, self._ret_name = outer

    def _function(self, node: ast.FunctionDef) -> None:
        env: Dict[str, object] = {}
        a = node.args
        for arg in list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs):
            u = unit_of_name(arg.arg)
            if u is not UNKNOWN:
                env[arg.arg] = u
        self._check_code(node.body, env, unit_of_name(node.name), node.name)

    # --- CFG element transfer ---------------------------------------------

    def _transfer(self, env: Dict[str, object], elem: Element) -> None:
        node = elem.node
        if elem.kind == TEST:
            if self._report:  # tests bind nothing (no walrus in-tree)
                self.eval(node, env)
        elif elem.kind == FOR:
            it = self._iter_element_unit(node.iter, env)
            self.eval(node.iter, env)
            self._bind_loop_target(node.target, node.iter, it, env)
        elif elem.kind == WITH:
            for item in node.items:
                self.eval(item.context_expr, env)
                if item.optional_vars is not None:
                    self._bind_opaque(item.optional_vars, env)
        else:
            self._stmt(node, env)

    # --- statements -------------------------------------------------------

    def _stmt(self, stmt: ast.stmt, env: Dict[str, object]) -> None:
        if not self._report and not isinstance(
            stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.ExceptHandler)
        ):
            # solve phase: statements that bind no name cannot change the
            # abstract state, so their (expensive) evaluation waits for
            # the single report sweep
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if self._report:  # nested defs are independent code bodies
                self._function(stmt)
                self._report = True
        elif isinstance(stmt, ast.ClassDef):
            if self._report:
                self._check_code(stmt.body, {}, UNKNOWN, "")
                self._report = True
        elif isinstance(stmt, ast.Assign):
            rhs = self.eval(stmt.value, env)
            for tgt in stmt.targets:
                self._bind_target(tgt, stmt.value, rhs, env)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                rhs = self.eval(stmt.value, env)
                self._bind_target(stmt.target, stmt.value, rhs, env)
        elif isinstance(stmt, ast.AugAssign):
            self._aug_assign(stmt, env)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                u = self.eval(stmt.value, env)
                if self._ret_unit is not UNKNOWN:
                    self._require(
                        self._ret_unit, u, stmt, f"return from {self._ret_name}()"
                    )
        elif isinstance(stmt, ast.Assert):
            self.eval(stmt.test, env)
            if stmt.msg is not None:
                self.eval(stmt.msg, env)
        elif isinstance(stmt, ast.Expr):
            self.eval(stmt.value, env)
        elif isinstance(stmt, ast.ExceptHandler):
            if stmt.name is not None:
                env[stmt.name] = UNKNOWN
        elif isinstance(stmt, (ast.Raise, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.eval(child, env)
        # imports, pass, global, nonlocal: nothing to do

    def _aug_assign(self, stmt: ast.AugAssign, env: Dict[str, object]) -> None:
        cur = self._load_unit(stmt.target, env)
        rhs = self.eval(stmt.value, env)
        if isinstance(stmt.op, (ast.Add, ast.Sub)):
            # literal adjustments (x_ms += 5.0) make no unit claim
            if _const_value(stmt.value) is not None:
                rhs = NEUTRAL
            res = self._unify(cur, rhs, stmt, "augmented assignment")
        elif isinstance(stmt.op, (ast.Mult, ast.Div)):
            div = isinstance(stmt.op, ast.Div)
            c = _const_value(stmt.value)
            if c is not None and c != 0 and _is_conversion_const(c):
                # ``x *= 8.0`` is a unit conversion: the value grew by
                # c, the quantity didn't — fold c into the scale exactly
                # as the ``x = x * 8.0`` spelling always did
                res = self._scale_adjust(cur, c, div)
                if (
                    _is_conversion_const(c, INLINE_CONVERSION_CONSTANTS)
                    and self._is_data_dimmed(cur)
                    and self.mod.is_core
                    and not self.mod.is_units_module
                ):
                    self.emit(
                        "units/inline-conversion",
                        stmt.value,
                        "inline unit-conversion arithmetic; "
                        "use a repro.units helper",
                    )
            else:
                res = self._combine_mult(cur, rhs, div)
        else:
            res = UNKNOWN
        if isinstance(stmt.target, ast.Name):
            env[stmt.target.id] = res

    @staticmethod
    def _is_data_dimmed(v: object) -> bool:
        if v is UNKNOWN or v is NEUTRAL:
            return False
        return any(m.dims[1] != 0 or m.dims == _RATE for m in _members(v))

    @staticmethod
    def _scale_adjust(cur: object, c: float, div: bool) -> object:
        if cur is UNKNOWN or cur is NEUTRAL:
            return cur

        def adj(u: Unit) -> Unit:
            if u.dims == _NONE:
                return DIMLESS  # pure number: scale bookkeeping ends here
            return Unit(u.dims, u.scale * abs(c) if div else u.scale / abs(c))

        adjusted = frozenset(adj(m) for m in _members(cur))
        if len(adjusted) == 1:
            return next(iter(adjusted))
        return UnitAlt(adjusted)

    def _bind_target(
        self, tgt: ast.expr, value_node: ast.expr, rhs: object, env: Dict[str, object]
    ) -> None:
        if isinstance(tgt, ast.Name):
            declared = unit_of_name(tgt.id)
            if declared is not UNKNOWN and declared is not DIMLESS:
                self._require(declared, rhs, value_node, f"assignment to {tgt.id}")
                env[tgt.id] = declared
            else:
                env[tgt.id] = rhs
        elif isinstance(tgt, ast.Attribute):
            declared = unit_of_name(tgt.attr)
            if declared is not UNKNOWN and declared is not DIMLESS:
                self._require(declared, rhs, value_node, f"assignment to .{tgt.attr}")
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            elts = tgt.elts
            if (
                isinstance(value_node, (ast.Tuple, ast.List))
                and len(value_node.elts) == len(elts)
                and not any(isinstance(e, ast.Starred) for e in elts)
                and not any(isinstance(e, ast.Starred) for e in value_node.elts)
            ):
                for t, v in zip(elts, value_node.elts):
                    self._bind_target(t, v, self.eval(v, env), env)
            else:
                # opaque unpack (``a_ms, b = f()``): the suffix *is* the
                # declaration — bind it so later uses are checked
                for t in elts:
                    self._bind_opaque(t, env)
        elif isinstance(tgt, ast.Starred):
            self._bind_opaque(tgt.value, env)

    def _bind_opaque(self, tgt: ast.expr, env: Dict[str, object]) -> None:
        """Bind a target whose value is unknown: suffixed names keep
        their declared unit, everything else goes unknown."""
        if isinstance(tgt, ast.Name):
            declared = unit_of_name(tgt.id)
            env[tgt.id] = declared if declared is not DIMLESS else DIMLESS
        elif isinstance(tgt, ast.Starred):
            self._bind_opaque(tgt.value, env)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for t in tgt.elts:
                self._bind_opaque(t, env)

    def _bind_loop_target(
        self, tgt: ast.expr, iter_node: ast.expr, elt_unit: object, env: Dict[str, object]
    ) -> None:
        if isinstance(tgt, ast.Name):
            if elt_unit is UNKNOWN:
                self._bind_opaque(tgt, env)
            else:
                env[tgt.id] = elt_unit
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            # zip(xs_ms, ys_bytes) binds pairwise
            if (
                isinstance(iter_node, ast.Call)
                and isinstance(iter_node.func, ast.Name)
                and iter_node.func.id == "zip"
                and len(iter_node.args) == len(tgt.elts)
            ):
                for t, src in zip(tgt.elts, iter_node.args):
                    self._bind_loop_target(t, src, self._iter_element_unit(src, env), env)
            else:
                for t in tgt.elts:
                    self._bind_opaque(t, env)

    def _iter_element_unit(self, node: ast.expr, env: Dict[str, object]) -> object:
        """Unit of one element when iterating ``node``.  Containers keep
        their suffix (``times_ms`` is a sequence of ms);``range`` yields
        counts."""
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("range", "enumerate")
        ):
            return DIMLESS if node.func.id == "range" else UNKNOWN
        if isinstance(node, (ast.Name, ast.Attribute)):
            return self._load_unit(node, env)
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("sorted", "list", "tuple", "reversed", "set"):
            if node.args:
                return self._iter_element_unit(node.args[0], env)
        return UNKNOWN

    # --- expression evaluation -------------------------------------------

    def _load_unit(self, node: ast.expr, env: Dict[str, object]) -> object:
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            return unit_of_name(node.id)
        if isinstance(node, ast.Attribute):
            return unit_of_name(node.attr)
        if isinstance(node, ast.Subscript):
            return self._load_unit(node.value, env)
        return UNKNOWN

    def eval(self, node: ast.expr, env: Dict[str, object]) -> object:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool) or not isinstance(node.value, (int, float)):
                return UNKNOWN
            return NEUTRAL if node.value == 0 else DIMLESS
        if isinstance(node, ast.Name):
            return self._load_unit(node, env)
        if isinstance(node, ast.Attribute):
            self.eval(node.value, env)
            return self._load_unit(node, env)
        if isinstance(node, ast.Subscript):
            self.eval(node.value, env)
            self.eval(node.slice, env)
            return self._load_unit(node, env)
        if isinstance(node, ast.BinOp):
            return self._binop(node, env)
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand, env)
        if isinstance(node, ast.Compare):
            return self._compare(node, env)
        if isinstance(node, ast.Call):
            return self._call(node, env)
        if isinstance(node, ast.IfExp):
            self.eval(node.test, env)
            a = self.eval(node.body, env)
            b = self.eval(node.orelse, env)
            return join_units(a, b)  # a conditional expression IS a join
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.eval(v, env)
            return UNKNOWN
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            inner = dict(env)
            for gen in node.generators:
                elt = self._iter_element_unit(gen.iter, inner)
                self.eval(gen.iter, inner)
                self._bind_loop_target(gen.target, gen.iter, elt, inner)
                for cond in gen.ifs:
                    self.eval(cond, inner)
            self.eval(node.elt, inner)
            return UNKNOWN
        if isinstance(node, ast.DictComp):
            inner = dict(env)
            for gen in node.generators:
                elt = self._iter_element_unit(gen.iter, inner)
                self.eval(gen.iter, inner)
                self._bind_loop_target(gen.target, gen.iter, elt, inner)
                for cond in gen.ifs:
                    self.eval(cond, inner)
            self.eval(node.key, inner)
            self.eval(node.value, inner)
            return UNKNOWN
        if isinstance(node, ast.Lambda):
            inner = dict(env)
            for arg in node.args.args:
                u = unit_of_name(arg.arg)
                inner[arg.arg] = u
            self.eval(node.body, inner)
            return UNKNOWN
        # tuples, dicts, f-strings, comprehension-free fallbacks: walk
        # children so nested calls/compares are still checked
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.eval(child, env)
        return UNKNOWN

    # --- operators --------------------------------------------------------

    def _binop(self, node: ast.BinOp, env: Dict[str, object]) -> object:
        if isinstance(node.op, (ast.Mult, ast.Div, ast.FloorDiv)):
            return self._product(node, env)
        a = self.eval(node.left, env)
        b = self.eval(node.right, env)
        if isinstance(node.op, (ast.Add, ast.Sub, ast.Mod)):
            # bare numeric literals (epsilons, paddings) are neutral:
            # `t_ms + 1e-9` is tolerance arithmetic, not a unit claim
            if _const_value(node.left) is not None:
                a = NEUTRAL
            if _const_value(node.right) is not None:
                b = NEUTRAL
            return self._unify(a, b, node, "arithmetic")
        return UNKNOWN  # Pow, shifts, bitwise: out of scope

    def _product(self, node: ast.expr, env: Dict[str, object]) -> object:
        """Flatten a Mult/Div chain: dims add, scales multiply, numeric
        conversion constants fold into the scale."""
        factors: List[Tuple[ast.expr, int]] = []

        def collect(n: ast.expr, sign: int) -> None:
            if isinstance(n, ast.BinOp) and isinstance(
                n.op, (ast.Mult, ast.Div, ast.FloorDiv)
            ):
                collect(n.left, sign)
                collect(n.right, -sign if isinstance(n.op, (ast.Div, ast.FloorDiv)) else sign)
            else:
                factors.append((n, sign))

        collect(node, 1)
        dims = [0, 0, 0]
        scale = 1.0
        known = True
        zero = False
        conv_consts: List[ast.expr] = []
        dimmed = False
        for f, sign in factors:
            c = _const_value(f)
            if c is not None:
                if c == 0:
                    zero = True
                    continue
                if _is_conversion_const(c):
                    if _is_conversion_const(c, INLINE_CONVERSION_CONSTANTS):
                        conv_consts.append(f)
                    scale = scale / (c ** sign)
                # pure-number factor otherwise: dims and scale untouched
                continue
            u = self.eval(f, env)
            if u is NEUTRAL:
                zero = True
                continue
            if u is UNKNOWN or isinstance(u, UnitAlt):
                # path-dependent factors poison the product: alternative
                # scales cannot be folded into one running scale
                known = False
                continue
            if u.dims != (0, 0, 0):
                dimmed = dimmed or u.dims[1] != 0 or u.dims == _RATE
            dims = [d + sign * x for d, x in zip(dims, u.dims)]
            scale *= u.scale ** sign
        # inline-conversion rule: conversion constants applied to a
        # data/bandwidth-dimensioned operand in repro.core outside the
        # sanctioned repro/units.py helpers
        if (
            conv_consts
            and dimmed
            and self.mod.is_core
            and not self.mod.is_units_module
        ):
            self.emit(
                "units/inline-conversion",
                conv_consts[0],
                "inline unit-conversion arithmetic; use a repro.units helper",
            )
        if zero:
            return NEUTRAL
        if not known:
            return UNKNOWN
        if tuple(dims) == (0, 0, 0):
            return DIMLESS  # pure ratio/number: scale bookkeeping ends here
        return Unit((dims[0], dims[1], dims[2]), scale)

    def _combine_mult(self, a: object, b: object, div: bool) -> object:
        if a is UNKNOWN or b is UNKNOWN:
            return UNKNOWN
        if a is NEUTRAL or b is NEUTRAL:
            return NEUTRAL
        if isinstance(a, UnitAlt) or isinstance(b, UnitAlt):
            return UNKNOWN  # alternative scales cannot multiply through
        sign = -1 if div else 1
        dims = tuple(x + sign * y for x, y in zip(a.dims, b.dims))
        scale = a.scale * (b.scale ** sign)
        if dims == (0, 0, 0):
            return DIMLESS
        return Unit(dims, scale)  # type: ignore[arg-type]

    def _compare(self, node: ast.Compare, env: Dict[str, object]) -> object:
        operands = [node.left] + list(node.comparators)
        units = [self.eval(o, env) for o in operands]
        for i, op in enumerate(node.ops):
            if isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn)):
                continue
            left, right = operands[i], operands[i + 1]
            # bare numeric literals compare against anything (sentinels,
            # thresholds written as plain numbers)
            if _const_value(left) is not None or _const_value(right) is not None:
                continue
            self._unify(units[i], units[i + 1], node, "comparison")
        return UNKNOWN

    def _call(self, node: ast.Call, env: Dict[str, object]) -> object:
        kw_units = {
            kw.arg: self.eval(kw.value, env)
            for kw in node.keywords
            if kw.value is not None
        }
        arg_units = [self.eval(a, env) for a in node.args]

        fname: Optional[str] = None
        if isinstance(node.func, ast.Name):
            fname = node.func.id
        elif isinstance(node.func, ast.Attribute):
            fname = node.func.attr
            self.eval(node.func.value, env)

        if fname in ("abs", "float", "round"):
            return arg_units[0] if arg_units else UNKNOWN
        if fname in ("min", "max"):
            out: object = NEUTRAL
            for a, u in zip(node.args, arg_units):
                if _const_value(a) is not None:
                    continue  # max(0.0, x_ms) clamps; the literal is neutral
                out = self._unify(out, u, node, f"{fname}() arguments")
            return out
        if fname == "len":
            return DIMLESS
        if fname in ("sum",):
            return UNKNOWN

        if fname is not None:
            self._bind_call_args(node, fname, arg_units, kw_units)
            if not fname.lower().startswith("from_"):
                # ``from_samples(...)`` names its *input*, not its result
                u = unit_of_name(fname)
                if u is not UNKNOWN and u is not DIMLESS:
                    return u
        return UNKNOWN

    def _bind_call_args(
        self,
        node: ast.Call,
        fname: str,
        arg_units: List[object],
        kw_units: Dict[Optional[str], object],
    ) -> None:
        params = self.registry.get(fname)
        if not params:
            return
        for i, (a, u) in enumerate(zip(node.args, arg_units)):
            if i >= len(params):
                break
            if _const_value(a) is not None:
                continue  # literal arguments configure values; no unit claim
            declared = unit_of_name(params[i])
            if declared is not UNKNOWN and declared is not DIMLESS:
                self._require(
                    declared, u, a, f"argument {params[i]!r} of {fname}()"
                )
        for kw in node.keywords:
            if kw.arg is None or kw.arg not in params:
                continue
            if _const_value(kw.value) is not None:
                continue
            declared = unit_of_name(kw.arg)
            if declared is not UNKNOWN and declared is not DIMLESS:
                self._require(
                    declared, kw_units.get(kw.arg), kw.value,
                    f"argument {kw.arg!r} of {fname}()",
                )

    # --- unification ------------------------------------------------------

    def _unify(self, a: object, b: object, node: ast.AST, where: str) -> object:
        if a is UNKNOWN or b is UNKNOWN:
            return UNKNOWN
        if a is NEUTRAL:
            return b
        if b is NEUTRAL:
            return a
        amem, bmem = _members(a), _members(b)
        if isinstance(a, UnitAlt) and isinstance(b, UnitAlt):
            # two path-dependent values may be correlated (both set by
            # the same branch): only a conflict on EVERY pairing is a
            # definite bug
            kinds = {self._conflict(x, y) for x in amem for y in bmem}
            if None not in kinds:
                self.emit(
                    "units/mixed-units" if "mixed" in kinds
                    else "units/scale-mismatch",
                    node,
                    f"{where} mixes {describe(a)} and {describe(b)} "
                    "on every path",
                )
            return UNKNOWN
        if isinstance(a, UnitAlt) or isinstance(b, UnitAlt):
            alt, single = (a, b) if isinstance(a, UnitAlt) else (b, a)
            assert isinstance(single, Unit)
            kinds = {
                self._conflict(m, single) for m in alt.members
            } - {None}
            if kinds:
                self.emit(
                    "units/mixed-units" if "mixed" in kinds
                    else "units/scale-mismatch",
                    node,
                    f"{where} mixes {describe(alt)} (path-dependent) "
                    f"and {describe(single)}",
                )
                return UNKNOWN
            return single
        assert isinstance(a, Unit) and isinstance(b, Unit)
        if a is DIMLESS and b is DIMLESS:
            return DIMLESS
        if a.dims != b.dims:
            self.emit(
                "units/mixed-units",
                node,
                f"{where} mixes {describe(a)} and {describe(b)}",
            )
            return UNKNOWN
        if not math.isclose(a.scale, b.scale, rel_tol=1e-9):
            self.emit(
                "units/scale-mismatch",
                node,
                f"{where} mixes {describe(a)} and {describe(b)}",
            )
            return UNKNOWN
        return a

    @staticmethod
    def _conflict(u: Unit, v: Unit) -> Optional[str]:
        if u.dims != v.dims:
            return "mixed"
        if not math.isclose(u.scale, v.scale, rel_tol=1e-9):
            return "scale"
        return None

    def _require(self, declared: Unit, got: object, node: ast.AST, where: str) -> None:
        if got is UNKNOWN or got is NEUTRAL or got is DIMLESS:
            return  # unknowns and bare numbers make no unit claim
        for member in _members(got):
            if member.dims == (0, 0, 0):
                continue
            kind = self._conflict(member, declared)
            if kind is None:
                continue
            suffix = " on some path" if isinstance(got, UnitAlt) else ""
            self.emit(
                "units/mixed-units" if kind == "mixed" else "units/scale-mismatch",
                node,
                f"{where} expects {describe(declared)}, got "
                f"{describe(member)}{suffix}",
            )
            return


def run(modules: Sequence[Module], registry: SignatureRegistry) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        if mod.is_units_module:
            continue  # the sanctioned conversion site
        findings.extend(FileChecker(mod, registry).check())
    return findings
