"""Minitron-4B — width-pruned Nemotron-4 [arXiv:2407.14679].

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000.
Nemotron family => squared-ReLU FFN, RoPE.
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    ffn_activation="relu2",
    source="arXiv:2407.14679 (Compact LMs via pruning+distillation)",
)

SMOKE_CONFIG = ModelConfig(
    name="minitron-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    ffn_activation="relu2",
    remat="none",
    source="reduced minitron-4b",
)
