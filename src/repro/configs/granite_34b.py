"""Granite-34B-Code — llama-arch code model, extreme MQA [arXiv:2405.04324].

88L, d_model=6144, 48 heads with kv=1 (MQA), d_ff=24576, vocab=49152.
kv=1 cannot shard across the 16-way model axis: KV projections replicate
(handled by the divisibility-aware sharding rules).
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    ffn_activation="swiglu",
    source="arXiv:2405.04324 (Granite Code Models)",
)

SMOKE_CONFIG = ModelConfig(
    name="granite-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    ffn_activation="swiglu",
    remat="none",
    source="reduced granite-34b",
)
