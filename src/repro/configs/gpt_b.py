"""GPT-B — the paper's §3 larger testbed model: context 6K, hidden 8K,
~1.2B params/layer (4·H² + 2·H·d_ff = 268M + 940M with d_ff=57344).
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="gpt-b",
    family="dense",
    num_layers=16,
    d_model=8192,
    num_heads=64,
    num_kv_heads=64,
    head_dim=128,
    d_ff=57344,
    vocab_size=50304,
    max_seq_len=6144,
    ffn_activation="gelu",
    source="paper §3 baseline model (GPT-B)",
)

SMOKE_CONFIG = ModelConfig(
    name="gpt-b-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=512,
    ffn_activation="gelu",
    remat="none",
    source="reduced gpt-b",
)
