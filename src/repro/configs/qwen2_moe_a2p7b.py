"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model=2048, 16H (GQA kv=16), vocab=151936,
MoE: 4 shared + 60 routed experts, top-4, expert d_ff=1408.
60 experts do not divide the 16-way model axis — the EP sharding rule
falls back to replication for the expert dim and shards the FFN feature
dim instead (divisibility-aware constrain).
"""
from repro.models.modules import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        num_shared_experts=4,
        expert_d_ff=1408,
        capacity_factor=1.25,
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B model card",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1, expert_d_ff=128),
    remat="none",
    source="reduced qwen2-moe-a2.7b",
)
