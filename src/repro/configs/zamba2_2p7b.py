"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54L, d_model=2560, Mamba2 ssm_state=64; a single *shared* transformer block
(32H GQA kv=32, d_ff=10240) applied every 6 layers (9 invocations).  The
real model adds per-invocation LoRA deltas on the shared block; we share
weights exactly (noted deviation, DESIGN.md §4).
"""
from repro.models.modules import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
    attn_period=6,
    shared_attn_block=True,
    source="arXiv:2411.15242 (Zamba2 suite)",
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk=32),
    attn_period=2,
    shared_attn_block=True,
    remat="none",
    source="reduced zamba2-2.7b",
)
