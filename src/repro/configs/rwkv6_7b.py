"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892].

32L, d_model=4096, d_ff=14336, vocab=65536.  Linear recurrence => O(1)
decode state; long_500k runs natively (DESIGN.md §4).
"""
from repro.models.modules import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,  # wkv heads = d_model / head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, chunk=128),
    causal=True,
    source="arXiv:2404.05892 (RWKV-5/6: Eagle & Finch)",
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    d_ff=384,
    vocab_size=512,
    rwkv=RWKVConfig(head_dim=64, chunk=32),
    causal=True,
    remat="none",
    source="reduced rwkv6-7b",
)
