"""GPT-A — the paper's §3 testbed model: "similar to GPT-3", context 4K,
hidden 4K, ~412M params/layer.  Layer size ≈ 4·H² (attn) + 2·H·d_ff with
d_ff chosen to land near the paper's 412M figure.
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="gpt-a",
    family="dense",
    num_layers=24,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=16384,  # 4·H² + 2·H·d_ff ≈ 67M + 134M... paper counts fp16 bytes; see note
    vocab_size=50304,
    ffn_activation="gelu",
    source="paper §3 baseline model (GPT-A)",
)

SMOKE_CONFIG = ModelConfig(
    name="gpt-a-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=512,
    ffn_activation="gelu",
    remat="none",
    source="reduced gpt-a",
)
