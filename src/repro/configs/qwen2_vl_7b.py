"""Qwen2-VL 7B — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L, d_model=3584, 28H (GQA kv=4), d_ff=18944, vocab=152064.
M-RoPE sections (16, 24, 24) over the 64-dim rotary half.

Vision frontend (ViT + projector) is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed sequence embeddings (text tokens and
image patches interleaved, already projected to d_model) plus the 3-row
(temporal/height/width) M-RoPE position ids.  Decode consumes text tokens.
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    ffn_activation="swiglu",
    source="arXiv:2409.12191 (Qwen2-VL)",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    mrope_sections=(8, 12, 12),
    ffn_activation="swiglu",
    remat="none",
    source="reduced qwen2-vl-7b",
)
