"""Architecture registry: one module per assigned architecture (+ the
paper's own GPT-A / GPT-B testbed models).

Every config cites its source in ``ModelConfig.source``.  ``get_config``
returns the full-size config; ``get_smoke_config`` returns the reduced
same-family variant used by CPU smoke tests (≤2 layers, d_model ≤ 512,
≤4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.modules import ModelConfig

ARCHS: List[str] = [
    "rwkv6_7b",
    "minitron_4b",
    "zamba2_2p7b",
    "granite_34b",
    "hubert_xlarge",
    "deepseek_v2_lite_16b",
    "nemotron_4_15b",
    "deepseek_coder_33b",
    "qwen2_vl_7b",
    "qwen2_moe_a2p7b",
    "gpt_a",
    "gpt_b",
]

# CLI ids (``--arch <id>``) use dashes, matching the assignment sheet
CLI_IDS = {a.replace("_", "-").replace("-2p7b", "-2.7b").replace("-a2p7b", "-a2.7b"): a for a in ARCHS}


def canon(arch: str) -> str:
    arch = arch.strip()
    if arch in ARCHS:
        return arch
    if arch in CLI_IDS:
        return CLI_IDS[arch]
    alt = arch.replace("-", "_").replace(".", "p")
    if alt in ARCHS:
        return alt
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(CLI_IDS)}")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.SMOKE_CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
