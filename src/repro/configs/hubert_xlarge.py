"""HuBERT-XLarge — encoder-only speech model [arXiv:2106.07447].

48L, d_model=1280, 16H, d_ff=5120, vocab=504 (k-means cluster targets).
The conv/mel frontend is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings (B, T, 1280).
Encoder-only => no autoregressive decode; decode_32k / long_500k are
skipped (DESIGN.md §4).
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,  # bidirectional encoder
    ffn_activation="gelu",
    tie_embeddings=False,  # inputs are frames, head is a classifier
    source="arXiv:2106.07447 (HuBERT)",
)

SMOKE_CONFIG = ModelConfig(
    name="hubert-smoke",
    family="audio",
    num_layers=2,
    d_model=192,
    num_heads=4,
    num_kv_heads=4,
    head_dim=48,
    d_ff=384,
    vocab_size=64,
    causal=False,
    ffn_activation="gelu",
    tie_embeddings=False,
    remat="none",
    source="reduced hubert-xlarge",
)
