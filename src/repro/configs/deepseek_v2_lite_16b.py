"""DeepSeek-V2-Lite 16B — MLA + fine-grained MoE [arXiv:2405.04434].

27L, d_model=2048, 16H (MLA kv_lora=512), vocab=102400,
MoE: 2 shared + 64 routed experts, top-6, expert d_ff=1408.

Note: the assignment line reads "MoE 64e top-6" while its bracket note
says "2 shared+160 routed"; we follow the primary spec (64 routed), which
also matches the DeepSeek-V2-Lite model card. The real model keeps layer 0
dense; we make all layers MoE to keep the stack scan-homogeneous (noted
deviation).
"""
from repro.models.modules import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,  # routed expert width
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        expert_d_ff=1408,
        capacity_factor=1.25,
    ),
    source="arXiv:2405.04434 (DeepSeek-V2)",
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mla=MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1, expert_d_ff=128),
    remat="none",
    source="reduced deepseek-v2-lite-16b",
)
