"""Nemotron-4 15B [arXiv:2402.16819].

32L, d_model=6144, 48H (GQA kv=8), d_ff=24576, vocab=256000,
squared-ReLU FFN (no GLU), RoPE.
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    ffn_activation="relu2",
    source="arXiv:2402.16819 (Nemotron-4 15B)",
)

SMOKE_CONFIG = ModelConfig(
    name="nemotron-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    ffn_activation="relu2",
    remat="none",
    source="reduced nemotron-4-15b",
)
