"""DeepSeek-Coder 33B — llama-arch code model [arXiv:2401.14196].

62L, d_model=7168, 56H (GQA kv=8), d_ff=19200, vocab=32256.
"""
from repro.models.modules import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    ffn_activation="swiglu",
    source="arXiv:2401.14196 (DeepSeek-Coder)",
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-coder-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    ffn_activation="swiglu",
    remat="none",
    source="reduced deepseek-coder-33b",
)
