"""Optimizer substrate: AdamW + warmup-cosine schedule + global-norm clip +
gradient accumulation.  No optax in this environment — states are plain
pytrees, shard like their parameters, and work under jit/pjit unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


def init_opt_state(params: Params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.peak_lr * (
        cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _decay_mask(path: Tuple, leaf) -> bool:
    """Weight decay on matrices only (no norms/bias/scalars)."""
    return leaf.ndim >= 2


def adamw_update(
    cfg: OptimizerConfig, grads: Params, params: Params, state: OptState
) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        g32 = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        upd = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        if _decay_mask(path, p):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)

    unflatten = lambda leaves: jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), leaves
    )
    return (
        unflatten(new_p),
        OptState(step, unflatten(new_mu), unflatten(new_nu)),
        {"grad_norm": gnorm, "lr": lr},
    )


def make_train_step(
    loss_fn: Callable[[Params, Dict], Any],
    opt_cfg: OptimizerConfig,
    *,
    loss_has_metrics: bool = True,
    accum_steps: int = 1,
):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1 splits the batch on dim0 and accumulates gradients in
    f32 (the paper's minibatch = microbatches × this, orthogonal to the
    pipeline's own microbatching).
    """

    def scalar_loss(params, batch):
        out = loss_fn(params, batch)
        if loss_has_metrics:
            loss, metrics = out
        else:
            loss, metrics = out, {}
        return loss, metrics

    grad_fn = jax.value_and_grad(scalar_loss, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            split = lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])
            batches = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / accum_steps, g_acc, g
                )
                return (g_acc, l_acc + l / accum_steps), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, 0.0), batches)
            metrics = {}
        params, opt_state, om = adamw_update(opt_cfg, grads, params, opt_state)
        metrics = {**metrics, **om, "loss": loss}
        return params, opt_state, metrics

    return train_step
