"""Serving driver: batched prefill/decode with the Splitwise-style split
(paper §5) and BubbleTea admission statistics.

  PYTHONPATH=src python -m repro.launch.serve --arch gpt-a --requests 16 \
      --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.bubbletea import InferenceModelSpec, PrefillLatencyModel
from repro.models.transformer import build_model
from repro.serving.engine import Request, ServingEngine, SplitwiseCluster


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-a")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--splitwise", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    reqs = [
        Request(
            i,
            rng.integers(0, cfg.vocab_size, size=rng.integers(4, args.prompt_len)).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]

    if args.splitwise:
        cluster = SplitwiseCluster(cfg, params, args.batch, args.max_len)
        serve = cluster.serve
    else:
        engine = ServingEngine(cfg, params, args.batch, args.max_len)
        serve = engine.generate

    done = []
    t0 = time.time()
    for i in range(0, len(reqs), args.batch):
        done += serve(reqs[i : i + args.batch])
    wall = time.time() - t0

    ttfts = [r.ttft_ms for r in done]
    tbts = [t for r in done for t in r.tbt_ms]
    print(f"[serve] arch={cfg.name} requests={len(done)} wall={wall:.2f}s")
    print(f"  TTFT ms: p50={np.percentile(ttfts,50):.1f} p99={np.percentile(ttfts,99):.1f}")
    if tbts:
        print(f"  TBT  ms: p50={np.percentile(tbts,50):.1f} p99={np.percentile(tbts,99):.1f}")
    if args.splitwise:
        print(f"  KV bytes moved: {cluster.kv_bytes_moved/1e6:.2f} MB")
    # reference: analytic TTFT model (paper Fig 14) for A100-class serving
    lm = PrefillLatencyModel(InferenceModelSpec("llama3-8b", 8e9))
    print(f"  [model] A100 TTFT(512, PP=1)={lm.ttft_ms(512,1):.0f}ms "
          f"(8192, PP=8)={lm.ttft_ms(8192,8):.0f}ms")


if __name__ == "__main__":
    main()
