"""End-to-end training driver.

On real hardware this runs the production mesh; on CPU it runs the same
code path on the host mesh with a reduced (smoke) config — the driver
logic (data pipeline -> sharded train step -> metrics -> async
checkpoints) is identical.

  PYTHONPATH=src python -m repro.launch.train --arch gpt-a --steps 200 \
      --batch 8 --seq 128 --smoke --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch zamba2-2.7b --smoke \
      --pipeline --steps 20        # cross-pod pipeline path (needs >=8 devs)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import canon, get_config, get_smoke_config
from repro.ckpt.checkpoint import AsyncCheckpointer
from repro.data.pipeline import DataConfig, make_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.transformer import build_model
from repro.optim.optimizer import OptimizerConfig, init_opt_state, make_train_step
from repro.parallel.pipeline import make_pipeline_loss
from repro.parallel.sharding import make_batch_shardings, make_param_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--pipeline", action="store_true", help="PP over pod axis")
    ap.add_argument("--n-micro", type=int, default=4)
    ap.add_argument("--boundary", default="striped", choices=["striped", "direct"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.pipeline)
    else:
        mesh = make_host_mesh(multi_pod=args.pipeline)
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} params={cfg.param_count()/1e6:.1f}M")

    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                              total_steps=args.steps)
    with compat.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        p_sh = make_param_shardings(jax.eval_shape(lambda: params), mesh)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_sh)
        opt_state = init_opt_state(params)
        if args.pipeline:
            loss_fn = make_pipeline_loss(cfg, mesh, n_micro=args.n_micro,
                                         boundary=args.boundary)
            step_fn = jax.jit(make_train_step(loss_fn, opt_cfg, loss_has_metrics=False),
                              donate_argnums=(0, 1))
        else:
            step_fn = jax.jit(make_train_step(model.loss, opt_cfg), donate_argnums=(0, 1))

        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        data = make_batches(cfg, DataConfig(seed=args.seed, batch_size=args.batch,
                                            seq_len=args.seq), num_steps=args.steps)
        t0 = time.time()
        tokens_done = 0
        for step, batch in enumerate(data):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            b_sh = make_batch_shardings(jax.eval_shape(lambda: batch), mesh)
            batch = jax.tree.map(lambda x, s: jax.device_put(x, s), batch, b_sh)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            tokens_done += args.batch * args.seq
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"tok/s {tokens_done/max(dt,1e-9):,.0f}", flush=True)
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          {"step": step, "loss": float(metrics["loss"])})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state},
                      {"step": args.steps})
            ckpt.close()
            print(f"[train] checkpoint at {ckpt.latest_path()}")
    return params


if __name__ == "__main__":
    main()
