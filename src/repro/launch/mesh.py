"""Production mesh builders.

The paper's placement (§4.2) maps onto the axes as:
  pod   -> DC            (pipeline stages cross it; thin DCN = WAN)
  data  -> DP inside a DC (all-reduce rings never leave a pod)
  model -> TP/EP on fast interconnect

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, multi_pod: bool = False):
    """Small test mesh matching whatever devices exist (CPU runs)."""
    n = len(jax.devices())
    if multi_pod:
        assert n >= 8 and n % 2 == 0
        per = n // 2
        dp = 2
        tp = per // dp
        return jax.make_mesh((2, dp, tp), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    dp = 2 if n % 2 == 0 else 1
    return jax.make_mesh((dp, n // dp), ("data", "model"))
