"""Assigned input shapes and the ShapeDtypeStruct builders for the dry-run.

SHAPES (assignment sheet):
  train_4k     seq=4,096    global_batch=256   -> train_step
  prefill_32k  seq=32,768   global_batch=32    -> prefill_step
  decode_32k   seq=32,768   global_batch=128   -> serve_step (1 new token)
  long_500k    seq=524,288  global_batch=1     -> serve_step, sub-quadratic

Policies (DESIGN.md §4):
  * hubert (encoder-only): decode_32k / long_500k skipped; prefill_32k
    lowers the encode forward.
  * long_500k: native for rwkv6 (O(1) state), zamba2 (Mamba2 + shared-attn
    KV) and deepseek-v2-lite (MLA latent cache is 27·(512+64)·S ≈ 16 GB
    total at 500k — the MLA selling point); dense/vlm archs get a
    sliding-window variant (window=8192).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import canon, get_config
from repro.models.modules import ModelConfig

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": {"seq_len": 4_096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32_768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32_768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524_288, "global_batch": 1, "kind": "decode"},
}

LONG_WINDOW = 8_192  # sliding window for dense archs at 500k (beyond-paper)


def shape_supported(arch: str, shape: str) -> Tuple[bool, str]:
    cfg = get_config(arch)
    if cfg.family == "audio" and shape in ("decode_32k", "long_500k"):
        return False, "encoder-only: no autoregressive decode (DESIGN.md §4)"
    return True, ""


def config_for(arch: str, shape: str) -> ModelConfig:
    """Arch config with the per-shape policy applied."""
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.family in ("dense", "vlm", "moe"):
        if cfg.mla is None:  # MLA's latent cache handles 500k natively
            cfg = dataclasses.replace(cfg, window=LONG_WINDOW)
    return cfg


def _sharded(sds: jax.ShapeDtypeStruct, mesh: Mesh, spec: P) -> jax.ShapeDtypeStruct:
    from repro.parallel.sharding import _fit_spec

    fitted = _fit_spec(sds.shape, spec, mesh)
    return jax.ShapeDtypeStruct(
        sds.shape, sds.dtype, sharding=NamedSharding(mesh, fitted if fitted else P())
    )


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def seq_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "model") if multi_pod else ("model",)


def batch_specs(
    cfg: ModelConfig, shape: str, mesh: Mesh, *, multi_pod: bool, pipeline: bool = False
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs (with shardings) for the input batch."""
    s = SHAPES[shape]
    B, T = s["global_batch"], s["seq_len"]
    kind = s["kind"]
    # under pipeline-over-pod the batch dim is sharded by data only (each
    # pod sees the full batch at its stage); otherwise pods split the batch
    ba = ("data",) if (pipeline or not multi_pod) else ("pod", "data")
    bspec = P(ba if len(ba) > 1 else ba[0])

    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if kind == "decode":
        out["tokens"] = _sharded(
            jax.ShapeDtypeStruct((B,), jnp.int32), mesh, bspec
        )
        out["pos"] = _sharded(jax.ShapeDtypeStruct((B,), jnp.int32), mesh, bspec)
        return out

    if cfg.family == "audio":
        out["embeds"] = _sharded(
            jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16),
            mesh,
            P(bspec[0], None, None),
        )
        out["labels"] = _sharded(
            jax.ShapeDtypeStruct((B, T), jnp.int32), mesh, P(bspec[0], None)
        )
        out["mask"] = _sharded(
            jax.ShapeDtypeStruct((B, T), jnp.float32), mesh, P(bspec[0], None)
        )
    elif cfg.family == "vlm" and kind == "train":
        out["embeds"] = _sharded(
            jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16),
            mesh,
            P(bspec[0], None, None),
        )
        out["positions"] = _sharded(
            jax.ShapeDtypeStruct((3, B, T), jnp.int32), mesh, P(None, bspec[0], None)
        )
        out["labels"] = _sharded(
            jax.ShapeDtypeStruct((B, T), jnp.int32), mesh, P(bspec[0], None)
        )
        out["mask"] = _sharded(
            jax.ShapeDtypeStruct((B, T), jnp.float32), mesh, P(bspec[0], None)
        )
    else:
        out["tokens"] = _sharded(
            jax.ShapeDtypeStruct((B, T), jnp.int32), mesh, P(bspec[0], None)
        )
    return out


def cache_specs(
    cfg: ModelConfig, shape: str, mesh: Mesh, model, *, multi_pod: bool
) -> Any:
    """Sharded ShapeDtypeStructs for the KV/state cache.

    Batch dim (the first dim after the leading layer/group dims that
    equals global_batch) shards over the batch axes; when B == 1
    (long_500k) the sequence dim shards over (pod×)model instead.
    """
    s = SHAPES[shape]
    B, S = s["global_batch"], s["seq_len"]
    cache = model.cache_shape(B, S)
    ba = batch_axes(multi_pod)
    sa = seq_axes(multi_pod)
    ba_size = 1
    for a in ba:
        ba_size *= mesh.shape[a]

    def spec_for(sds: jax.ShapeDtypeStruct) -> P:
        dims: list = [None] * len(sds.shape)
        placed_batch = None
        for i in range(1, len(sds.shape)):
            if sds.shape[i] == B and B % ba_size == 0 and B > 1:
                dims[i] = ba if len(ba) > 1 else ba[0]
                placed_batch = i
                break
        # shard the largest remaining dim (seq for KV caches, heads for
        # SSM states) over the model axis — and over pod too when the
        # batch could not take it (long_500k's B == 1)
        rem = sa if placed_batch is None else ("model",)
        cand = [
            i
            for i in range(1, len(sds.shape))
            if i != placed_batch and sds.shape[i] > 1
        ]
        if cand:
            longest = max(cand, key=lambda i: sds.shape[i])
            dims[longest] = rem if len(rem) > 1 else rem[0]
        return P(*dims)

    def one(sds):
        return _sharded(sds, mesh, spec_for(sds))

    return jax.tree.map(one, cache)
